"""Kernel vs ref allclose — the CORE correctness signal for L1.

Hypothesis sweeps shapes/block sizes; every Pallas kernel is checked
against its pure-jnp oracle in kernels/ref.py, and the custom-vjp wrappers
are checked against jax.grad of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import attention as attn_mod
from compile.kernels import fused_update, matmul as mm_mod, pushsum_mix, ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ===========================================================================
# Blocked matmul
# ===========================================================================
class TestMatmul:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_random_shapes(self, m, k, n, seed):
        x, y = rand((m, k), seed), rand((k, n), seed + 1)
        got = mm_mod.matmul(x, y)
        np.testing.assert_allclose(got, ref.matmul(x, y), rtol=2e-5,
                                   atol=2e-5)

    @settings(**SETTINGS)
    @given(
        bm=st.sampled_from([8, 16, 32, 64, 128]),
        bk=st.sampled_from([8, 16, 32, 64, 128]),
        bn=st.sampled_from([8, 16, 32, 64, 128]),
    )
    def test_block_size_invariance(self, bm, bk, bn):
        x, y = rand((64, 48), 7), rand((48, 80), 8)
        got = mm_mod.matmul(x, y, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, ref.matmul(x, y), rtol=2e-5,
                                   atol=2e-5)

    def test_mxu_aligned_tile(self):
        x, y = rand((256, 256), 1), rand((256, 256), 2)
        got = mm_mod.matmul(x, y)
        np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4,
                                   atol=1e-4)

    def test_identity(self):
        x = rand((32, 32), 3)
        np.testing.assert_allclose(
            mm_mod.matmul(x, jnp.eye(32)), x, rtol=1e-6, atol=1e-6
        )

    def test_zero_operand(self):
        x = rand((16, 24), 4)
        got = mm_mod.matmul(x, jnp.zeros((24, 8)))
        assert float(jnp.abs(got).max()) == 0.0

    def test_vmem_budget_default_blocks(self):
        # Default 128-tiles must fit well inside a 16 MiB VMEM core budget.
        assert mm_mod.vmem_bytes(128, 128, 128) < 16 * 2**20 // 4

    def test_mxu_utilization_full_on_aligned(self):
        assert mm_mod.mxu_utilization(128, 128, 128) == 1.0
        assert mm_mod.mxu_utilization(64, 128, 128) == 0.5

    def test_pick_block_divides(self):
        for dim in [1, 7, 96, 100, 128, 1000]:
            b = mm_mod._pick_block(dim, 128)
            assert dim % b == 0 and 1 <= b <= 128


class TestPmatmulGrad:
    def test_grad_matches_ref(self):
        x, y = rand((24, 16), 11), rand((16, 20), 12)

        f_ker = lambda x, y: (kernels.pmatmul(x, y) ** 2).sum()  # noqa: E731
        f_ref = lambda x, y: (ref.matmul(x, y) ** 2).sum()  # noqa: E731
        gx_k, gy_k = jax.grad(f_ker, argnums=(0, 1))(x, y)
        gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gy_k, gy_r, rtol=1e-4, atol=1e-4)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000))
    def test_grad_random(self, seed):
        x, y = rand((8, 12), seed), rand((12, 6), seed + 1)
        g = jax.grad(lambda a: kernels.pmatmul(a, y).sum())(x)
        np.testing.assert_allclose(
            g, jnp.tile(y.sum(1), (8, 1)), rtol=1e-5, atol=1e-5
        )


# ===========================================================================
# Blocked causal attention
# ===========================================================================
class TestAttention:
    @settings(**SETTINGS)
    @given(
        bh=st.integers(1, 6),
        t=st.sampled_from([8, 16, 24, 32, 64]),
        dh=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, bh, t, dh, seed):
        q, k, v = (rand((bh, t, dh), seed + i) for i in range(3))
        got = attn_mod.attention(q, k, v, causal=True)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @settings(**SETTINGS)
    @given(bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]))
    def test_block_size_invariance(self, bq, bk):
        q, k, v = (rand((2, 32, 16), 40 + i) for i in range(3))
        got = attn_mod.attention(q, k, v, bq=bq, bk=bk, causal=True)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        q, k, v = (rand((2, 16, 8), 50 + i) for i in range(3))
        got = attn_mod.attention(q, k, v, causal=False)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_causality(self):
        """Output at position t must not depend on keys at positions > t."""
        q, k, v = (rand((1, 16, 8), 60 + i) for i in range(3))
        base = attn_mod.attention(q, k, v, causal=True)
        k2 = k.at[:, 10:].set(999.0)
        v2 = v.at[:, 10:].set(-999.0)
        pert = attn_mod.attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(base[:, :10], pert[:, :10], rtol=1e-5,
                                   atol=1e-5)

    def test_softmax_rows_bounded(self):
        """Attention output is a convex combination of V rows."""
        q, k = rand((1, 16, 8), 70), rand((1, 16, 8), 71)
        v = jnp.ones((1, 16, 8))
        got = attn_mod.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, jnp.ones_like(got), rtol=1e-4,
                                   atol=1e-4)

    def test_numerical_stability_large_logits(self):
        q, k, v = (rand((1, 16, 8), 80 + i, scale=30.0) for i in range(3))
        got = attn_mod.attention(q, k, v, causal=True)
        assert bool(jnp.isfinite(got).all())

    def test_grad_matches_ref(self):
        q, k, v = (rand((2, 16, 8), 90 + i) for i in range(3))

        f_ker = lambda q, k, v: (kernels.pattention(q, k, v) ** 2).sum()  # noqa: E731
        f_ref = lambda q, k, v: (  # noqa: E731
            ref.attention(q, k, v, causal=True) ** 2
        ).sum()
        gk = jax.grad(f_ker, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


# ===========================================================================
# Fused optimizer updates
# ===========================================================================
class TestFusedUpdate:
    @settings(**SETTINGS)
    @given(
        p=st.integers(1, 5000),
        seed=st.integers(0, 2**16),
        lr=st.floats(1e-4, 1.0),
        mom=st.floats(0.0, 0.99),
    )
    def test_sgdm_matches_ref(self, p, seed, lr, mom):
        x, u, g = (rand((p,), seed + i) for i in range(3))
        lr_a = jnp.array([lr], jnp.float32)
        got = fused_update.sgdm_update(x, u, g, lr_a, momentum=mom)
        want = ref.sgdm_update(x, u, g, lr_a, momentum=mom)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(p=st.integers(1, 5000), seed=st.integers(0, 2**16),
           t=st.integers(1, 10000))
    def test_adam_matches_ref(self, p, seed, t):
        x, m, v, g = (rand((p,), seed + i) for i in range(4))
        v = jnp.abs(v)
        sc = jnp.array([1e-3, 1 - 0.9**t, 1 - 0.98**t], jnp.float32)
        got = fused_update.adam_update(x, m, v, g, sc)
        want = ref.adam_update(x, m, v, g, sc)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)

    def test_sgdm_zero_grad_zero_momentum_is_identity(self):
        x = rand((100,), 1)
        z = jnp.zeros(100)
        x2, u2 = fused_update.sgdm_update(
            x, z, z, jnp.array([0.1], jnp.float32),
            momentum=0.9, weight_decay=0.0,
        )
        np.testing.assert_allclose(x2, x, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(u2, z, atol=1e-7)

    def test_sgdm_plain_sgd_when_no_momentum(self):
        x, g = rand((64,), 2), rand((64,), 3)
        x2, _ = fused_update.sgdm_update(
            x, jnp.zeros(64), g, jnp.array([0.5], jnp.float32),
            momentum=0.0, weight_decay=0.0,
        )
        np.testing.assert_allclose(x2, x - 0.5 * g, rtol=1e-6, atol=1e-6)

    def test_block_size_invariance(self):
        x, u, g = (rand((1000,), 20 + i) for i in range(3))
        lr = jnp.array([0.01], jnp.float32)
        a = fused_update.sgdm_update(x, u, g, lr, block=100)
        b = fused_update.sgdm_update(x, u, g, lr, block=4096)
        for ai, bi in zip(a, b):
            np.testing.assert_allclose(ai, bi, rtol=1e-6, atol=1e-6)


# ===========================================================================
# Dense push-sum mixing
# ===========================================================================
def column_stochastic(n, seed):
    rng = np.random.default_rng(seed)
    p = rng.random((n, n)).astype(np.float32) + 0.1
    return jnp.asarray(p / p.sum(0, keepdims=True))


class TestPushsumMix:
    @settings(**SETTINGS)
    @given(n=st.integers(2, 24), d=st.integers(1, 64),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, d, seed):
        p = column_stochastic(n, seed)
        x = rand((n, d), seed + 1)
        w = jnp.ones((n,))
        got = pushsum_mix.gossip_round(p, x, w)
        want = ref.gossip_round(p, x, w)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)

    @settings(**SETTINGS)
    @given(n=st.integers(2, 16), seed=st.integers(0, 2**16))
    def test_mass_conservation(self, n, seed):
        """Column-stochastic mixing preserves Σx and Σw exactly."""
        p = column_stochastic(n, seed)
        x = rand((n, 8), seed + 1)
        w = jnp.ones((n,))
        x2, w2, _ = pushsum_mix.gossip_round(p, x, w)
        np.testing.assert_allclose(x2.sum(0), x.sum(0), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(float(w2.sum()), float(w.sum()),
                                   rtol=1e-5)

    def test_debias_recovers_average_dense(self):
        """With P = (1/n)·11ᵀ one round yields the exact average at z."""
        n, d = 8, 16
        p = jnp.full((n, n), 1.0 / n)
        x = rand((n, d), 5)
        w = jnp.ones((n,))
        _, _, z = pushsum_mix.gossip_round(p, x, w)
        avg = x.mean(0)
        for i in range(n):
            np.testing.assert_allclose(z[i], avg, rtol=1e-4, atol=1e-5)

    def test_rounds_converge_to_average(self):
        """Repeated sparse gossip converges z → initial average (PushSum)."""
        n, d, k = 8, 4, 40
        rng = np.random.default_rng(0)
        mats = []
        for t in range(k):
            p = np.zeros((n, n), np.float32)
            for i in range(n):
                j = (i + 2 ** (t % 3)) % n
                p[i, i] = 0.5
                p[j, i] = 0.5
            mats.append(p)
        x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        w = jnp.ones((n,))
        _, _, z = pushsum_mix.gossip_rounds(jnp.asarray(np.stack(mats)), x, w)
        avg = x.mean(0)
        for i in range(n):
            np.testing.assert_allclose(z[i], avg, rtol=1e-3, atol=1e-3)

    def test_weights_stay_positive(self):
        n = 8
        p = column_stochastic(n, 3)
        w = jnp.ones((n,))
        x = rand((n, 4), 4)
        for _ in range(20):
            x, w, _ = pushsum_mix.gossip_round(p, x, w)
        assert float(w.min()) > 0.0

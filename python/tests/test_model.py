"""L2 model tests: shapes, gradient correctness (finite differences),
flat-parameter round-trip, and basic trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=10, deadline=None)


def tiny_lm_cfg():
    return M.TransformerConfig(vocab=32, d_model=16, n_layers=1, n_heads=2,
                               d_ff=32, seq_len=8, batch=2)


class TestTransformer:
    def test_logits_shape(self):
        cfg = tiny_lm_cfg()
        params = M.init_transformer(cfg)
        toks = jnp.zeros((cfg.batch, cfg.seq_len), jnp.int32)
        logits = M.transformer_logits(params, toks, cfg)
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)

    def test_loss_finite_and_near_uniform_at_init(self):
        cfg = tiny_lm_cfg()
        params = M.init_transformer(cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1)),
            jnp.int32,
        )
        loss = M.transformer_loss(params, toks, cfg)
        assert bool(jnp.isfinite(loss))
        # With 0.02-scale init the LM is near-uniform: loss ≈ log(vocab).
        assert abs(float(loss) - np.log(cfg.vocab)) < 0.5

    def test_causality_of_loss(self):
        """Loss at step t only depends on tokens ≤ t."""
        cfg = tiny_lm_cfg()
        params = M.init_transformer(cfg)
        rng = np.random.default_rng(1)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (1, cfg.seq_len)), jnp.int32
        )
        l1 = M.transformer_logits(params, toks, cfg)
        toks2 = toks.at[0, -1].set((int(toks[0, -1]) + 1) % cfg.vocab)
        l2 = M.transformer_logits(params, toks2, cfg)
        np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-4,
                                   atol=1e-4)

    def test_grad_nonzero_every_leaf(self):
        cfg = tiny_lm_cfg()
        params = M.init_transformer(cfg)
        rng = np.random.default_rng(2)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1)),
            jnp.int32,
        )
        g = jax.grad(lambda p: M.transformer_loss(p, toks, cfg))(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.isfinite(x).all()) for x in leaves)
        nonzero = sum(float(jnp.abs(x).sum()) > 0 for x in leaves)
        assert nonzero >= len(leaves) - 1  # pos_emb rows past T can be 0

    def test_few_sgd_steps_reduce_loss(self):
        cfg = tiny_lm_cfg()
        params = M.init_transformer(cfg)
        rng = np.random.default_rng(3)
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (4, cfg.seq_len + 1)), jnp.int32
        )
        loss_fn = jax.jit(lambda p: M.transformer_loss(p, toks, cfg))
        grad_fn = jax.jit(jax.grad(lambda p: M.transformer_loss(p, toks, cfg)))
        l0 = float(loss_fn(params))
        for _ in range(10):
            g = grad_fn(params)
            params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
        assert float(loss_fn(params)) < l0


class TestMlp:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000))
    def test_logits_shape(self, seed):
        cfg = M.MlpConfig(in_dim=8, hidden=(16,), classes=4, batch=6)
        params = M.init_mlp(cfg, seed)
        x = jnp.zeros((6, 8))
        assert M.mlp_logits(params, x).shape == (6, 4)

    def test_grad_matches_finite_difference(self):
        cfg = M.MlpConfig(in_dim=4, hidden=(8,), classes=3, batch=5)
        params = M.init_mlp(cfg, 0)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((5, 4)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 3, 5), jnp.int32)

        from jax.flatten_util import ravel_pytree

        flat, unravel = ravel_pytree(params)
        f = lambda fl: M.mlp_loss(unravel(fl), x, y)  # noqa: E731
        g = jax.grad(f)(flat)
        eps = 1e-3
        rng2 = np.random.default_rng(1)
        for idx in rng2.integers(0, flat.shape[0], 10):
            e = jnp.zeros_like(flat).at[idx].set(eps)
            fd = (float(f(flat + e)) - float(f(flat - e))) / (2 * eps)
            assert abs(fd - float(g[idx])) < 5e-2, (idx, fd, float(g[idx]))

    def test_loss_acc_consistency(self):
        cfg = M.MlpConfig(in_dim=4, hidden=(8,), classes=3, batch=64)
        params = M.init_mlp(cfg, 0)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((64, 4)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 3, 64), jnp.int32)
        loss, acc = M.mlp_loss_acc(params, x, y)
        assert bool(jnp.isfinite(loss)) and 0.0 <= float(acc) <= 1.0


class TestFlatSurface:
    @pytest.mark.parametrize("name", ["mlp_small", "lm_tiny"])
    def test_train_step_shapes(self, name):
        cfg, flat0, _, train_step, eval_step, specs = M.make_flat(name)
        p = flat0.shape[0]
        batch = [
            jnp.zeros(s.shape, s.dtype) for s in specs.values()
        ]
        loss, grads = train_step(flat0, *batch)
        assert loss.shape == () and grads.shape == (p,)
        l2, m2 = eval_step(flat0, *batch)
        assert l2.shape == () and m2.shape == ()

    def test_flat_roundtrip(self):
        cfg, flat0, unravel, _, _, _ = M.make_flat("mlp_small")
        from jax.flatten_util import ravel_pytree

        again, _ = ravel_pytree(unravel(flat0))
        np.testing.assert_array_equal(np.asarray(flat0), np.asarray(again))

    def test_param_counts_positive_and_ordered(self):
        assert M.param_count("lm_tiny") < M.param_count("lm_small")
        assert M.param_count("mlp_small") > 0

    def test_train_grad_matches_pytree_grad(self):
        cfg, flat0, unravel, train_step, _, specs = M.make_flat("mlp_small")
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal(
            tuple(specs["x"].shape)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, cfg.classes,
                                     tuple(specs["y"].shape)), jnp.int32)
        loss, gflat = train_step(flat0, x, y)
        from jax.flatten_util import ravel_pytree

        g_tree = jax.grad(lambda p: M.mlp_loss(p, x, y))(unravel(flat0))
        g2, _ = ravel_pytree(g_tree)
        np.testing.assert_allclose(np.asarray(gflat), np.asarray(g2),
                                   rtol=1e-5, atol=1e-5)

"""AOT export tests: manifest consistency and HLO-text emission."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_module():
    fn = lambda x: (x * 2.0 + 1.0,)  # noqa: E731
    text = aot.to_hlo_text(
        jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    )
    assert "HloModule" in text and "ENTRY" in text


def test_export_model_tmpdir(tmp_path):
    manifest = {"artifacts": {}, "models": {}}
    p = aot.export_model("mlp_small", str(tmp_path), manifest)
    assert p == M.param_count("mlp_small")
    assert (tmp_path / "train_mlp_small.hlo.txt").exists()
    assert (tmp_path / "eval_mlp_small.hlo.txt").exists()
    init = np.fromfile(tmp_path / "mlp_small.init.bin", dtype="<f4")
    assert init.shape == (p,)
    meta = manifest["artifacts"]["train_mlp_small"]
    assert meta["param_count"] == p
    assert meta["inputs"][0]["shape"] == [p]


def test_export_updates_tmpdir(tmp_path):
    manifest = {"artifacts": {}, "models": {}}
    aot.export_updates("unit", 64, str(tmp_path), manifest)
    assert (tmp_path / "update_sgdm_unit.hlo.txt").exists()
    assert (tmp_path / "update_adam_unit.hlo.txt").exists()
    meta = manifest["artifacts"]["update_adam_unit"]
    assert meta["param_count"] == 64
    assert meta["outputs"] == ["x_new", "m_new", "v_new"]
    assert meta["inputs"][-1]["shape"] == [3]


def test_export_gossip_tmpdir(tmp_path):
    manifest = {"artifacts": {}, "models": {}}
    aot.export_gossip(4, 8, str(tmp_path), manifest)
    text = (tmp_path / "gossip_dense_n4.hlo.txt").read_text()
    assert "HloModule" in text
    assert manifest["artifacts"]["gossip_dense_n4"]["n"] == 4


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestBuiltArtifacts:
    def test_manifest_matches_files(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), name
            with open(path) as fh:
                head = fh.read(200)
            assert "HloModule" in head, name

    def test_init_bins_match_param_counts(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        for name, meta in manifest["models"].items():
            init = np.fromfile(
                os.path.join(ART, meta["init"]), dtype="<f4"
            )
            assert init.shape == (meta["param_count"],), name
            assert np.isfinite(init).all(), name

"""Dense push-sum mixing as a Pallas kernel: one gossip round for all n
nodes at once.

Stack the push-sum numerators into X ∈ R^{n×d} and the weights into
w ∈ R^n; a gossip round is X' = P X, w' = P w with the column-stochastic
mixing matrix P ∈ R^{n×n}. Expressing the round as a single MXU-tiled
matmul (rather than n pointwise axpys) is the TPU-shaped formulation used
by the averaging/consensus experiments (Fig. 2, Appendix A) where d is
large and n modest.

The weight vector is mixed in the same kernel by augmenting X with one
extra column, so one HBM pass covers both (matches Alg. 1 lines 6–7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul


def gossip_round(
    p_mat: jax.Array, x: jax.Array, w: jax.Array, *, interpret: bool = True
):
    """One push-sum round. p_mat: f32[n,n], x: f32[n,d], w: f32[n].

    Returns (x', w', z') where z' = x' / w' are the de-biased parameters.
    """
    n, d = x.shape
    aug = jnp.concatenate([x, w[:, None]], axis=1)  # [n, d+1]
    mixed = matmul.matmul(p_mat, aug, interpret=interpret)
    x_new = mixed[:, :d]
    w_new = mixed[:, d]
    z_new = x_new / w_new[:, None]
    return x_new, w_new, z_new


def gossip_rounds(
    p_mats: jax.Array, x: jax.Array, w: jax.Array, *, interpret: bool = True
):
    """Scan ``k`` gossip rounds. p_mats: f32[k,n,n]. Returns final (x,w,z)."""

    def body(carry, p_k):
        x_c, w_c = carry
        x_n, w_n, _ = gossip_round(p_k, x_c, w_c, interpret=interpret)
        return (x_n, w_n), None

    (x_f, w_f), _ = jax.lax.scan(body, (x, w), p_mats)
    return x_f, w_f, x_f / w_f[:, None]

"""Fused optimizer-update Pallas kernels over the flat parameter vector.

SGP applies the local optimizer step to the *biased* push-sum numerator
``x`` using gradients evaluated at the de-biased ``z = x / w`` (Alg. 3 in
the paper). A naive implementation makes 4–6 HBM round-trips over the
P-element state per step; these kernels fuse the whole update into one
pass, tiled in 1-D VMEM blocks — the TPU analogue of a fused CUDA
elementwise kernel.

Two variants, matching the paper's experiments:
  * Nesterov momentum (ImageNet protocol, Goyal et al. 2017)
  * Adam (machine-translation protocol, Vaswani et al. 2017)

These are exported as standalone HLO artifacts and used by the Rust
coordinator's *ablation* path (``optim_ablation`` bench compares against
the pure-Rust hot loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int) -> int:
    b = min(n, want)
    while n % b:
        b -= 1
    return b


def _sgdm_kernel(x_ref, u_ref, g_ref, lr_ref, o_x_ref, o_u_ref,
                 *, momentum: float, weight_decay: float):
    """Nesterov momentum with decoupled-from-nothing L2 (Goyal protocol):
    g' = g + wd*x ; u <- m*u + g' ; x <- x - lr*(m*u + g')."""
    g = g_ref[...] + weight_decay * x_ref[...]
    u_new = momentum * u_ref[...] + g
    o_u_ref[...] = u_new
    o_x_ref[...] = x_ref[...] - lr_ref[0] * (momentum * u_new + g)


@functools.partial(
    jax.jit, static_argnames=("momentum", "weight_decay", "block", "interpret")
)
def sgdm_update(
    x: jax.Array,
    u: jax.Array,
    g: jax.Array,
    lr: jax.Array,
    *,
    momentum: float = 0.9,
    weight_decay: float = 1e-4,
    block: int = 4096,
    interpret: bool = True,
):
    """Fused Nesterov step. x, u, g: f32[P]; lr: f32[1] → (x', u')."""
    (p,) = x.shape
    b = _pick_block(p, block)
    grid = (p // b,)
    spec = pl.BlockSpec((b,), lambda i: (i,))
    lr_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(
            _sgdm_kernel, momentum=momentum, weight_decay=weight_decay
        ),
        grid=grid,
        in_specs=[spec, spec, spec, lr_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((p,), x.dtype),
            jax.ShapeDtypeStruct((p,), x.dtype),
        ],
        interpret=interpret,
    )(x, u, g, lr)


def _adam_kernel(x_ref, m_ref, v_ref, g_ref, sc_ref,
                 o_x_ref, o_m_ref, o_v_ref,
                 *, beta1: float, beta2: float, eps: float):
    """Adam; sc = [lr, bias_c1, bias_c2] with bias_cK = 1 - betaK^t
    precomputed by the caller (t is a runtime scalar)."""
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    o_m_ref[...] = m_new
    o_v_ref[...] = v_new
    m_hat = m_new / sc_ref[1]
    v_hat = v_new / sc_ref[2]
    o_x_ref[...] = x_ref[...] - sc_ref[0] * m_hat / (jnp.sqrt(v_hat) + eps)


@functools.partial(
    jax.jit, static_argnames=("beta1", "beta2", "eps", "block", "interpret")
)
def adam_update(
    x: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    scalars: jax.Array,
    *,
    beta1: float = 0.9,
    beta2: float = 0.98,
    eps: float = 1e-9,
    block: int = 4096,
    interpret: bool = True,
):
    """Fused Adam step. x/m/v/g: f32[P]; scalars: f32[3] = [lr, 1-b1^t, 1-b2^t]."""
    (p,) = x.shape
    b = _pick_block(p, block)
    spec = pl.BlockSpec((b,), lambda i: (i,))
    sc_spec = pl.BlockSpec((3,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=(p // b,),
        in_specs=[spec, spec, spec, spec, sc_spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((p,), x.dtype)] * 3,
        interpret=interpret,
    )(x, m, v, g, scalars)

"""Blocked causal self-attention Pallas kernel (flash-attention insight,
TPU idiom).

The paper's transformer workload spends its time in attention; on V100s
that is a sequence of cuBLAS GEMMs plus a materialized T×T softmax. The
flash-attention *insight* — never materialize the T×T score matrix in
HBM — is expressed here the TPU way: one grid program per (batch·head,
query-block), K/V streamed through VMEM in blocks along the key axis with
a running (max, denominator, accumulator) triple, instead of warp-level
reductions over shared memory.

interpret=True throughout (see matmul.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 *, bq: int, bk: int, n_kblocks: int, scale: float,
                 causal: bool):
    """Grid = (batch*heads, n_qblocks, n_kblocks); k axis is the reduction."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [bq, dh]
    k = k_ref[0]  # [bk, dh]
    v = v_ref[0]  # [bk, dh]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq,bk]
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                      # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                   # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)          # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kblocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "causal", "interpret")
)
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bq: int = 64,
    bk: int = 64,
    causal: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Multi-head attention. q, k, v: f32[BH, T, Dh] → f32[BH, T, Dh].

    BH is the flattened (batch × heads) axis; one grid program handles one
    (BH, query-block) pair and streams key/value blocks through VMEM.
    """
    bh, t, dh = q.shape
    while t % bq:
        bq -= 1
    while t % bk:
        bk -= 1
    n_kblocks = t // bk
    scale = 1.0 / (dh ** 0.5)

    return pl.pallas_call(
        functools.partial(
            _attn_kernel, bq=bq, bk=bk, n_kblocks=n_kblocks, scale=scale,
            causal=causal,
        ),
        grid=(bh, t // bq, n_kblocks),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(bq: int, bk: int, dh: int, dtype_bytes: int = 4) -> int:
    """Per-step VMEM: q/o blocks, k/v blocks, acc + running stats."""
    return dtype_bytes * (2 * bq * dh + 2 * bk * dh + bq * dh + 2 * bq)

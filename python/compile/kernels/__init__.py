"""L1: Pallas kernels for the compute hot-spots (see DESIGN.md
§Hardware-Adaptation), plus differentiable wrappers.

``pallas_call`` has no automatic reverse-mode rule, so the model-facing
entry points here are ``jax.custom_vjp`` wrappers whose forward passes run
the Pallas kernels and whose backward passes are themselves built from the
same kernels where possible (matmul backward = two more blocked matmuls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as _attention_mod
from . import fused_update  # noqa: F401  (re-export)
from . import matmul as _matmul_mod
from . import pushsum_mix  # noqa: F401  (re-export)
from . import ref as _ref


# --------------------------------------------------------------------------
# Differentiable blocked matmul: dX = dO @ Yᵀ and dY = Xᵀ @ dO are blocked
# Pallas matmuls as well, so fwd *and* bwd lower through the MXU-tiled path.
# --------------------------------------------------------------------------
@jax.custom_vjp
def pmatmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return _matmul_mod.matmul(x, y)


def _pmatmul_fwd(x, y):
    return _matmul_mod.matmul(x, y), (x, y)


def _pmatmul_bwd(res, g):
    x, y = res
    dx = _matmul_mod.matmul(g, y.T)
    dy = _matmul_mod.matmul(x.T, g)
    return dx, dy


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


# --------------------------------------------------------------------------
# Differentiable blocked causal attention: forward is the flash-style Pallas
# kernel; backward recomputes scores with jnp (exact math, checked against
# jax.grad of the reference in pytest). Recompute-not-store is the
# flash-attention memory tradeoff.
# --------------------------------------------------------------------------
@jax.custom_vjp
def pattention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    return _attention_mod.attention(q, k, v, causal=True)


def _pattention_fwd(q, k, v):
    return _attention_mod.attention(q, k, v, causal=True), (q, k, v)


def _pattention_bwd(res, g):
    q, k, v = res
    _, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                       # [B, T, T]
    dv = jnp.einsum("bqk,bqd->bkd", p, g)
    dp = jnp.einsum("bqd,bkd->bqk", g, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = jnp.where(mask[None], ds, 0.0) * scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, k)
    dk = jnp.einsum("bqk,bqd->bkd", ds, q)
    return dq, dk, dv


pattention.defvjp(_pattention_fwd, _pattention_bwd)

ref = _ref
matmul = _matmul_mod
attention = _attention_mod

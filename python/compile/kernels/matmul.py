"""Blocked Pallas matmul — the L1 compute hot-spot shared by the model MLPs,
attention projections, and the dense push-sum mixing kernel.

TPU adaptation of the paper's GPU compute (see DESIGN.md §Hardware-
Adaptation): instead of CUDA threadblocks staging tiles through shared
memory, the ``BlockSpec`` index maps express the HBM→VMEM schedule and the
inner ``jnp.dot`` targets the 128×128 MXU systolic array. The accumulator
lives in a VMEM scratch buffer across the K-reduction grid axis.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime can run. Correctness is asserted against ``ref.py`` in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# Default tile sizes: 128 matches the MXU systolic array edge; a
# (128, 128) f32 tile is 64 KiB, so the working set (x-tile + y-tile +
# accumulator) is ~192 KiB — far below the ~16 MiB per-core VMEM budget,
# leaving room for double buffering by the pipeline.
DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x[i,k] @ y[k,j]; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``want`` (keeps grids exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BLOCK,
    bk: int = DEFAULT_BLOCK,
    bn: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """``x @ y`` via the blocked Pallas kernel.

    x: f32[M, K], y: f32[K, N] → f32[M, N]. Block sizes are clamped to
    divisors of the corresponding dims so the grid covers the operands
    exactly (no masking needed on the hot path).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bk = _pick_block(k, bk)
    bn = _pick_block(n, bn)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (x, y, out, acc)."""
    return dtype_bytes * (bm * bk + bk * bn + 2 * bm * bn)


def mxu_utilization(bm: int, bk: int, bn: int, edge: int = 128) -> float:
    """Fraction of MXU lanes used by a (bm, bk)x(bk, bn) tile — 1.0 when
    every tile dim is a multiple of the systolic-array edge."""
    eff = lambda d: min(d, edge) / edge  # noqa: E731
    return eff(bm) * eff(bn) * eff(bk)

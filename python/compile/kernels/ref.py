"""Pure-jnp oracles for every Pallas kernel. These are the correctness
ground truth: pytest asserts kernel-vs-ref allclose, hypothesis sweeps
shapes/dtypes. Nothing here is used on the hot path."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """q,k,v: [BH, T, Dh] — reference softmax attention."""
    _, t, dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (dh ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def sgdm_update(x, u, g, lr, *, momentum=0.9, weight_decay=1e-4):
    g = g + weight_decay * x
    u_new = momentum * u + g
    x_new = x - lr[0] * (momentum * u_new + g)
    return x_new, u_new


def adam_update(x, m, v, g, scalars, *, beta1=0.9, beta2=0.98, eps=1e-9):
    lr, c1, c2 = scalars[0], scalars[1], scalars[2]
    m_new = beta1 * m + (1 - beta1) * g
    v_new = beta2 * v + (1 - beta2) * g * g
    x_new = x - lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
    return x_new, m_new, v_new


def gossip_round(p_mat, x, w):
    x_new = p_mat @ x
    w_new = p_mat @ w
    return x_new, w_new, x_new / w_new[:, None]

"""AOT lowering: JAX → HLO **text** artifacts + manifest for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py for the reference wiring.

Emitted under ``artifacts/``:

  train_<model>.hlo.txt    (flat f32[P], batch...) → (loss f32[], grads f32[P])
  eval_<model>.hlo.txt     (flat f32[P], batch...) → (loss f32[], metric f32[])
  <model>.init.bin         initial flat params, little-endian f32
  update_sgdm_<m>.hlo.txt  fused Nesterov step  (ablation path)
  update_adam_<m>.hlo.txt  fused Adam step      (ablation path)
  gossip_dense_n<N>.hlo.txt  one dense push-sum round over stacked states
  manifest.json            shapes/dtypes/param counts for the Rust loader

Python runs ONCE (``make artifacts``); the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fused_update, pushsum_mix


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_meta(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}


def export_model(name: str, outdir: str, manifest: dict) -> int:
    cfg, flat0, _, train_step, eval_step, batch_specs = M.make_flat(name)
    p = int(flat0.shape[0])
    flat_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    specs = [flat_spec, *batch_specs.values()]

    for kind, fn in (("train", train_step), ("eval", eval_step)):
        art = f"{kind}_{name}"
        path = os.path.join(outdir, f"{art}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][art] = {
            "file": f"{art}.hlo.txt",
            "kind": f"{kind}_step",
            "model": name,
            "param_count": p,
            "inputs": [
                {"name": "params", **_spec_meta(flat_spec)},
                *[
                    {"name": k, **_spec_meta(v)}
                    for k, v in batch_specs.items()
                ],
            ],
            "outputs": ["loss", "grads"] if kind == "train"
            else ["loss", "metric"],
        }
        print(f"  wrote {art}.hlo.txt ({len(text)} chars)")

    init_file = f"{name}.init.bin"
    np.asarray(flat0, dtype="<f4").tofile(os.path.join(outdir, init_file))
    manifest["models"][name] = {
        "param_count": p,
        "init": init_file,
        "config": {
            k: (list(v) if isinstance(v, tuple) else v)
            for k, v in vars(cfg).items()
        },
    }
    return p


def export_updates(model_name: str, p: int, outdir: str, manifest: dict):
    vec = jax.ShapeDtypeStruct((p,), jnp.float32)

    sgdm = functools.partial(fused_update.sgdm_update,
                             momentum=0.9, weight_decay=1e-4)
    art = f"update_sgdm_{model_name}"
    text = to_hlo_text(
        jax.jit(sgdm).lower(vec, vec, vec,
                            jax.ShapeDtypeStruct((1,), jnp.float32))
    )
    with open(os.path.join(outdir, f"{art}.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"][art] = {
        "file": f"{art}.hlo.txt", "kind": "update_sgdm", "param_count": p,
        "inputs": [{"name": n, "shape": [p], "dtype": "float32"}
                   for n in ("x", "u", "g")] +
                  [{"name": "lr", "shape": [1], "dtype": "float32"}],
        "outputs": ["x_new", "u_new"],
    }
    print(f"  wrote {art}.hlo.txt")

    adam = functools.partial(fused_update.adam_update,
                             beta1=0.9, beta2=0.98, eps=1e-9)
    art = f"update_adam_{model_name}"
    text = to_hlo_text(
        jax.jit(adam).lower(vec, vec, vec, vec,
                            jax.ShapeDtypeStruct((3,), jnp.float32))
    )
    with open(os.path.join(outdir, f"{art}.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"][art] = {
        "file": f"{art}.hlo.txt", "kind": "update_adam", "param_count": p,
        "inputs": [{"name": n, "shape": [p], "dtype": "float32"}
                   for n in ("x", "m", "v", "g")] +
                  [{"name": "scalars", "shape": [3], "dtype": "float32"}],
        "outputs": ["x_new", "m_new", "v_new"],
    }
    print(f"  wrote {art}.hlo.txt")


def export_gossip(n: int, d: int, outdir: str, manifest: dict):
    art = f"gossip_dense_n{n}"
    fn = lambda p, x, w: pushsum_mix.gossip_round(p, x, w)  # noqa: E731
    text = to_hlo_text(
        jax.jit(fn).lower(
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        )
    )
    with open(os.path.join(outdir, f"{art}.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"][art] = {
        "file": f"{art}.hlo.txt", "kind": "gossip_dense", "n": n, "d": d,
        "inputs": [
            {"name": "p", "shape": [n, n], "dtype": "float32"},
            {"name": "x", "shape": [n, d], "dtype": "float32"},
            {"name": "w", "shape": [n], "dtype": "float32"},
        ],
        "outputs": ["x_new", "w_new", "z_new"],
    }
    print(f"  wrote {art}.hlo.txt")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output dir (or a path inside it)")
    ap.add_argument("--models", nargs="*",
                    default=["mlp_small", "lm_tiny", "lm_small",
                             "lm_small_b16"])
    ap.add_argument("--gossip-n", nargs="*", type=int, default=[16, 32])
    ap.add_argument("--gossip-d", type=int, default=1024)
    args = ap.parse_args()

    outdir = args.out
    if outdir.endswith(".hlo.txt"):  # Makefile passes the stamp file path
        outdir = os.path.dirname(outdir)
    os.makedirs(outdir, exist_ok=True)

    manifest: dict = {"artifacts": {}, "models": {}}
    pcounts = {}
    for name in args.models:
        print(f"[aot] model {name}")
        pcounts[name] = export_model(name, outdir, manifest)

    # Fused-update ablation artifacts for the smallest model.
    abl = "mlp_small" if "mlp_small" in pcounts else args.models[0]
    print(f"[aot] fused updates for {abl}")
    export_updates(abl, pcounts[abl], outdir, manifest)

    for n in args.gossip_n:
        print(f"[aot] gossip_dense n={n} d={args.gossip_d}")
        export_gossip(n, args.gossip_d, outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Stamp file the Makefile tracks.
    with open(os.path.join(outdir, "model.hlo.txt"), "w") as f:
        f.write("// stamp: see manifest.json for the real artifacts\n")
    print(f"[aot] manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()

"""L2: model forward/backward graphs over a **flat parameter vector**.

Two model families, mirroring the paper's two workloads:

* ``transformer`` — a causal transformer LM (the WMT'16 Transformer
  analogue; validation NLL stands in for BLEU, see DESIGN.md §2). Attention
  and all projections go through the L1 Pallas kernels (``pmatmul`` /
  ``pattention``).
* ``mlp`` — a ReLU MLP classifier (the ResNet-50/ImageNet analogue for the
  many full-training sweeps in Tables 1–5). Matmuls via ``pmatmul``.

**Flat-parameter convention.** Every exported graph takes the parameters as
a single ``f32[P]`` vector and returns gradients as ``f32[P]``. The
ravel/unravel happens *inside* the graph (via ``jax.flatten_util``), so the
Rust coordinator's per-node state is just a ``Vec<f32>`` and the gossip /
optimizer / collective machinery is completely model-agnostic.

This module is build-time only: ``aot.py`` lowers the functions defined
here to HLO text once; Python never runs on the training path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import kernels


# ===========================================================================
# Configs and presets
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 32
    batch: int = 4
    kind: str = "transformer"

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    in_dim: int = 32
    hidden: Tuple[int, ...] = (128, 128)
    classes: int = 10
    batch: int = 32
    kind: str = "mlp"


PRESETS = {
    # Integration-test scale: a few hundred µs per step on one CPU core.
    "mlp_small": MlpConfig(),
    "mlp_wide": MlpConfig(in_dim=64, hidden=(256, 256, 256), classes=16,
                          batch=32),
    # Rust-integration-test scale transformer.
    "lm_tiny": TransformerConfig(vocab=128, d_model=32, n_layers=2,
                                 n_heads=2, d_ff=64, seq_len=16, batch=2),
    # End-to-end example scale (~1M params; the 100M-param/ResNet-50 scale of
    # the paper is substituted down for the single-CPU-core testbed, see
    # DESIGN.md §2 and EXPERIMENTS.md).
    "lm_small": TransformerConfig(),
    # Large-batch regime of Fig. 3 (same model, 4× the tokens per step —
    # the paper's 25K- vs 400K-token contrast scaled down).
    "lm_small_b16": TransformerConfig(batch=16),
}


# ===========================================================================
# Transformer LM
# ===========================================================================
def init_transformer(cfg: TransformerConfig, seed: int = 0):
    """He/Glorot-style init; returns a pytree of parameter arrays."""
    k = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(k, 4 + 6 * cfg.n_layers))
    d, dff = cfg.d_model, cfg.d_ff

    def dense(key, fan_in, fan_out):
        return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * (
            fan_in ** -0.5
        )

    params = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, d)) * 0.02,
        "pos_emb": jax.random.normal(next(keys), (cfg.seq_len, d)) * 0.02,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "out": dense(next(keys), d, cfg.vocab),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wqkv": dense(next(keys), d, 3 * d),
                "wo": dense(next(keys), d, d),
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "w1": dense(next(keys), d, dff),
                "w2": dense(next(keys), dff, d),
            }
        )
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dense(x, w):
    """[B, T, Din] @ [Din, Dout] through the Pallas blocked matmul."""
    b, t, din = x.shape
    return kernels.pmatmul(x.reshape(b * t, din), w).reshape(b, t, -1)


def transformer_logits(params, tokens, cfg: TransformerConfig):
    """tokens: i32[B, T] → logits f32[B, T, V]."""
    b, t = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t]
    for lp in params["layers"]:
        # --- attention block -------------------------------------------
        a_in = _layer_norm(h, lp["ln1"]["g"], lp["ln1"]["b"])
        qkv = _dense(a_in, lp["wqkv"])                     # [B, T, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(x):  # [B, T, D] → [B*H, T, Dh]
            return (
                x.reshape(b, t, cfg.n_heads, cfg.d_head)
                .transpose(0, 2, 1, 3)
                .reshape(b * cfg.n_heads, t, cfg.d_head)
            )

        att = kernels.pattention(heads(q), heads(k), heads(v))
        att = (
            att.reshape(b, cfg.n_heads, t, cfg.d_head)
            .transpose(0, 2, 1, 3)
            .reshape(b, t, cfg.d_model)
        )
        h = h + _dense(att, lp["wo"])
        # --- MLP block --------------------------------------------------
        m_in = _layer_norm(h, lp["ln2"]["g"], lp["ln2"]["b"])
        h = h + _dense(jax.nn.gelu(_dense(m_in, lp["w1"])), lp["w2"])
    h = _layer_norm(h, params["ln_f"]["g"], params["ln_f"]["b"])
    return _dense(h, params["out"])


def transformer_loss(params, tokens, cfg: TransformerConfig):
    """tokens: i32[B, T+1]; next-token cross-entropy (mean nats/token)."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = transformer_logits(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


# ===========================================================================
# MLP classifier
# ===========================================================================
def init_mlp(cfg: MlpConfig, seed: int = 0):
    k = jax.random.PRNGKey(seed)
    dims = (cfg.in_dim, *cfg.hidden, cfg.classes)
    keys = jax.random.split(k, len(dims) - 1)
    return {
        "w": [
            jax.random.normal(keys[i], (dims[i], dims[i + 1])) *
            (2.0 / dims[i]) ** 0.5
            for i in range(len(dims) - 1)
        ],
        "b": [jnp.zeros((dims[i + 1],)) for i in range(len(dims) - 1)],
    }


def mlp_logits(params, x):
    h = x
    n = len(params["w"])
    for i in range(n):
        h = kernels.pmatmul(h, params["w"][i]) + params["b"][i]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_loss(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()


def mlp_loss_acc(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == y).astype(jnp.float32).mean()
    return loss, acc


# ===========================================================================
# Flat-parameter export surface
# ===========================================================================
def make_flat(name: str):
    """Build the flat-parameter train/eval functions for a preset.

    Returns (cfg, flat0, unravel, train_step, eval_step, batch_specs) where
      train_step(flat, *batch) → (loss, grads f32[P])
      eval_step(flat, *batch)  → (loss, metric)   [metric = acc or loss]
    """
    cfg = PRESETS[name]
    if cfg.kind == "transformer":
        params0 = init_transformer(cfg)
        flat0, unravel = ravel_pytree(params0)

        def train_step(flat, tokens):
            loss, g = jax.value_and_grad(
                lambda p: transformer_loss(p, tokens, cfg)
            )(unravel(flat))
            return loss, ravel_pytree(g)[0]

        def eval_step(flat, tokens):
            loss = transformer_loss(unravel(flat), tokens, cfg)
            return loss, loss

        batch_specs = {
            "tokens": jax.ShapeDtypeStruct(
                (cfg.batch, cfg.seq_len + 1), jnp.int32
            )
        }
    else:
        params0 = init_mlp(cfg)
        flat0, unravel = ravel_pytree(params0)

        def train_step(flat, x, y):
            loss, g = jax.value_and_grad(
                lambda p: mlp_loss(p, x, y)
            )(unravel(flat))
            return loss, ravel_pytree(g)[0]

        def eval_step(flat, x, y):
            return mlp_loss_acc(unravel(flat), x, y)

        batch_specs = {
            "x": jax.ShapeDtypeStruct((cfg.batch, cfg.in_dim), jnp.float32),
            "y": jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
        }
    return cfg, flat0, unravel, train_step, eval_step, batch_specs


def param_count(name: str) -> int:
    return int(make_flat(name)[1].shape[0])

//! Ablation: pure-Rust optimizer hot loop vs the PJRT fused-update
//! artifact (L1 Pallas kernel). Both compute identical math (pinned by
//! integration_runtime tests); this bench measures which belongs on the
//! L3 hot path. Expected: the Rust loop wins at small P (no host⇄PJRT
//! literal traffic) — which is why it is the default.

use sgp::benchkit::{bench, black_box, section};
use sgp::model;
use sgp::optim::{OptimKind, Optimizer};
use sgp::rng::Pcg;
use sgp::runtime::Runtime;

fn main() {
    let p = 22_026usize; // mlp_small parameter count
    let mut rng = Pcg::new(1);
    let g = rng.gaussian_vec(p);

    section(&format!("Nesterov step, P={p}"));
    let mut x = rng.gaussian_vec(p);
    let mut opt = Optimizer::new(OptimKind::Nesterov, p);
    bench("optim/rust/nesterov", || {
        opt.step(&mut x, &g, 0.01);
        black_box(&x);
    });

    section(&format!("Adam step, P={p}"));
    let mut x = rng.gaussian_vec(p);
    let mut opt = Optimizer::new(OptimKind::Adam, p);
    bench("optim/rust/adam", || {
        opt.step(&mut x, &g, 1e-3);
        black_box(&x);
    });

    // PJRT fused-update path (needs artifacts).
    let dir = model::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built — skipping PJRT ablation arm)");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");

    section("PJRT fused-update artifacts (Pallas kernels, incl. transfers)");
    let x0 = rng.gaussian_vec(p);
    let u0 = vec![0.0f32; p];
    let _ = rt.update_sgdm("update_sgdm_mlp_small", &x0, &u0, &g, 0.01); // compile
    bench("optim/pjrt/nesterov-fused", || {
        black_box(
            rt.update_sgdm("update_sgdm_mlp_small", &x0, &u0, &g, 0.01)
                .unwrap(),
        );
    });
    let m0 = vec![0.0f32; p];
    let v0 = vec![0.0f32; p];
    let _ = rt.update_adam("update_adam_mlp_small", &x0, &m0, &v0, &g, 1e-3, 1);
    bench("optim/pjrt/adam-fused", || {
        black_box(
            rt.update_adam("update_adam_mlp_small", &x0, &m0, &v0, &g, 1e-3, 1)
                .unwrap(),
        );
    });
    println!(
        "\nverdict: the Rust loop is the hot path default; the fused-Pallas \
         path exists for parity with the paper's fused-GPU-kernel setup and \
         wins only when the update can stay device-resident."
    );
}

//! Theorem-1 rate study: SGP on synthetic least squares at the paper's
//! γ = √(n/K) operating point. Sweeps K (error should shrink ≈ 1/√K once
//! the 1/√(nK) term dominates) and n, and prints the table recorded in
//! EXPERIMENTS.md; plus a microbench of the pure-algorithm iteration.

use sgp::benchkit::{bench, black_box, section};
use sgp::gossip::PushSumEngine;
use sgp::metrics::print_table;
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn run(n: usize, iters: u64, noise: f32, seed: u64) -> (f64, f64) {
    let d = 16;
    let mut rng = Pcg::new(seed);
    let centers: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut opt = vec![0.0f64; d];
    for c in &centers {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / n as f64;
        }
    }
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut eng = PushSumEngine::new(init, 0, false);
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let gamma = ((n as f64 / iters as f64).sqrt()).min(0.25) as f32;
    for k in 0..iters {
        for i in 0..n {
            let z = eng.states[i].debiased();
            for (j, x) in eng.states[i].x.iter_mut().enumerate() {
                *x -= gamma * (z[j] - centers[i][j] + noise * rng.gaussian() as f32);
            }
        }
        eng.step(k, &sched);
    }
    let mean = eng.mean_x();
    let err = mean
        .iter()
        .zip(&opt)
        .map(|(m, o)| {
            let e = *m as f64 - o;
            e * e
        })
        .sum::<f64>()
        .sqrt();
    (err, eng.consensus_distance().0)
}

fn main() {
    let mut rows = Vec::new();
    for n in [8usize, 16, 32] {
        for iters in [250u64, 1000, 4000] {
            let (err, cons) = run(n, iters, 0.3, 42);
            rows.push(vec![
                n.to_string(),
                iters.to_string(),
                format!("{:.4}", (n as f64 / iters as f64).sqrt().min(0.25)),
                format!("{err:.4}"),
                format!("{cons:.2e}"),
            ]);
        }
    }
    print_table(
        "Theorem 1 rate check — SGP on least squares, γ = √(n/K)",
        &["n", "K", "γ", "‖x̄−x*‖", "consensus"],
        &rows,
    );

    section("pure-algorithm iteration microbench");
    let n = 16;
    let d = 1024;
    let mut rng = Pcg::new(7);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut eng = PushSumEngine::new(init, 0, false);
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let mut k = 0u64;
    bench("sgp_iteration/quadratic/n16/d1024", || {
        for i in 0..n {
            let w = eng.states[i].w as f32;
            for x in eng.states[i].x.iter_mut() {
                *x -= 0.01 * (*x / w);
            }
        }
        eng.step(k, &sched);
        k += 1;
        black_box(&eng.states[0].x[0]);
    });
}

//! Fig. D.4 regeneration: simulated training throughput (images/s) and
//! scaling efficiency for SGP vs AR-SGD on both fabrics, plus collective
//! cost-model microbenches.

use sgp::benchkit::{bench, black_box, section};
use sgp::collectives;
use sgp::experiments;
use sgp::net::LinkModel;

fn main() {
    // The paper-shaped table + CSV (results/figd4_throughput.csv).
    experiments::figd4().expect("fig d4");

    section("collective substrate microbenches");
    let link = LinkModel::ethernet_10g();
    bench("collectives/ring_time_model", || {
        black_box(collectives::ring_allreduce_time(32, 100 << 20, &link));
    });
    let mut vs: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32; 22_026]).collect();
    bench("collectives/allreduce_mean/22k/n16", || {
        collectives::allreduce_mean(&mut vs);
        black_box(&vs);
    });
    let vs2: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32; 923_904]).collect();
    bench("collectives/mean_of/924k/n16", || {
        black_box(collectives::mean_of(&vs2));
    });
}

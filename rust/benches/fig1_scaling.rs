//! Fig. 1c/d regeneration + timing-recursion microbenches.
//!
//! Prints the simulated seconds/iteration grid (method × nodes × fabric)
//! that reproduces the paper's scaling plots — AR-SGD degrades with n over
//! 10 GbE while SGP stays flat; everything is compute-bound on InfiniBand
//! — and measures the cost of the timing recursion itself.

use sgp::benchkit::{bench, black_box, section};
use sgp::experiments;
use sgp::net::{CommPattern, ComputeModel, LinkModel, TimingSim};
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn main() {
    // The paper-shaped table + CSV (results/fig1cd_timing.csv).
    experiments::fig1_timing_csv().expect("fig1 timing");

    section("timing-recursion microbench (n=32)");
    let n = 32;
    let compute = ComputeModel::resnet50_dgx1();
    let mut rng = Pcg::new(1);
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);

    let mut sim = TimingSim::new(n, LinkModel::ethernet_10g());
    bench("timing/advance/allreduce/n32", || {
        let comp = compute.sample_all(n, &mut rng);
        black_box(sim.advance(&CommPattern::AllReduce { bytes: 100 << 20 }, &comp));
    });

    let mut sim = TimingSim::new(n, LinkModel::ethernet_10g());
    let mut rng2 = Pcg::new(2);
    bench("timing/advance/pushsum/n32", || {
        let comp = compute.sample_all(n, &mut rng2);
        black_box(sim.advance(
            &CommPattern::PushSum { schedule: &sched, bytes: 100 << 20, tau: 1 },
            &comp,
        ));
    });

    section("300-iteration sweep (what one grid cell of Fig 1c costs)");
    bench("timing/sweep300/sgp/n32", || {
        black_box(sgp::net::average_iteration_time(
            32,
            LinkModel::ethernet_10g(),
            &compute,
            300,
            7,
            |_| sgp::net::OwnedCommPattern::PushSum {
                schedule: Schedule::new(TopologyKind::OnePeerExp, 32),
                bytes: 100 << 20,
                tau: 0,
            },
        ));
    });
}

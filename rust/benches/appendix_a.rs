//! Appendix A regeneration: λ₂ of mixing-matrix products for the four
//! peer-selection schemes at n = 32, plus spectral-tooling microbenches.

use sgp::benchkit::{bench, black_box, section};
use sgp::experiments;
use sgp::topology::{spectral, Mat, Schedule, TopologyKind};

fn main() {
    // The paper-shaped table + CSV (results/appendix_a_lambda2.csv).
    experiments::appendix_a().expect("appendix A");

    section("spectral microbenches (n=32)");
    let s = Schedule::new(TopologyKind::OnePeerExp, 32);
    let mats: Vec<Mat> = (0..5u64).map(|k| s.mixing_matrix(k)).collect();
    bench("spectral/mixing_matrix/n32", || {
        black_box(s.mixing_matrix(3));
    });
    bench("spectral/product5/n32", || {
        black_box(Mat::product(&mats));
    });
    let prod = Mat::product(&mats);
    bench("spectral/lambda2/n32", || {
        black_box(spectral::lambda2(&prod));
    });
    bench("spectral/singular_values/n32", || {
        black_box(spectral::singular_values(&prod));
    });
}

//! Gossip hot-path microbenchmarks: one PushSum engine step at the two
//! parameter scales the experiments use (MLP ≈ 22k params, transformer
//! ≈ 924k params), plus the de-bias and consensus-statistics kernels.
//! This is the L3 cost that must stay off the critical path relative to
//! gradient compute (see EXPERIMENTS.md §Perf).

use sgp::algorithms::{AlgoParams, DistributedAlgorithm, RoundCtx, Sgp};
use sgp::benchkit::{bench, black_box, section};
use sgp::gossip::PushSumEngine;
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn engine(n: usize, dim: usize, delay: u64) -> PushSumEngine {
    let mut rng = Pcg::new(1);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    PushSumEngine::new(init, delay, false)
}

fn main() {
    section("gossip engine: one step (send+aggregate all nodes)");
    for (dim, tag) in [(22_026usize, "mlp-22k"), (923_904, "lm-924k")] {
        for n in [8usize, 16] {
            let sched = Schedule::new(TopologyKind::OnePeerExp, n);
            let mut eng = engine(n, dim, 0);
            let mut k = 0u64;
            bench(&format!("pushsum_step/1peer/{tag}/n{n}"), || {
                eng.step(k, &sched);
                k += 1;
            });
        }
    }

    section("gossip engine: overlap (τ=1) and 2-peer variants, n=16");
    let sched2 = Schedule::new(TopologyKind::TwoPeerExp, 16);
    let mut eng = engine(16, 22_026, 0);
    let mut k = 0u64;
    bench("pushsum_step/2peer/mlp-22k/n16", || {
        eng.step(k, &sched2);
        k += 1;
    });
    let sched1 = Schedule::new(TopologyKind::OnePeerExp, 16);
    let mut eng = engine(16, 22_026, 1);
    let mut k = 0u64;
    bench("pushsum_step/1peer-tau1/mlp-22k/n16", || {
        eng.step(k, &sched1);
        k += 1;
    });

    section("dispatch overhead: direct engine step vs boxed DistributedAlgorithm");
    // The trait indirection must cost ~nothing next to the O(n·dim) gossip
    // work: identical PushSum math, once called directly and once through
    // a `Box<dyn DistributedAlgorithm>` vtable (incl. the schedule clone
    // the owned timing pattern carries).
    for (dim, tag) in [(22_026usize, "mlp-22k"), (923_904, "lm-924k")] {
        let n = 16;
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut eng = engine(n, dim, 0);
        let mut k = 0u64;
        bench(&format!("dispatch/direct-engine/{tag}/n{n}"), || {
            eng.step(k, &sched);
            k += 1;
        });

        let mut rng = Pcg::new(1);
        let mut params = AlgoParams::new(n, rng.gaussian_vec(dim), OptimKind::Sgd);
        params.seed = 0;
        let mut alg: Box<dyn DistributedAlgorithm> =
            Box::new(Sgp::with_topology(TopologyKind::OnePeerExp, &params));
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1f64; n];
        let mut k = 0u64;
        bench(&format!("dispatch/boxed-trait/{tag}/n{n}"), || {
            let ctx = RoundCtx { k, comp: &comp, msg_bytes: 4 * dim, link: &link };
            black_box(alg.communicate(&ctx));
            k += 1;
        });
    }

    section("debias + statistics");
    let eng = engine(16, 923_904, 0);
    let mut out = vec![0.0f32; 923_904];
    bench("debias_into/lm-924k", || {
        eng.states[0].debias_into(&mut out);
        black_box(&out);
    });
    bench("consensus_distance/lm-924k/n16", || {
        black_box(eng.consensus_distance());
    });
    bench("total_mass/lm-924k/n16", || {
        black_box(eng.total_mass());
    });
}

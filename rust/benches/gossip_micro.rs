//! Gossip hot-path microbenchmarks: one PushSum engine step at the two
//! parameter scales the experiments use (MLP ≈ 22k params, transformer
//! ≈ 924k params), plus the de-bias and consensus-statistics kernels and
//! the fault-injected step. This is the L3 cost that must stay off the
//! critical path relative to gradient compute (see EXPERIMENTS.md §Perf).
//!
//! Besides the human-readable report, the run writes machine-readable
//! `results/BENCH_gossip.json` (override with `BENCH_JSON=<path>`), the
//! execution-engine scaling curve `results/BENCH_engine.json` (override
//! with `BENCH_ENGINE_JSON=<path>`), and the compression curve
//! `results/BENCH_compress.json` (`BENCH_COMPRESS_JSON=<path>`) — the
//! perf-trajectory artifacts `repro bench-check` diffs against the
//! committed baselines under `benchmarks/baselines/`.
//!
//! Set `SGP_BENCH_FAST=1` for the CI smoke configuration: smaller time
//! budgets and fewer sizes per curve. The JSON schema is identical and
//! every entry a fast run emits keeps its full-run name — fast mode is a
//! strict **subset** of the full suite — so the perf gate keeps matching
//! entries by name while the wall-clock stays bounded. Arm the committed
//! baselines from the same mode CI enforces (`SGP_BENCH_FAST=1`):
//! baselines recorded from a full run additionally track entries the CI
//! run never produces, which the gate reports as "gone (ignored)".

use std::time::Duration;

use sgp::algorithms::{AlgoParams, DistributedAlgorithm, RoundCtx, Sgp};
use sgp::benchkit::{bench_for, black_box, section, JsonReport};
use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn engine(n: usize, dim: usize, delay: u64) -> PushSumEngine {
    let mut rng = Pcg::new(1);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    PushSumEngine::new(init, delay, false)
}

fn main() {
    let fast = std::env::var("SGP_BENCH_FAST")
        .ok()
        .is_some_and(|v| v != "0" && !v.is_empty());
    // One knob scales every curve: smaller budgets and fewer sizes in
    // fast (CI smoke) mode, identical names/schema either way.
    let budget = if fast {
        Duration::from_millis(250)
    } else {
        Duration::from_secs(2)
    };
    let dims: &[(usize, &str)] = if fast {
        &[(22_026, "mlp-22k")]
    } else {
        &[(22_026, "mlp-22k"), (923_904, "lm-924k")]
    };
    let mut report = JsonReport::new();

    section("gossip engine: one step (send+aggregate all nodes)");
    for &(dim, tag) in dims {
        for n in [8usize, 16] {
            let sched = Schedule::new(TopologyKind::OnePeerExp, n);
            let mut eng = engine(n, dim, 0);
            let mut k = 0u64;
            report.push(bench_for(
                &format!("pushsum_step/1peer/{tag}/n{n}"),
                budget,
                || {
                    eng.step(k, &sched);
                    k += 1;
                },
            ));
        }
    }

    section("gossip engine: overlap (τ=1) and 2-peer variants, n=16");
    let sched2 = Schedule::new(TopologyKind::TwoPeerExp, 16);
    let mut eng = engine(16, 22_026, 0);
    let mut k = 0u64;
    report.push(bench_for("pushsum_step/2peer/mlp-22k/n16", budget, || {
        eng.step(k, &sched2);
        k += 1;
    }));
    let sched1 = Schedule::new(TopologyKind::OnePeerExp, 16);
    let mut eng = engine(16, 22_026, 1);
    let mut k = 0u64;
    report.push(bench_for("pushsum_step/1peer-tau1/mlp-22k/n16", budget, || {
        eng.step(k, &sched1);
        k += 1;
    }));

    section("fault injection: lossy + churn step vs clean step, n=16");
    // The fault layer's overhead budget: a lossy step with churn should
    // stay within a small factor of the clean step at both scales.
    for &(dim, tag) in dims {
        let sched = Schedule::new(TopologyKind::OnePeerExp, 16);
        let clock = FaultClock::new(
            FaultPlan::lossless()
                .with_drop(0.05)
                .with_crash(3, 64, Some(128))
                .with_seed(1),
        );
        let mut eng = engine(16, dim, 0);
        let mut k = 0u64;
        report.push(bench_for(
            &format!("pushsum_step_faulty/5pct-drop/{tag}/n16"),
            budget,
            || {
                eng.step_faulty(k % 256, &sched, &clock);
                k += 1;
            },
        ));
    }

    section("dispatch overhead: direct engine step vs boxed DistributedAlgorithm");
    // The trait indirection must cost ~nothing next to the O(n·dim) gossip
    // work: identical PushSum math, once called directly and once through
    // a `Box<dyn DistributedAlgorithm>` vtable (incl. the schedule clone
    // the owned timing pattern carries).
    for &(dim, tag) in dims {
        let n = 16;
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut eng = engine(n, dim, 0);
        let mut k = 0u64;
        report.push(bench_for(
            &format!("dispatch/direct-engine/{tag}/n{n}"),
            budget,
            || {
                eng.step(k, &sched);
                k += 1;
            },
        ));

        let mut rng = Pcg::new(1);
        let mut params = AlgoParams::new(n, rng.gaussian_vec(dim), OptimKind::Sgd);
        params.seed = 0;
        let mut alg: Box<dyn DistributedAlgorithm> =
            Box::new(Sgp::with_topology(TopologyKind::OnePeerExp, &params));
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1f64; n];
        let mut k = 0u64;
        report.push(bench_for(
            &format!("dispatch/boxed-trait/{tag}/n{n}"),
            budget,
            || {
                let ctx = RoundCtx::new(k, &comp, 4 * dim, &link);
                black_box(alg.communicate(&ctx));
                k += 1;
            },
        ));
    }

    section("debias + statistics");
    // Fixed at the lm-924k scale in BOTH modes so fast-mode entries keep
    // their full-run names (the perf gate matches by name).
    let eng = engine(16, 923_904, 0);
    let mut out = vec![0.0f32; 923_904];
    report.push(bench_for("debias_into/lm-924k", budget, || {
        eng.states[0].debias_into(&mut out);
        black_box(&out);
    }));
    report.push(bench_for("consensus_distance/lm-924k/n16", budget, || {
        black_box(eng.consensus_distance());
    }));
    report.push(bench_for("total_mass/lm-924k/n16", budget, || {
        black_box(eng.total_mass());
    }));

    section("execution engine: sequential vs pool-sharded step scaling");
    // The engine scaling curve (ISSUE 3/5 acceptance): one full gossip
    // step at large N, sequential baseline vs the persistent-pool engine
    // at several shard counts — N ≥ 1024 is where the pool must deliver
    // ≥ 2× over the old per-round-spawn design. Results are bit-identical
    // by construction (the engine-equivalence suite verifies it); this
    // curve records how much wall-clock the pool buys on this machine.
    // Written separately to results/BENCH_engine.json so the perf gate
    // can track the speedup.
    let mut engine_report = JsonReport::new();
    let engine_budget = if fast {
        Duration::from_millis(500)
    } else {
        Duration::from_secs(2)
    };
    let engine_ns: &[usize] =
        if fast { &[256, 1024] } else { &[64, 256, 1024, 2048] };
    for &n in engine_ns {
        let dim = 22_026; // MLP-scale parameters per node
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for shards in [1usize, 2, 4, 8] {
            let exec = ExecPolicy::parallel(shards);
            let mut eng = engine(n, dim, 0);
            let mut k = 0u64;
            engine_report.push(bench_for(
                &format!("engine_step/mlp-22k/n{n}/shards{shards}"),
                engine_budget,
                || {
                    eng.step_exec(k, &sched, None, exec);
                    k += 1;
                },
            ));
        }
    }
    let engine_path = std::env::var("BENCH_ENGINE_JSON")
        .unwrap_or_else(|_| "results/BENCH_engine.json".to_string());
    let engine_path = std::path::PathBuf::from(engine_path);
    match engine_report.write(&engine_path) {
        Ok(()) => println!("\nwrote {}", engine_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", engine_path.display()),
    }

    section("compression: encode cost + wire bytes per scheme (n=16)");
    // The compression scaling curve (ISSUE 4 acceptance): one full gossip
    // step per scheme at each parameter scale, with the per-iteration
    // wire bytes attached so the curve pairs CPU cost against byte
    // reduction (compression trades a little encode CPU for a lot of
    // simulated bandwidth). Written to results/BENCH_compress.json.
    let mut compress_report = JsonReport::new();
    let compress_budget = if fast {
        Duration::from_millis(250)
    } else {
        Duration::from_secs(1)
    };
    for &(dim, tag) in dims {
        let n = 16;
        let full_bytes = 4 * dim;
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for spec in [
            Compression::Identity,
            Compression::TopK { den: 16 },
            Compression::Qsgd { bits: 4 },
        ] {
            let mut eng = engine(n, dim, 0);
            let mut k = 0u64;
            let stats = bench_for(
                &format!("compress_step/{}/{tag}/n{n}", spec.label().replace(':', "")),
                compress_budget,
                || {
                    eng.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
                    k += 1;
                },
            );
            // n messages per step, each at the encoded size.
            let wire = n as u64 * spec.encoded_bytes(dim, full_bytes) as u64;
            compress_report.push(stats.with_bytes(wire));
        }
    }
    let compress_path = std::env::var("BENCH_COMPRESS_JSON")
        .unwrap_or_else(|_| "results/BENCH_compress.json".to_string());
    let compress_path = std::path::PathBuf::from(compress_path);
    match compress_report.write(&compress_path) {
        Ok(()) => println!("\nwrote {}", compress_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", compress_path.display()),
    }

    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "results/BENCH_gossip.json".to_string());
    let path = std::path::PathBuf::from(path);
    match report.write(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}

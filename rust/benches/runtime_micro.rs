//! PJRT runtime microbenchmarks: the per-call cost of the train/eval/gossip
//! artifacts — the L2 compute that dominates each simulated node's
//! iteration, and the runtime overhead around it.

use sgp::benchkit::{bench, bench_for, black_box, section};
use sgp::data::Batch;
use sgp::model;
use sgp::rng::Pcg;
use sgp::runtime::Runtime;
use std::time::Duration;

fn main() {
    let dir = model::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let mut rng = Pcg::new(1);

    section("train_step / eval_step latency per model");
    for mname in ["mlp_small", "lm_tiny", "lm_small"] {
        if rt.manifest.models.get(mname).is_none() {
            continue;
        }
        let init = model::read_init(&rt.dir, &rt.manifest, mname).unwrap();
        let kind = rt.manifest.model_cfg_str(mname, "kind").unwrap().to_string();
        let b = rt.manifest.model_cfg_usize(mname, "batch").unwrap();
        let batch = if kind == "transformer" {
            let seq = rt.manifest.model_cfg_usize(mname, "seq_len").unwrap();
            let vocab = rt.manifest.model_cfg_usize(mname, "vocab").unwrap();
            Batch::Tokens {
                t: (0..b * (seq + 1)).map(|_| rng.below(vocab) as i32).collect(),
                b,
                seq,
            }
        } else {
            let in_dim = rt.manifest.model_cfg_usize(mname, "in_dim").unwrap();
            let classes = rt.manifest.model_cfg_usize(mname, "classes").unwrap();
            Batch::Classif {
                x: rng.gaussian_vec(b * in_dim),
                y: (0..b).map(|_| rng.below(classes) as i32).collect(),
                b,
                in_dim,
            }
        };
        let _ = rt.train_step(mname, &init, &batch).unwrap(); // compile once
        bench_for(
            &format!("runtime/train_step/{mname}"),
            Duration::from_secs(3),
            || {
                black_box(rt.train_step(mname, &init, &batch).unwrap());
            },
        );
        let _ = rt.eval_step(mname, &init, &batch).unwrap();
        bench_for(
            &format!("runtime/eval_step/{mname}"),
            Duration::from_secs(2),
            || {
                black_box(rt.eval_step(mname, &init, &batch).unwrap());
            },
        );
    }

    section("dense-gossip artifact (MXU-tiled Pallas matmul)");
    for n in [16usize, 32] {
        let name = format!("gossip_dense_n{n}");
        if let Ok(meta) = rt.manifest.artifact(&name) {
            let d = meta.d.unwrap();
            let x = rng.gaussian_vec(n * d);
            let w = vec![1.0f32; n];
            let p: Vec<f32> = (0..n * n).map(|_| 1.0 / n as f32).collect();
            let _ = rt.gossip_dense(n, &p, &x, &w).unwrap();
            bench(&format!("runtime/gossip_dense/n{n}xd{d}"), || {
                black_box(rt.gossip_dense(n, &p, &x, &w).unwrap());
            });
        }
    }

    section("executable cache hit");
    bench("runtime/executable_cache_hit", || {
        black_box(rt.executable("train_mlp_small").unwrap());
    });
}

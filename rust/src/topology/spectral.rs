//! Spectral tools for mixing-matrix analysis (Appendix A of the paper).
//!
//! The worst-case averaging error after k gossip iterations is governed by
//! the second-largest **singular value** of the product
//! `P^(k-1:0) = P^(k-1) ⋯ P^(0)`:
//!
//! Σᵢ ‖yᵢ^(k) − ȳ‖² ≤ λ₂(P^(k-1:0)) Σᵢ ‖yᵢ^(0) − ȳ‖².
//!
//! Singular values are computed as the square roots of the eigenvalues of
//! AᵀA via a cyclic Jacobi eigensolver — exact enough (1e-12) for the n ≤ a
//! few hundred matrices in play, with no external linear-algebra crate.

use super::mat::Mat;

/// Eigenvalues of a symmetric matrix via cyclic Jacobi rotations,
/// descending order.
pub fn symmetric_eigenvalues(m: &Mat) -> Vec<f64> {
    let n = m.n;
    let mut a = m.clone();
    for _sweep in 0..100 {
        let mut off = 0.0;
        for r in 0..n {
            for c in r + 1..n {
                off += a.at(r, c) * a.at(r, c);
            }
        }
        if off < 1e-24 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a.at(p, q);
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = a.at(p, p);
                let aqq = a.at(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply Givens rotation J(p,q,θ) on both sides.
                for k in 0..n {
                    let akp = a.at(k, p);
                    let akq = a.at(k, q);
                    *a.at_mut(k, p) = c * akp - s * akq;
                    *a.at_mut(k, q) = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a.at(p, k);
                    let aqk = a.at(q, k);
                    *a.at_mut(p, k) = c * apk - s * aqk;
                    *a.at_mut(q, k) = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| a.at(i, i)).collect();
    eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
    eig
}

/// All singular values of `m`, descending.
pub fn singular_values(m: &Mat) -> Vec<f64> {
    let ata = m.transpose().matmul(m);
    symmetric_eigenvalues(&ata)
        .into_iter()
        .map(|e| e.max(0.0).sqrt())
        .collect()
}

/// The paper's λ₂(P^(k-1:0)): the worst-case contraction factor of the
/// *squared* consensus error, Σ‖yᵢ−ȳ‖² ≤ λ₂·Σ‖yᵢ⁰−ȳ‖². Computed as the
/// squared largest singular value of the deviation-restricted operator
/// `P · (I − (1/n)11ᵀ)` (the mass-preserving direction projected out).
/// With this convention our n=32 numbers land on the paper's quoted
/// 0 / ≈0.6 / ≈0.4 / ≈0.2.
pub fn lambda2(m: &Mat) -> f64 {
    let n = m.n;
    let proj = Mat::from_fn(n, |r, c| {
        (if r == c { 1.0 } else { 0.0 }) - 1.0 / n as f64
    });
    let err_op = m.matmul(&proj);
    let s = singular_values(&err_op)[0];
    s * s
}

/// λ₂ of the product of a schedule's first `k` mixing matrices.
pub fn lambda2_of_product(mats: &[Mat]) -> f64 {
    lambda2(&Mat::product(mats))
}

/// Monte-Carlo estimate of E[λ₂(P^(k-1:0))] for randomized schedules.
pub fn expected_lambda2(
    schedule: &crate::topology::Schedule,
    window: usize,
    trials: usize,
) -> f64 {
    let mut total = 0.0;
    for t in 0..trials {
        let mut sched = schedule.clone();
        sched.seed = schedule.seed.wrapping_add(t as u64 * 7919);
        let mats: Vec<Mat> =
            (0..window as u64).map(|k| sched.mixing_matrix(k)).collect();
        total += lambda2_of_product(&mats);
    }
    total / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Schedule, TopologyKind};

    #[test]
    fn eigenvalues_of_diagonal() {
        let m = Mat::from_fn(3, |r, c| if r == c { (r + 1) as f64 } else { 0.0 });
        let e = symmetric_eigenvalues(&m);
        assert!((e[0] - 3.0).abs() < 1e-10);
        assert!((e[1] - 2.0).abs() < 1e-10);
        assert!((e[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn singular_values_of_scaled_identity() {
        let m = Mat::from_fn(4, |r, c| if r == c { -2.0 } else { 0.0 });
        let s = singular_values(&m);
        assert!(s.iter().all(|v| (v - 2.0).abs() < 1e-10));
    }

    #[test]
    fn lambda2_of_uniform_is_zero() {
        assert!(lambda2(&Mat::uniform(8)) < 1e-10);
    }

    #[test]
    fn lambda2_of_identity_is_one() {
        assert!((lambda2(&Mat::identity(8)) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn exp_graph_cycle_reaches_exact_consensus() {
        // Appendix A: after ⌊log2(n-1)⌋+? iterations of deterministic
        // exponential-graph cycling, λ₂ of the product is exactly 0 — all
        // nodes hold the average. For n = 32 that is 5 iterations.
        let s = Schedule::new(TopologyKind::OnePeerExp, 32);
        let mats: Vec<Mat> = (0..5u64).map(|k| s.mixing_matrix(k)).collect();
        let l2 = lambda2_of_product(&mats);
        assert!(l2 < 1e-9, "λ₂ = {l2}");
    }

    #[test]
    fn exp_graph_partial_cycle_not_converged() {
        let s = Schedule::new(TopologyKind::OnePeerExp, 32);
        let mats: Vec<Mat> = (0..3u64).map(|k| s.mixing_matrix(k)).collect();
        assert!(lambda2_of_product(&mats) > 0.1);
    }

    #[test]
    fn complete_cycling_worse_than_exp_cycling() {
        // Appendix A: for n = 32 after 5 iterations, complete-graph cycling
        // has λ₂ ≈ 0.6 while exponential cycling is at 0.
        let s = Schedule::new(TopologyKind::CompleteCycling, 32);
        let mats: Vec<Mat> = (0..5u64).map(|k| s.mixing_matrix(k)).collect();
        let l2 = lambda2_of_product(&mats);
        assert!(l2 > 0.4 && l2 < 0.8, "λ₂ = {l2}");
    }
}

//! Minimal dense square-matrix type (f64, row-major) for mixing-matrix
//! algebra and spectral analysis. n is small (≤ a few hundred nodes), so a
//! straightforward O(n³) implementation is the right tool.

/// Dense square f64 matrix, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Side length.
    pub n: usize,
    a: Vec<f64>,
}

impl Mat {
    /// The n×n zero matrix.
    pub fn zeros(n: usize) -> Self {
        Self { n, a: vec![0.0; n * n] }
    }

    /// The n×n identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    /// Build an n×n matrix from an entry function `(row, col) → value`.
    pub fn from_fn(n: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(n);
        for r in 0..n {
            for c in 0..n {
                *m.at_mut(r, c) = f(r, c);
            }
        }
        m
    }

    /// Uniform averaging matrix (1/n)·11ᵀ.
    pub fn uniform(n: usize) -> Self {
        Self::from_fn(n, |_, _| 1.0 / n as f64)
    }

    /// Entry (r, c).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.a[r * self.n + c]
    }

    /// Mutable entry (r, c).
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.a[r * self.n + c]
    }

    /// Row r as a slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.a[r * self.n..(r + 1) * self.n]
    }

    /// Matrix product `self · other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for r in 0..n {
            for k in 0..n {
                let v = self.at(r, k);
                if v == 0.0 {
                    continue;
                }
                for c in 0..n {
                    *out.at_mut(r, c) += v * other.at(k, c);
                }
            }
        }
        out
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        (0..self.n)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// The transposed matrix.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.n, |r, c| self.at(c, r))
    }

    /// Column sums (a column-stochastic matrix sums to 1 everywhere).
    pub fn col_sums(&self) -> Vec<f64> {
        (0..self.n)
            .map(|c| (0..self.n).map(|r| self.at(r, c)).sum())
            .collect()
    }

    /// Row sums.
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.n).map(|r| self.row(r).iter().sum()).collect()
    }

    /// Non-negative entries and unit column sums, within `tol`.
    pub fn is_column_stochastic(&self, tol: f64) -> bool {
        self.a.iter().all(|&v| v >= -tol)
            && self.col_sums().iter().all(|s| (s - 1.0).abs() <= tol)
    }

    /// Column- and row-stochastic, within `tol`.
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        self.is_column_stochastic(tol)
            && self.row_sums().iter().all(|s| (s - 1.0).abs() <= tol)
    }

    /// Frobenius distance.
    pub fn dist(&self, other: &Mat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Product P^(k-1) ⋯ P^(0) of a sequence (applied left to right as given).
    pub fn product(mats: &[Mat]) -> Mat {
        assert!(!mats.is_empty());
        let mut acc = mats[0].clone();
        for m in &mats[1..] {
            acc = m.matmul(&acc);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_noop() {
        let m = Mat::from_fn(4, |r, c| (r * 4 + c) as f64);
        assert_eq!(m.matmul(&Mat::identity(4)), m);
        assert_eq!(Mat::identity(4).matmul(&m), m);
    }

    #[test]
    fn matvec_matches_matmul() {
        let m = Mat::from_fn(3, |r, c| (r + 2 * c) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = m.matvec(&x);
        assert_eq!(y, vec![
            m.at(0, 0) - m.at(0, 1) + 2.0 * m.at(0, 2),
            m.at(1, 0) - m.at(1, 1) + 2.0 * m.at(1, 2),
            m.at(2, 0) - m.at(2, 1) + 2.0 * m.at(2, 2),
        ]);
    }

    #[test]
    fn uniform_is_doubly_stochastic_projection() {
        let u = Mat::uniform(5);
        assert!(u.is_doubly_stochastic(1e-12));
        assert!(u.matmul(&u).dist(&u) < 1e-12); // idempotent
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(4, |r, c| (r as f64).sin() + c as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn product_order() {
        // product([A, B]) must equal B·A (P^(1) P^(0)).
        let a = Mat::from_fn(2, |r, c| if r == c { 2.0 } else { 0.0 });
        let mut b = Mat::zeros(2);
        *b.at_mut(0, 1) = 1.0;
        *b.at_mut(1, 0) = 1.0;
        let p = Mat::product(&[a.clone(), b.clone()]);
        assert_eq!(p, b.matmul(&a));
    }
}

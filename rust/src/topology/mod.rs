//! Communication topologies and time-varying mixing-matrix schedules.
//!
//! SGP only requires each column of `P^(k)` to sum to 1 (column-stochastic)
//! and the union graph over any window of `B` iterations to be strongly
//! connected (Assumption 4). Each node chooses its own outgoing mixing
//! weights — here uniform over its out-neighbours (incl. the self-loop),
//! matching Appendix C of the paper.

pub mod mat;
pub mod spectral;

pub use mat::Mat;

use crate::rng::Pcg;

/// The topology families used across the paper's experiments (Appendix A)
/// plus the baselines used for the averaging comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Directed exponential graph, cycling 2^0, 2^1, … hops; each node
    /// sends to exactly ONE peer per iteration (paper's SGP default).
    OnePeerExp,
    /// Same graph, transmitting to TWO consecutive-offset peers/iteration.
    TwoPeerExp,
    /// Fully-connected: every node sends to all others every iteration
    /// (the "dense" topology of Fig. 2; equivalent to exact averaging).
    Complete,
    /// Cycle deterministically through the n-1 edges of the complete graph,
    /// one peer per iteration (Appendix A comparison).
    CompleteCycling,
    /// One peer per iteration sampled uniformly from the exponential-graph
    /// neighbour list (Appendix A "random scheme").
    RandomExp,
    /// One peer per iteration sampled uniformly from ALL other nodes.
    RandomAny,
    /// Static directed ring (worst-case connectivity baseline).
    Ring,
    /// Undirected bipartite exponential pairing (hypercube XOR matching):
    /// the symmetric, doubly-stochastic schedule used by D-PSGD.
    BipartiteExp,
}

/// A time-varying schedule: for node `i` at iteration `k`, which peers does
/// it transmit to? Mixing weights are uniform: `1 / (1 + |out(i,k)|)`.
///
/// ```
/// use sgp::topology::{Schedule, TopologyKind};
///
/// // The paper's default: the directed exponential graph, one peer per
/// // iteration, cycling hop distances 2^0, 2^1, 2^2, … (Fig. A.1).
/// let s = Schedule::new(TopologyKind::OnePeerExp, 8);
/// assert_eq!(s.out_peers(0, 0), vec![1]);
/// assert_eq!(s.out_peers(0, 1), vec![2]);
/// assert_eq!(s.out_peers(0, 2), vec![4]);
/// assert_eq!(s.out_peers(0, 3), vec![1]); // the cycle restarts
/// // Every column of the induced mixing matrix sums to 1 (SGP's only
/// // structural requirement).
/// assert!(s.mixing_matrix(0).is_column_stochastic(1e-12));
/// ```
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Topology family.
    pub kind: TopologyKind,
    /// Number of nodes.
    pub n: usize,
    /// Seed for the randomized kinds (deterministic given seed + k + i).
    pub seed: u64,
}

impl Schedule {
    /// A schedule of the given family with seed 0.
    pub fn new(kind: TopologyKind, n: usize) -> Self {
        Self { kind, n, seed: 0 }
    }

    /// A schedule with an explicit seed (matters for the randomized kinds).
    pub fn with_seed(kind: TopologyKind, n: usize, seed: u64) -> Self {
        Self { kind, n, seed }
    }

    /// Exponential-graph hop offsets: 2^0, 2^1, …, 2^⌊log2(n-1)⌋.
    pub fn exp_offsets(n: usize) -> Vec<usize> {
        (0..Self::exp_offset_count(n)).map(|j| Self::exp_offset(n, j)).collect()
    }

    /// Number of exponential-graph hop offsets for `n` nodes (the number
    /// of powers of two ≤ n−1; 1 for the degenerate n ≤ 1 graph).
    fn exp_offset_count(n: usize) -> usize {
        if n <= 1 {
            1
        } else {
            (usize::BITS - (n - 1).leading_zeros()) as usize
        }
    }

    /// The `j`-th exponential-graph hop offset (2^j), allocation-free.
    fn exp_offset(n: usize, j: usize) -> usize {
        if n <= 1 {
            0
        } else {
            1usize << j
        }
    }

    /// Length of the deterministic cycle (number of distinct phases).
    pub fn cycle_len(&self) -> usize {
        match self.kind {
            TopologyKind::OnePeerExp | TopologyKind::TwoPeerExp => {
                Self::exp_offsets(self.n).len()
            }
            TopologyKind::CompleteCycling => self.n - 1,
            TopologyKind::BipartiteExp => Self::exp_offsets(self.n).len(),
            _ => 1,
        }
    }

    /// Out-neighbours of node `i` at iteration `k` (self-loop NOT included;
    /// every node is implicitly its own in/out-neighbour).
    pub fn out_peers(&self, i: usize, k: u64) -> Vec<usize> {
        let mut out = Vec::new();
        self.out_peers_into(i, k, &mut out);
        out
    }

    /// [`Self::out_peers`] into a caller-owned buffer (cleared first) —
    /// the allocation-free form the gossip hot path calls per node per
    /// round. Exponential-graph offsets are computed arithmetically
    /// (offset j is 2^j), so no offset table is materialized either.
    pub fn out_peers_into(&self, i: usize, k: u64, out: &mut Vec<usize>) {
        out.clear();
        let n = self.n;
        if n <= 1 {
            return;
        }
        match self.kind {
            TopologyKind::OnePeerExp => {
                let c = Self::exp_offset_count(n);
                let h = Self::exp_offset(n, (k as usize) % c);
                out.push((i + h) % n);
            }
            TopologyKind::TwoPeerExp => {
                let c = Self::exp_offset_count(n);
                let a = Self::exp_offset(n, (k as usize) % c);
                let b = Self::exp_offset(n, (k as usize + 1) % c);
                let p1 = (i + a) % n;
                let p2 = (i + b) % n;
                out.push(p1);
                if p2 != p1 {
                    out.push(p2);
                }
            }
            TopologyKind::Complete => out.extend((0..n).filter(|&j| j != i)),
            TopologyKind::CompleteCycling => {
                let h = 1 + (k as usize) % (n - 1);
                out.push((i + h) % n);
            }
            TopologyKind::RandomExp => {
                let c = Self::exp_offset_count(n);
                let mut rng = self.peer_rng(i, k);
                let h = Self::exp_offset(n, rng.below(c));
                out.push((i + h) % n);
            }
            TopologyKind::RandomAny => {
                let mut rng = self.peer_rng(i, k);
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                out.push(j);
            }
            TopologyKind::Ring => out.push((i + 1) % n),
            TopologyKind::BipartiteExp => {
                // Hypercube matching: pair i ↔ i XOR 2^(k mod log2 n).
                // Perfect matching when n is a power of two; nodes whose
                // partner is out of range idle that iteration.
                let c = Self::exp_offset_count(n);
                let h = Self::exp_offset(n, (k as usize) % c);
                let j = i ^ h;
                if j < n && j != i {
                    out.push(j);
                }
            }
        }
    }

    /// Out-neighbours of physical node `i` at iteration `k` when only the
    /// (sorted) `alive` members survive: the schedule re-indexes itself
    /// over the survivor ranks, so the induced mixing stays
    /// column-stochastic over exactly the surviving set — the churn
    /// contract of the fault subsystem (DESIGN.md §Faults). Dead or
    /// unknown nodes send to no-one.
    pub fn out_peers_among(&self, i: usize, k: u64, alive: &[usize]) -> Vec<usize> {
        let mut out = Vec::new();
        self.out_peers_among_into(i, k, alive, &mut out);
        out
    }

    /// [`Self::out_peers_among`] into a caller-owned buffer (cleared
    /// first) — the allocation-free form used by the fault-mode gossip
    /// round and timing recursion.
    pub fn out_peers_among_into(
        &self,
        i: usize,
        k: u64,
        alive: &[usize],
        out: &mut Vec<usize>,
    ) {
        debug_assert!(alive.windows(2).all(|w| w[0] < w[1]), "alive must be sorted");
        if alive.len() == self.n {
            self.out_peers_into(i, k, out);
            return;
        }
        out.clear();
        let Ok(rank) = alive.binary_search(&i) else {
            return;
        };
        if alive.len() <= 1 {
            return;
        }
        let virt = Schedule { kind: self.kind, n: alive.len(), seed: self.seed };
        virt.out_peers_into(rank, k, out);
        for r in out.iter_mut() {
            *r = alive[*r];
        }
    }

    /// [`Self::out_peers_among_into`] against a [`PeerMemo`] that has
    /// already been built for the current membership epoch — O(1) rank
    /// lookup instead of a per-call binary search. Produces byte-identical
    /// output to the unmemoized form (locked by a regression test).
    ///
    /// The caller owns invalidation: call [`PeerMemo::ensure`] whenever the
    /// fault clock reports a membership event (Crash/Rejoin/Leave), then
    /// this method any number of times within the epoch.
    pub fn out_peers_among_memo(
        &self,
        i: usize,
        k: u64,
        memo: &PeerMemo,
        out: &mut Vec<usize>,
    ) {
        debug_assert_eq!(memo.rank_of.len(), self.n, "memo sized for wrong n");
        if memo.alive.len() == self.n {
            self.out_peers_into(i, k, out);
            return;
        }
        out.clear();
        let Some(&rank) = memo.rank_of.get(i) else {
            return;
        };
        if rank < 0 || memo.alive.len() <= 1 {
            return;
        }
        let virt = Schedule { kind: self.kind, n: memo.alive.len(), seed: self.seed };
        virt.out_peers_into(rank as usize, k, out);
        for r in out.iter_mut() {
            *r = memo.alive[*r];
        }
    }

    /// When the mixing at iteration `k` is a unit-shift permutation — every
    /// node sends to exactly one peer at constant offset `h`, i.e.
    /// `out(i, k) = {(i + h) mod n}` for all `i` — returns `Some(h)`.
    ///
    /// Holds for [`TopologyKind::OnePeerExp`] (h = 2^(k mod c)),
    /// [`TopologyKind::Ring`] (h = 1) and [`TopologyKind::CompleteCycling`]
    /// (h = 1 + k mod (n−1)); `None` for every other kind and for n ≤ 1.
    ///
    /// The event engine's cold fast path keys off this: under a unit
    /// permutation every node's out-weight is exactly ½, so a graph of
    /// all-identical (template) states is a bit-exact fixed point and a
    /// quiescent node's in-neighbour can be found arithmetically as
    /// `(i + n − h) mod n` without materializing anything.
    pub fn unit_permutation_shift(&self, k: u64) -> Option<usize> {
        let n = self.n;
        if n <= 1 {
            return None;
        }
        match self.kind {
            TopologyKind::OnePeerExp => {
                let c = Self::exp_offset_count(n);
                Some(Self::exp_offset(n, (k as usize) % c) % n)
            }
            TopologyKind::Ring => Some(1),
            TopologyKind::CompleteCycling => Some(1 + (k as usize) % (n - 1)),
            _ => None,
        }
    }

    /// Column-stochastic mixing matrix over the `alive.len()` survivors
    /// (row/col order = survivor rank order), uniform out-weights with a
    /// self-loop — the fault-mode analogue of [`Self::mixing_matrix`].
    pub fn mixing_matrix_among(&self, k: u64, alive: &[usize]) -> Mat {
        let m = alive.len();
        let mut p = Mat::zeros(m);
        for (ci, &c) in alive.iter().enumerate() {
            let peers = self.out_peers_among(c, k, alive);
            // Resolve peer ranks BEFORE weighting: a peer the survivor set
            // does not know (a schedule round or stale caller naming a
            // permanently-departed node) is skipped and the column
            // re-weighted over the peers that remain — the column must
            // keep summing to 1, never panic mid-sweep.
            let ranks: Vec<usize> = peers
                .iter()
                .filter_map(|r| alive.binary_search(r).ok())
                .collect();
            let w = 1.0 / (1.0 + ranks.len() as f64);
            *p.at_mut(ci, ci) += w;
            for ri in ranks {
                *p.at_mut(ri, ci) += w;
            }
        }
        p
    }

    fn peer_rng(&self, i: usize, k: u64) -> Pcg {
        // Deterministic per (seed, node, iteration) — reproducible runs.
        Pcg::with_stream(self.seed ^ k.wrapping_mul(0x9e37_79b9_7f4a_7c15), i as u64 + 1)
    }

    /// Whether the induced mixing matrix is symmetric (required by D-PSGD).
    pub fn is_symmetric(&self, k: u64) -> bool {
        let n = self.n;
        (0..n).all(|i| {
            self.out_peers(i, k)
                .iter()
                .all(|&j| self.out_peers(j, k).contains(&i))
        })
    }

    /// Column-stochastic mixing matrix `P^(k)` (row r, col c = weight node c
    /// assigns to the message it sends node r), uniform out-weights and a
    /// self-loop, exactly as in Appendix C.
    pub fn mixing_matrix(&self, k: u64) -> Mat {
        let n = self.n;
        let mut p = Mat::zeros(n);
        for c in 0..n {
            let peers = self.out_peers(c, k);
            let w = 1.0 / (1.0 + peers.len() as f64);
            *p.at_mut(c, c) += w;
            for &r in &peers {
                *p.at_mut(r, c) += w;
            }
        }
        p
    }

    /// Doubly-stochastic symmetric matrix for D-PSGD (pairwise averaging on
    /// the bipartite matching; identity rows for idle nodes).
    pub fn symmetric_mixing_matrix(&self, k: u64) -> Mat {
        let n = self.n;
        let mut p = Mat::zeros(n);
        for i in 0..n {
            let peers = self.out_peers(i, k);
            if peers.is_empty() {
                *p.at_mut(i, i) = 1.0;
            } else {
                let w = 1.0 / (1.0 + peers.len() as f64);
                *p.at_mut(i, i) = w;
                for &j in &peers {
                    *p.at_mut(i, j) = w;
                }
            }
        }
        p
    }

    /// Union edge set over a window of `b` iterations starting at `k0` —
    /// used to verify Assumption 4 (B-strong connectivity).
    pub fn union_reachable(&self, k0: u64, b: u64) -> bool {
        let n = self.n;
        let mut adj = vec![vec![false; n]; n];
        for i in 0..n {
            adj[i][i] = true;
        }
        for k in k0..k0 + b {
            for i in 0..n {
                for j in self.out_peers(i, k) {
                    adj[j][i] = true; // edge from sender i to receiver j
                }
            }
        }
        // Floyd–Warshall closure, then check all-pairs reachability.
        for m in 0..n {
            for a in 0..n {
                if adj[a][m] {
                    for b2 in 0..n {
                        if adj[m][b2] {
                            adj[a][b2] = true;
                        }
                    }
                }
            }
        }
        adj.iter().all(|row| row.iter().all(|&x| x))
    }
}

/// Memoized survivor-rank table for [`Schedule::out_peers_among_memo`].
///
/// `out_peers_among_into` re-derives the survivor rank of the sender with a
/// binary search on every call; in sparse/event mode that is one search per
/// *arrival*, not per round, so churny long runs pay it millions of times
/// for a membership set that only changes on Crash/Rejoin/Leave events.
/// The memo pins the `rank_of` table to a membership *epoch* (a counter the
/// caller bumps on every membership event) and rebuilds only when the epoch
/// moves. `rebuilds()` exposes the rebuild count so tests can pin the
/// invalidation contract.
#[derive(Clone, Debug, Default)]
pub struct PeerMemo {
    /// Epoch the table was last built for (`None` = never built).
    epoch: Option<u64>,
    /// Sorted survivor set the table was built from.
    alive: Vec<usize>,
    /// `rank_of[i]` = survivor rank of physical node `i`, or −1 if dead.
    rank_of: Vec<isize>,
    /// Number of table rebuilds (diagnostics / regression tests).
    rebuilds: u64,
}

impl PeerMemo {
    /// An unbuilt memo sized for an `n`-node schedule (`n = 0` defers
    /// sizing to the first [`PeerMemo::ensure`]). The first `ensure` call
    /// builds the table; rebuild allocation only ever happens on a
    /// membership epoch change, keeping the per-arrival path
    /// allocation-free.
    pub fn new(n: usize) -> Self {
        Self {
            epoch: None,
            alive: Vec::with_capacity(n),
            rank_of: vec![-1; n],
            rebuilds: 0,
        }
    }

    /// Rebuild the rank table from `alive` (sorted, over an `n`-node
    /// schedule) iff `epoch` differs from the epoch the table was last
    /// built for. Returns whether a rebuild happened.
    pub fn ensure(&mut self, epoch: u64, alive: &[usize], n: usize) -> bool {
        if self.epoch == Some(epoch) && self.rank_of.len() == n {
            return false;
        }
        debug_assert!(alive.windows(2).all(|w| w[0] < w[1]), "alive must be sorted");
        self.rank_of.clear();
        self.rank_of.resize(n, -1);
        self.alive.clear();
        self.alive.extend_from_slice(alive);
        for (rank, &node) in alive.iter().enumerate() {
            self.rank_of[node] = rank as isize;
        }
        self.epoch = Some(epoch);
        self.rebuilds += 1;
        true
    }

    /// Epoch of the current table (`None` before the first build).
    pub fn epoch(&self) -> Option<u64> {
        self.epoch
    }

    /// How many times the table has been (re)built.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// The survivor set the table was built from.
    pub fn alive(&self) -> &[usize] {
        &self.alive
    }

    /// Whether physical node `i` is in the memoized survivor set.
    pub fn is_alive(&self, i: usize) -> bool {
        self.rank_of.get(i).is_some_and(|&r| r >= 0)
    }
}

/// Hybrid schedule phases from the paper's Table 3: e.g. AllReduce for the
/// first 30 epochs then 1-peer SGP, or 2-peer then 1-peer.
#[derive(Clone, Debug)]
pub struct HybridSchedule {
    /// `(first iteration of phase, schedule)`, in ascending order.
    pub phases: Vec<(u64, Schedule)>,
}

impl HybridSchedule {
    /// A single-phase "hybrid" (plain schedule).
    pub fn single(s: Schedule) -> Self {
        Self { phases: vec![(0, s)] }
    }

    /// Two phases switching at iteration `switch_at`.
    pub fn two_phase(first: Schedule, switch_at: u64, second: Schedule) -> Self {
        Self { phases: vec![(0, first), (switch_at, second)] }
    }

    /// The schedule active at iteration `k`.
    pub fn at(&self, k: u64) -> &Schedule {
        let mut cur = &self.phases[0].1;
        for (start, s) in &self.phases {
            if *start <= k {
                cur = s;
            }
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_offsets_power_of_two() {
        assert_eq!(Schedule::exp_offsets(8), vec![1, 2, 4]);
        assert_eq!(Schedule::exp_offsets(32), vec![1, 2, 4, 8, 16]);
        assert_eq!(Schedule::exp_offsets(5), vec![1, 2, 4]);
        assert_eq!(Schedule::exp_offsets(2), vec![1]);
    }

    #[test]
    fn one_peer_exp_matches_paper_example() {
        // Fig. A.1: node 0's neighbours in an 8-node graph are 1, 2, 4.
        let s = Schedule::new(TopologyKind::OnePeerExp, 8);
        assert_eq!(s.out_peers(0, 0), vec![1]);
        assert_eq!(s.out_peers(0, 1), vec![2]);
        assert_eq!(s.out_peers(0, 2), vec![4]);
        assert_eq!(s.out_peers(0, 3), vec![1]); // cycle restarts
    }

    #[test]
    fn one_peer_send_and_receive_exactly_one() {
        for n in [4usize, 8, 16, 32] {
            let s = Schedule::new(TopologyKind::OnePeerExp, n);
            for k in 0..10u64 {
                let mut recv = vec![0usize; n];
                for i in 0..n {
                    let peers = s.out_peers(i, k);
                    assert_eq!(peers.len(), 1);
                    recv[peers[0]] += 1;
                }
                assert!(recv.iter().all(|&r| r == 1), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn two_peer_send_and_receive_exactly_two() {
        let s = Schedule::new(TopologyKind::TwoPeerExp, 16);
        for k in 0..8u64 {
            let mut recv = vec![0usize; 16];
            for i in 0..16 {
                let peers = s.out_peers(i, k);
                assert_eq!(peers.len(), 2);
                for p in peers {
                    recv[p] += 1;
                }
            }
            assert!(recv.iter().all(|&r| r == 2));
        }
    }

    #[test]
    fn mixing_matrix_column_stochastic() {
        for kind in [
            TopologyKind::OnePeerExp,
            TopologyKind::TwoPeerExp,
            TopologyKind::Complete,
            TopologyKind::CompleteCycling,
            TopologyKind::RandomExp,
            TopologyKind::RandomAny,
            TopologyKind::Ring,
            TopologyKind::BipartiteExp,
        ] {
            let s = Schedule::new(kind, 8);
            for k in 0..6u64 {
                let p = s.mixing_matrix(k);
                for c in 0..8 {
                    let sum: f64 = (0..8).map(|r| p.at(r, c)).sum();
                    assert!((sum - 1.0).abs() < 1e-12, "{kind:?} k={k} c={c}");
                }
            }
        }
    }

    #[test]
    fn one_peer_matrix_entries_are_half() {
        let s = Schedule::new(TopologyKind::OnePeerExp, 8);
        let p = s.mixing_matrix(0);
        for c in 0..8 {
            assert_eq!(p.at(c, c), 0.5);
            assert_eq!(p.at((c + 1) % 8, c), 0.5);
        }
    }

    #[test]
    fn bipartite_is_symmetric_and_doubly_stochastic() {
        let s = Schedule::new(TopologyKind::BipartiteExp, 16);
        for k in 0..6u64 {
            assert!(s.is_symmetric(k));
            let p = s.symmetric_mixing_matrix(k);
            for i in 0..16 {
                let rs: f64 = (0..16).map(|j| p.at(i, j)).sum();
                let cs: f64 = (0..16).map(|j| p.at(j, i)).sum();
                assert!((rs - 1.0).abs() < 1e-12 && (cs - 1.0).abs() < 1e-12);
                for j in 0..16 {
                    assert_eq!(p.at(i, j), p.at(j, i));
                }
            }
        }
    }

    #[test]
    fn directed_exp_is_not_symmetric() {
        let s = Schedule::new(TopologyKind::OnePeerExp, 8);
        assert!(!s.is_symmetric(0));
    }

    #[test]
    fn union_strongly_connected_within_cycle() {
        for kind in [TopologyKind::OnePeerExp, TopologyKind::Ring] {
            let s = Schedule::new(kind, 8);
            let b = match kind {
                TopologyKind::Ring => 8,
                _ => s.cycle_len() as u64,
            };
            assert!(s.union_reachable(0, b), "{kind:?}");
        }
    }

    #[test]
    fn hybrid_switches_at_boundary() {
        let h = HybridSchedule::two_phase(
            Schedule::new(TopologyKind::Complete, 8),
            100,
            Schedule::new(TopologyKind::OnePeerExp, 8),
        );
        assert_eq!(h.at(0).kind, TopologyKind::Complete);
        assert_eq!(h.at(99).kind, TopologyKind::Complete);
        assert_eq!(h.at(100).kind, TopologyKind::OnePeerExp);
        assert_eq!(h.at(1_000_000).kind, TopologyKind::OnePeerExp);
    }

    #[test]
    fn out_peers_among_full_membership_is_identity() {
        let alive: Vec<usize> = (0..8).collect();
        for kind in [
            TopologyKind::OnePeerExp,
            TopologyKind::TwoPeerExp,
            TopologyKind::BipartiteExp,
            TopologyKind::RandomAny,
        ] {
            let s = Schedule::with_seed(kind, 8, 3);
            for k in 0..6u64 {
                for i in 0..8 {
                    assert_eq!(s.out_peers_among(i, k, &alive), s.out_peers(i, k));
                }
            }
        }
    }

    #[test]
    fn out_peers_among_reindexes_over_survivors() {
        let s = Schedule::new(TopologyKind::OnePeerExp, 8);
        let alive = vec![0, 1, 2, 4, 6, 7]; // 3 and 5 are down
        for k in 0..12u64 {
            let mut recv = vec![0usize; 8];
            for &i in &alive {
                let peers = s.out_peers_among(i, k, &alive);
                assert_eq!(peers.len(), 1, "k={k} i={i}");
                assert!(alive.contains(&peers[0]), "sent to a dead node");
                assert_ne!(peers[0], i);
                recv[peers[0]] += 1;
            }
            // Dead nodes send to no-one; survivors each receive exactly one.
            assert!(s.out_peers_among(3, k, &alive).is_empty());
            assert!(s.out_peers_among(5, k, &alive).is_empty());
            for &i in &alive {
                assert_eq!(recv[i], 1, "k={k}");
            }
            assert_eq!(recv[3] + recv[5], 0);
        }
    }

    #[test]
    fn mixing_matrix_among_column_stochastic_under_churn() {
        for kind in [
            TopologyKind::OnePeerExp,
            TopologyKind::TwoPeerExp,
            TopologyKind::CompleteCycling,
            TopologyKind::BipartiteExp,
            TopologyKind::Ring,
        ] {
            let s = Schedule::new(kind, 16);
            for alive in [
                (0..16).filter(|i| i % 3 != 0).collect::<Vec<_>>(),
                vec![1, 5, 9],
                (0..16).collect(),
            ] {
                for k in 0..8u64 {
                    let p = s.mixing_matrix_among(k, &alive);
                    for c in 0..alive.len() {
                        let sum: f64 = (0..alive.len()).map(|r| p.at(r, c)).sum();
                        assert!(
                            (sum - 1.0).abs() < 1e-12,
                            "{kind:?} k={k} col {c} sums to {sum}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_survivor_idles() {
        let s = Schedule::new(TopologyKind::OnePeerExp, 8);
        assert!(s.out_peers_among(2, 0, &[2]).is_empty());
    }

    #[test]
    fn unit_permutation_shift_matches_out_peers() {
        for kind in [
            TopologyKind::OnePeerExp,
            TopologyKind::Ring,
            TopologyKind::CompleteCycling,
        ] {
            for n in [2usize, 3, 5, 8, 16] {
                let s = Schedule::new(kind, n);
                for k in 0..12u64 {
                    let h = s
                        .unit_permutation_shift(k)
                        .expect("permutation kinds always report a shift");
                    for i in 0..n {
                        assert_eq!(
                            s.out_peers(i, k),
                            vec![(i + h) % n],
                            "{kind:?} n={n} k={k} i={i}"
                        );
                    }
                }
            }
        }
        for kind in [
            TopologyKind::TwoPeerExp,
            TopologyKind::Complete,
            TopologyKind::RandomExp,
            TopologyKind::RandomAny,
            TopologyKind::BipartiteExp,
        ] {
            let s = Schedule::new(kind, 8);
            assert_eq!(s.unit_permutation_shift(0), None, "{kind:?}");
        }
        assert_eq!(Schedule::new(TopologyKind::Ring, 1).unit_permutation_shift(0), None);
    }

    #[test]
    fn memoized_peers_match_unmemoized() {
        for kind in [
            TopologyKind::OnePeerExp,
            TopologyKind::TwoPeerExp,
            TopologyKind::CompleteCycling,
            TopologyKind::BipartiteExp,
            TopologyKind::RandomAny,
        ] {
            let s = Schedule::with_seed(kind, 16, 7);
            for alive in [
                (0..16).collect::<Vec<_>>(),
                (0..16).filter(|i| i % 3 != 0).collect(),
                vec![2, 9],
                vec![5],
            ] {
                let mut memo = PeerMemo::new(16);
                assert!(memo.ensure(0, &alive, 16));
                assert!(
                    !memo.ensure(0, &alive, 16),
                    "same epoch must not rebuild"
                );
                let (mut a, mut b) = (Vec::new(), Vec::new());
                for k in 0..10u64 {
                    for i in 0..16 {
                        s.out_peers_among_into(i, k, &alive, &mut a);
                        s.out_peers_among_memo(i, k, &memo, &mut b);
                        assert_eq!(a, b, "{kind:?} k={k} i={i} alive={alive:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn memo_invalidates_on_leave_and_rejoin_events() {
        use crate::faults::FaultClock;
        use crate::faults::FaultPlan;
        let n = 8usize;
        // Node 2 crashes at k=3 and rejoins at k=6; node 5 leaves for good
        // at k=4. Each membership event must trigger exactly one rebuild.
        let clock = FaultClock::new(
            FaultPlan::lossless()
                .with_crash(2, 3, Some(6))
                .with_crash(5, 4, None),
        );
        let s = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut memo = PeerMemo::new(n);
        let mut epoch = 0u64;
        let mut alive = Vec::new();
        let (mut want, mut got) = (Vec::new(), Vec::new());
        for k in 0..10u64 {
            if clock.membership_changed_at(k) {
                epoch += 1;
            }
            clock.alive_into(n, k, &mut alive);
            let rebuilt = memo.ensure(epoch, &alive, n);
            // The memo rebuilds exactly when membership changed (after the
            // initial build at k=0).
            assert_eq!(
                rebuilt,
                k == 0 || clock.membership_changed_at(k),
                "k={k}"
            );
            assert_eq!(memo.alive(), &alive[..]);
            for i in 0..n {
                s.out_peers_among_into(i, k, &alive, &mut want);
                s.out_peers_among_memo(i, k, &memo, &mut got);
                assert_eq!(want, got, "k={k} i={i}");
                assert_eq!(memo.is_alive(i), alive.contains(&i));
            }
        }
        // Initial build + crash@3 + leave@4 + rejoin@6.
        assert_eq!(memo.rebuilds(), 4);
    }

    #[test]
    fn random_peers_deterministic_given_seed() {
        let s = Schedule::with_seed(TopologyKind::RandomAny, 16, 99);
        let a: Vec<_> = (0..20).map(|k| s.out_peers(3, k)).collect();
        let b: Vec<_> = (0..20).map(|k| s.out_peers(3, k)).collect();
        assert_eq!(a, b);
    }
}

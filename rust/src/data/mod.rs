//! Synthetic per-node data shards with controllable heterogeneity.
//!
//! The paper's problem (1) has node-local distributions `D_i`; Assumption 2
//! quantifies their dissimilarity with ζ². These generators expose a
//! `heterogeneity ∈ [0, 1]` knob: 0 makes all nodes i.i.d. (ζ² ≈ 0), 1
//! makes every node's shard strongly skewed toward its own classes /
//! transition structure.
//!
//! * [`Blobs`] — Gaussian mixture classification (the ImageNet/ResNet
//!   analogue for the Table 1–5 sweeps).
//! * [`BigramLm`] — a Zipf-weighted Markov bigram language source (the
//!   WMT/Transformer analogue for Fig. 3): genuinely learnable structure
//!   for next-token prediction.
//!
//! Batches are deterministic functions of `(seed, node, step)` so every
//! experiment replays exactly.

use crate::rng::Pcg;

/// One batch, matching the artifact input layouts from `manifest.json`.
#[derive(Clone, Debug)]
pub enum Batch {
    /// Classification batch: x is f32\[b, in_dim\] row-major; y is i32\[b\].
    Classif {
        /// Features, row-major `[b, in_dim]`.
        x: Vec<f32>,
        /// Class labels, `[b]`.
        y: Vec<i32>,
        /// Batch size.
        b: usize,
        /// Feature dimension.
        in_dim: usize,
    },
    /// LM batch: tokens are i32\[b, seq+1\] row-major (inputs = \[:, :-1\],
    /// targets = \[:, 1:\]).
    Tokens {
        /// Token ids, row-major `[b, seq + 1]`.
        t: Vec<i32>,
        /// Batch size.
        b: usize,
        /// Sequence length (inputs per row).
        seq: usize,
    },
}

/// Gaussian-blobs classification source.
#[derive(Clone, Debug)]
pub struct Blobs {
    /// Feature dimension.
    pub in_dim: usize,
    /// Number of classes in the global mixture.
    pub classes: usize,
    /// Samples per batch.
    pub batch: usize,
    /// Number of node shards.
    pub n_nodes: usize,
    /// 0 = iid shards, 1 = each node sees (almost) only its own classes.
    pub heterogeneity: f64,
    /// Gaussian noise scale around the class means.
    pub noise: f32,
    seed: u64,
    /// Class means, fixed by the global seed.
    means: Vec<Vec<f32>>,
}

impl Blobs {
    /// A blobs source with class means fixed by `seed`.
    pub fn new(
        in_dim: usize,
        classes: usize,
        batch: usize,
        n_nodes: usize,
        heterogeneity: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg::with_stream(seed, 0xb10b);
        let means = (0..classes)
            .map(|_| {
                let v = rng.gaussian_vec(in_dim);
                let norm: f32 =
                    v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                // Means on a radius-3 sphere: clearly separable but noisy.
                v.iter().map(|x| 3.0 * x / norm).collect()
            })
            .collect();
        Self { in_dim, classes, batch, n_nodes, heterogeneity, noise: 1.0, seed, means }
    }

    fn class_weights(&self, node: usize) -> Vec<f64> {
        // Node i prefers classes c with c ≡ i (mod n): weight 1−h for the
        // uniform component + h·classes for "its" classes.
        (0..self.classes)
            .map(|c| {
                let own = c % self.n_nodes == node % self.n_nodes;
                (1.0 - self.heterogeneity)
                    + if own { self.heterogeneity * self.n_nodes as f64 } else { 0.0 }
            })
            .collect()
    }

    fn sample(&self, weights: &[f64], rng: &mut Pcg) -> (Vec<f32>, i32) {
        let c = rng.categorical(weights);
        let x = self.means[c]
            .iter()
            .map(|m| m + self.noise * rng.gaussian() as f32)
            .collect();
        (x, c as i32)
    }

    /// Training batch for `node` at `step` (deterministic).
    pub fn train_batch(&self, node: usize, step: u64) -> Batch {
        let mut rng = Pcg::with_stream(
            self.seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            node as u64 + 1,
        );
        let w = self.class_weights(node);
        let mut x = Vec::with_capacity(self.batch * self.in_dim);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let (xi, yi) = self.sample(&w, &mut rng);
            x.extend(xi);
            y.push(yi);
        }
        Batch::Classif { x, y, b: self.batch, in_dim: self.in_dim }
    }

    /// Validation batches drawn from the *global* (uniform-class) mixture.
    pub fn val_batches(&self, count: usize) -> Vec<Batch> {
        let w = vec![1.0; self.classes];
        (0..count)
            .map(|i| {
                let mut rng = Pcg::with_stream(self.seed ^ 0x7a1, i as u64 + 1);
                let mut x = Vec::with_capacity(self.batch * self.in_dim);
                let mut y = Vec::with_capacity(self.batch);
                for _ in 0..self.batch {
                    let (xi, yi) = self.sample(&w, &mut rng);
                    x.extend(xi);
                    y.push(yi);
                }
                Batch::Classif { x, y, b: self.batch, in_dim: self.in_dim }
            })
            .collect()
    }
}

/// Zipf-weighted Markov bigram language source.
#[derive(Clone, Debug)]
pub struct BigramLm {
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length (tokens per row, excluding the shifted target).
    pub seq: usize,
    /// Rows per batch.
    pub batch: usize,
    /// Number of node shards.
    pub n_nodes: usize,
    /// 0 = one shared chain, 1 = every node speaks its own dialect.
    pub heterogeneity: f64,
    seed: u64,
    /// Global cumulative transition rows [vocab × vocab].
    cum: Vec<f64>,
}

impl BigramLm {
    /// A bigram source whose chain structure is fixed by `seed`.
    pub fn new(
        vocab: usize,
        seq: usize,
        batch: usize,
        n_nodes: usize,
        heterogeneity: f64,
        seed: u64,
    ) -> Self {
        // Transition structure: from token v, mass concentrates on a few
        // successors at deterministic offsets (Zipf decay) — a low-entropy,
        // learnable chain.
        let mut cum = vec![0.0f64; vocab * vocab];
        for v in 0..vocab {
            let mut acc = 0.0;
            for w in 0..vocab {
                // Rank of w among v's successors.
                let rank = (w + vocab - (v * 7 + 1) % vocab) % vocab;
                let p = 1.0 / (1.0 + rank as f64).powf(1.5);
                acc += p;
                cum[v * vocab + w] = acc;
            }
            let total = acc;
            for w in 0..vocab {
                cum[v * vocab + w] /= total;
            }
        }
        Self { vocab, seq, batch, n_nodes, heterogeneity, seed, cum }
    }

    fn next_token(&self, prev: usize, node_shift: usize, rng: &mut Pcg) -> usize {
        // With prob h, the node's dialect shifts the successor pattern.
        let row = if self.heterogeneity > 0.0 && rng.f64() < self.heterogeneity {
            (prev + node_shift) % self.vocab
        } else {
            prev
        };
        let u = rng.f64();
        let base = row * self.vocab;
        pick_token(&self.cum[base..base + self.vocab], u)
    }

    fn gen_batch(&self, node_shift: usize, rng: &mut Pcg) -> Batch {
        let cols = self.seq + 1;
        let mut t = Vec::with_capacity(self.batch * cols);
        for _ in 0..self.batch {
            let mut tok = rng.below(self.vocab);
            t.push(tok as i32);
            for _ in 0..self.seq {
                tok = self.next_token(tok, node_shift, rng);
                t.push(tok as i32);
            }
        }
        Batch::Tokens { t, b: self.batch, seq: self.seq }
    }

    /// Training batch for `node` at `step` (deterministic).
    pub fn train_batch(&self, node: usize, step: u64) -> Batch {
        let mut rng = Pcg::with_stream(
            self.seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            node as u64 + 1,
        );
        let shift = 1 + node * 13 % self.vocab.max(1);
        self.gen_batch(shift, &mut rng)
    }

    /// Validation batches from the global (dialect-free) chain.
    pub fn val_batches(&self, count: usize) -> Vec<Batch> {
        (0..count)
            .map(|i| {
                let mut rng =
                    Pcg::with_stream(self.seed ^ 0x1a57, i as u64 + 1);
                self.gen_batch(0, &mut rng)
            })
            .collect()
    }
}

/// The token a uniform draw `u ∈ [0, 1)` selects from a nondecreasing
/// cumulative row: the smallest index whose cumulative mass **strictly
/// exceeds** `u`. Token `i`'s probability mass is `[cum[i-1], cum[i])`, so
/// an exact hit `u == cum[i]` belongs to token `i + 1` — the boundary the
/// old `binary_search(…).unwrap()` implementation got wrong (and panicked
/// on NaN for). `partition_point` never panics: an unordered (NaN)
/// comparison simply reads as "not ≤ u" and the final clamp keeps the
/// index in range on degenerate rows.
fn pick_token(cum_row: &[f64], u: f64) -> usize {
    let i = cum_row.partition_point(|&p| p <= u);
    i.min(cum_row.len().saturating_sub(1))
}

/// Unified source used by the trainer.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// Gaussian-blobs classification (ImageNet analogue).
    Blobs(Blobs),
    /// Bigram LM (NMT analogue).
    Lm(BigramLm),
}

impl DataSource {
    /// Training batch for `node` at `step` (deterministic).
    pub fn train_batch(&self, node: usize, step: u64) -> Batch {
        match self {
            DataSource::Blobs(b) => b.train_batch(node, step),
            DataSource::Lm(l) => l.train_batch(node, step),
        }
    }

    /// Shared validation batches (drawn from the global distribution).
    pub fn val_batches(&self, count: usize) -> Vec<Batch> {
        match self {
            DataSource::Blobs(b) => b.val_batches(count),
            DataSource::Lm(l) => l.val_batches(count),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(h: f64) -> Blobs {
        Blobs::new(8, 10, 64, 4, h, 42)
    }

    #[test]
    fn batches_are_deterministic() {
        let b = blobs(0.5);
        let b1 = b.train_batch(2, 17);
        let b2 = b.train_batch(2, 17);
        match (b1, b2) {
            (Batch::Classif { x: x1, y: y1, .. }, Batch::Classif { x: x2, y: y2, .. }) => {
                assert_eq!(x1, x2);
                assert_eq!(y1, y2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn different_nodes_get_different_batches() {
        let b = blobs(0.0);
        let (b1, b2) = (b.train_batch(0, 0), b.train_batch(1, 0));
        match (b1, b2) {
            (Batch::Classif { x: x1, .. }, Batch::Classif { x: x2, .. }) => {
                assert_ne!(x1, x2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn heterogeneity_skews_class_histogram() {
        let b = blobs(1.0);
        let mut counts = vec![0usize; 10];
        for step in 0..50 {
            if let Batch::Classif { y, .. } = b.train_batch(0, step) {
                for yi in y {
                    counts[yi as usize] += 1;
                }
            }
        }
        // Node 0 of 4 prefers classes {0, 4, 8}.
        let own: usize = [0usize, 4, 8].iter().map(|&c| counts[c]).sum();
        let total: usize = counts.iter().sum();
        assert!(own as f64 / total as f64 > 0.7, "{counts:?}");
    }

    #[test]
    fn zero_heterogeneity_is_roughly_uniform() {
        let b = blobs(0.0);
        let mut counts = vec![0usize; 10];
        for step in 0..100 {
            if let Batch::Classif { y, .. } = b.train_batch(1, step) {
                for yi in y {
                    counts[yi as usize] += 1;
                }
            }
        }
        let total: usize = counts.iter().sum();
        for c in counts {
            let f = c as f64 / total as f64;
            assert!((f - 0.1).abs() < 0.04, "{f}");
        }
    }

    #[test]
    fn blob_shapes_match_manifest_layout() {
        let b = blobs(0.0);
        if let Batch::Classif { x, y, b: bs, in_dim } = b.train_batch(0, 0) {
            assert_eq!(x.len(), bs * in_dim);
            assert_eq!(y.len(), bs);
            assert!(y.iter().all(|&c| (0..10).contains(&c)));
        } else {
            panic!();
        }
    }

    #[test]
    fn lm_tokens_in_range_and_shaped() {
        let l = BigramLm::new(128, 16, 4, 8, 0.3, 7);
        if let Batch::Tokens { t, b, seq } = l.train_batch(3, 5) {
            assert_eq!(t.len(), b * (seq + 1));
            assert!(t.iter().all(|&v| (0..128).contains(&v)));
        } else {
            panic!();
        }
    }

    #[test]
    fn lm_chain_has_low_entropy_structure() {
        // The most likely successor should dominate: verify the chain is
        // actually predictable (a transformer can learn it).
        let l = BigramLm::new(64, 64, 8, 4, 0.0, 3);
        let mut follow = vec![0usize; 64];
        let mut total = 0usize;
        for step in 0..40 {
            if let Batch::Tokens { t, b, seq } = l.train_batch(0, step) {
                for r in 0..b {
                    for c in 0..seq {
                        let prev = t[r * (seq + 1) + c] as usize;
                        let next = t[r * (seq + 1) + c + 1] as usize;
                        let rank = (next + 64 - (prev * 7 + 1) % 64) % 64;
                        if rank == 0 {
                            follow[prev] += 1;
                        }
                        total += 1;
                    }
                }
            }
        }
        let top: usize = follow.iter().sum();
        assert!(top as f64 / total as f64 > 0.25, "{top}/{total}");
    }

    #[test]
    fn pick_token_boundary_and_degenerate_rows() {
        let cum = [0.25, 0.5, 0.75, 1.0];
        // An exact CDF hit belongs to the NEXT token: u ∈ [0, 0.25) is
        // token 0, so u == 0.25 is the first draw of token 1's mass.
        assert_eq!(pick_token(&cum, 0.25), 1);
        assert_eq!(pick_token(&cum, 0.5), 2);
        assert_eq!(pick_token(&cum, 0.75), 3);
        // Interior draws pick the bracketing token.
        assert_eq!(pick_token(&cum, 0.0), 0);
        assert_eq!(pick_token(&cum, 0.24), 0);
        assert_eq!(pick_token(&cum, 0.26), 1);
        assert_eq!(pick_token(&cum, 0.999), 3);
        // Agreement with the linear-scan definition on a fine grid.
        for step in 0..1000 {
            let u = step as f64 / 1000.0;
            let linear = cum.iter().position(|&p| p > u).unwrap_or(cum.len() - 1);
            assert_eq!(pick_token(&cum, u), linear, "u={u}");
        }
        // NaN / degenerate rows never panic and stay in range.
        assert_eq!(pick_token(&[f64::NAN; 4], 0.3), 0);
        assert_eq!(pick_token(&[0.5, f64::NAN, f64::NAN, 1.0], 0.9), 1);
        assert_eq!(pick_token(&[1.0], 0.7), 0);
    }

    #[test]
    fn lm_sampled_distribution_matches_transition_row() {
        // Regression pin for the sampler: empirical successor frequencies
        // of one source token must match the cumulative row's implied
        // probabilities (the old exact-hit bug systematically shifted
        // boundary mass to the wrong token).
        let l = BigramLm::new(16, 1, 1, 1, 0.0, 9);
        let src = 3usize;
        let probs: Vec<f64> = (0..16)
            .map(|w| {
                let hi = l.cum[src * 16 + w];
                let lo = if w == 0 { 0.0 } else { l.cum[src * 16 + w - 1] };
                hi - lo
            })
            .collect();
        let mut counts = vec![0usize; 16];
        let mut rng = Pcg::new(123);
        let trials = 60_000;
        for _ in 0..trials {
            counts[l.next_token(src, 0, &mut rng)] += 1;
        }
        for (w, (&c, &p)) in counts.iter().zip(&probs).enumerate() {
            let f = c as f64 / trials as f64;
            assert!(
                (f - p).abs() < 0.01,
                "successor {w}: empirical {f:.4} vs row {p:.4}"
            );
        }
    }

    #[test]
    fn val_batches_identical_across_calls() {
        let l = BigramLm::new(32, 8, 2, 4, 0.5, 11);
        let a = l.val_batches(3);
        let b = l.val_batches(3);
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Batch::Tokens { t: t1, .. }, Batch::Tokens { t: t2, .. }) => {
                    assert_eq!(t1, t2)
                }
                _ => panic!(),
            }
        }
    }
}

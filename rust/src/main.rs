//! `repro` — CLI launcher for the SGP reproduction.
//!
//! Subcommands:
//!   train        one training run (model × algorithm × cluster)
//!   bench <exp>  regenerate a paper table/figure (all, fig1, table1..5, …)
//!   faults       robustness sweep under message loss / churn (offline)
//!   engine-sweep large-N scaling sweep of the parallel execution engine
//!   scale-sweep  event-engine scaling sweep to ~10^6 nodes (wall + peak RSS)
//!   compress-sweep compressed-gossip sweep: byte reduction × heterogeneity
//!   soak         durable checkpoint → restore → elastic-join soak (offline)
//!   bench-check  CI perf gate: fresh BENCH_*.json vs committed baselines
//!   coord        deployment coordinator: register workers, track liveness
//!   worker       deployment gossip worker (connects to a coordinator)
//!   trace        analyze a JSONL observability trace (any source)
//!   audit        static determinism/unsafety lint over the repo's own source
//!   algos        list the registered distributed algorithms
//!   spectral     Appendix-A λ₂ analysis (no artifacts needed)
//!   average      PushSum averaging demo through the Pallas dense-gossip HLO
//!   convergence  Theorem 1/2 sanity demo (pure Rust)
//!   inspect      print the artifact manifest

use anyhow::{bail, Context, Result};

use sgp::algorithms;
use sgp::benchgate;
use sgp::cli::Args;
use sgp::config::{Fabric, TrainConfig};
use sgp::coordinator::TrainerBuilder;
use sgp::experiments;
use sgp::faults::Crash;
use sgp::gossip::{Compression, ExecPolicy};
use sgp::net::cluster::{coord, worker, HeartbeatPolicy};
use sgp::metrics;
use sgp::optim::OptimKind;
use sgp::runtime::Runtime;

const USAGE: &str = "\
repro — Stochastic Gradient Push (ICML 2019) reproduction

USAGE:
  repro train   [--model mlp_small] [--algo <name>] [--nodes 8]
                [--epochs 10] [--steps-per-epoch 16] [--fabric ethernet|ib]
                [--tau 1] [--grad-delay 1] [--seed 0] [--adam]
                [--heterogeneity 0.3] [--engine sequential|parallel|event]
                [--shards K] [--compress none|topk:D|qsgd:B]
                (see `repro algos` for the registered algorithm names;
                --engine parallel shards the gossip round across K workers,
                --engine event drives aggregation off a priority queue of
                message arrivals — both bit-identical to sequential at the
                same seed;
                --compress encodes gossip messages — top 1-in-D coords or
                B-bit quantized — with per-edge error feedback, and the
                timing charges the actual encoded bytes)
  repro bench   <all|fig1|table1|table2|table3|table4|table5|fig2|fig3|
                 figd3|figd4|appendix-a> [--fast]
  repro faults  [--drop 0..0.2 | --drop 0,0.05,0.1] [--crash 3@40:80,5@60]
                [--nodes 16] [--iters 200] [--algos ar-sgd,sgp,...]
                [--seed 1] [--no-rescue] [--fast]
                [--engine sequential|parallel|event] [--shards K]
                [--compress none|topk:D|qsgd:B]
                offline robustness sweep: final error / consensus / makespan
                per algorithm × fault level. --crash uses node@iter[:rejoin]
                (no :rejoin = permanent leave). Rescue (senders re-absorb
                undelivered push-sum mass) is on by default; --no-rescue
                surfaces the naive-loss instability (DESIGN.md §Faults).
                Writes results/faults_sweep.csv.
  repro engine-sweep [--max-n 4096] [--dim 1024] [--steps 50]
                [--shards 2,4,8] [--threads 0,2,4] [--seed 1] [--fast]
                large-N scaling sweep of the gossip execution engine:
                sequential vs pool-sharded wall-clock plus a bit-identity
                check. --threads sweeps the worker-pool size (0 = the
                machine default). Writes results/engine_sweep.csv.
  repro scale-sweep [--max-n 1048576] [--dim 64] [--steps 64] [--active 64]
                [--dense-cap 4096] [--seed 1] [--fast]
                event-engine scaling sweep: wall-clock and peak-RSS curves
                as the node count grows to ~10^6, for the sparse engine's
                quiescent (all-cold) and active (perturbed hot set) modes
                plus a dense reference at small N. The quiescent curve
                asserts zero materialization — the cold-template fixed
                point checked at full scale. Writes
                results/BENCH_event.json (outside the bench-check gate:
                absolute wall-clock at 10^6 nodes is machine-bound).
  repro bench-check [--results results] [--baselines benchmarks/baselines]
                [--tol 0.25] [--update]
                CI perf-regression gate: diff fresh results/BENCH_*.json
                against committed baselines, failing on a >tol throughput
                regression of any tracked entry; --update records the
                fresh numbers as the new baselines.
  repro compress-sweep [--schemes topk:4,topk:16,qsgd:8,qsgd:4]
                [--het 0.25,0.5,0.75] [--nodes 32] [--iters 300]
                [--dim 256] [--seed 1] [--shards 1,2,7] [--fast]
                compressed-gossip sweep: wire-byte reduction × gradient
                heterogeneity for SGP vs the dense baseline, with a
                cross-shard bit-identity check. Writes
                results/compress_sweep.csv.
  repro soak    [--nodes 16] [--dim 64] [--iters 120] [--drop 0.02]
                [--seed 11] [--engine sequential|parallel|event] [--shards K]
                [--compress none|topk:D|qsgd:B] [--trace PATH]
                [--checkpoint-dir DIR] [--fast]
                durable-checkpoint soak: twin push-sum engines run the same
                lossy, crash-afflicted schedule; the subject engine is
                checkpointed to disk on the snapshot-policy cadence, torn
                down, restored from the file, and must continue
                bit-identically before a brand-new rank joins mid-run via
                the mass-conserving φ-split. Audits Σw = n₀ to 1e-9 every
                round; writes a \"soak\" JSONL trace (re-audited by `repro
                trace`) and leaves the snapshot files under
                --checkpoint-dir (default results/soak_ckpt).
  repro coord   --world N [--bind 127.0.0.1:0] [--rounds 400]
                [--cooldown rounds/4] [--dim 32] [--seed 1] [--lr 0.05]
                [--compress none|topk:D|qsgd:B] [--round-ms 2]
                [--round-timeout-ms 250] [--slow-ms 500] [--dead-ms 2000]
                [--deadline-s 120] [--port-file PATH] [--log PATH]
                [--summary PATH] [--checkpoint-dir DIR] [--verbose]
                deployment coordinator: waits for N `repro worker`
                processes, assigns ranks + the peer table, tracks
                liveness (two thresholds: slow → degraded, silent/EOF →
                leave), broadcasts membership events, and audits the
                final reports (consensus spread + push-sum mass ledger).
                Writes a JSONL sgp-trace membership log and a summary
                JSON, and answers plaintext Prometheus scrapes (`GET
                /metrics`) on its listen port while running.
                --checkpoint-dir writes a JSON run manifest there at start
                (world, seed, scheme, rounds — what a restarted fleet needs
                to resume compatibly) and logs snapshot trace events on
                membership changes. --verbose mirrors the structured
                events to stderr.
  repro worker  --coord HOST:PORT [--bind 127.0.0.1:0] [--hb-ms 50]
                [--io-timeout-ms 5000] [--trace PATH]
                [--checkpoint-dir DIR] [--checkpoint-every K] [--verbose]
                deployment gossip worker: joins the coordinator, then
                runs the push-sum loop over TCP, sending compressed
                shares (the `gossip::Compression` bit-packed encodings)
                to its schedule peers. All config arrives in the
                coordinator's Assign message. --trace writes this
                worker's JSONL sgp-trace (per-peer traffic, ledger).
                --checkpoint-dir persists this worker's (x, w, banks)
                snapshot every K rounds (--checkpoint-every, default 50);
                on startup the worker warm-restores from the latest
                compatible snapshot for its assigned rank, so a restarted
                process rejoins with its pre-crash state instead of the
                cold init.
  repro trace   <FILE>
                analyze a JSONL sgp-trace from any surface (engine, sim,
                coord, worker): per-node summaries, straggler ranking,
                bytes-per-edge matrix, round-latency histogram, and a
                recomputed push-sum mass-ledger reconciliation (exits
                non-zero if the trace disagrees with itself by > 1e-9).
  repro audit   [--deny] [--rule D001|D002|U001|P001|A001] [--json]
                [--root DIR] [--allow PATH]
                static analysis over the repo's own source (rust/src):
                determinism hazards (D001 HashMap/HashSet, D002
                wall-clock), unannotated unsafe (U001), hot-path panics
                (P001), and allocation inside zero-alloc-anchored
                functions (A001), checked against the committed
                allowlist analysis/allow.toml (every pin needs a reason;
                stale pins fail). --deny exits non-zero on any
                violation; --json emits the machine report CI archives.
  repro algos
  repro spectral
  repro average [--nodes 32] [--rounds 8]
  repro convergence [--nodes 16] [--iters 2000] [--trace PATH]
  repro inspect
";

/// Parse `--engine sequential|parallel|event` + `--shards K` into an
/// [`ExecPolicy`]. `--shards K` alone (K > 1) implies the parallel engine;
/// `--engine parallel` without `--shards` sizes itself to the machine.
fn parse_exec(args: &Args) -> Result<ExecPolicy> {
    let shards = args.usize_or("shards", 0)?;
    match args.value_of("engine")? {
        None => Ok(ExecPolicy::parallel(shards)),
        Some(name) => ExecPolicy::parse(name, shards).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown engine `{name}` (expected sequential|parallel|event)"
            )
        }),
    }
}

/// Parse a comma-separated integer-list option (`--shards 1,2,7`);
/// `None` when the option was not given.
fn parse_usize_list(args: &Args, name: &str) -> Result<Option<Vec<usize>>> {
    match args.value_of(name)? {
        None => Ok(None),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .with_context(|| format!("--{name} `{v}`: not an integer"))
            })
            .collect::<Result<Vec<usize>>>()
            .map(Some),
    }
}

/// Parse `--compress none|topk:D|qsgd:B` into a [`Compression`] spec
/// (identity when absent).
fn parse_compress(args: &Args) -> Result<Compression> {
    match args.value_of("compress")? {
        None => Ok(Compression::Identity),
        Some(spec) => Compression::parse(spec).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown compression `{spec}` (expected none, topk:D with D ≥ 1, \
                 or qsgd:B with 2 ≤ B ≤ 16)"
            )
        }),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let model = args.str_or("model", "mlp_small")?;
    let nodes = args.usize_or("nodes", 8)?;
    let mut cfg = TrainConfig::imagenet_like(&model, nodes, args.u64_or("seed", 0)?);
    cfg.epochs = args.f64_or("epochs", 10.0)?;
    cfg.steps_per_epoch = args.u64_or("steps-per-epoch", 16)?;
    cfg.heterogeneity = args.f64_or("heterogeneity", 0.3)?;
    if let Some(f) = args.value_of("fabric")? {
        cfg.link = Fabric::parse(f)
            .ok_or_else(|| anyhow::anyhow!("unknown fabric `{f}`"))?
            .link();
    }
    if args.flag_strict("adam")? {
        cfg.optim = OptimKind::Adam;
        cfg.lr = sgp::optim::LrSchedule::constant(1e-3);
    }
    let algo_name = args.str_or("algo", "sgp")?;
    if algorithms::spec(&algo_name).is_none() {
        bail!(
            "unknown algorithm `{algo_name}` (known: {})\n{USAGE}",
            algorithms::names().join(", ")
        );
    }
    let iters = cfg.total_iters();
    let exec = parse_exec(args)?;
    let compress = parse_compress(args)?;
    let mut trainer = TrainerBuilder::new(&rt)
        .config(cfg)
        .algorithm(&algo_name)
        .tau(args.u64_or("tau", 1)?)
        .grad_delay(args.u64_or("grad-delay", 1)?)
        .engine(exec)
        .compressor(compress)
        .build()?;
    // Only advertise compression where the strategy's messages actually
    // carry it; exact collectives (AR-SGD) and AD-PSGD ship dense, so a
    // requested spec is a no-op there — warn instead of misreporting.
    let compress_note = match (compress.is_identity(), trainer.algo.compresses_gossip()) {
        (true, _) => String::new(),
        (false, true) => format!(", {} gossip compression", compress.label()),
        (false, false) => {
            eprintln!(
                "note: {} does not route its exchange through the gossip \
                 engine; --compress {} is ignored (messages ship dense)",
                trainer.algo.name(),
                compress.label()
            );
            String::new()
        }
    };
    println!(
        "training {model} with {} on {nodes} nodes ({iters} iters, {} \
         engine{compress_note})…",
        trainer.algo.name(),
        exec.label()
    );
    let r = trainer.run()?;
    r.write_csv(&experiments::results_dir())?;
    metrics::print_table(
        "result",
        &["label", "train loss", "val loss", "val metric", "sim time", "wall"],
        &[vec![
            r.label.clone(),
            format!("{:.4}", r.final_train_loss()),
            format!("{:.4}", r.final_val_loss),
            format!("{:.4}", r.final_val_metric),
            metrics::hours(r.sim_total_s),
            format!("{:.1}s", r.wall_s),
        ]],
    );
    Ok(())
}

/// Parse `--drop`: either a comma list (`0,0.05,0.1`) or an inclusive
/// range `a..b` swept in 5 evenly-spaced levels. Probabilities must lie
/// in [0, 1] — reported as a usage error, not a downstream panic.
fn parse_drops(s: &str) -> Result<Vec<f64>> {
    let prob = |txt: &str| -> Result<f64> {
        let v: f64 =
            txt.trim().parse().with_context(|| format!("--drop `{txt}`"))?;
        if !(0.0..=1.0).contains(&v) {
            bail!("--drop {v}: probability must be in [0, 1]");
        }
        Ok(v)
    };
    if let Some((a, b)) = s.split_once("..") {
        let lo = prob(a)?;
        let hi = prob(b)?;
        if hi < lo {
            bail!("--drop range {lo}..{hi} is reversed");
        }
        let steps = 5usize;
        return Ok((0..steps)
            .map(|i| lo + (hi - lo) * i as f64 / (steps - 1) as f64)
            .collect());
    }
    s.split(',').map(prob).collect()
}

/// Parse `--crash`: comma list of `node@iter` (permanent leave) or
/// `node@iter:rejoin` (rejoin from checkpoint).
fn parse_crashes(s: &str) -> Result<Vec<Crash>> {
    s.split(',')
        .map(|spec| {
            let spec = spec.trim();
            let (node, rest) = spec
                .split_once('@')
                .with_context(|| format!("--crash `{spec}`: expected node@iter[:rejoin]"))?;
            let node = node.parse().with_context(|| format!("--crash node `{node}`"))?;
            let (at, rejoin) = match rest.split_once(':') {
                Some((a, r)) => (
                    a.parse().with_context(|| format!("--crash iter `{a}`"))?,
                    Some(r.parse().with_context(|| format!("--crash rejoin `{r}`"))?),
                ),
                None => (rest.parse().with_context(|| format!("--crash iter `{rest}`"))?, None),
            };
            if let Some(r) = rejoin {
                if r <= at {
                    bail!("--crash `{spec}`: rejoin must come after the crash");
                }
            }
            Ok(Crash { node, at, rejoin })
        })
        .collect()
}

fn cmd_faults(args: &Args) -> Result<()> {
    let mut sweep = experiments::FaultSweep::new(args.flag_strict("fast")?);
    if let Some(d) = args.value_of("drop")? {
        sweep.drops = parse_drops(d)?;
    }
    if let Some(c) = args.value_of("crash")? {
        sweep.crashes = parse_crashes(c)?;
    }
    sweep.n = args.usize_or("nodes", sweep.n)?;
    sweep.iters = args.u64_or("iters", sweep.iters)?;
    sweep.seed = args.u64_or("seed", sweep.seed)?;
    sweep.rescue = !args.flag_strict("no-rescue")?;
    sweep.exec = parse_exec(args)?;
    sweep.compress = parse_compress(args)?;
    if let Some(a) = args.value_of("algos")? {
        sweep.algos = a.split(',').map(|s| s.trim().to_string()).collect();
        for name in &sweep.algos {
            if algorithms::spec(name).is_none() {
                bail!(
                    "unknown algorithm `{name}` (known: {})",
                    algorithms::names().join(", ")
                );
            }
        }
    }
    for c in &sweep.crashes {
        if c.node >= sweep.n {
            bail!("--crash node {} out of range (n = {})", c.node, sweep.n);
        }
    }
    experiments::faults_sweep(&sweep)
}

fn cmd_algos() {
    let rows: Vec<Vec<String>> = algorithms::REGISTRY
        .iter()
        .map(|s| {
            vec![
                s.name.to_string(),
                s.aliases.join(", "),
                s.summary.to_string(),
            ]
        })
        .collect();
    metrics::print_table(
        "registered distributed algorithms",
        &["name", "aliases", "summary"],
        &rows,
    );
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp_opt = args.value_of("exp")?;
    let exp = args
        .positional
        .first()
        .map(String::as_str)
        .or(exp_opt)
        .unwrap_or("all")
        .to_string();
    let fast = args.flag_strict("fast")?;
    match exp.as_str() {
        "appendix-a" => experiments::appendix_a()?,
        "figd4" => experiments::figd4()?,
        other => {
            let rt = Runtime::open_default()?;
            match other {
                "all" => experiments::all(&rt, fast)?,
                "fig1" | "table1" => experiments::fig1_table1(&rt, fast)?,
                "table2" => experiments::table2(&rt, fast)?,
                "table3" => experiments::table3(&rt, fast)?,
                "table4" => experiments::table4(&rt, fast)?,
                "table5" => experiments::table5(&rt, fast)?,
                "fig2" => experiments::fig2(&rt, fast)?,
                "fig3" => experiments::fig3(&rt, fast)?,
                "figd3" => experiments::figd3(&rt, fast)?,
                _ => bail!("unknown experiment `{other}`\n{USAGE}"),
            }
        }
    }
    Ok(())
}

fn cmd_engine_sweep(args: &Args) -> Result<()> {
    let mut sweep = experiments::EngineSweep::new(args.flag_strict("fast")?);
    let max_n = args.usize_or("max-n", *sweep.ns.last().unwrap_or(&1024))?;
    if max_n < 2 {
        bail!("--max-n {max_n}: need at least 2 nodes to gossip");
    }
    sweep.ns.retain(|&n| n <= max_n);
    // `--max-n` beyond the built-in ceiling extends the sweep to that
    // point (and below the smallest default it becomes the single point)
    // instead of being silently ignored.
    if sweep.ns.last().is_none_or(|&top| max_n > top) {
        sweep.ns.push(max_n);
    }
    sweep.dim = args.usize_or("dim", sweep.dim)?;
    sweep.steps = args.u64_or("steps", sweep.steps)?;
    sweep.seed = args.u64_or("seed", sweep.seed)?;
    if let Some(s) = parse_usize_list(args, "shards")? {
        sweep.shards = s;
    }
    if let Some(t) = parse_usize_list(args, "threads")? {
        sweep.threads = t;
    }
    experiments::engine_sweep(&sweep)
}

fn cmd_scale_sweep(args: &Args) -> Result<()> {
    let mut sweep = experiments::ScaleSweep::new(args.flag_strict("fast")?);
    let max_n = args.usize_or("max-n", *sweep.ns.last().unwrap_or(&1024))?;
    if max_n < 2 {
        bail!("--max-n {max_n}: need at least 2 nodes to gossip");
    }
    sweep.ns.retain(|&n| n <= max_n);
    if sweep.ns.last().is_none_or(|&top| max_n > top) {
        sweep.ns.push(max_n);
    }
    sweep.dim = args.usize_or("dim", sweep.dim)?;
    sweep.steps = args.u64_or("steps", sweep.steps)?;
    sweep.active = args.usize_or("active", sweep.active)?;
    sweep.dense_cap = args.usize_or("dense-cap", sweep.dense_cap)?;
    sweep.seed = args.u64_or("seed", sweep.seed)?;
    experiments::scale_sweep(&sweep)
}

fn cmd_bench_check(args: &Args) -> Result<()> {
    let mut cfg = benchgate::BenchCheck::default();
    if let Some(d) = args.value_of("results")? {
        cfg.results_dir = d.into();
    }
    if let Some(d) = args.value_of("baselines")? {
        cfg.baseline_dir = d.into();
    }
    cfg.tol = args.f64_or("tol", cfg.tol)?;
    cfg.update = args.flag_strict("update")?;
    benchgate::bench_check(&cfg)
}

fn cmd_compress_sweep(args: &Args) -> Result<()> {
    let mut sweep = experiments::CompressSweep::new(args.flag_strict("fast")?);
    if let Some(s) = args.value_of("schemes")? {
        sweep.schemes = s
            .split(',')
            .map(|v| {
                let v = v.trim();
                Compression::parse(v)
                    .filter(|c| !c.is_identity())
                    .with_context(|| {
                        format!("--schemes `{v}`: expected topk:D or qsgd:B")
                    })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(h) = args.value_of("het")? {
        sweep.hets = h
            .split(',')
            .map(|v| {
                let v = v.trim();
                let z: f64 = v
                    .parse()
                    .with_context(|| format!("--het `{v}`: not a number"))?;
                if !(0.0..=1.0).contains(&z) {
                    bail!("--het {z}: heterogeneity must be in [0, 1]");
                }
                Ok(z)
            })
            .collect::<Result<Vec<_>>>()?;
    }
    sweep.n = args.usize_or("nodes", sweep.n)?;
    sweep.iters = args.u64_or("iters", sweep.iters)?;
    sweep.dim = args.usize_or("dim", sweep.dim)?;
    sweep.seed = args.u64_or("seed", sweep.seed)?;
    if let Some(s) = parse_usize_list(args, "shards")? {
        sweep.shards = s;
    }
    experiments::compress_sweep(&sweep)
}

fn cmd_soak(args: &Args) -> Result<()> {
    let mut run = experiments::SoakRun::new(args.flag_strict("fast")?);
    run.n = args.usize_or("nodes", run.n)?;
    run.dim = args.usize_or("dim", run.dim)?;
    run.iters = args.u64_or("iters", run.iters)?;
    run.seed = args.u64_or("seed", run.seed)?;
    run.drop = args.f64_or("drop", run.drop)?;
    if !(0.0..=1.0).contains(&run.drop) {
        bail!("--drop {}: probability must be in [0, 1]", run.drop);
    }
    run.exec = parse_exec(args)?;
    // Only override the soak's compressed default when --compress was
    // actually given (parse_compress maps "absent" to Identity).
    if args.value_of("compress")?.is_some() {
        run.compress = parse_compress(args)?;
    }
    if let Some(t) = args.value_of("trace")? {
        run.trace = t.into();
    }
    if let Some(d) = args.value_of("checkpoint-dir")? {
        run.ckpt_dir = d.into();
    }
    experiments::soak(&run)
}

fn cmd_coord(args: &Args) -> Result<()> {
    let world = args.usize_or("world", 4)?;
    if world < 2 {
        bail!("--world must be at least 2 (got {world})");
    }
    let rounds = args.u64_or("rounds", 400)?;
    let cooldown = args.u64_or("cooldown", rounds / 4)?;
    let hb = HeartbeatPolicy {
        slow_after_ms: args.u64_or("slow-ms", 500)?,
        dead_after_ms: args.u64_or("dead-ms", 2000)?,
    };
    if hb.dead_after_ms <= hb.slow_after_ms {
        bail!(
            "--dead-ms ({}) must exceed --slow-ms ({}) — the degraded band \
             between the two thresholds is the point",
            hb.dead_after_ms,
            hb.slow_after_ms
        );
    }
    let cfg = coord::CoordConfig {
        bind: args.str_or("bind", "127.0.0.1:0")?,
        world,
        rounds,
        cooldown,
        dim: args.usize_or("dim", 32)?,
        seed: args.u64_or("seed", 1)?,
        lr: args.f64_or("lr", 0.05)? as f32,
        scheme: parse_compress(args)?,
        round_ms: args.u32_or("round-ms", 2)?,
        round_timeout_ms: args.u32_or("round-timeout-ms", 250)?,
        hb,
        deadline_s: args.u64_or("deadline-s", 120)?,
        port_file: args.value_of("port-file")?.map(std::path::PathBuf::from),
        log_path: std::path::PathBuf::from(
            args.str_or("log", "results/deploy/membership.jsonl")?,
        ),
        summary_path: std::path::PathBuf::from(
            args.str_or("summary", "results/deploy/summary.json")?,
        ),
        checkpoint_dir: args.value_of("checkpoint-dir")?.map(std::path::PathBuf::from),
        verbose: args.flag_strict("verbose")?,
    };
    let s = coord::run_coordinator(&cfg)?;
    println!(
        "deployment complete: {}/{} survivors {:?}, consensus spread {:.3e}, \
         missing push-sum mass {:.6}, max ledger residual {:.3e}",
        s.survivors.len(),
        s.world,
        s.survivors,
        s.spread,
        s.missing_w,
        s.max_ledger_residual
    );
    println!("summary: {}", cfg.summary_path.display());
    println!("membership log: {}", cfg.log_path.display());
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let cfg = worker::WorkerConfig {
        coord: args.require("coord")?.to_string(),
        bind: args.str_or("bind", "127.0.0.1:0")?,
        hb_ms: args.u64_or("hb-ms", 50)?,
        io_timeout_ms: args.u64_or("io-timeout-ms", 5000)?,
        verbose: args.flag_strict("verbose")?,
        trace: args.value_of("trace")?.map(std::path::PathBuf::from),
        checkpoint_dir: args.value_of("checkpoint-dir")?.map(std::path::PathBuf::from),
        checkpoint_every: args.u64_or("checkpoint-every", 50)?,
    };
    let rep = worker::run_worker(&cfg)?;
    println!(
        "worker rank {} finished after {} rounds: w={:.6} recv_w={:.6} \
         sent_w={:.6} rescued_w={:.6} ({} rescues, {} timeouts)",
        rep.rank,
        rep.rounds,
        rep.done.w,
        rep.done.recv_w,
        rep.done.sent_w,
        rep.done.rescued_w,
        rep.done.rescues,
        rep.done.timeouts
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let path = match args.value_of("file")? {
        Some(p) => p,
        None => args
            .positional
            .first()
            .map(String::as_str)
            .context("usage: repro trace <FILE> (a JSONL sgp-trace)")?,
    };
    sgp::obs::analyze::run(std::path::Path::new(path))
}

fn cmd_audit(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.str_or("root", ".")?);
    let mut cfg = sgp::analysis::AuditConfig::new(root);
    if let Some(p) = args.value_of("allow")? {
        cfg.allow = std::path::PathBuf::from(p);
    }
    if let Some(r) = args.value_of("rule")? {
        cfg.rule = Some(r.to_uppercase());
    }
    let deny = args.flag_strict("deny")?;
    let json = args.flag_strict("json")?;
    let report = sgp::analysis::run(&cfg)?;
    if json {
        print!("{}", sgp::analysis::render_json(&report));
    } else {
        print!("{}", sgp::analysis::render_text(&report));
    }
    if deny && !report.clean() {
        bail!(
            "audit --deny: {} violation(s), {} stale allowlist entr{}",
            report.violations.len(),
            report.stale.len(),
            if report.stale.len() == 1 { "y" } else { "ies" }
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args)?,
        Some("bench") => cmd_bench(&args)?,
        Some("faults") => cmd_faults(&args)?,
        Some("engine-sweep") => cmd_engine_sweep(&args)?,
        Some("scale-sweep") => cmd_scale_sweep(&args)?,
        Some("compress-sweep") => cmd_compress_sweep(&args)?,
        Some("soak") => cmd_soak(&args)?,
        Some("bench-check") => cmd_bench_check(&args)?,
        Some("coord") => cmd_coord(&args)?,
        Some("worker") => cmd_worker(&args)?,
        Some("trace") => cmd_trace(&args)?,
        Some("audit") => cmd_audit(&args)?,
        Some("algos") => cmd_algos(),
        Some("spectral") => experiments::appendix_a()?,
        Some("average") => {
            let rt = Runtime::open_default()?;
            experiments::averaging(
                &rt,
                args.usize_or("nodes", 32)?,
                args.u64_or("rounds", 8)?,
            )?;
        }
        Some("convergence") => experiments::convergence_demo(
            args.usize_or("nodes", 16)?,
            args.u64_or("iters", 2000)?,
            args.value_of("trace")?.map(std::path::Path::new),
        )?,
        Some("inspect") => {
            let rt = Runtime::open_default()?;
            let mut rows: Vec<Vec<String>> = rt
                .manifest
                .artifacts
                .iter()
                .map(|(name, a)| {
                    vec![
                        name.clone(),
                        a.kind.clone(),
                        a.param_count.map(|p| p.to_string()).unwrap_or_default(),
                        a.file.clone(),
                    ]
                })
                .collect();
            rows.sort();
            metrics::print_table("artifacts", &["name", "kind", "params", "file"], &rows);
        }
        Some("help") | None => println!("{USAGE}"),
        Some(other) => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
    Ok(())
}

//! `repro` — CLI launcher for the SGP reproduction.
//!
//! Subcommands:
//!   train        one training run (model × algorithm × cluster)
//!   bench <exp>  regenerate a paper table/figure (all, fig1, table1..5, …)
//!   spectral     Appendix-A λ₂ analysis (no artifacts needed)
//!   average      PushSum averaging demo through the Pallas dense-gossip HLO
//!   convergence  Theorem 1/2 sanity demo (pure Rust)
//!   inspect      print the artifact manifest

use anyhow::{bail, Result};

use sgp::algorithms::Algorithm;
use sgp::cli::Args;
use sgp::config::{Fabric, TrainConfig};
use sgp::coordinator::Trainer;
use sgp::experiments;
use sgp::metrics;
use sgp::optim::OptimKind;
use sgp::runtime::Runtime;

const USAGE: &str = "\
repro — Stochastic Gradient Push (ICML 2019) reproduction

USAGE:
  repro train   [--model mlp_small] [--algo sgp|ar-sgd|sgp-2p|osgp|osgp-biased|
                 dpsgd|adpsgd|hybrid-ar-1p|hybrid-2p-1p] [--nodes 8]
                [--epochs 10] [--steps-per-epoch 16] [--fabric ethernet|ib]
                [--tau 1] [--seed 0] [--adam] [--heterogeneity 0.3]
  repro bench   <all|fig1|table1|table2|table3|table4|table5|fig2|fig3|
                 figd3|figd4|appendix-a> [--fast]
  repro spectral
  repro average [--nodes 32] [--rounds 8]
  repro convergence [--nodes 16] [--iters 2000]
  repro inspect
";

fn build_algo(name: &str, n: usize, tau: u64, switch_at: u64) -> Result<Algorithm> {
    Ok(match name {
        "ar-sgd" | "arsgd" | "ar" => Algorithm::ArSgd,
        "sgp" | "sgp-1p" => Algorithm::sgp_1peer(n),
        "sgp-2p" => Algorithm::sgp_2peer(n),
        "osgp" => Algorithm::osgp_1peer(n, tau.max(1)),
        "osgp-biased" => Algorithm::osgp_biased(n, tau.max(1)),
        "dpsgd" => Algorithm::dpsgd(n),
        "adpsgd" => Algorithm::adpsgd(n),
        "hybrid-ar-1p" => Algorithm::hybrid_ar_then_1p(n, switch_at),
        "hybrid-2p-1p" => Algorithm::hybrid_2p_then_1p(n, switch_at),
        other => bail!("unknown algorithm `{other}`\n{USAGE}"),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let model = args.str_or("model", "mlp_small");
    let nodes = args.usize_or("nodes", 8)?;
    let mut cfg = TrainConfig::imagenet_like(&model, nodes, args.u64_or("seed", 0)?);
    cfg.epochs = args.f64_or("epochs", 10.0)?;
    cfg.steps_per_epoch = args.u64_or("steps-per-epoch", 16)?;
    cfg.heterogeneity = args.f64_or("heterogeneity", 0.3)?;
    if let Some(f) = args.get("fabric") {
        cfg.link = Fabric::parse(f)
            .ok_or_else(|| anyhow::anyhow!("unknown fabric `{f}`"))?
            .link();
    }
    if args.flag("adam") {
        cfg.optim = OptimKind::Adam;
        cfg.lr = sgp::optim::LrSchedule::constant(1e-3);
    }
    let tau = args.u64_or("tau", 1)?;
    let switch = cfg.total_iters() / 3;
    let algorithm = build_algo(&args.str_or("algo", "sgp"), nodes, tau, switch)?;
    println!(
        "training {model} with {} on {nodes} nodes ({} iters)…",
        algorithm.name(),
        cfg.total_iters()
    );
    let trainer = Trainer::new(&rt, cfg, algorithm)?;
    let r = trainer.run()?;
    r.write_csv(&experiments::results_dir())?;
    metrics::print_table(
        "result",
        &["label", "train loss", "val loss", "val metric", "sim time", "wall"],
        &[vec![
            r.label.clone(),
            format!("{:.4}", r.final_train_loss()),
            format!("{:.4}", r.final_val_loss),
            format!("{:.4}", r.final_val_metric),
            metrics::hours(r.sim_total_s),
            format!("{:.1}s", r.wall_s),
        ]],
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("exp"))
        .unwrap_or("all")
        .to_string();
    let fast = args.flag("fast");
    match exp.as_str() {
        "appendix-a" => experiments::appendix_a()?,
        "figd4" => experiments::figd4()?,
        other => {
            let rt = Runtime::open_default()?;
            match other {
                "all" => experiments::all(&rt, fast)?,
                "fig1" | "table1" => experiments::fig1_table1(&rt, fast)?,
                "table2" => experiments::table2(&rt, fast)?,
                "table3" => experiments::table3(&rt, fast)?,
                "table4" => experiments::table4(&rt, fast)?,
                "table5" => experiments::table5(&rt, fast)?,
                "fig2" => experiments::fig2(&rt, fast)?,
                "fig3" => experiments::fig3(&rt, fast)?,
                "figd3" => experiments::figd3(&rt, fast)?,
                _ => bail!("unknown experiment `{other}`\n{USAGE}"),
            }
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args)?,
        Some("bench") => cmd_bench(&args)?,
        Some("spectral") => experiments::appendix_a()?,
        Some("average") => {
            let rt = Runtime::open_default()?;
            experiments::averaging(
                &rt,
                args.usize_or("nodes", 32)?,
                args.u64_or("rounds", 8)?,
            )?;
        }
        Some("convergence") => experiments::convergence_demo(
            args.usize_or("nodes", 16)?,
            args.u64_or("iters", 2000)?,
        )?,
        Some("inspect") => {
            let rt = Runtime::open_default()?;
            let mut rows: Vec<Vec<String>> = rt
                .manifest
                .artifacts
                .iter()
                .map(|(name, a)| {
                    vec![
                        name.clone(),
                        a.kind.clone(),
                        a.param_count.map(|p| p.to_string()).unwrap_or_default(),
                        a.file.clone(),
                    ]
                })
                .collect();
            rows.sort();
            metrics::print_table("artifacts", &["name", "kind", "params", "file"], &rows);
        }
        Some("help") | None => println!("{USAGE}"),
        Some(other) => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
    Ok(())
}

//! Run configuration: everything a training run needs, with presets
//! mirroring the paper's experimental grid.

use crate::net::{ComputeModel, LinkModel};
use crate::optim::{LrSchedule, OptimKind};

/// Which fabric the simulated cluster uses (Sec. 6: low- vs high-bandwidth).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fabric {
    /// 10 Gbps Ethernet (the paper's low-bandwidth rig).
    Ethernet,
    /// 100 Gbps InfiniBand with GPUDirect RDMA (the high-bandwidth rig).
    Infiniband,
}

impl Fabric {
    /// The α–β link model of this fabric.
    pub fn link(&self) -> LinkModel {
        match self {
            Fabric::Ethernet => LinkModel::ethernet_10g(),
            Fabric::Infiniband => LinkModel::infiniband_100g(),
        }
    }

    /// Parse a CLI fabric name (`ethernet`/`eth`/`10g`, `infiniband`/`ib`/`100g`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ethernet" | "eth" | "10g" => Some(Fabric::Ethernet),
            "infiniband" | "ib" | "100g" => Some(Fabric::Infiniband),
            _ => None,
        }
    }
}

/// Full training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model preset name (must exist in the artifact manifest).
    pub model: String,
    /// Number of simulated nodes.
    pub n_nodes: usize,
    /// Epochs to run (fractional allowed for fast tests).
    pub epochs: f64,
    /// Iterations per epoch. With n nodes the paper halves iterations as n
    /// doubles (fixed total samples); callers encode that here.
    pub steps_per_epoch: u64,
    /// Local optimizer family.
    pub optim: OptimKind,
    /// Learning-rate protocol.
    pub lr: LrSchedule,
    /// Seed for data shards, compute jitter and randomized schedules.
    pub seed: u64,
    /// Data heterogeneity knob (the paper's ζ²).
    pub heterogeneity: f64,
    /// Simulated fabric.
    pub link: LinkModel,
    /// Per-node compute-time profile (stragglers included).
    pub compute: ComputeModel,
    /// Evaluate every this many epochs (0 = only at the end).
    pub eval_every_epochs: f64,
    /// Record consensus statistics at eval points.
    pub track_consensus: bool,
    /// Validation batches per evaluation.
    pub val_batches: usize,
}

impl TrainConfig {
    /// Small-scale analogue of the paper's ImageNet protocol: blobs-MLP,
    /// Nesterov, Goyal LR schedule, 90 "epochs".
    pub fn imagenet_like(model: &str, n: usize, seed: u64) -> Self {
        // Fixed total work: scaling n divides per-epoch steps (the paper's
        // "double the nodes, halve the iterations").
        let steps_per_epoch = (512 / n as u64).max(4);
        Self {
            model: model.to_string(),
            n_nodes: n,
            epochs: 90.0,
            steps_per_epoch,
            optim: OptimKind::Nesterov,
            lr: LrSchedule::goyal(n, 0.05),
            seed,
            heterogeneity: 0.3,
            link: LinkModel::ethernet_10g(),
            compute: ComputeModel::resnet50_dgx1(),
            eval_every_epochs: 10.0,
            track_consensus: true,
            val_batches: 8,
        }
    }

    /// Small-scale analogue of the WMT16 transformer protocol: bigram-LM,
    /// Adam, constant LR (Fig. 3).
    pub fn nmt_like(model: &str, n: usize, seed: u64) -> Self {
        Self {
            model: model.to_string(),
            n_nodes: n,
            epochs: 10.0,
            steps_per_epoch: 30,
            optim: OptimKind::Adam,
            lr: LrSchedule::constant(1e-3),
            seed,
            heterogeneity: 0.2,
            link: LinkModel::ethernet_10g(),
            // Calibrated so compute:communication matches the paper's
            // Transformer/10 GbE regime (~0.4 ptp-to-compute ratio for the
            // small-batch setting): our 3.7 MB message ⇒ ~3 ms ptp.
            compute: ComputeModel {
                base_s: 0.015,
                jitter_sigma: 0.12,
                p_slow: 0.01,
                slow_factor: 2.0,
            },
            eval_every_epochs: 1.0,
            track_consensus: false,
            val_batches: 8,
        }
    }

    /// Fast configuration for integration tests.
    pub fn test_tiny(model: &str, n: usize) -> Self {
        Self {
            model: model.to_string(),
            n_nodes: n,
            epochs: 2.0,
            steps_per_epoch: 5,
            optim: OptimKind::Nesterov,
            lr: LrSchedule::constant(0.05),
            seed: 0,
            heterogeneity: 0.3,
            link: LinkModel::ethernet_10g(),
            compute: ComputeModel::deterministic(0.3),
            eval_every_epochs: 1.0,
            track_consensus: true,
            val_batches: 2,
        }
    }

    /// Total iterations of the run (`epochs × steps_per_epoch`, rounded).
    pub fn total_iters(&self) -> u64 {
        (self.epochs * self.steps_per_epoch as f64).round() as u64
    }

    /// Fractional epoch that iteration `iter` falls in.
    pub fn epoch_of(&self, iter: u64) -> f64 {
        iter as f64 / self.steps_per_epoch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_halves_steps_per_epoch() {
        let c8 = TrainConfig::imagenet_like("mlp_small", 8, 0);
        let c16 = TrainConfig::imagenet_like("mlp_small", 16, 0);
        assert_eq!(c8.steps_per_epoch, 2 * c16.steps_per_epoch);
    }

    #[test]
    fn total_iters_rounds() {
        let mut c = TrainConfig::test_tiny("mlp_small", 2);
        c.epochs = 2.5;
        c.steps_per_epoch = 4;
        assert_eq!(c.total_iters(), 10);
        assert!((c.epoch_of(6) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn fabric_links_and_parse() {
        assert_eq!(Fabric::Ethernet.link().name, "ethernet-10g");
        assert_eq!(Fabric::Infiniband.link().name, "infiniband-100g");
        assert_eq!(Fabric::parse("ib"), Some(Fabric::Infiniband));
        assert_eq!(Fabric::parse("eth"), Some(Fabric::Ethernet));
        assert_eq!(Fabric::parse("token-ring"), None);
    }
}

//! Discrete-event simulation clock for the asynchronous baseline
//! (AD-PSGD, Lian et al. 2018) and other event-driven experiments.
//!
//! A tiny binary-heap scheduler over (time, node, event) with a strict
//! causality guarantee: events pop in non-decreasing time order, ties
//! broken deterministically by sequence number so runs are reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event: fires at `time`, ties broken by `seq`.
#[derive(Clone, Debug, PartialEq)]
pub struct Event<T> {
    /// Simulated firing time (seconds).
    pub time: f64,
    /// Insertion sequence number (deterministic tie-break).
    pub seq: u64,
    /// The scheduled item.
    pub payload: T,
}

impl<T: PartialEq> Eq for Event<T> {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (max-heap).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-time event queue.
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    seq: u64,
    now: f64,
}

impl<T: PartialEq> EventQueue<T> {
    /// An empty queue with the clock at 0.
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// An empty queue with room for `cap` events before reallocating.
    ///
    /// The event engine pre-sizes its arrival queue with this so the
    /// steady-state hot path stays allocation-free (the heap's buffer is
    /// retained across pops).
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), seq: 0, now: 0.0 }
    }

    /// Reserve room for at least `additional` more events.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    /// Schedule `payload` at `time` (panics if `time` is in the past).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time >= self.now, "cannot schedule into the past");
        self.heap.push(Event { time, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        Some(ev)
    }

    /// The current simulated time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Firing time of the earliest pending event, if any, without popping.
    ///
    /// Lets event-driven engines drain "everything due by tick `k`" with a
    /// peek-then-pop loop instead of popping speculatively and re-pushing
    /// (a re-push would burn a sequence number and perturb tie-breaks).
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Iterate over all pending events in arbitrary (heap) order.
    ///
    /// For inspection only — mass audits, staleness bounds — never for
    /// delivery ordering, which must go through [`EventQueue::pop`].
    pub fn iter(&self) -> impl Iterator<Item = &Event<T>> {
        self.heap.iter()
    }

    /// Drop all pending events and rewind the clock (and sequence counter)
    /// to 0, retaining the heap's capacity. Used when an engine drains its
    /// in-flight state at end of run: the queue must forget its schedule
    /// so a subsequent run can start from virtual time 0 again.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.payload))
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_broken_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<_> =
            std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(0.5, ());
        q.push(0.7, ());
        q.pop();
        assert_eq!(q.now(), 0.5);
        q.push(0.6, ());
        q.pop();
        assert_eq!(q.now(), 0.6);
        q.pop();
        assert_eq!(q.now(), 0.7);
    }

    #[test]
    fn peek_does_not_advance_clock() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(2.0, 'x');
        q.push(1.0, 'y');
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.iter().count(), 2);
        q.pop();
        assert_eq!(q.peek_time(), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn scheduling_into_the_past_panics() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.pop();
        q.push(0.5, ());
    }
}

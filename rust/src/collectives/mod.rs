//! Exact-averaging collectives substrate: the AllReduce that the AR-SGD
//! baseline (Goyal et al., 2017) synchronizes with, plus its α–β cost
//! model. We implement the in-process *semantics* (exact averaging) and a
//! faithful ring-AllReduce *timing* model; the paper's NCCL/Gloo stack is
//! below the level the experiments depend on.

use crate::net::LinkModel;

/// Exactly average a set of flat vectors in place (the AllReduce result:
/// every participant ends with the same mean vector).
pub fn allreduce_mean(vs: &mut [Vec<f32>]) {
    let n = vs.len();
    assert!(n > 0);
    let dim = vs[0].len();
    let mut acc = vec![0.0f64; dim];
    for v in vs.iter() {
        assert_eq!(v.len(), dim);
        for (a, b) in acc.iter_mut().zip(v) {
            *a += *b as f64;
        }
    }
    let inv = 1.0 / n as f64;
    let mean: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
    for v in vs.iter_mut() {
        v.copy_from_slice(&mean);
    }
}

/// Weighted mean into a fresh vector (helper for hybrid schemes / eval).
pub fn mean_of(vs: &[Vec<f32>]) -> Vec<f32> {
    let n = vs.len();
    let dim = vs[0].len();
    let mut acc = vec![0.0f64; dim];
    for v in vs {
        for (a, b) in acc.iter_mut().zip(v) {
            *a += *b as f64;
        }
    }
    acc.iter().map(|a| (a / n as f64) as f32).collect()
}

/// Time for a bandwidth-optimal ring AllReduce of `bytes` over `n` nodes:
/// 2(n−1) latency terms plus 2(n−1)/n bandwidth terms (reduce-scatter +
/// all-gather). This is the standard α–β model (Thakur et al.).
pub fn ring_allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes as f64 / n as f64;
    steps as f64 * (link.alpha_s + chunk / link.beta_bps)
}

/// Time for a binary-tree AllReduce (reduce + broadcast): 2·log2(n) rounds
/// of full-message sends — latency-better, bandwidth-worse than ring.
pub fn tree_allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let rounds = 2.0 * (n as f64).log2().ceil();
    rounds * (link.alpha_s + bytes as f64 / link.beta_bps)
}

/// The better of ring/tree for the message size — what a real collective
/// library's algorithm picker does.
pub fn allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    ring_allreduce_time(n, bytes, link).min(tree_allreduce_time(n, bytes, link))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::rng::Pcg;

    #[test]
    fn allreduce_mean_makes_all_equal_to_mean() {
        let mut rng = Pcg::new(1);
        let mut vs: Vec<Vec<f32>> = (0..8).map(|_| rng.gaussian_vec(32)).collect();
        let expect: Vec<f32> = (0..32)
            .map(|j| vs.iter().map(|v| v[j]).sum::<f32>() / 8.0)
            .collect();
        allreduce_mean(&mut vs);
        for v in &vs {
            for (a, b) in v.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ring_time_bandwidth_term_saturates_with_n() {
        // For large messages the ring bandwidth term approaches 2·M/β
        // regardless of n — that's why AR stays flat on InfiniBand.
        let link = LinkModel::infiniband_100g();
        let t8 = ring_allreduce_time(8, 100 << 20, &link);
        let t32 = ring_allreduce_time(32, 100 << 20, &link);
        assert!((t32 - t8) / t8 < 0.35, "t8={t8} t32={t32}");
    }

    #[test]
    fn ring_latency_term_grows_linearly() {
        // For tiny messages the 2(n−1)·α term dominates.
        let link = LinkModel::ethernet_10g();
        let t4 = ring_allreduce_time(4, 8, &link);
        let t32 = ring_allreduce_time(32, 8, &link);
        assert!(t32 > 8.0 * t4 * 0.9);
    }

    #[test]
    fn tree_beats_ring_for_small_messages_large_n() {
        let link = LinkModel::ethernet_10g();
        assert!(
            tree_allreduce_time(64, 64, &link) < ring_allreduce_time(64, 64, &link)
        );
    }

    #[test]
    fn single_node_costs_nothing() {
        let link = LinkModel::ethernet_10g();
        assert_eq!(allreduce_time(1, 1 << 20, &link), 0.0);
    }
}

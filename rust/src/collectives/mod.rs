//! Exact-averaging collectives substrate: the AllReduce that the AR-SGD
//! baseline (Goyal et al., 2017) synchronizes with, plus its α–β cost
//! model. We implement the in-process *semantics* (exact averaging) and a
//! faithful ring-AllReduce *timing* model; the paper's NCCL/Gloo stack is
//! below the level the experiments depend on.

use crate::gossip::ExecPolicy;
use crate::net::LinkModel;
use crate::rng::Pcg;
use crate::runtime::pool;

/// Exactly average a set of flat vectors in place (the AllReduce result:
/// every participant ends with the same mean vector).
pub fn allreduce_mean(vs: &mut [Vec<f32>]) {
    let n = vs.len();
    assert!(n > 0);
    let dim = vs[0].len();
    let mut acc = vec![0.0f64; dim];
    for v in vs.iter() {
        assert_eq!(v.len(), dim);
        for (a, b) in acc.iter_mut().zip(v) {
            *a += *b as f64;
        }
    }
    let inv = 1.0 / n as f64;
    let mean: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
    for v in vs.iter_mut() {
        v.copy_from_slice(&mean);
    }
}

/// Weighted mean into a fresh vector (helper for hybrid schemes / eval).
pub fn mean_of(vs: &[Vec<f32>]) -> Vec<f32> {
    let n = vs.len();
    let dim = vs[0].len();
    let mut acc = vec![0.0f64; dim];
    for v in vs {
        for (a, b) in acc.iter_mut().zip(v) {
            *a += *b as f64;
        }
    }
    acc.iter().map(|a| (a / n as f64) as f32).collect()
}

/// [`mean_of`] under an execution policy: the *coordinates* are
/// partitioned into contiguous ranges, one persistent-pool worker per
/// range ([`crate::runtime::pool`]). Every coordinate still accumulates
/// over the views in the same order as the sequential loop, so the result
/// is **bit-identical** to [`mean_of`] for any shard count — the same
/// determinism contract as the gossip engine. (This is an eval-time
/// helper: the output vector and per-worker partials are allocated per
/// call, unlike the allocation-free gossip round.)
pub fn mean_of_exec(vs: &[Vec<f32>], exec: ExecPolicy) -> Vec<f32> {
    let n = vs.len() as f64;
    let dim = vs[0].len();
    let shards = exec.shards_for(dim);
    if shards <= 1 {
        return mean_of(vs);
    }
    let chunk = dim.div_ceil(shards);
    let used = dim.div_ceil(chunk);
    let mut out = vec![0.0f32; dim];
    let table = MeanTable { out: out.as_mut_ptr(), dim, chunk, vs, n };
    // SAFETY: shard s writes only coordinates [s·chunk, s·chunk+len) —
    // disjoint output ranges — and the pool runs each index exactly once.
    pool::global().run(used, &|s| unsafe { table.run(s) });
    out
}

/// Raw coordinate-range view of the output vector for the pool workers;
/// shard `s` writes only its own contiguous range.
struct MeanTable<'a> {
    out: *mut f32,
    dim: usize,
    chunk: usize,
    vs: &'a [Vec<f32>],
    n: f64,
}

// SAFETY: workers write disjoint output ranges; `vs` is shared read-only.
unsafe impl Send for MeanTable<'_> {}
unsafe impl Sync for MeanTable<'_> {}

impl MeanTable<'_> {
    /// # Safety
    /// `s·chunk < dim` and each shard index runs on exactly one worker.
    unsafe fn run(&self, s: usize) {
        let lo = s * self.chunk;
        debug_assert!(
            lo < self.dim,
            "mean shard {s} out of range (chunk {}, dim {})",
            self.chunk,
            self.dim
        );
        let len = self.chunk.min(self.dim - lo);
        let dst = std::slice::from_raw_parts_mut(self.out.add(lo), len);
        let mut acc = vec![0.0f64; len];
        for v in self.vs {
            for (a, b) in acc.iter_mut().zip(&v[lo..lo + len]) {
                *a += *b as f64;
            }
        }
        for (o, a) in dst.iter_mut().zip(&acc) {
            *o = (a / self.n) as f32;
        }
    }
}

/// Shape of the ring algorithm: `(serial steps, parallel transfers per
/// step, seconds per transfer)` — the single source both the clean and
/// fault-inflated cost paths derive from.
fn ring_shape(n: usize, bytes: usize, link: &LinkModel) -> (usize, usize, f64) {
    let chunk = bytes as f64 / n as f64;
    (2 * (n - 1), n, link.alpha_s + chunk / link.beta_bps)
}

/// Shape of the binary-tree algorithm (reduce + broadcast), same triple.
fn tree_shape(n: usize, bytes: usize, link: &LinkModel) -> (usize, usize, f64) {
    let rounds = 2 * (n as f64).log2().ceil() as usize;
    (rounds, (n / 2).max(1), link.alpha_s + bytes as f64 / link.beta_bps)
}

/// Time for a bandwidth-optimal ring AllReduce of `bytes` over `n` nodes:
/// 2(n−1) latency terms plus 2(n−1)/n bandwidth terms (reduce-scatter +
/// all-gather). This is the standard α–β model (Thakur et al.).
pub fn ring_allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let (steps, _, transfer) = ring_shape(n, bytes, link);
    steps as f64 * transfer
}

/// Time for a binary-tree AllReduce (reduce + broadcast): 2·log2(n) rounds
/// of full-message sends — latency-better, bandwidth-worse than ring.
pub fn tree_allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let (steps, _, transfer) = tree_shape(n, bytes, link);
    steps as f64 * transfer
}

/// The better of ring/tree for the message size — what a real collective
/// library's algorithm picker does. `bytes` is the on-wire payload per
/// node; a compressed collective passes its encoded size.
pub fn allreduce_time(n: usize, bytes: usize, link: &LinkModel) -> f64 {
    ring_allreduce_time(n, bytes, link).min(tree_allreduce_time(n, bytes, link))
}

/// Time for a compressed-exchange "allreduce": an all-gather of whole
/// encoded messages, `n − 1` serial ring steps of `enc_bytes` each.
///
/// Reduce-scatter — the trick that makes dense ring-allreduce
/// bandwidth-optimal — needs partial sums to stay the same size as their
/// inputs, which sparse/quantized encodings do not (the sum of two top-k
/// messages has up to 2k coordinates). Compressed collectives
/// (GossipGraD-style exchange) therefore ship whole encoded messages and
/// reduce at the endpoints: the bandwidth term scales with `n · enc`
/// instead of `2 · dense`. This is the honest break-even the
/// compress-sweep exposes — compression must beat a factor `n/2` of
/// encoding ratio before a compressed collective outruns the dense ring,
/// whereas every gossip message enjoys the full ratio.
pub fn compressed_allgather_time(n: usize, enc_bytes: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    (n - 1) as f64 * (link.alpha_s + enc_bytes as f64 / link.beta_bps)
}

/// Maximum retransmissions per transfer before a collective step gives up
/// and eats the cost anyway (bounds the fault model; real stacks abort).
pub const MAX_RETRANSMITS: u32 = 8;

/// One serial collective step of `parallel` concurrent transfers, each
/// taking `transfer` seconds, with per-transfer drop probability `p`: the
/// step completes when the *slowest* transfer lands, and each dropped
/// transfer is retransmitted (geometric, capped). This is the mechanism
/// behind the paper's sensitivity claim — a collective must wait for
/// every link, so the per-step slowdown grows with the number of parallel
/// transfers, while a push-sum node only ever waits for its own message.
fn faulty_step_time(parallel: usize, transfer: f64, p: f64, rng: &mut Pcg) -> f64 {
    let mut worst = 1u32;
    for _ in 0..parallel {
        let mut attempts = 1u32;
        while attempts <= MAX_RETRANSMITS && rng.f64() < p {
            attempts += 1;
        }
        worst = worst.max(attempts);
    }
    worst as f64 * transfer
}

/// AllReduce time under per-message drop probability `p`, retransmitting
/// lost chunks (deterministic given `rng`). With `p = 0` this equals
/// [`allreduce_time`] exactly, so fault-free comparisons are unbiased.
pub fn allreduce_time_faulty(
    n: usize,
    bytes: usize,
    link: &LinkModel,
    p: f64,
    rng: &mut Pcg,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    if p <= 0.0 {
        return allreduce_time(n, bytes, link);
    }
    let (ring_steps, ring_par, ring_transfer) = ring_shape(n, bytes, link);
    let ring: f64 = (0..ring_steps)
        .map(|_| faulty_step_time(ring_par, ring_transfer, p, rng))
        .sum();
    let (tree_steps, tree_par, tree_transfer) = tree_shape(n, bytes, link);
    let tree: f64 = (0..tree_steps)
        .map(|_| faulty_step_time(tree_par, tree_transfer, p, rng))
        .sum();
    ring.min(tree)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::rng::Pcg;

    #[test]
    fn allreduce_mean_makes_all_equal_to_mean() {
        let mut rng = Pcg::new(1);
        let mut vs: Vec<Vec<f32>> = (0..8).map(|_| rng.gaussian_vec(32)).collect();
        let expect: Vec<f32> = (0..32)
            .map(|j| vs.iter().map(|v| v[j]).sum::<f32>() / 8.0)
            .collect();
        allreduce_mean(&mut vs);
        for v in &vs {
            for (a, b) in v.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sharded_mean_bit_identical_to_sequential() {
        use crate::gossip::ExecPolicy;
        let mut rng = Pcg::new(4);
        let vs: Vec<Vec<f32>> = (0..9).map(|_| rng.gaussian_vec(37)).collect();
        let seq = mean_of(&vs);
        for shards in [1usize, 2, 7, 64] {
            let par = mean_of_exec(&vs, ExecPolicy::parallel(shards));
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "shards={shards}");
            }
        }
    }

    #[test]
    fn ring_time_bandwidth_term_saturates_with_n() {
        // For large messages the ring bandwidth term approaches 2·M/β
        // regardless of n — that's why AR stays flat on InfiniBand.
        let link = LinkModel::infiniband_100g();
        let t8 = ring_allreduce_time(8, 100 << 20, &link);
        let t32 = ring_allreduce_time(32, 100 << 20, &link);
        assert!((t32 - t8) / t8 < 0.35, "t8={t8} t32={t32}");
    }

    #[test]
    fn ring_latency_term_grows_linearly() {
        // For tiny messages the 2(n−1)·α term dominates.
        let link = LinkModel::ethernet_10g();
        let t4 = ring_allreduce_time(4, 8, &link);
        let t32 = ring_allreduce_time(32, 8, &link);
        assert!(t32 > 8.0 * t4 * 0.9);
    }

    #[test]
    fn tree_beats_ring_for_small_messages_large_n() {
        let link = LinkModel::ethernet_10g();
        assert!(
            tree_allreduce_time(64, 64, &link) < ring_allreduce_time(64, 64, &link)
        );
    }

    #[test]
    fn single_node_costs_nothing() {
        let link = LinkModel::ethernet_10g();
        assert_eq!(allreduce_time(1, 1 << 20, &link), 0.0);
        assert_eq!(compressed_allgather_time(1, 1 << 20, &link), 0.0);
        let mut rng = Pcg::new(1);
        assert_eq!(allreduce_time_faulty(1, 1 << 20, &link, 0.2, &mut rng), 0.0);
    }

    #[test]
    fn compressed_allgather_breaks_even_only_past_n_over_two() {
        // The structural disadvantage of compressed collectives vs
        // compressed gossip: the all-gather bandwidth term is n·enc
        // against the dense ring's ≈ 2·dense, so an 8× encoder wins at
        // n = 8 (8·enc = dense < 2·dense) but loses at n = 32
        // (32·enc = 4·dense > 2·dense). Gossip keeps the full 8× at any n.
        use crate::gossip::Compression;
        let link = LinkModel::ethernet_10g();
        let dense = 100 << 20;
        let enc = Compression::Qsgd { bits: 4 }.encoded_bytes(25 << 20, dense);
        assert!(enc * 8 <= dense + 8 * 8, "qsgd:4 is ≈ 8× (8-byte header): {enc}");
        assert!(
            compressed_allgather_time(8, enc, &link) < ring_allreduce_time(8, dense, &link),
            "small n: compressed all-gather wins"
        );
        assert!(
            compressed_allgather_time(32, enc, &link) > ring_allreduce_time(32, dense, &link),
            "large n: the dense ring wins back"
        );
    }

    #[test]
    fn faulty_allreduce_equals_clean_at_zero_drop() {
        let link = LinkModel::ethernet_10g();
        let mut rng = Pcg::new(2);
        for n in [4usize, 16, 32] {
            assert_eq!(
                allreduce_time_faulty(n, 100 << 20, &link, 0.0, &mut rng),
                allreduce_time(n, 100 << 20, &link)
            );
        }
    }

    #[test]
    fn faulty_allreduce_inflates_with_drop_rate_and_n() {
        let link = LinkModel::ethernet_10g();
        let avg = |n: usize, p: f64| {
            let mut rng = Pcg::new(3);
            (0..200)
                .map(|_| allreduce_time_faulty(n, 100 << 20, &link, p, &mut rng))
                .sum::<f64>()
                / 200.0
        };
        let clean = avg(16, 0.0);
        let lossy = avg(16, 0.05);
        assert!(lossy > 1.2 * clean, "5% loss must inflate: {clean} → {lossy}");
        // More parallel links ⇒ worse relative inflation (the scaling trap).
        let r8 = avg(8, 0.05) / avg(8, 0.0);
        let r32 = avg(32, 0.05) / avg(32, 0.0);
        assert!(r32 > r8, "inflation must grow with n: {r8} vs {r32}");
    }
}

//! Durable checkpoint/restore for the push-sum engines: a versioned,
//! CRC'd, length-framed binary snapshot of *everything* the
//! mass-conservation ledger and the bit-identity contract depend on —
//! per-node `(x, w)` states, the per-destination mailboxes in their exact
//! in-memory order, the per-edge error-feedback banks, the dropped-mass
//! ledger and counters, attached RNG cursors, and the membership epoch
//! the engine last reconciled against.
//!
//! # File layout
//!
//! A snapshot file is a fixed 48-byte header, a run of length-framed
//! sections, and a trailing CRC-32 over everything before it
//! (the same IEEE CRC the cluster wire format carries —
//! [`crate::net::cluster::wire::crc32`]):
//!
//! ```text
//! off  size  field
//! 0    u32   magic   = 0x5350_4753          # "SGPS" little-endian
//! 4    u16   version = 1
//! 6    u8    engine kind                    # 0 dense / 1 sparse / 2 event-dense
//! 7    u8    flags                          # bit0 biased, bit1 sparse section present
//! 8    u64   round                          # next round the restored engine executes
//! 16   u64   n                              # logical node count
//! 24   u64   dim                            # parameter dimension
//! 32   u64   delay                          # overlap τ
//! 40   u64   epoch                          # membership epoch last reconciled
//! 48   ..    sections                       # tag u8 | len u64 | payload, ascending tag
//! end  u32   crc                            # CRC-32 (IEEE) of bytes[..len-4]
//! ```
//!
//! Sections (all integers little-endian; always written in this order):
//!
//! | tag | section | payload |
//! |-----|---------|---------|
//! | 1 | nodes   | `u64 count`, then per node `dim × f32 x`, `f64 w` |
//! | 2 | mail    | `u64 dests`, per destination `u64 msgs`, per message `u64 from`, `u64 sent_iter`, `u64 deliver_iter`, `dim × f32 x`, `f64 w` |
//! | 3 | banks   | `u64 count`, per bank `u64 from`, `u64 to`, `dim × f32 x`, `f64 w` |
//! | 4 | ledger  | `dim × f64 dropped_x`, `f64 dropped_w`, `u64 drop/rescue/reconciled/sent counts`, `f64 recv_w`, `f64 sent_w`, `f64 rescued_w` |
//! | 5 | rng     | `u64 count`, per cursor `u64 state`, `u64 inc`, `u8 has_spare`, `f64 spare` |
//! | 6 | sparse  | `dim × f32 template_x`, `f64 template_w`, `u64 sent`, `u64 hot`, per hot node `u64 index`, `dim × f32 x`, `f64 w` |
//!
//! The **mailbox order is load-bearing**: the engine's `drain_due`
//! swap-remove scan makes the per-destination message permutation part of
//! the bit-identity contract (under τ ≥ 2 it determines *future*
//! application orders), so messages are serialized — and restored — in
//! their exact in-memory order, never sorted or canonicalized. The
//! arrival scheduler of event-mode execution is deliberately *not*
//! serialized: it is rebuilt losslessly from the restored mailboxes on
//! the next event-mode round.
//!
//! # Determinism contract
//!
//! `restore(save(engine))` at round `r` continues **bit-identical** to
//! the uninterrupted run, across every [`crate::gossip::ExecPolicy`],
//! under any fault plan and compression spec — pinned by the property
//! battery in `rust/tests/snapshot_resume.rs` and documented in
//! DESIGN.md §6. Decoding never panics: every malformed, truncated or
//! bit-flipped input maps to a [`SnapshotError`].

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

use crate::gossip::{EventEngine, PushSumEngine};
use crate::net::cluster::wire::crc32;
use crate::rng::Pcg;

/// Snapshot magic: "SGPS" little-endian.
pub const MAGIC: u32 = 0x5350_4753;
/// Snapshot format version this build reads and writes.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes (everything before the first section).
pub const HEADER_BYTES: usize = 48;
/// Upper bound on the node count a snapshot may declare — a corrupted
/// header errors instead of driving huge downstream allocations.
pub const MAX_NODES: u64 = 1 << 32;
/// Upper bound on the parameter dimension a snapshot may declare.
pub const MAX_DIM: u64 = 1 << 28;

const TAG_NODES: u8 = 1;
const TAG_MAIL: u8 = 2;
const TAG_BANKS: u8 = 3;
const TAG_LEDGER: u8 = 4;
const TAG_RNG: u8 = 5;
const TAG_SPARSE: u8 = 6;

const FLAG_BIASED: u8 = 1;
const FLAG_SPARSE: u8 = 2;

/// Errors produced by the snapshot codec and the restore path. Every
/// malformed input maps to a variant here — decoding never panics
/// (pinned by the corruption battery in `rust/tests/snapshot_resume.rs`).
#[derive(Debug)]
pub enum SnapshotError {
    /// Filesystem error while reading or writing a snapshot file.
    Io(io::Error),
    /// File did not start with [`MAGIC`].
    BadMagic(u32),
    /// Unknown snapshot format version.
    BadVersion(u16),
    /// Unknown engine-kind byte.
    BadKind(u8),
    /// CRC mismatch (bit corruption somewhere in the file).
    BadCrc {
        /// CRC computed over the received bytes.
        computed: u32,
        /// CRC carried by the file.
        carried: u32,
    },
    /// Input ended before a field or section could be read.
    Truncated {
        /// Bytes the decoder needed.
        needed: usize,
        /// Bytes that remained.
        have: usize,
    },
    /// Structurally invalid content (bad count, index out of range,
    /// section length mismatch, …). The string names the check.
    Malformed(&'static str),
    /// The snapshot's engine kind does not match the restore target
    /// (e.g. a sparse snapshot handed to [`PushSumEngine::restore`]).
    EngineMismatch(&'static str),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Io(e) => write!(f, "snapshot i/o error: {e}"),
            Self::BadMagic(m) => write!(f, "bad snapshot magic {m:#010x}"),
            Self::BadVersion(v) => write!(f, "unsupported snapshot version {v}"),
            Self::BadKind(k) => write!(f, "unknown engine kind {k}"),
            Self::BadCrc { computed, carried } => write!(
                f,
                "snapshot crc mismatch: computed {computed:#010x}, file carries {carried:#010x}"
            ),
            Self::Truncated { needed, have } => {
                write!(f, "snapshot truncated: needed {needed} bytes, {have} remained")
            }
            Self::Malformed(what) => write!(f, "malformed snapshot: {what}"),
            Self::EngineMismatch(what) => write!(f, "engine kind mismatch: {what}"),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for SnapshotError {
    fn from(e: io::Error) -> Self {
        Self::Io(e)
    }
}

/// Which engine a snapshot was captured from (header byte 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The dense [`PushSumEngine`].
    Dense,
    /// The sparse fast path of the [`EventEngine`] (template + hot set).
    Sparse,
    /// An [`EventEngine`] that has materialized into its dense escape
    /// hatch — restored as an event engine wrapping a dense core.
    EventDense,
}

impl EngineKind {
    fn to_byte(self) -> u8 {
        match self {
            Self::Dense => 0,
            Self::Sparse => 1,
            Self::EventDense => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Self, SnapshotError> {
        match b {
            0 => Ok(Self::Dense),
            1 => Ok(Self::Sparse),
            2 => Ok(Self::EventDense),
            other => Err(SnapshotError::BadKind(other)),
        }
    }
}

/// One persisted PRNG position (see [`Pcg::cursor`]) — harnesses attach
/// the cursors of whatever streams drive the run (gradient noise,
/// compression draws, perturbations) so a restored run continues the
/// exact sequences.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngCursor {
    /// PCG state word.
    pub state: u64,
    /// PCG stream increment.
    pub inc: u64,
    /// Cached Box–Muller spare, if one was pending.
    pub spare: Option<f64>,
}

impl RngCursor {
    /// Capture the position of a live generator.
    pub fn of(rng: &Pcg) -> Self {
        let (state, inc, spare) = rng.cursor();
        Self { state, inc, spare }
    }

    /// Rebuild the generator at this position.
    pub fn to_pcg(&self) -> Pcg {
        Pcg::from_cursor(self.state, self.inc, self.spare)
    }
}

/// One node's persisted `(x, w)` state.
#[derive(Clone, Debug)]
pub(crate) struct SnapNode {
    pub(crate) x: Vec<f32>,
    pub(crate) w: f64,
}

/// One in-flight message, destination implied by its mailbox.
#[derive(Clone, Debug)]
pub(crate) struct SnapMsg {
    pub(crate) from: u64,
    pub(crate) sent_iter: u64,
    pub(crate) deliver_iter: u64,
    pub(crate) x: Vec<f32>,
    pub(crate) w: f64,
}

/// One per-edge error-feedback bank.
#[derive(Clone, Debug)]
pub(crate) struct SnapBank {
    pub(crate) from: u64,
    pub(crate) to: u64,
    pub(crate) x: Vec<f32>,
    pub(crate) w: f64,
}

/// The dropped-mass ledger plus the engine's (and, on the deployment
/// path, the worker's) mass-flow counters.
#[derive(Clone, Debug, Default)]
pub(crate) struct SnapLedger {
    pub(crate) dropped_x: Vec<f64>,
    pub(crate) dropped_w: f64,
    pub(crate) drop_count: u64,
    pub(crate) rescue_count: u64,
    pub(crate) reconciled_count: u64,
    pub(crate) sent_count: u64,
    pub(crate) recv_w: f64,
    pub(crate) sent_w: f64,
    pub(crate) rescued_w: f64,
}

/// The sparse fast path's state: the shared cold template, the send
/// counter, and the materialized hot set.
#[derive(Clone, Debug)]
pub(crate) struct SnapSparse {
    pub(crate) template_x: Vec<f32>,
    pub(crate) template_w: f64,
    pub(crate) sent: u64,
    pub(crate) hot: Vec<(u64, Vec<f32>, f64)>,
}

/// A decoded (or freshly captured) engine snapshot.
///
/// Produce one with [`PushSumEngine::save`] / [`EventEngine::save`] or by
/// decoding bytes with [`Snapshot::from_bytes`]; turn it back into a live
/// engine with [`Snapshot::restore`]. The struct is deliberately opaque —
/// the fields are crate-internal so every snapshot in circulation is
/// either engine-captured or codec-validated.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub(crate) round: u64,
    pub(crate) kind: EngineKind,
    pub(crate) biased: bool,
    pub(crate) n: u64,
    pub(crate) dim: u64,
    pub(crate) delay: u64,
    pub(crate) epoch: u64,
    pub(crate) nodes: Vec<SnapNode>,
    pub(crate) mail: Vec<Vec<SnapMsg>>,
    pub(crate) banks: Vec<SnapBank>,
    pub(crate) ledger: SnapLedger,
    pub(crate) rngs: Vec<RngCursor>,
    pub(crate) sparse: Option<SnapSparse>,
}

/// The engine a [`Snapshot::restore`] call produced, matching the
/// snapshot's [`EngineKind`].
pub enum Restored {
    /// A dense [`PushSumEngine`].
    Dense(PushSumEngine),
    /// An [`EventEngine`] (sparse fast path or materialized-dense).
    Event(EventEngine),
}

impl Snapshot {
    /// The round the restored engine should execute next.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Which engine captured this snapshot.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Logical node count.
    pub fn n(&self) -> usize {
        self.n as usize
    }

    /// Parameter dimension.
    pub fn dim(&self) -> usize {
        self.dim as usize
    }

    /// Overlap delay τ of the captured engine.
    pub fn delay(&self) -> u64 {
        self.delay
    }

    /// Whether the captured engine ran the biased (w ≡ 1) ablation.
    pub fn biased(&self) -> bool {
        self.biased
    }

    /// Membership epoch the engine had last reconciled its banks against
    /// (see `PushSumEngine::save`) — the field that routes
    /// rejoin-from-checkpoint through the survivor schedule.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// RNG cursors attached by the capturing harness (empty unless
    /// [`Self::set_rngs`] was called).
    pub fn rngs(&self) -> &[RngCursor] {
        &self.rngs
    }

    /// Attach the PRNG cursors of the harness streams driving the run, so
    /// a restore can continue their draw sequences bit-identically.
    pub fn set_rngs(&mut self, rngs: Vec<RngCursor>) {
        self.rngs = rngs;
    }

    /// Rebuild a live engine from this snapshot, dispatching on the
    /// engine kind. The restored engine continues **bit-identical** to
    /// the uninterrupted run — the determinism contract pinned by
    /// `rust/tests/snapshot_resume.rs`.
    ///
    /// ```
    /// use sgp::gossip::PushSumEngine;
    /// use sgp::snapshot::{Restored, Snapshot};
    /// use sgp::topology::{Schedule, TopologyKind};
    ///
    /// let init: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32]).collect();
    /// let mut live = PushSumEngine::new(init, 0, false);
    /// let sched = Schedule::new(TopologyKind::OnePeerExp, 4);
    /// for k in 0..3 {
    ///     live.step(k, &sched);
    /// }
    ///
    /// // Durable roundtrip: engine → bytes → decoded snapshot → engine.
    /// let snap = Snapshot::from_bytes(&live.save(3).to_bytes()).unwrap();
    /// let mut back = match snap.restore().unwrap() {
    ///     Restored::Dense(e) => e,
    ///     Restored::Event(_) => unreachable!("dense snapshot"),
    /// };
    ///
    /// // Both engines continue bit-identically from round 3.
    /// for k in 3..8 {
    ///     live.step(k, &sched);
    ///     back.step(k, &sched);
    /// }
    /// for (a, b) in live.states.iter().zip(&back.states) {
    ///     assert_eq!(a.w.to_bits(), b.w.to_bits());
    ///     assert!(a.x.iter().zip(&b.x).all(|(p, q)| p.to_bits() == q.to_bits()));
    /// }
    /// ```
    pub fn restore(&self) -> Result<Restored, SnapshotError> {
        match self.kind {
            EngineKind::Dense => Ok(Restored::Dense(PushSumEngine::restore(self)?)),
            EngineKind::Sparse | EngineKind::EventDense => {
                Ok(Restored::Event(EventEngine::restore(self)?))
            }
        }
    }

    /// Serialize to the binary file format (header, sections, CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_BYTES + 64);
        put_u32(&mut out, MAGIC);
        put_u16(&mut out, VERSION);
        out.push(self.kind.to_byte());
        let mut flags = 0u8;
        if self.biased {
            flags |= FLAG_BIASED;
        }
        if self.sparse.is_some() {
            flags |= FLAG_SPARSE;
        }
        out.push(flags);
        put_u64(&mut out, self.round);
        put_u64(&mut out, self.n);
        put_u64(&mut out, self.dim);
        put_u64(&mut out, self.delay);
        put_u64(&mut out, self.epoch);

        let mut body = Vec::new();

        put_u64(&mut body, self.nodes.len() as u64);
        for nd in &self.nodes {
            put_f32s(&mut body, &nd.x);
            put_f64(&mut body, nd.w);
        }
        section(&mut out, TAG_NODES, &mut body);

        put_u64(&mut body, self.mail.len() as u64);
        for mailbox in &self.mail {
            put_u64(&mut body, mailbox.len() as u64);
            for m in mailbox {
                put_u64(&mut body, m.from);
                put_u64(&mut body, m.sent_iter);
                put_u64(&mut body, m.deliver_iter);
                put_f32s(&mut body, &m.x);
                put_f64(&mut body, m.w);
            }
        }
        section(&mut out, TAG_MAIL, &mut body);

        put_u64(&mut body, self.banks.len() as u64);
        for b in &self.banks {
            put_u64(&mut body, b.from);
            put_u64(&mut body, b.to);
            put_f32s(&mut body, &b.x);
            put_f64(&mut body, b.w);
        }
        section(&mut out, TAG_BANKS, &mut body);

        for &d in &self.ledger.dropped_x {
            put_f64(&mut body, d);
        }
        put_f64(&mut body, self.ledger.dropped_w);
        put_u64(&mut body, self.ledger.drop_count);
        put_u64(&mut body, self.ledger.rescue_count);
        put_u64(&mut body, self.ledger.reconciled_count);
        put_u64(&mut body, self.ledger.sent_count);
        put_f64(&mut body, self.ledger.recv_w);
        put_f64(&mut body, self.ledger.sent_w);
        put_f64(&mut body, self.ledger.rescued_w);
        section(&mut out, TAG_LEDGER, &mut body);

        put_u64(&mut body, self.rngs.len() as u64);
        for c in &self.rngs {
            put_u64(&mut body, c.state);
            put_u64(&mut body, c.inc);
            body.push(u8::from(c.spare.is_some()));
            put_f64(&mut body, c.spare.unwrap_or(0.0));
        }
        section(&mut out, TAG_RNG, &mut body);

        if let Some(sp) = &self.sparse {
            put_f32s(&mut body, &sp.template_x);
            put_f64(&mut body, sp.template_w);
            put_u64(&mut body, sp.sent);
            put_u64(&mut body, sp.hot.len() as u64);
            for (i, x, w) in &sp.hot {
                put_u64(&mut body, *i);
                put_f32s(&mut body, x);
                put_f64(&mut body, *w);
            }
            section(&mut out, TAG_SPARSE, &mut body);
        }

        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Decode a snapshot from file bytes, validating magic, version,
    /// engine kind, the trailing CRC, every section length, and every
    /// index bound. Malformed input returns a [`SnapshotError`] — never
    /// a panic, never an attacker-sized allocation.
    pub fn from_bytes(buf: &[u8]) -> Result<Self, SnapshotError> {
        if buf.len() < HEADER_BYTES + 4 {
            return Err(SnapshotError::Truncated {
                needed: HEADER_BYTES + 4,
                have: buf.len(),
            });
        }
        let mut r = Reader::new(&buf[..buf.len() - 4]);
        let magic = r.u32()?;
        if magic != MAGIC {
            return Err(SnapshotError::BadMagic(magic));
        }
        let version = r.u16()?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let kind = EngineKind::from_byte(r.u8()?)?;
        let flags = r.u8()?;
        let round = r.u64()?;
        let n = r.u64()?;
        let dim = r.u64()?;
        let delay = r.u64()?;
        let epoch = r.u64()?;
        if n == 0 || n > MAX_NODES {
            return Err(SnapshotError::Malformed("node count out of range"));
        }
        if dim == 0 || dim > MAX_DIM {
            return Err(SnapshotError::Malformed("dimension out of range"));
        }
        let carried = u32::from_le_bytes(match buf[buf.len() - 4..].try_into() {
            Ok(b) => b,
            Err(_) => return Err(SnapshotError::Malformed("crc field")),
        });
        let computed = crc32(&buf[..buf.len() - 4]);
        if computed != carried {
            return Err(SnapshotError::BadCrc { computed, carried });
        }
        let d = dim as usize;

        let mut s = r.sub_section(TAG_NODES)?;
        let count = s.counted(4 * d + 8)?;
        if count != 0 && count != n as usize {
            return Err(SnapshotError::Malformed("node section count"));
        }
        let mut nodes = Vec::with_capacity(count);
        for _ in 0..count {
            nodes.push(SnapNode { x: s.f32s(d)?, w: s.f64()? });
        }
        s.finish("nodes")?;

        let mut s = r.sub_section(TAG_MAIL)?;
        let dests = s.counted(8)?;
        if dests != 0 && dests != n as usize {
            return Err(SnapshotError::Malformed("mail section destination count"));
        }
        let mut mail = Vec::with_capacity(dests);
        for _ in 0..dests {
            let msgs = s.counted(24 + 4 * d + 8)?;
            let mut mailbox = Vec::with_capacity(msgs);
            for _ in 0..msgs {
                let from = s.u64()?;
                if from >= n {
                    return Err(SnapshotError::Malformed("message sender out of range"));
                }
                mailbox.push(SnapMsg {
                    from,
                    sent_iter: s.u64()?,
                    deliver_iter: s.u64()?,
                    x: s.f32s(d)?,
                    w: s.f64()?,
                });
            }
            mail.push(mailbox);
        }
        s.finish("mail")?;

        let mut s = r.sub_section(TAG_BANKS)?;
        let count = s.counted(16 + 4 * d + 8)?;
        let mut banks = Vec::with_capacity(count);
        for _ in 0..count {
            let from = s.u64()?;
            let to = s.u64()?;
            if from >= n || to >= n {
                return Err(SnapshotError::Malformed("bank edge out of range"));
            }
            banks.push(SnapBank { from, to, x: s.f32s(d)?, w: s.f64()? });
        }
        s.finish("banks")?;

        let mut s = r.sub_section(TAG_LEDGER)?;
        let mut dropped_x = Vec::with_capacity(d);
        for _ in 0..d {
            dropped_x.push(s.f64()?);
        }
        let ledger = SnapLedger {
            dropped_x,
            dropped_w: s.f64()?,
            drop_count: s.u64()?,
            rescue_count: s.u64()?,
            reconciled_count: s.u64()?,
            sent_count: s.u64()?,
            recv_w: s.f64()?,
            sent_w: s.f64()?,
            rescued_w: s.f64()?,
        };
        s.finish("ledger")?;

        let mut s = r.sub_section(TAG_RNG)?;
        let count = s.counted(25)?;
        let mut rngs = Vec::with_capacity(count);
        for _ in 0..count {
            let state = s.u64()?;
            let inc = s.u64()?;
            let has = s.u8()?;
            let spare = s.f64()?;
            rngs.push(RngCursor { state, inc, spare: (has != 0).then_some(spare) });
        }
        s.finish("rng")?;

        let sparse = if flags & FLAG_SPARSE != 0 {
            let mut s = r.sub_section(TAG_SPARSE)?;
            let template_x = s.f32s(d)?;
            let template_w = s.f64()?;
            let sent = s.u64()?;
            let hot_count = s.counted(8 + 4 * d + 8)?;
            let mut hot = Vec::with_capacity(hot_count);
            let mut prev: Option<u64> = None;
            for _ in 0..hot_count {
                let i = s.u64()?;
                if i >= n {
                    return Err(SnapshotError::Malformed("hot index out of range"));
                }
                if prev.is_some_and(|p| p >= i) {
                    return Err(SnapshotError::Malformed("hot indices not ascending"));
                }
                prev = Some(i);
                hot.push((i, s.f32s(d)?, s.f64()?));
            }
            s.finish("sparse")?;
            Some(hot).map(|hot| SnapSparse { template_x, template_w, sent, hot })
        } else {
            None
        };
        r.finish("file")?;

        // Cross-section consistency with the engine kind.
        match kind {
            EngineKind::Dense | EngineKind::EventDense => {
                if nodes.len() != n as usize || mail.len() != n as usize {
                    return Err(SnapshotError::Malformed(
                        "dense snapshot requires n nodes and n mailboxes",
                    ));
                }
                if sparse.is_some() {
                    return Err(SnapshotError::Malformed(
                        "dense snapshot carries a sparse section",
                    ));
                }
            }
            EngineKind::Sparse => {
                if sparse.is_none() {
                    return Err(SnapshotError::Malformed(
                        "sparse snapshot missing its sparse section",
                    ));
                }
                if !nodes.is_empty() || mail.iter().any(|m| !m.is_empty()) {
                    return Err(SnapshotError::Malformed(
                        "sparse snapshot carries dense node state",
                    ));
                }
            }
        }

        Ok(Self {
            round,
            kind,
            biased: flags & FLAG_BIASED != 0,
            n,
            dim,
            delay,
            epoch,
            nodes,
            mail,
            banks,
            ledger,
            rngs,
            sparse,
        })
    }

    /// Write the snapshot to `path` (creating parent directories).
    pub fn write_file(&self, path: &Path) -> Result<(), SnapshotError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    /// Read and decode a snapshot file.
    pub fn read_file(path: &Path) -> Result<Self, SnapshotError> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

/// When the run should capture a snapshot: a round cadence, a
/// membership-change trigger, or both. Threaded through
/// [`crate::coordinator::TrainerBuilder`], the fault harness
/// ([`crate::faults::harness::FaultRunConfig`]), and the cluster worker.
///
/// ```
/// use sgp::snapshot::SnapshotPolicy;
///
/// // Every 5 rounds: due after rounds 4, 9, 14, … (rounds are 0-based).
/// let p = SnapshotPolicy::every(5);
/// assert!(!p.due(3, false) && p.due(4, false) && !p.due(5, false));
///
/// // Membership changes force a capture regardless of the cadence.
/// let p = p.and_on_membership_change();
/// assert!(p.due(7, true) && !p.due(7, false));
///
/// // `never()` is inert, so callers can thread it unconditionally.
/// assert!(!SnapshotPolicy::never().due(0, true));
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotPolicy {
    /// Capture after every `every_rounds`-th round (0 disables the
    /// cadence).
    pub every_rounds: u64,
    /// Also capture on any round whose membership epoch changed (crash,
    /// rejoin, permanent leave).
    pub on_membership_change: bool,
}

impl SnapshotPolicy {
    /// Never capture.
    pub fn never() -> Self {
        Self { every_rounds: 0, on_membership_change: false }
    }

    /// Capture after every `k`-th round (after rounds k−1, 2k−1, …).
    /// `k = 0` disables the cadence (equivalent to [`Self::never`]).
    pub fn every(k: u64) -> Self {
        Self { every_rounds: k, on_membership_change: false }
    }

    /// Additionally capture whenever the membership epoch changes.
    pub fn and_on_membership_change(mut self) -> Self {
        self.on_membership_change = true;
        self
    }

    /// Whether a snapshot is due after executing round `round`
    /// (`epoch_changed` reports whether this round crossed a
    /// membership-epoch boundary).
    pub fn due(&self, round: u64, epoch_changed: bool) -> bool {
        (self.every_rounds > 0 && (round + 1) % self.every_rounds == 0)
            || (self.on_membership_change && epoch_changed)
    }
}

/// A policy plus the directory its captures land in — the unit the
/// trainer, the fault harness and the worker thread through their
/// configs. File names are `{label}.r{round:08}.snap`, so a directory
/// holds the full per-label history and the latest capture is the
/// lexically greatest file.
#[derive(Clone, Debug)]
pub struct SnapshotSink {
    /// When to capture.
    pub policy: SnapshotPolicy,
    /// Directory snapshot files are written into (created on first
    /// store).
    pub dir: PathBuf,
}

impl SnapshotSink {
    /// A sink writing `policy`-triggered captures into `dir`.
    pub fn new(policy: SnapshotPolicy, dir: impl Into<PathBuf>) -> Self {
        Self { policy, dir: dir.into() }
    }

    /// The file path a capture of `label` at `round` is stored under.
    pub fn path_for(&self, label: &str, round: u64) -> PathBuf {
        self.dir.join(format!("{label}.r{round:08}.snap"))
    }

    /// Write `snap` into the sink's directory under `label`, returning
    /// the path written.
    pub fn store(&self, label: &str, snap: &Snapshot) -> Result<PathBuf, SnapshotError> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.path_for(label, snap.round());
        std::fs::write(&path, snap.to_bytes())?;
        Ok(path)
    }
}

// --- little-endian encode helpers -----------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append one `tag | len | payload` section, draining `body` for reuse.
fn section(out: &mut Vec<u8>, tag: u8, body: &mut Vec<u8>) {
    out.push(tag);
    put_u64(out, body.len() as u64);
    out.extend_from_slice(body);
    body.clear();
}

// --- bounded decode cursor -------------------------------------------------

/// A bounds-checked cursor over snapshot bytes: every read either
/// succeeds inside the buffer or returns [`SnapshotError::Truncated`].
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated { needed: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, SnapshotError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, SnapshotError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, SnapshotError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, SnapshotError> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read an element count and bound it by the bytes actually present
    /// (`min_item_bytes` per element), so a corrupted count can never
    /// drive a huge allocation.
    fn counted(&mut self, min_item_bytes: usize) -> Result<usize, SnapshotError> {
        let count = self.u64()?;
        let cap = (self.remaining() / min_item_bytes.max(1)) as u64;
        if count > cap {
            return Err(SnapshotError::Malformed("count exceeds section payload"));
        }
        Ok(count as usize)
    }

    /// Expect the next section to carry `tag`; return a sub-reader over
    /// exactly its payload.
    fn sub_section(&mut self, tag: u8) -> Result<Reader<'a>, SnapshotError> {
        let t = self.u8()?;
        if t != tag {
            return Err(SnapshotError::Malformed("unexpected section tag"));
        }
        let len = self.u64()?;
        if len > self.remaining() as u64 {
            return Err(SnapshotError::Truncated {
                needed: len as usize,
                have: self.remaining(),
            });
        }
        Ok(Reader::new(self.take(len as usize)?))
    }

    /// Assert the cursor consumed its buffer exactly — a leftover byte
    /// means a length field lied.
    fn finish(&self, _what: &'static str) -> Result<(), SnapshotError> {
        if self.remaining() != 0 {
            return Err(SnapshotError::Malformed("trailing bytes in section"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Schedule, TopologyKind};

    fn tiny_engine() -> PushSumEngine {
        let init: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32, -0.5 * i as f32]).collect();
        PushSumEngine::new(init, 1, false)
    }

    #[test]
    fn header_roundtrip_and_accessors() {
        let mut eng = tiny_engine();
        let sched = Schedule::new(TopologyKind::OnePeerExp, 4);
        for k in 0..5 {
            eng.step(k, &sched);
        }
        let snap = eng.save(5);
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back.round(), 5);
        assert_eq!(back.kind(), EngineKind::Dense);
        assert_eq!(back.n(), 4);
        assert_eq!(back.dim(), 2);
        assert_eq!(back.delay(), 1);
        assert!(!back.biased());
    }

    #[test]
    fn rng_cursors_survive_the_roundtrip() {
        let mut rng = Pcg::new(7);
        let _ = rng.gaussian(); // leave a cached spare in the cursor
        let mut snap = tiny_engine().save(0);
        snap.set_rngs(vec![RngCursor::of(&rng)]);
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back.rngs().len(), 1);
        let mut a = rng.clone();
        let mut b = back.rngs()[0].to_pcg();
        for _ in 0..32 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
        }
    }

    #[test]
    fn bad_magic_version_kind_and_crc_error_cleanly() {
        let bytes = tiny_engine().save(0).to_bytes();

        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(
            Snapshot::from_bytes(&bad),
            Err(SnapshotError::BadMagic(_))
        ));

        let mut bad = bytes.clone();
        bad[4] = 0xEE;
        assert!(matches!(
            Snapshot::from_bytes(&bad),
            Err(SnapshotError::BadVersion(_))
        ));

        let mut bad = bytes.clone();
        bad[6] = 9;
        assert!(matches!(Snapshot::from_bytes(&bad), Err(SnapshotError::BadKind(9))));

        // A flipped payload bit is caught by the CRC, not a panic.
        let mut bad = bytes.clone();
        let mid = bytes.len() / 2;
        bad[mid] ^= 0x10;
        assert!(matches!(
            Snapshot::from_bytes(&bad),
            Err(SnapshotError::BadCrc { .. })
        ));
    }

    #[test]
    fn truncation_at_every_length_errors_never_panics() {
        let bytes = tiny_engine().save(3).to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                Snapshot::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }

    #[test]
    fn policy_cadence_and_membership_trigger() {
        let p = SnapshotPolicy::every(4);
        let due: Vec<u64> = (0..12).filter(|&k| p.due(k, false)).collect();
        assert_eq!(due, vec![3, 7, 11]);
        assert!(!p.due(5, true), "membership trigger off by default");
        let p = p.and_on_membership_change();
        assert!(p.due(5, true));
        assert!(!SnapshotPolicy::never().due(9, false));
    }

    #[test]
    fn sink_store_and_read_back() {
        let dir = std::env::temp_dir().join(format!("sgp_snap_sink_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sink = SnapshotSink::new(SnapshotPolicy::every(2), &dir);
        let snap = tiny_engine().save(7);
        let path = sink.store("unit", &snap).unwrap();
        assert_eq!(path, sink.path_for("unit", 7));
        let back = Snapshot::read_file(&path).unwrap();
        assert_eq!(back.round(), 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kind_mismatch_is_a_typed_error() {
        let snap = tiny_engine().save(0);
        assert!(matches!(
            EventEngine::restore(&snap),
            Err(SnapshotError::EngineMismatch(_))
        ));
    }
}

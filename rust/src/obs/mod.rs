//! Unified observability: structured tracing, per-node/per-edge gossip
//! metrics, and hot-path phase profiling across the simulator and the
//! real deployment.
//!
//! Three pieces, one schema:
//!
//! * **Recorders** (this module) — [`ObsSink`], the event-sink trait the
//!   runtime surfaces call into, plus two concrete ring-buffered
//!   implementations: [`EngineObs`] (attached to
//!   [`crate::gossip::PushSumEngine`] via `set_obs`) and [`TimingObs`]
//!   (attached to [`crate::net::TimingSim`]). Every counter is
//!   pre-allocated at construction — per-node arrays, a flat per-edge
//!   matrix, a fixed-capacity round ring — so recording on the gossip
//!   hot path performs **zero heap allocations** after warm-up
//!   (`rust/tests/alloc_regression.rs` runs with an `EngineObs`
//!   attached).
//! * **Trace schema** ([`trace`]) — the versioned JSONL format every
//!   surface emits (engine/sim recorders, the deployment coordinator's
//!   membership log, worker-side traces) and the parser built on the
//!   repo's own [`crate::model::json`] reader.
//! * **Analysis** ([`analyze`]) — the `repro trace` report: per-node
//!   summaries, straggler ranking, bytes-per-edge matrix, mass-ledger
//!   reconciliation, and a round-latency histogram.
//!
//! # Zero-allocation constraints
//!
//! The engine's merge phase runs with an `EngineObs` borrowed out of the
//! engine (`Option<Box<_>>::take`, a move, not a clone); per-message
//! recording is two array index bumps, and the per-round record is a
//! `Copy` struct written into a pre-filled ring slot (oldest overwritten
//! once full). Phase timers use [`std::time::Instant`] (vDSO
//! `clock_gettime` — no allocation) and are only read when a sink is
//! attached, so an un-instrumented engine pays a single branch per round.

pub mod analyze;
pub mod trace;

/// The three phases of one sharded gossip round (see
/// ARCHITECTURE.md §3): parallel compute+send, the deterministic ordered
/// merge, parallel aggregate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Phase 1 — per-shard local compute + send into shard outboxes.
    Compute,
    /// Phase 2 — ordered merge on the coordinating thread.
    Merge,
    /// Phase 3 — per-shard aggregation of due deliveries.
    Aggregate,
}

impl Phase {
    /// Stable lowercase label used in trace output.
    pub fn label(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Merge => "merge",
            Phase::Aggregate => "aggregate",
        }
    }
}

/// One gossip round's observed counters and span timers. Plain `Copy`
/// data: writing a record is a slot assignment, never an allocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRecord {
    /// Iteration index the round ran at.
    pub k: u64,
    /// Messages put on the wire this round (delivered + dropped; rescued
    /// sends never transmit).
    pub msgs: u64,
    /// Messages dropped into the loss ledger this round.
    pub dropped: u64,
    /// Messages rescued (re-absorbed at the sender) this round.
    pub rescued: u64,
    /// Encoded wire bytes for this round's messages
    /// (`msgs × Compression::encoded_bytes`).
    pub wire_bytes: u64,
    /// ℓ1 norm of all error-feedback bank numerators after the round
    /// (0 under identity compression).
    pub bank_l1: f64,
    /// Push-sum weight held across all error-feedback banks after the
    /// round.
    pub bank_w: f64,
    /// Wall nanoseconds of the compute+send phase.
    pub compute_ns: u64,
    /// Wall nanoseconds of the ordered merge phase.
    pub merge_ns: u64,
    /// Wall nanoseconds of the aggregate phase.
    pub aggregate_ns: u64,
    /// Nanoseconds the coordinating thread spent blocked in pool
    /// dispatch/barrier handoffs this round (0 on the sequential path).
    pub pool_wait_ns: u64,
}

/// The event-sink interface the runtime surfaces call into. Every method
/// takes plain scalars or a borrowed `Copy` record and defaults to a
/// no-op, so implementations choose what to retain and callers pay
/// nothing for events a sink ignores. Implementations must not allocate
/// in these callbacks — they run on the gossip hot path under the
/// zero-allocation regression gate.
pub trait ObsSink {
    /// One gossip round completed.
    fn on_round(&mut self, rec: &RoundRecord) {
        let _ = rec;
    }

    /// One message entered a mailbox (merge phase): `from → to`,
    /// `wire_bytes` encoded bytes.
    fn on_send(&mut self, from: usize, to: usize, wire_bytes: u64) {
        let _ = (from, to, wire_bytes);
    }

    /// One message was dropped into the loss ledger (merge phase).
    fn on_drop(&mut self, from: usize, to: usize, wire_bytes: u64) {
        let _ = (from, to, wire_bytes);
    }

    /// One timing-simulator iteration advanced: the makespan after it and
    /// the node whose clock is the new maximum (the straggler).
    fn on_iter(&mut self, k: u64, makespan_s: f64, slowest: usize) {
        let _ = (k, makespan_s, slowest);
    }
}

/// Per-edge tracking is a dense `n × n` matrix; above this node count it
/// is skipped (per-node counters remain) so attaching observability to a
/// large-N sweep engine cannot allocate hundreds of megabytes.
pub const MAX_EDGE_TRACK_NODES: usize = 512;

/// Ring-buffered recorder for [`crate::gossip::PushSumEngine`]: per-node
/// send/receive/drop counters, a per-edge byte/message matrix (for
/// `n ≤` [`MAX_EDGE_TRACK_NODES`]), and the last `cap` [`RoundRecord`]s.
/// All storage is allocated in [`EngineObs::new`]; recording never
/// allocates.
#[derive(Clone, Debug)]
pub struct EngineObs {
    n: usize,
    /// Messages sent per source node (whole run).
    sent_msgs: Vec<u64>,
    /// Messages received per destination node (whole run).
    recv_msgs: Vec<u64>,
    /// Messages dropped per source node (whole run).
    drop_msgs: Vec<u64>,
    /// Flat `n × n` wire-byte matrix (`from * n + to`); empty when edge
    /// tracking is disabled.
    edge_bytes: Vec<u64>,
    /// Flat `n × n` message-count matrix; empty when edge tracking is
    /// disabled.
    edge_msgs: Vec<u64>,
    /// Fixed-capacity round ring (pre-filled; oldest overwritten).
    ring: Vec<RoundRecord>,
    head: usize,
    len: usize,
    /// Whole-run totals (survive ring wrap-around).
    total_rounds: u64,
    total_msgs: u64,
    total_dropped: u64,
    total_rescued: u64,
    total_wire_bytes: u64,
}

impl EngineObs {
    /// A recorder for `n` nodes keeping the most recent `cap` round
    /// records (`cap` is clamped to ≥ 1). This is the only allocating
    /// call; everything after is index arithmetic.
    pub fn new(n: usize, cap: usize) -> Self {
        let cap = cap.max(1);
        let edges = if n <= MAX_EDGE_TRACK_NODES { n * n } else { 0 };
        Self {
            n,
            sent_msgs: vec![0; n],
            recv_msgs: vec![0; n],
            drop_msgs: vec![0; n],
            edge_bytes: vec![0; edges],
            edge_msgs: vec![0; edges],
            ring: vec![RoundRecord::default(); cap],
            head: 0,
            len: 0,
            total_rounds: 0,
            total_msgs: 0,
            total_dropped: 0,
            total_rescued: 0,
            total_wire_bytes: 0,
        }
    }

    /// Node count this recorder was sized for.
    pub fn nodes(&self) -> usize {
        self.n
    }

    /// Whether the per-edge matrix is being tracked
    /// (`n ≤` [`MAX_EDGE_TRACK_NODES`]).
    pub fn tracks_edges(&self) -> bool {
        !self.edge_msgs.is_empty()
    }

    /// Wire bytes recorded on the edge `from → to` (0 when edge tracking
    /// is disabled).
    pub fn edge_bytes(&self, from: usize, to: usize) -> u64 {
        if self.tracks_edges() { self.edge_bytes[from * self.n + to] } else { 0 }
    }

    /// Messages recorded on the edge `from → to` (0 when edge tracking is
    /// disabled).
    pub fn edge_msgs(&self, from: usize, to: usize) -> u64 {
        if self.tracks_edges() { self.edge_msgs[from * self.n + to] } else { 0 }
    }

    /// Per-node `(sent, received, dropped)` message counts.
    pub fn node_counts(&self, node: usize) -> (u64, u64, u64) {
        (self.sent_msgs[node], self.recv_msgs[node], self.drop_msgs[node])
    }

    /// Whole-run totals `(rounds, msgs, dropped, rescued, wire_bytes)` —
    /// these survive ring wrap-around.
    pub fn totals(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.total_rounds,
            self.total_msgs,
            self.total_dropped,
            self.total_rescued,
            self.total_wire_bytes,
        )
    }

    /// The retained round records, oldest first (at most `cap`).
    pub fn rounds(&self) -> impl Iterator<Item = &RoundRecord> {
        let cap = self.ring.len();
        (0..self.len).map(move |i| &self.ring[(self.head + i) % cap])
    }
}

impl ObsSink for EngineObs {
    fn on_round(&mut self, rec: &RoundRecord) {
        let cap = self.ring.len();
        if self.len < cap {
            self.ring[(self.head + self.len) % cap] = *rec;
            self.len += 1;
        } else {
            self.ring[self.head] = *rec;
            self.head = (self.head + 1) % cap;
        }
        self.total_rounds += 1;
        self.total_msgs += rec.msgs;
        self.total_dropped += rec.dropped;
        self.total_rescued += rec.rescued;
        self.total_wire_bytes += rec.wire_bytes;
    }

    fn on_send(&mut self, from: usize, to: usize, wire_bytes: u64) {
        self.sent_msgs[from] += 1;
        self.recv_msgs[to] += 1;
        if !self.edge_msgs.is_empty() {
            let e = from * self.n + to;
            self.edge_msgs[e] += 1;
            self.edge_bytes[e] += wire_bytes;
        }
    }

    fn on_drop(&mut self, from: usize, to: usize, wire_bytes: u64) {
        // A dropped message was on the wire: it counts for the sender and
        // the edge, but never reached the receiver.
        self.sent_msgs[from] += 1;
        self.drop_msgs[from] += 1;
        if !self.edge_msgs.is_empty() {
            let e = from * self.n + to;
            self.edge_msgs[e] += 1;
            self.edge_bytes[e] += wire_bytes;
        }
    }
}

/// One observed timing-simulator iteration (`Copy`, ring-stored).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterStat {
    /// Iteration index.
    pub k: u64,
    /// Simulated makespan (max node clock) after the iteration, seconds.
    pub makespan_s: f64,
    /// Node whose clock is the maximum — the iteration's straggler.
    pub slowest: u32,
}

/// Ring-buffered recorder for [`crate::net::TimingSim`]: the last `cap`
/// per-iteration makespans plus a whole-run per-node straggler count
/// (how often each node's clock was the round maximum). Pre-allocated;
/// recording never allocates.
#[derive(Clone, Debug)]
pub struct TimingObs {
    ring: Vec<IterStat>,
    head: usize,
    len: usize,
    /// Per-node count of iterations where this node was the slowest.
    slowest_counts: Vec<u64>,
    total_iters: u64,
}

impl TimingObs {
    /// A recorder for `n` nodes keeping the most recent `cap` iteration
    /// stats (`cap` clamped to ≥ 1).
    pub fn new(n: usize, cap: usize) -> Self {
        Self {
            ring: vec![IterStat::default(); cap.max(1)],
            head: 0,
            len: 0,
            slowest_counts: vec![0; n],
            total_iters: 0,
        }
    }

    /// Iterations recorded over the whole run.
    pub fn total_iters(&self) -> u64 {
        self.total_iters
    }

    /// Per-node straggler counts (iterations where the node's clock was
    /// the maximum).
    pub fn slowest_counts(&self) -> &[u64] {
        &self.slowest_counts
    }

    /// The retained iteration stats, oldest first.
    pub fn iters(&self) -> impl Iterator<Item = &IterStat> {
        let cap = self.ring.len();
        (0..self.len).map(move |i| &self.ring[(self.head + i) % cap])
    }
}

impl ObsSink for TimingObs {
    fn on_iter(&mut self, k: u64, makespan_s: f64, slowest: usize) {
        let rec = IterStat { k, makespan_s, slowest: slowest as u32 };
        let cap = self.ring.len();
        if self.len < cap {
            self.ring[(self.head + self.len) % cap] = rec;
            self.len += 1;
        } else {
            self.ring[self.head] = rec;
            self.head = (self.head + 1) % cap;
        }
        if slowest < self.slowest_counts.len() {
            self.slowest_counts[slowest] += 1;
        }
        self.total_iters += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_obs_ring_overwrites_oldest_and_totals_survive() {
        let mut o = EngineObs::new(4, 3);
        for k in 0..5u64 {
            o.on_round(&RoundRecord { k, msgs: 1, wire_bytes: 10, ..Default::default() });
        }
        let ks: Vec<u64> = o.rounds().map(|r| r.k).collect();
        assert_eq!(ks, vec![2, 3, 4], "ring keeps the newest cap records");
        let (rounds, msgs, _, _, bytes) = o.totals();
        assert_eq!((rounds, msgs, bytes), (5, 5, 50), "totals cover all rounds");
    }

    #[test]
    fn engine_obs_edge_matrix_and_node_counts() {
        let mut o = EngineObs::new(3, 8);
        o.on_send(0, 1, 100);
        o.on_send(0, 1, 100);
        o.on_send(2, 0, 100);
        o.on_drop(1, 2, 100);
        assert_eq!(o.edge_msgs(0, 1), 2);
        assert_eq!(o.edge_bytes(0, 1), 200);
        assert_eq!(o.node_counts(0), (2, 1, 0));
        assert_eq!(o.node_counts(1), (1, 2, 1), "drops count as sent, not received");
    }

    #[test]
    fn edge_tracking_disables_above_the_cap() {
        let o = EngineObs::new(MAX_EDGE_TRACK_NODES + 1, 4);
        assert!(!o.tracks_edges());
        assert_eq!(o.edge_bytes(0, 1), 0);
    }

    #[test]
    fn timing_obs_counts_stragglers() {
        let mut o = TimingObs::new(3, 2);
        o.on_iter(0, 1.0, 2);
        o.on_iter(1, 2.0, 2);
        o.on_iter(2, 3.0, 0);
        assert_eq!(o.slowest_counts(), &[1, 0, 2]);
        assert_eq!(o.total_iters(), 3);
        let ks: Vec<u64> = o.iters().map(|s| s.k).collect();
        assert_eq!(ks, vec![1, 2]);
    }
}

//! The `repro trace` analyzer: offline summaries over a parsed trace.
//!
//! Reads one JSONL trace (any source — `engine`, `sim`, `coord`,
//! `worker`, `soak`) and prints per-node summaries: a straggler ranking by
//! phase latency or degraded-span count, a bytes-per-edge matrix,
//! drop/rescue totals, and a round-latency histogram. For coordinator
//! and worker traces it additionally **re-derives the push-sum mass
//! ledger** from the raw `done`/`audit` events — `w = 1 + recv_w −
//! sent_w` per node, `missing_w = world − Σ w` over clean survivors —
//! and fails (non-zero CLI exit) when the recomputed numbers drift from
//! the logged ones by more than [`TOL`]. Because the trace writer
//! round-trips every float exactly, a healthy trace reconciles to 0.0.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::trace::{TraceEvent, TraceFile};
use crate::metrics::print_table;

/// Reconciliation tolerance: recomputed ledger quantities must match
/// the logged ones to within this absolute error.
pub const TOL: f64 = 1e-9;

/// Load `path`, print the per-source summary, and verify ledger
/// consistency where the source carries mass accounting.
pub fn run(path: &Path) -> Result<()> {
    let tf = TraceFile::load(path)?;
    println!(
        "trace {} — source {:?} v{} world {} rounds {} ({} events)",
        path.display(),
        tf.meta.source,
        tf.meta.version,
        tf.meta.world.map_or_else(|| "?".to_string(), |w| w.to_string()),
        tf.meta.rounds.map_or_else(|| "?".to_string(), |r| r.to_string()),
        tf.events.len()
    );
    match tf.meta.source.as_str() {
        "coord" => analyze_coord(&tf),
        "worker" => analyze_worker(&tf),
        "engine" => analyze_engine(&tf),
        "sim" => analyze_sim(&tf),
        "soak" => analyze_soak(&tf),
        other => {
            println!("unknown source {other:?} — listing event kinds only");
            print_kind_counts(&tf);
            Ok(())
        }
    }
}

fn print_kind_counts(tf: &TraceFile) {
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for ev in &tf.events {
        *counts.entry(ev.kind.as_str()).or_insert(0) += 1;
    }
    let rows: Vec<Vec<String>> =
        counts.iter().map(|(k, c)| vec![k.to_string(), c.to_string()]).collect();
    print_table("event kinds", &["kind", "count"], &rows);
}

/// 8-bucket linear histogram over the finite samples.
fn print_histogram(title: &str, unit: &str, vals: &[f64]) {
    let finite: Vec<f64> = vals.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return;
    }
    let min = finite.iter().copied().fold(f64::INFINITY, f64::min);
    let max = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    println!("\n## {title} ({} samples, {unit})", finite.len());
    if max <= min {
        println!("  all samples = {min:.3}");
        return;
    }
    const BUCKETS: usize = 8;
    let width = (max - min) / BUCKETS as f64;
    let mut counts = [0usize; BUCKETS];
    for v in &finite {
        let idx = (((v - min) / width) as usize).min(BUCKETS - 1);
        counts[idx] += 1;
    }
    let peak = counts.iter().copied().max().unwrap_or(1).max(1);
    for (i, c) in counts.iter().enumerate() {
        let lo = min + i as f64 * width;
        let hi = lo + width;
        let bar = "#".repeat(((c * 40).div_ceil(peak)).min(40));
        println!("  [{lo:>12.3}, {hi:>12.3})  {c:>6}  {bar}");
    }
}

#[derive(Clone, Copy, Default)]
struct EdgeStat {
    msgs: u64,
    bytes: u64,
}

/// Bytes-per-edge: full from×to matrix up to 16 nodes, top-10 edges by
/// bytes above that.
fn print_edges(edges: &BTreeMap<(u32, u32), EdgeStat>) {
    if edges.is_empty() {
        return;
    }
    let mut nodes: Vec<u32> = edges.keys().flat_map(|&(a, b)| [a, b]).collect();
    nodes.sort_unstable();
    nodes.dedup();
    let total_msgs: u64 = edges.values().map(|e| e.msgs).sum();
    let total_bytes: u64 = edges.values().map(|e| e.bytes).sum();
    if nodes.len() <= 16 {
        let mut header: Vec<String> = vec!["bytes from\\to".to_string()];
        header.extend(nodes.iter().map(|n| n.to_string()));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let rows: Vec<Vec<String>> = nodes
            .iter()
            .map(|&from| {
                let mut row = vec![from.to_string()];
                row.extend(nodes.iter().map(|&to| {
                    edges
                        .get(&(from, to))
                        .filter(|e| e.msgs > 0 || e.bytes > 0)
                        .map_or_else(|| ".".to_string(), |e| e.bytes.to_string())
                }));
                row
            })
            .collect();
        print_table("bytes per edge", &header_refs, &rows);
    } else {
        let mut top: Vec<(&(u32, u32), &EdgeStat)> = edges.iter().collect();
        top.sort_by(|a, b| b.1.bytes.cmp(&a.1.bytes));
        let rows: Vec<Vec<String>> = top
            .iter()
            .take(10)
            .map(|((from, to), e)| {
                vec![from.to_string(), to.to_string(), e.msgs.to_string(), e.bytes.to_string()]
            })
            .collect();
        print_table(
            &format!("heaviest edges (top 10 of {})", edges.len()),
            &["from", "to", "msgs", "bytes"],
            &rows,
        );
    }
    println!("total over {} edges: {total_msgs} msgs, {total_bytes} bytes", edges.len());
}

#[derive(Default)]
struct RankStat<'a> {
    joins: u64,
    degraded: u64,
    recovered: u64,
    leave: Option<u64>,
    dim_mismatch: bool,
    done: Option<&'a TraceEvent>,
}

/// Coordinator trace: per-rank liveness/ledger table, straggler ranking
/// by average ms/round, killed-rank detection (a `leave` with no
/// `done`), and reconciliation of every `done` ledger plus the final
/// `audit` against a from-scratch recomputation.
fn analyze_coord(tf: &TraceFile) -> Result<()> {
    let world = tf.meta.world.unwrap_or_else(|| {
        tf.events.iter().filter_map(|e| e.rank).map(|r| r as usize + 1).max().unwrap_or(0)
    });
    let mut ranks: Vec<RankStat> = (0..world).map(|_| RankStat::default()).collect();
    let mut assign_t: Option<u64> = None;
    let mut audit: Option<&TraceEvent> = None;
    let mut deadline = false;
    for ev in &tf.events {
        match (ev.kind.as_str(), ev.rank) {
            ("assign", _) => assign_t = assign_t.or(Some(ev.t_ms)),
            ("audit", _) => audit = Some(ev),
            ("deadline", _) => deadline = true,
            (kind, Some(r)) if (r as usize) < world => {
                let st = &mut ranks[r as usize];
                match kind {
                    "join" => st.joins += 1,
                    "degraded" => st.degraded += 1,
                    "recovered" => st.recovered += 1,
                    "leave" => st.leave = Some(ev.round.unwrap_or(0)),
                    "dim_mismatch" => st.dim_mismatch = true,
                    "done" => st.done = Some(ev),
                    _ => {}
                }
            }
            _ => {}
        }
    }

    let rows: Vec<Vec<String>> = ranks
        .iter()
        .enumerate()
        .map(|(r, st)| {
            let (round, w, resid, ms) = match st.done {
                Some(d) => {
                    let round = d.round.unwrap_or(0);
                    let ms = assign_t
                        .filter(|_| round > 0)
                        .map(|a| d.t_ms.saturating_sub(a) as f64 / round as f64);
                    (
                        round.to_string(),
                        d.num("w").map_or_else(|| "-".to_string(), |w| format!("{w:.6}")),
                        d.num("ledger_residual")
                            .map_or_else(|| "-".to_string(), |x| format!("{x:.3e}")),
                        ms.map_or_else(|| "-".to_string(), |m| format!("{m:.2}")),
                    )
                }
                None => ("-".to_string(), "-".to_string(), "-".to_string(), "-".to_string()),
            };
            vec![
                r.to_string(),
                st.joins.to_string(),
                st.degraded.to_string(),
                st.recovered.to_string(),
                st.leave.map_or_else(|| "-".to_string(), |k| k.to_string()),
                round,
                w,
                resid,
                ms,
            ]
        })
        .collect();
    print_table(
        "per-rank summary",
        &[
            "rank",
            "joins",
            "degraded",
            "recovered",
            "leave@round",
            "done@round",
            "w",
            "ledger_residual",
            "ms/round",
        ],
        &rows,
    );

    let mut lat: Vec<(usize, f64)> = ranks
        .iter()
        .enumerate()
        .filter_map(|(r, st)| {
            let d = st.done?;
            let round = d.round?;
            if round == 0 {
                return None;
            }
            Some((r, d.t_ms.saturating_sub(assign_t?) as f64 / round as f64))
        })
        .collect();
    lat.sort_by(|a, b| b.1.total_cmp(&a.1));
    if !lat.is_empty() {
        println!("\nstraggler ranking (avg ms/round, slowest first):");
        for (r, ms) in &lat {
            println!("  rank {r}: {ms:.2} ms/round ({} degraded spans)", ranks[*r].degraded);
        }
        let samples: Vec<f64> = lat.iter().map(|(_, m)| *m).collect();
        print_histogram("round latency", "ms/round", &samples);
    }

    let rescued_w: f64 = ranks.iter().filter_map(|st| st.done?.num("rescued_w")).sum();
    let rescues: f64 = ranks.iter().filter_map(|st| st.done?.num("rescues")).sum();
    let timeouts: f64 = ranks.iter().filter_map(|st| st.done?.num("timeouts")).sum();
    println!(
        "\ndrop/rescue totals: {} recv timeouts, {} bank rescues carrying w={rescued_w:.6}",
        timeouts as u64, rescues as u64
    );

    let killed: Vec<usize> = ranks
        .iter()
        .enumerate()
        .filter(|(_, st)| st.leave.is_some() && st.done.is_none())
        .map(|(r, _)| r)
        .collect();
    if killed.is_empty() {
        println!("killed ranks (leave without done): none");
    } else {
        println!("killed ranks (leave without done): {killed:?}");
    }
    if deadline {
        println!("NOTE: the run deadline fired before every worker reported");
    }

    // --- Ledger reconciliation against the raw events. -----------------
    // Mirrors run_coordinator's audit exactly: a rank counts as a clean
    // survivor iff it reported `done`, was never declared dead (`leave`),
    // and passed the dim check — summed in ascending rank order so the
    // floating-point result is bit-identical to the coordinator's.
    let mut max_resid = 0.0f64;
    let mut sum_w = 0.0f64;
    let mut included = 0usize;
    for (r, st) in ranks.iter().enumerate() {
        let Some(d) = st.done else { continue };
        let (w, recv_w, sent_w, logged) = match (
            d.num("w"),
            d.num("recv_w"),
            d.num("sent_w"),
            d.num("ledger_residual"),
        ) {
            (Some(a), Some(b), Some(c), Some(l)) => (a, b, c, l),
            _ => bail!("rank {r}: done event is missing ledger fields"),
        };
        let recomputed = w - (1.0 + recv_w - sent_w);
        if (recomputed - logged).abs() > TOL {
            bail!(
                "rank {r}: ledger residual mismatch — logged {logged:e}, \
                 recomputed w-(1+recv_w-sent_w) = {recomputed:e}"
            );
        }
        if st.leave.is_none() && !st.dim_mismatch {
            included += 1;
            sum_w += w;
            max_resid = max_resid.max(recomputed.abs());
        }
    }
    if let Some(a) = audit {
        let logged_missing = a.num("missing_w").context("audit event has no missing_w")?;
        let logged_max =
            a.num("max_ledger_residual").context("audit event has no max_ledger_residual")?;
        if let Some(s) = a.num("survivors").map(|s| s as usize) {
            if s != included {
                bail!("audit says {s} survivors, trace has {included} clean done events");
            }
        }
        let missing = world as f64 - sum_w;
        if (missing - logged_missing).abs() > TOL {
            bail!(
                "missing mass mismatch — audit logged {logged_missing:e}, \
                 recomputed from done events {missing:e}"
            );
        }
        if (max_resid - logged_max).abs() > TOL {
            bail!(
                "max ledger residual mismatch — audit logged {logged_max:e}, \
                 recomputed {max_resid:e}"
            );
        }
        println!(
            "ledger reconciliation: OK (survivors {included}, missing_w {missing:.6}, \
             max residual {max_resid:.3e})"
        );
    } else if included > 0 {
        println!(
            "ledger reconciliation: OK ({included} done events self-consistent; \
             no audit event to cross-check — incomplete run?)"
        );
    } else {
        println!("ledger reconciliation: no done events to check");
    }
    Ok(())
}

/// Worker trace: per-peer traffic matrix, error counters, and the
/// node's own `done` ledger rechecked against `w = 1 + recv_w − sent_w`.
fn analyze_worker(tf: &TraceFile) -> Result<()> {
    let mut edges: BTreeMap<(u32, u32), EdgeStat> = BTreeMap::new();
    let mut send_failed = 0u64;
    let mut malformed = 0u64;
    let mut peer_leaves = 0u64;
    let mut done: Option<&TraceEvent> = None;
    for ev in &tf.events {
        match ev.kind.as_str() {
            "edge" => {
                if let (Some(from), Some(to)) = (ev.rank, ev.num("to")) {
                    let e = edges.entry((from, to as u32)).or_default();
                    e.msgs += ev.num("sent_msgs").unwrap_or(0.0) as u64;
                    e.bytes += ev.num("sent_bytes").unwrap_or(0.0) as u64;
                }
            }
            "send_failed" => send_failed += 1,
            "malformed_share" => malformed += 1,
            "peer_leave" => peer_leaves += 1,
            "done" => done = Some(ev),
            _ => {}
        }
    }
    print_kind_counts(tf);
    print_edges(&edges);
    println!(
        "\nerrors: {send_failed} failed sends, {malformed} malformed shares, \
         {peer_leaves} peer-leave notifications"
    );
    match done {
        Some(d) => {
            let (w, recv_w, sent_w, logged) = match (
                d.num("w"),
                d.num("recv_w"),
                d.num("sent_w"),
                d.num("ledger_residual"),
            ) {
                (Some(a), Some(b), Some(c), Some(l)) => (a, b, c, l),
                _ => bail!("done event is missing ledger fields"),
            };
            let recomputed = w - (1.0 + recv_w - sent_w);
            if (recomputed - logged).abs() > TOL {
                bail!(
                    "ledger residual mismatch — logged {logged:e}, recomputed {recomputed:e}"
                );
            }
            println!(
                "ledger reconciliation: OK (w {w:.6}, residual {recomputed:.3e}, \
                 rescued_w {:.6})",
                d.num("rescued_w").unwrap_or(0.0)
            );
        }
        None => println!("ledger reconciliation: no done event (worker killed mid-run?)"),
    }
    Ok(())
}

/// Engine trace: phase-latency profile over the retained ring of
/// rounds, drop/rescue totals, round-latency histogram, and the
/// bytes-per-edge matrix when edge tracking was on.
fn analyze_engine(tf: &TraceFile) -> Result<()> {
    let mut edges: BTreeMap<(u32, u32), EdgeStat> = BTreeMap::new();
    let mut round_ms: Vec<f64> = Vec::new();
    let phases = ["compute_ns", "merge_ns", "aggregate_ns", "pool_wait_ns"];
    let mut sums = [0.0f64; 4];
    let mut maxs = [0.0f64; 4];
    let mut totals: Option<&TraceEvent> = None;
    let mut n_rounds = 0usize;
    for ev in &tf.events {
        match ev.kind.as_str() {
            "round" => {
                n_rounds += 1;
                let mut total = 0.0;
                for (i, p) in phases.iter().enumerate() {
                    let v = ev.num(p).unwrap_or(0.0);
                    sums[i] += v;
                    maxs[i] = maxs[i].max(v);
                    if i < 3 {
                        total += v; // pool wait overlaps the phases; not additive
                    }
                }
                round_ms.push(total / 1e6);
            }
            "edge" => {
                if let (Some(from), Some(to)) = (ev.rank, ev.num("to")) {
                    let e = edges.entry((from, to as u32)).or_default();
                    e.msgs += ev.num("msgs").unwrap_or(0.0) as u64;
                    e.bytes += ev.num("bytes").unwrap_or(0.0) as u64;
                }
            }
            "totals" => totals = Some(ev),
            _ => {}
        }
    }
    if n_rounds > 0 {
        let rows: Vec<Vec<String>> = phases
            .iter()
            .enumerate()
            .map(|(i, p)| {
                vec![
                    p.trim_end_matches("_ns").to_string(),
                    format!("{:.1}", sums[i] / n_rounds as f64 / 1e3),
                    format!("{:.1}", maxs[i] / 1e3),
                ]
            })
            .collect();
        print_table(
            &format!("phase latency over last {n_rounds} rounds"),
            &["phase", "mean µs", "max µs"],
            &rows,
        );
        print_histogram("round latency", "ms", &round_ms);
    }
    print_edges(&edges);
    if let Some(t) = totals {
        println!(
            "\nwhole-run totals: {} rounds, {} msgs ({} bytes on the wire), \
             {} dropped, {} rescued",
            t.num("rounds").unwrap_or(0.0) as u64,
            t.num("msgs").unwrap_or(0.0) as u64,
            t.num("wire_bytes").unwrap_or(0.0) as u64,
            t.num("dropped").unwrap_or(0.0) as u64,
            t.num("rescued").unwrap_or(0.0) as u64,
        );
    }
    Ok(())
}

/// Soak trace (`repro soak`): re-verify the durable-checkpoint run's
/// audit trail offline — every per-round `mass` event must conserve Σw
/// to [`TOL`], the run must contain at least one `snapshot`, one
/// `restore` and one elastic `join`, and the final `audit` event must
/// report a bit-identical subject with the same conserved mass. Any
/// violation is a hard failure (non-zero CLI exit), so the trace file is
/// a self-contained proof the crash→restore→join cycle preserved the
/// push-sum ledger.
fn analyze_soak(tf: &TraceFile) -> Result<()> {
    let mut snapshots = 0u64;
    let mut snapshot_bytes = 0u64;
    let mut restores: Vec<u64> = Vec::new();
    let mut joins: Vec<(u32, u64)> = Vec::new();
    let mut mass_rounds = 0u64;
    let mut worst_drift = 0.0f64;
    let mut audit: Option<&TraceEvent> = None;
    for ev in &tf.events {
        match ev.kind.as_str() {
            "snapshot" => {
                snapshots += 1;
                snapshot_bytes += ev.num("bytes").unwrap_or(0.0) as u64;
            }
            "restore" => restores.push(ev.num("round").unwrap_or(0.0) as u64),
            "join" => {
                joins.push((ev.rank.unwrap_or(0), ev.num("donor").unwrap_or(0.0) as u64))
            }
            "mass" => {
                let (sum_w, expected) = match (ev.num("sum_w"), ev.num("expected_w")) {
                    (Some(s), Some(e)) => (s, e),
                    _ => bail!("mass event at round {:?} missing fields", ev.round),
                };
                let drift = (sum_w - expected).abs();
                if drift > TOL {
                    bail!(
                        "round {:?}: Σw drifted by {drift:e} (sum_w {sum_w}, \
                         expected {expected})",
                        ev.round
                    );
                }
                worst_drift = worst_drift.max(drift);
                mass_rounds += 1;
            }
            "audit" => audit = Some(ev),
            _ => {}
        }
    }
    print_kind_counts(tf);
    if mass_rounds == 0 {
        bail!("soak trace carries no mass events — nothing was audited");
    }
    if snapshots == 0 {
        bail!("soak trace carries no snapshot events — checkpointing never ran");
    }
    if restores.is_empty() {
        bail!("soak trace carries no restore event — the crash path never ran");
    }
    if joins.is_empty() {
        bail!("soak trace carries no join event — elastic scale-up never ran");
    }
    let a = audit.context("soak trace has no final audit event — run died mid-way")?;
    let (sum_w, expected) = match (a.num("sum_w"), a.num("expected_w")) {
        (Some(s), Some(e)) => (s, e),
        _ => bail!("audit event is missing mass fields"),
    };
    if (sum_w - expected).abs() > TOL {
        bail!("final audit: Σw {sum_w} vs expected {expected} exceeds {TOL:e}");
    }
    if a.num("bit_identical") != Some(1.0) {
        bail!("final audit: subject engine was not bit-identical to the reference");
    }
    println!(
        "\nchurn cycle: {snapshots} snapshots ({snapshot_bytes} bytes), restore at \
         round(s) {restores:?}, elastic join(s) {joins:?} (rank, donor)"
    );
    println!(
        "soak ledger: OK ({mass_rounds} rounds audited, worst Σw drift {worst_drift:.3e}, \
         final consensus {:.3e})",
        a.num("consensus").unwrap_or(f64::NAN)
    );
    Ok(())
}

/// Timing-simulator trace: straggler ranking by slowest-node counts and
/// an iteration-latency histogram from consecutive makespan deltas.
fn analyze_sim(tf: &TraceFile) -> Result<()> {
    let mut stragglers: Vec<(u32, u64)> = Vec::new();
    let mut makespans: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    for ev in &tf.events {
        match ev.kind.as_str() {
            "straggler" => {
                if let (Some(r), Some(c)) = (ev.rank, ev.num("count")) {
                    stragglers.push((r, c as u64));
                }
            }
            "iter" => makespans.push(ev.num("makespan_s").unwrap_or(f64::NAN)),
            "totals" => total_iters = ev.num("iters").unwrap_or(0.0) as u64,
            _ => {}
        }
    }
    stragglers.sort_by(|a, b| b.1.cmp(&a.1));
    if !stragglers.is_empty() {
        println!("\nstraggler ranking (iterations as the slowest node, whole run):");
        for (r, c) in &stragglers {
            println!("  rank {r}: {c}");
        }
    }
    // The sim clock is cumulative, so consecutive deltas are per-iter
    // latencies; the ring start has no predecessor and is skipped.
    let deltas: Vec<f64> = makespans
        .windows(2)
        .map(|w| (w[1] - w[0]) * 1000.0)
        .filter(|d| *d >= 0.0)
        .collect();
    print_histogram("iteration latency", "ms", &deltas);
    println!("\nwhole-run totals: {total_iters} iterations simulated");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceWriter;

    fn coord_trace(dir: &std::path::Path, break_residual: bool) -> std::path::PathBuf {
        let path = dir.join("coord.jsonl");
        let mut w = TraceWriter::create(&path, "coord", 4, 50).unwrap();
        w.event(1, "join", 0, 0, &[]);
        w.event(1, "join", 1, 0, &[]);
        w.event(1, "join", 2, 0, &[]);
        w.event(1, "join", 3, 0, &[]);
        w.event(2, "assign", u32::MAX, 0, &[]);
        w.event(90, "leave", 2, 17, &[]);
        for r in [0u32, 1, 3] {
            let (recv_w, sent_w) = (1.25 + r as f64 * 0.01, 1.5);
            let w_final = 1.0 + recv_w - sent_w;
            let logged = if break_residual && r == 1 { 0.5 } else { 0.0 };
            w.event(
                200 + r as u64,
                "done",
                r,
                50,
                &[
                    ("w", w_final),
                    ("recv_w", recv_w),
                    ("sent_w", sent_w),
                    ("rescued_w", 0.1),
                    ("rescues", 1.0),
                    ("timeouts", 2.0),
                    ("ledger_residual", logged),
                ],
            );
        }
        let sum_w = (1.0 + 1.25 - 1.5) + (1.0 + 1.26 - 1.5) + (1.0 + 1.28 - 1.5);
        w.event(
            210,
            "audit",
            u32::MAX,
            50,
            &[
                ("world", 4.0),
                ("survivors", 3.0),
                ("missing_w", 4.0 - sum_w),
                ("max_ledger_residual", 0.0),
                ("spread", 1e-8),
            ],
        );
        path
    }

    #[test]
    fn coord_reconciliation_accepts_consistent_and_rejects_corrupt() {
        let dir = std::env::temp_dir().join(format!("sgp_analyze_{}", std::process::id()));
        let good = coord_trace(&dir, false);
        run(&good).expect("consistent trace reconciles");
        let bad = coord_trace(&dir, true);
        let err = run(&bad).expect_err("corrupted ledger_residual must fail");
        assert!(err.to_string().contains("ledger residual mismatch"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn soak_trace(dir: &std::path::Path, drift: bool, complete: bool) -> std::path::PathBuf {
        let name = format!("soak_{}_{}.jsonl", drift, complete);
        let path = dir.join(name);
        let mut w = TraceWriter::create(&path, "soak", 9, 20).unwrap();
        let gr = u32::MAX; // GLOBAL_RANK
        for k in 0..20u64 {
            let sum_w = if drift && k == 13 { 8.0 + 1e-6 } else { 8.0 };
            w.event(k, "mass", gr, k, &[("sum_w", sum_w), ("expected_w", 8.0)]);
        }
        w.event(7, "snapshot", gr, 7, &[("bytes", 4096.0)]);
        w.event(9, "restore", gr, 9, &[("round", 10.0)]);
        w.event(14, "join", 8, 14, &[("donor", 2.0)]);
        if complete {
            w.event(
                20,
                "audit",
                gr,
                19,
                &[
                    ("sum_w", 8.0),
                    ("expected_w", 8.0),
                    ("consensus", 1e-4),
                    ("bit_identical", 1.0),
                ],
            );
        }
        path
    }

    #[test]
    fn soak_reconciliation_accepts_clean_and_rejects_drift_or_truncation() {
        let dir =
            std::env::temp_dir().join(format!("sgp_analyze_soak_{}", std::process::id()));
        run(&soak_trace(&dir, false, true)).expect("clean soak trace reconciles");
        let err = run(&soak_trace(&dir, true, true)).expect_err("Σw drift must fail");
        assert!(err.to_string().contains("drifted"), "got: {err}");
        let err = run(&soak_trace(&dir, false, false)).expect_err("missing audit must fail");
        assert!(err.to_string().contains("no final audit"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn histogram_and_edges_handle_degenerate_input() {
        print_histogram("empty", "ms", &[]);
        print_histogram("constant", "ms", &[1.0, 1.0, 1.0]);
        print_histogram("nan-only", "ms", &[f64::NAN]);
        print_edges(&BTreeMap::new());
        let mut edges = BTreeMap::new();
        edges.insert((0u32, 1u32), EdgeStat { msgs: 3, bytes: 300 });
        print_edges(&edges);
    }
}

//! The versioned JSONL trace schema shared by every runtime surface.
//!
//! A trace file is newline-delimited JSON. The **first line is a meta
//! record** identifying the schema and the run:
//!
//! ```json
//! {"schema":"sgp-trace","v":1,"source":"coord","world":4,"rounds":500}
//! ```
//!
//! Every following line is an **event**: `t_ms` (milliseconds since the
//! source started), `kind` (a fixed identifier — see the taxonomy in
//! ARCHITECTURE.md §6), `rank` (the node the event is about;
//! `4294967295` = `u32::MAX` marks run-global events), `round` (the
//! gossip round it refers to), plus kind-specific numeric fields.
//! Numbers are written exactly: integral values as integers, everything
//! else in shortest-round-trip `{:e}` form, non-finite values as `null`
//! (the repo's [`crate::model::json`] parser rejects bare `NaN`). The
//! reader maps `null` back to `NaN`, so a parsed trace reproduces the
//! emitted `f64` bit patterns.
//!
//! Versioning: `v` is bumped whenever an existing field changes meaning
//! or type. Adding a new event kind or a new numeric field is *not* a
//! version bump — readers ignore fields they don't know. The parser in
//! this module rejects any version other than [`TRACE_SCHEMA_VERSION`].
//!
//! Range safety: the parser hard-fails on `rank ≥ world` or
//! `round > rounds`, and some emit sites (the cluster worker and
//! coordinator) log ranks/rounds that arrive straight off the wire — a
//! single garbage frame must not render a whole trace unparseable. The
//! writer therefore enforces the parser's invariants itself: an
//! out-of-range rank is written as [`GLOBAL_RANK`] and an out-of-range
//! round as `0`, with the raw wire values preserved in `raw_rank` /
//! `raw_round` numeric fields.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{EngineObs, TimingObs};
use crate::model::json::Json;

/// Version of the JSONL trace schema this build emits and accepts.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// Version of the coordinator's `summary.json` schema (`schema_version`
/// field). Tracked separately from the trace schema: the summary is a
/// single document with its own shape.
pub const SUMMARY_SCHEMA_VERSION: u64 = 1;

/// Rank value marking an event that is about the run, not one node.
pub const GLOBAL_RANK: u32 = u32::MAX;

/// Render a float for the trace: integral values as integers (exact for
/// |v| ≤ 2⁵³), everything else in shortest-round-trip `{:e}` form,
/// non-finite as `null`.
fn push_num(out: &mut String, v: f64) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() <= 9.0e15 && !(v == 0.0 && v.is_sign_negative()) {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v:e}");
    }
}

/// Escape a string for a JSON literal (kinds/sources are plain
/// identifiers, but the writer stays safe for arbitrary input).
fn push_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Line-buffered JSONL trace writer. Every [`TraceWriter::event`] call
/// writes one complete line and flushes it, so a SIGKILLed process
/// leaves a readable prefix. A disabled writer ([`TraceWriter::disabled`]
/// or one whose file failed to open) swallows events, letting call sites
/// emit unconditionally.
pub struct TraceWriter {
    file: Option<BufWriter<File>>,
    line: String,
    /// The meta line's `world`/`rounds` — the bounds the parser will
    /// enforce, so [`TraceWriter::event`] clamps against them.
    world: usize,
    rounds: u64,
}

impl TraceWriter {
    /// Create `path` (and its parent directory) and write the meta line.
    pub fn create(path: &Path, source: &str, world: usize, rounds: u64) -> io::Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut w = Self {
            file: Some(BufWriter::new(File::create(path)?)),
            line: String::new(),
            world,
            rounds,
        };
        w.line.clear();
        w.line.push_str("{\"schema\":\"sgp-trace\",\"v\":");
        let _ = write!(w.line, "{TRACE_SCHEMA_VERSION},\"source\":");
        push_str(&mut w.line, source);
        let _ = write!(w.line, ",\"world\":{world},\"rounds\":{rounds}}}");
        w.write_line()?;
        Ok(w)
    }

    /// A writer that discards everything (no file, no I/O).
    pub fn disabled() -> Self {
        Self { file: None, line: String::new(), world: 0, rounds: 0 }
    }

    /// Whether events are actually being written.
    pub fn is_enabled(&self) -> bool {
        self.file.is_some()
    }

    /// Append one event line. `rank == GLOBAL_RANK` marks a run-global
    /// event; `extras` are kind-specific numeric fields (non-finite
    /// values are written as `null`). Write errors disable the writer
    /// (first error is reported on stderr) — tracing must never take
    /// down the run it observes.
    ///
    /// Ranks/rounds outside the meta line's declared bounds (possible at
    /// emit sites that log values straight off the wire) are clamped to
    /// `GLOBAL_RANK`/`0` with the raw values carried in `raw_rank` /
    /// `raw_round`, so one garbage frame cannot make the file violate
    /// the parser's range checks.
    pub fn event(&mut self, t_ms: u64, kind: &str, rank: u32, round: u64, extras: &[(&str, f64)]) {
        if self.file.is_none() {
            return;
        }
        let raw_rank =
            (rank != GLOBAL_RANK && rank as usize >= self.world).then_some(rank);
        let raw_round = (round > self.rounds).then_some(round);
        let rank = if raw_rank.is_some() { GLOBAL_RANK } else { rank };
        let round = if raw_round.is_some() { 0 } else { round };
        let mut s = std::mem::take(&mut self.line);
        s.clear();
        let _ = write!(s, "{{\"t_ms\":{t_ms},\"kind\":");
        push_str(&mut s, kind);
        let _ = write!(s, ",\"rank\":{rank},\"round\":{round}");
        for (key, v) in extras {
            s.push(',');
            push_str(&mut s, key);
            s.push(':');
            push_num(&mut s, *v);
        }
        if let Some(r) = raw_rank {
            let _ = write!(s, ",\"raw_rank\":{r}");
        }
        if let Some(r) = raw_round {
            let _ = write!(s, ",\"raw_round\":{r}");
        }
        s.push('}');
        self.line = s;
        if let Err(e) = self.write_line() {
            eprintln!("trace: write failed ({e}); disabling trace output");
            self.file = None;
        }
    }

    fn write_line(&mut self) -> io::Result<()> {
        if let Some(f) = self.file.as_mut() {
            f.write_all(self.line.as_bytes())?;
            f.write_all(b"\n")?;
            f.flush()?;
        }
        Ok(())
    }
}

/// The parsed meta (first) line of a trace file.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    /// Which surface emitted the trace (`"engine"`, `"sim"`, `"coord"`,
    /// `"worker"`).
    pub source: String,
    /// Schema version (always [`TRACE_SCHEMA_VERSION`] after parsing).
    pub version: u64,
    /// Number of nodes in the run, when the source knew it.
    pub world: Option<usize>,
    /// Planned round/iteration count, when the source knew it.
    pub rounds: Option<u64>,
}

/// One parsed trace event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Milliseconds since the source started.
    pub t_ms: u64,
    /// Event kind identifier.
    pub kind: String,
    /// Node the event is about (`None` for run-global events).
    pub rank: Option<u32>,
    /// Gossip round the event refers to.
    pub round: Option<u64>,
    /// Kind-specific numeric fields (JSON `null` parses to `NaN`).
    pub num: BTreeMap<String, f64>,
}

impl TraceEvent {
    /// Kind-specific numeric field lookup.
    pub fn num(&self, key: &str) -> Option<f64> {
        self.num.get(key).copied()
    }
}

/// A fully parsed and validated trace.
#[derive(Clone, Debug)]
pub struct TraceFile {
    /// The meta line.
    pub meta: TraceMeta,
    /// Events in file order.
    pub events: Vec<TraceEvent>,
}

impl TraceFile {
    /// Read and parse `path`, validating schema version and id ranges.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Parse trace text: the first non-empty line must be an
    /// `sgp-trace` v[`TRACE_SCHEMA_VERSION`] meta record; every later
    /// non-empty line must be an event whose `rank` is `< world` and
    /// whose `round` is `≤ rounds` (when the meta declared them).
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (meta_no, meta_line) = match lines.next() {
            Some(x) => x,
            None => bail!("empty trace: no meta line"),
        };
        let mv = Json::parse(meta_line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", meta_no + 1))?;
        match mv.get("schema").and_then(Json::as_str) {
            Some("sgp-trace") => {}
            Some(other) => bail!("line {}: unknown schema {other:?}", meta_no + 1),
            None => bail!("line {}: not an sgp-trace meta line (missing \"schema\")", meta_no + 1),
        }
        let version = mv
            .get("v")
            .and_then(Json::as_f64)
            .with_context(|| format!("line {}: meta has no version field \"v\"", meta_no + 1))?
            as u64;
        if version != TRACE_SCHEMA_VERSION {
            bail!(
                "line {}: unsupported trace schema version {version} (this build reads v{TRACE_SCHEMA_VERSION})",
                meta_no + 1
            );
        }
        let meta = TraceMeta {
            source: mv.get("source").and_then(Json::as_str).unwrap_or("unknown").to_string(),
            version,
            world: mv.get("world").and_then(Json::as_usize),
            rounds: mv.get("rounds").and_then(Json::as_f64).map(|r| r as u64),
        };

        let mut events = Vec::new();
        for (no, line) in lines {
            let v = Json::parse(line).map_err(|e| anyhow::anyhow!("line {}: {e}", no + 1))?;
            let obj = v
                .as_obj()
                .with_context(|| format!("line {}: event is not a JSON object", no + 1))?;
            let kind = v
                .get("kind")
                .and_then(Json::as_str)
                .with_context(|| format!("line {}: event has no \"kind\"", no + 1))?
                .to_string();
            let t_ms = v
                .get("t_ms")
                .and_then(Json::as_f64)
                .with_context(|| format!("line {}: event has no numeric \"t_ms\"", no + 1))?
                as u64;
            let rank = match v.get("rank").and_then(Json::as_f64) {
                None => None,
                Some(r) if r as u32 == GLOBAL_RANK => None,
                Some(r) => {
                    let r = r as u32;
                    if let Some(world) = meta.world {
                        if (r as usize) >= world {
                            bail!("line {}: rank {r} out of range (world {world})", no + 1);
                        }
                    }
                    Some(r)
                }
            };
            let round = v.get("round").and_then(Json::as_f64).map(|r| r as u64);
            if let (Some(r), Some(max)) = (round, meta.rounds) {
                if r > max {
                    bail!("line {}: round {r} out of range (rounds {max})", no + 1);
                }
            }
            let mut num = BTreeMap::new();
            for (key, val) in obj {
                if matches!(key.as_str(), "t_ms" | "kind" | "rank" | "round") {
                    continue;
                }
                match val {
                    Json::Num(x) => {
                        num.insert(key.clone(), *x);
                    }
                    Json::Null => {
                        num.insert(key.clone(), f64::NAN);
                    }
                    _ => {} // readers ignore fields they don't know
                }
            }
            events.push(TraceEvent { t_ms, kind, rank, round, num });
        }
        Ok(Self { meta, events })
    }
}

/// Write an engine run's recorder out as a trace (source `"engine"`):
/// one `round` event per retained [`super::RoundRecord`] (counters, bank
/// norms, phase timers), one `edge` event per active edge, and a
/// run-global `totals` event. `rounds` is the number of iterations the
/// run executed.
pub fn write_engine_trace(path: &Path, obs: &EngineObs, rounds: u64) -> Result<()> {
    let n = obs.nodes();
    let mut w = TraceWriter::create(path, "engine", n, rounds)
        .with_context(|| format!("creating trace {}", path.display()))?;
    let mut t_ns: u64 = 0;
    for rec in obs.rounds() {
        t_ns += rec.compute_ns + rec.merge_ns + rec.aggregate_ns;
        w.event(
            t_ns / 1_000_000,
            "round",
            GLOBAL_RANK,
            rec.k,
            &[
                ("msgs", rec.msgs as f64),
                ("dropped", rec.dropped as f64),
                ("rescued", rec.rescued as f64),
                ("wire_bytes", rec.wire_bytes as f64),
                ("bank_l1", rec.bank_l1),
                ("bank_w", rec.bank_w),
                ("compute_ns", rec.compute_ns as f64),
                ("merge_ns", rec.merge_ns as f64),
                ("aggregate_ns", rec.aggregate_ns as f64),
                ("pool_wait_ns", rec.pool_wait_ns as f64),
            ],
        );
    }
    if obs.tracks_edges() {
        for from in 0..n {
            for to in 0..n {
                let msgs = obs.edge_msgs(from, to);
                if msgs > 0 {
                    w.event(
                        t_ns / 1_000_000,
                        "edge",
                        from as u32,
                        rounds,
                        &[
                            ("to", to as f64),
                            ("msgs", msgs as f64),
                            ("bytes", obs.edge_bytes(from, to) as f64),
                        ],
                    );
                }
            }
        }
    }
    let (total_rounds, msgs, dropped, rescued, wire_bytes) = obs.totals();
    w.event(
        t_ns / 1_000_000,
        "totals",
        GLOBAL_RANK,
        rounds,
        &[
            ("rounds", total_rounds as f64),
            ("msgs", msgs as f64),
            ("dropped", dropped as f64),
            ("rescued", rescued as f64),
            ("wire_bytes", wire_bytes as f64),
        ],
    );
    Ok(())
}

/// Write a timing-simulator recorder out as a trace (source `"sim"`):
/// one `iter` event per retained [`super::IterStat`] (rank = that
/// iteration's straggler), one `straggler` event per node with its
/// whole-run slowest count, and a run-global `totals` event.
pub fn write_sim_trace(path: &Path, obs: &TimingObs, iters: u64) -> Result<()> {
    let n = obs.slowest_counts().len();
    let mut w = TraceWriter::create(path, "sim", n, iters)
        .with_context(|| format!("creating trace {}", path.display()))?;
    for st in obs.iters() {
        w.event(
            (st.makespan_s * 1000.0) as u64,
            "iter",
            st.slowest,
            st.k,
            &[("makespan_s", st.makespan_s)],
        );
    }
    let last_ms = obs
        .iters()
        .last()
        .map(|st| (st.makespan_s * 1000.0) as u64)
        .unwrap_or(0);
    for (node, count) in obs.slowest_counts().iter().enumerate() {
        if *count > 0 {
            w.event(last_ms, "straggler", node as u32, iters, &[("count", *count as f64)]);
        }
    }
    w.event(last_ms, "totals", GLOBAL_RANK, iters, &[("iters", obs.total_iters() as f64)]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip_with_special_values() {
        let dir = std::env::temp_dir().join(format!("sgp_trace_rt_{}", std::process::id()));
        let path = dir.join("t.jsonl");
        let mut w = TraceWriter::create(&path, "engine", 4, 10).unwrap();
        w.event(5, "round", GLOBAL_RANK, 3, &[("a", 1.5), ("b", f64::NAN), ("c", -3.0)]);
        w.event(6, "edge", 2, 10, &[("bytes", 1e18)]);
        drop(w);
        let tf = TraceFile::load(&path).unwrap();
        assert_eq!(tf.meta.source, "engine");
        assert_eq!(tf.meta.world, Some(4));
        assert_eq!(tf.events.len(), 2);
        assert_eq!(tf.events[0].rank, None);
        assert_eq!(tf.events[0].round, Some(3));
        assert_eq!(tf.events[0].num("a"), Some(1.5));
        assert!(tf.events[0].num("b").unwrap().is_nan(), "null maps back to NaN");
        assert_eq!(tf.events[1].rank, Some(2));
        assert_eq!(tf.events[1].num("bytes"), Some(1e18));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_clamps_out_of_range_wire_values_so_the_trace_still_parses() {
        let dir = std::env::temp_dir().join(format!("sgp_trace_clamp_{}", std::process::id()));
        let path = dir.join("t.jsonl");
        let mut w = TraceWriter::create(&path, "worker", 4, 100).unwrap();
        // A garbage frame's sender/round logged straight off the wire.
        w.event(1, "malformed_share", 9000, 7_000_000, &[("w", 0.5)]);
        // Boundary values must NOT be clamped.
        w.event(2, "done", 3, 100, &[]);
        w.event(3, "audit", GLOBAL_RANK, 100, &[]);
        drop(w);
        let tf = TraceFile::load(&path).unwrap();
        assert_eq!(tf.events.len(), 3);
        assert_eq!(tf.events[0].rank, None, "out-of-range rank clamps to global");
        assert_eq!(tf.events[0].round, Some(0), "out-of-range round clamps to 0");
        assert_eq!(tf.events[0].num("raw_rank"), Some(9000.0));
        assert_eq!(tf.events[0].num("raw_round"), Some(7_000_000.0));
        assert_eq!(tf.events[0].num("w"), Some(0.5), "extras survive the clamp");
        assert_eq!(tf.events[1].rank, Some(3));
        assert_eq!(tf.events[1].round, Some(100));
        assert_eq!(tf.events[1].num("raw_rank"), None, "in-range events carry no raw fields");
        assert_eq!(tf.events[2].rank, None, "GLOBAL_RANK passes through unclamped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parser_rejects_bad_version_rank_and_round() {
        let bad_version = "{\"schema\":\"sgp-trace\",\"v\":99,\"source\":\"x\"}\n";
        assert!(TraceFile::parse(bad_version).is_err());
        let bad_rank = "{\"schema\":\"sgp-trace\",\"v\":1,\"source\":\"x\",\"world\":2,\"rounds\":5}\n\
                        {\"t_ms\":0,\"kind\":\"join\",\"rank\":2,\"round\":0}\n";
        assert!(TraceFile::parse(bad_rank).is_err());
        let bad_round = "{\"schema\":\"sgp-trace\",\"v\":1,\"source\":\"x\",\"world\":2,\"rounds\":5}\n\
                         {\"t_ms\":0,\"kind\":\"join\",\"rank\":0,\"round\":6}\n";
        assert!(TraceFile::parse(bad_round).is_err());
        assert!(TraceFile::parse("{\"v\":1}\n").is_err(), "meta must carry the schema tag");
        assert!(TraceFile::parse("").is_err(), "empty trace is an error");
    }
}

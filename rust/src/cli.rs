//! Tiny argument parser (the offline build has no clap): subcommand +
//! `--key value` / `--key=value` / `--flag` options with typed getters
//! and error messages.
//!
//! Two foot-guns of the original parser are now hard errors instead of
//! silent misreads:
//!
//! * **duplicates** — a repeated `--opt`/`--flag` used to silently keep
//!   only the last value; it now errors, naming the option.
//! * **values that look like flags** — `--opt --val` cannot be told apart
//!   from two flags, so the space form never consumes a `--`-prefixed
//!   value (the option is recorded as a bare flag). The explicit form
//!   `--opt=--val` passes such values, and every typed getter errors —
//!   with that hint — when it finds a bare flag where a value was
//!   expected, so the ambiguity can no longer slip through unnoticed.
//!   The mirror-image misread (`--flag positional` swallowing the
//!   positional as the flag's value) is caught at the consumer via
//!   [`Args::flag_strict`].

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand, positionals, `--key value` options and
/// bare `--flag`s.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-option token.
    pub subcommand: Option<String>,
    /// Remaining non-option tokens, in order.
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    /// Errors on duplicate options/flags; `--opt=--val` is the explicit
    /// form for values that start with `--`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.insert_opt(k, v)?;
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.insert_opt(name, &v)?;
                } else {
                    out.insert_flag(name)?;
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Record `--name value`, rejecting duplicates (including a prior
    /// bare-flag occurrence of the same name).
    fn insert_opt(&mut self, name: &str, value: &str) -> Result<()> {
        if self.opts.contains_key(name) || self.flags.iter().any(|f| f == name) {
            bail!("duplicate option --{name}: given more than once");
        }
        self.opts.insert(name.to_string(), value.to_string());
        Ok(())
    }

    /// Record a bare `--name`, rejecting duplicates (including a prior
    /// valued occurrence of the same name).
    fn insert_flag(&mut self, name: &str) -> Result<()> {
        if self.flags.iter().any(|f| f == name) || self.opts.contains_key(name) {
            bail!("duplicate option --{name}: given more than once");
        }
        self.flags.push(name.to_string());
        Ok(())
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Was the bare flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Like [`Self::flag`], but errors if `--name` swallowed a value: a
    /// schema-free parser reads `--fast table1` as `fast = "table1"`, and
    /// for a name the caller knows to be boolean that silently discards a
    /// positional AND the flag. Callers consuming boolean flags should
    /// prefer this over [`Self::flag`].
    pub fn flag_strict(&self, name: &str) -> Result<bool> {
        if let Some(v) = self.get(name) {
            bail!(
                "--{name} is a bare flag but was given the value `{v}`; \
                 if `{v}` is a positional argument, put it before --{name}"
            );
        }
        Ok(self.flag(name))
    }

    /// The value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// The value of option `--name`, erroring if `--name` was given as a
    /// bare flag: that is the `--name --value` ambiguity (the next token
    /// looked like a flag, so nothing was consumed as the value) — the
    /// caller expected a value, so surface it with the `=`-form hint
    /// instead of silently falling back to the default. Every typed
    /// getter routes through this; prefer it over [`Self::get`] whenever
    /// the name is value-carrying.
    pub fn value_of(&self, name: &str) -> Result<Option<&str>> {
        match self.get(name) {
            Some(v) => Ok(Some(v)),
            None if self.flag(name) => bail!(
                "option --{name} requires a value; use --{name}=<value> \
                 (the `=` form also passes values that start with `--`)"
            ),
            None => Ok(None),
        }
    }

    /// String option with a default (error if `--name` was given as a
    /// bare flag — see [`Self::value_of`]).
    pub fn str_or(&self, name: &str, default: &str) -> Result<String> {
        Ok(self.value_of(name)?.unwrap_or(default).to_string())
    }

    /// Integer option with a default (error names the offending flag).
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// u64 option with a default (error names the offending flag).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// `u32` option with a default (error names the offending flag) —
    /// millisecond thresholds and similar wire-width-bounded values.
    pub fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// Float option with a default (error names the offending flag).
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.value_of(name)? {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not a number")),
        }
    }

    /// Required option (error if absent or given as a bare flag).
    pub fn require(&self, name: &str) -> Result<&str> {
        match self.value_of(name)? {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // NOTE: a bare `--flag` followed by a non-option token is read as
        // `--flag <value>` (option form); trailing flags are unambiguous.
        let a = parse("train extra --model mlp_small --nodes 8 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp_small"));
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 8);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --exp=table1 --epochs=4.5");
        assert_eq!(a.get("exp"), Some("table1"));
        assert!((a.f64_or("epochs", 0.0).unwrap() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x");
        assert_eq!(a.usize_or("nodes", 4).unwrap(), 4);
        assert!(a.require("model").is_err());
        let a = parse("x --nodes eight");
        assert!(a.usize_or("nodes", 4).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench table1 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["table1"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn equals_form_passes_values_starting_with_dashes() {
        let a = parse("run --label=--weird --drop=-0.5");
        assert_eq!(a.get("label"), Some("--weird"));
        assert_eq!(a.get("drop"), Some("-0.5"));
    }

    #[test]
    fn duplicate_options_and_flags_error() {
        let dup = |s: &str| Args::parse(s.split_whitespace().map(String::from));
        assert!(dup("x --nodes 8 --nodes 9").is_err(), "repeated option");
        assert!(dup("x --nodes=8 --nodes 9").is_err(), "mixed forms");
        assert!(dup("x --fast --fast").is_err(), "repeated flag");
        assert!(dup("x --fast --fast=1").is_err(), "flag then option");
        assert!(dup("x --nodes 8 --fast").is_ok(), "distinct names fine");
    }

    #[test]
    fn strict_flag_rejects_a_swallowed_positional() {
        // `bench --fast table1` reads as `fast = "table1"` (schema-free
        // parsing cannot know --fast is boolean); flag_strict turns that
        // silent double-misread (flag lost AND positional lost) into an
        // error, while genuine flag/option uses pass through.
        let a = parse("bench --fast table1");
        assert!(!a.flag("fast"));
        let err = a.flag_strict("fast").unwrap_err().to_string();
        assert!(err.contains("positional"), "{err}");
        assert!(parse("bench table1 --fast").flag_strict("fast").unwrap());
        assert!(!parse("bench table1").flag_strict("fast").unwrap());
    }

    #[test]
    fn bare_flag_errors_when_a_value_is_expected() {
        // `--nodes --engine par`: `--nodes` is recorded as a bare flag
        // (the old parser did the same, silently); every typed getter now
        // refuses to treat it as "absent" and points at the `=` form.
        let a = parse("train --nodes --engine par");
        assert!(a.flag("nodes"));
        let err = a.usize_or("nodes", 4).unwrap_err().to_string();
        assert!(err.contains("--nodes=<value>"), "{err}");
        assert!(a.u64_or("nodes", 4).is_err());
        assert!(a.f64_or("nodes", 4.0).is_err());
        assert!(a.require("nodes").is_err());
        assert!(a.str_or("nodes", "x").is_err(), "string getters too");
        assert!(a.value_of("nodes").is_err());
        // Genuine flags with no value expectation are untouched.
        let b = parse("bench --fast");
        assert!(b.flag("fast"));
        assert_eq!(b.usize_or("nodes", 4).unwrap(), 4);
        assert_eq!(b.str_or("model", "mlp").unwrap(), "mlp");
        assert_eq!(b.value_of("model").unwrap(), None);
    }
}

//! Tiny argument parser (the offline build has no clap): subcommand +
//! `--key value` / `--flag` options with typed getters and error messages.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand, positionals, `--key value` options and
/// bare `--flag`s.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-option token.
    pub subcommand: Option<String>,
    /// Remaining non-option tokens, in order.
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Was the bare flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer option with a default (error names the offending flag).
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// u64 option with a default (error names the offending flag).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// Float option with a default (error names the offending flag).
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not a number")),
        }
    }

    /// Required option (error if absent).
    pub fn require(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // NOTE: a bare `--flag` followed by a non-option token is read as
        // `--flag <value>` (option form); trailing flags are unambiguous.
        let a = parse("train extra --model mlp_small --nodes 8 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp_small"));
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 8);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --exp=table1 --epochs=4.5");
        assert_eq!(a.get("exp"), Some("table1"));
        assert!((a.f64_or("epochs", 0.0).unwrap() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x");
        assert_eq!(a.usize_or("nodes", 4).unwrap(), 4);
        assert!(a.require("model").is_err());
        let a = parse("x --nodes eight");
        assert!(a.usize_or("nodes", 4).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench table1 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["table1"]);
        assert!(a.flag("fast"));
    }
}

//! τ-Overlap SGP (Alg. 2) as a strategy: non-blocking sends whose messages
//! land up to τ rounds late, reusing the delay buffers of the PushSum
//! engine. `biased = true` freezes the push-sum weight at 1 — the Table-4
//! ablation that "directly incorporates delayed messages without
//! accounting for the bias".

use anyhow::Result;

use crate::gossip::PushSumEngine;
use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;
use crate::topology::{Schedule, TopologyKind};

use super::{AlgoParams, DistributedAlgorithm, RoundCtx};

/// τ-Overlap SGP strategy state (delayed PushSum engine + optimizers).
pub struct Osgp {
    engine: PushSumEngine,
    schedule: Schedule,
    opts: Vec<Optimizer>,
    tau: u64,
    biased: bool,
}

impl Osgp {
    /// Overlap-SGP over `kind` with delay τ (clamped ≥ 1); `biased` freezes
    /// the push-sum weight (the Table-4 ablation).
    pub fn new(kind: TopologyKind, tau: u64, biased: bool, p: &AlgoParams) -> Self {
        let tau = tau.max(1);
        Self {
            engine: PushSumEngine::new(vec![p.init.clone(); p.n], tau, biased),
            schedule: Schedule::with_seed(kind, p.n, p.seed),
            opts: (0..p.n).map(|_| Optimizer::new(p.optim, p.init.len())).collect(),
            tau,
            biased,
        }
    }
}

/// Registry builder for `osgp`.
pub fn build(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::OnePeerExp);
    Ok(Box::new(Osgp::new(kind, p.tau, false, p)))
}

/// Registry builder for `osgp-biased` (the Table-4 ablation).
pub fn build_biased(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::OnePeerExp);
    Ok(Box::new(Osgp::new(kind, p.tau, true, p)))
}

impl DistributedAlgorithm for Osgp {
    fn name(&self) -> String {
        if self.biased {
            format!("biased {}-OSGP", self.tau)
        } else {
            format!("{}-OSGP", self.tau)
        }
    }

    fn n(&self) -> usize {
        self.engine.n
    }

    fn dim(&self) -> usize {
        self.engine.dim
    }

    fn local_view(&self, i: usize, out: &mut [f32]) {
        self.engine.states[i].debias_into(out);
    }

    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32) {
        self.opts[i].step(&mut self.engine.states[i].x, grad, lr);
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        self.engine
            .step_compressed(ctx.k, &self.schedule, ctx.faults, ctx.exec, ctx.compress);
        OwnedCommPattern::PushSum {
            schedule: self.schedule.clone(),
            bytes: ctx.wire_bytes(self.engine.dim),
            tau: self.tau,
        }
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        self.engine.consensus_distance()
    }

    fn compresses_gossip(&self) -> bool {
        true
    }

    fn snapshot(&self, round: u64) -> Option<crate::snapshot::Snapshot> {
        Some(self.engine.save(round))
    }

    fn drain(&mut self) {
        self.engine.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    #[test]
    fn overlap_keeps_mass_in_flight_until_drain() {
        let n = 8;
        let mut p = AlgoParams::new(n, vec![1.0f32; 4], OptimKind::Sgd);
        p.tau = 2;
        let mut alg = Osgp::new(TopologyKind::OnePeerExp, p.tau, false, &p);
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1; n];
        for k in 0..6 {
            let ctx = RoundCtx::new(k, &comp, 16, &link);
            match alg.communicate(&ctx) {
                OwnedCommPattern::PushSum { tau, .. } => assert_eq!(tau, 2),
                _ => panic!("wrong pattern"),
            }
        }
        // In-flight τ-delayed messages exist mid-run; drain flushes them.
        alg.drain();
        let (mean, _, _) = alg.consensus_stats();
        assert!(mean < 1e-4, "identical inits stay in consensus: {mean}");
    }

    #[test]
    fn names_encode_tau_and_bias() {
        let mut p = AlgoParams::new(4, vec![0.0; 2], OptimKind::Sgd);
        p.tau = 3;
        assert_eq!(build(&p).unwrap().name(), "3-OSGP");
        assert_eq!(build_biased(&p).unwrap().name(), "biased 3-OSGP");
    }
}

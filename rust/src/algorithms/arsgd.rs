//! AllReduce parallel SGD (Goyal et al., 2017) as an ordinary strategy:
//! a *replicated* state with complete mixing. Every node sees the same
//! parameters, gradients are exactly averaged behind a global barrier,
//! and one optimizer slot (whose state is by construction identical on
//! every node) applies the averaged step. No special case in the
//! coordinator — the barrier lives entirely in the returned
//! [`OwnedCommPattern::AllReduce`] timing pattern.

use anyhow::{bail, Result};

use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;

use super::{AlgoParams, DistributedAlgorithm, RoundCtx};

/// AllReduce-SGD strategy state (replicated parameters + one optimizer).
pub struct ArSgd {
    n: usize,
    /// The replicated parameter vector (all nodes identical).
    params: Vec<f32>,
    /// The replicated optimizer slot.
    opt: Optimizer,
    /// Gradient accumulator for the current round.
    gsum: Vec<f32>,
    grads_seen: usize,
    pending_lr: f32,
}

impl ArSgd {
    /// Build the replicated state from the shared parameters.
    pub fn new(p: &AlgoParams) -> Self {
        Self {
            n: p.n,
            params: p.init.clone(),
            opt: Optimizer::new(p.optim, p.init.len()),
            gsum: vec![0.0; p.init.len()],
            grads_seen: 0,
            pending_lr: 0.0,
        }
    }

    /// Apply the accumulated mean gradient to the replicated state — the
    /// exact-averaging step every node takes after the collective.
    fn flush(&mut self) {
        if self.grads_seen == 0 {
            return;
        }
        let inv = 1.0 / self.grads_seen as f32;
        for a in self.gsum.iter_mut() {
            *a *= inv;
        }
        let lr = self.pending_lr;
        self.opt.step(&mut self.params, &self.gsum, lr);
        for a in self.gsum.iter_mut() {
            *a = 0.0;
        }
        self.grads_seen = 0;
    }
}

/// Registry builder for `ar-sgd`.
pub fn build(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    if p.topology.is_some() {
        bail!("ar-sgd mixes exactly (complete graph); a topology override is not supported");
    }
    Ok(Box::new(ArSgd::new(p)))
}

impl DistributedAlgorithm for ArSgd {
    fn name(&self) -> String {
        "AR-SGD".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.params.len()
    }

    fn local_view(&self, _i: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.params);
    }

    fn apply_step(&mut self, _i: usize, grad: &[f32], lr: f32) {
        for (a, g) in self.gsum.iter_mut().zip(grad) {
            *a += g;
        }
        self.grads_seen += 1;
        self.pending_lr = lr;
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        self.flush();
        OwnedCommPattern::AllReduce { bytes: ctx.msg_bytes }
    }

    fn average(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        (0.0, 0.0, 0.0)
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn drain(&mut self) {
        // Honor the trait contract: a gradient handed over but not yet
        // flushed by a communicate() call still lands.
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    #[test]
    fn averaged_gradient_step_on_replicated_state() {
        let p = AlgoParams::new(2, vec![0.0f32; 2], OptimKind::Sgd);
        let mut a = ArSgd::new(&p);
        a.apply_step(0, &[1.0, 0.0], 0.1);
        a.apply_step(1, &[3.0, 0.0], 0.1);
        let link = LinkModel::ethernet_10g();
        let ctx = RoundCtx::new(0, &[0.1, 0.1], 64, &link);
        let pat = a.communicate(&ctx);
        assert!(matches!(pat, OwnedCommPattern::AllReduce { bytes: 64 }));
        // SGD with weight decay 1e-4 on x=0: x -= lr * mean(g) = -0.1*2.0.
        let v = a.node_view(0);
        assert!((v[0] + 0.2).abs() < 1e-6, "{}", v[0]);
        assert_eq!(v[1], 0.0);
        assert_eq!(a.consensus_stats(), (0.0, 0.0, 0.0));
    }
}

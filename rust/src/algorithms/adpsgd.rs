//! Asynchronous D-PSGD (Lian et al., 2018) as a strategy driven by the
//! discrete-event queue.
//!
//! Staleness semantics per node update, exactly as in the paper: the
//! gradient is computed on a snapshot (here: the round-start view the
//! coordinator evaluates at), a pairwise average with a uniformly random
//! peer happens atomically, and only then is the stale gradient applied.
//! Within every round the [`crate::sim::EventQueue`] orders the n updates
//! by each node's cumulative simulated clock — stragglers genuinely fall
//! behind and their averages/updates land later in the sequence —
//! while the per-node update budget stays equal to the synchronous
//! algorithms' (one gradient per node per round), keeping runs comparable.
//!
//! Timing is barrier-free: each node's clock advances by its own compute
//! plus half a point-to-point message (the partially-overlapped averaging
//! thread of Lian et al., App. C), reported as
//! [`OwnedCommPattern::Async`].

use anyhow::{bail, Result};

use crate::faults::MembershipEvent;
use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;
use crate::rng::Pcg;
use crate::sim::EventQueue;

use super::{consensus_of, AlgoParams, DistributedAlgorithm, RoundCtx};

/// AD-PSGD strategy state (per-node parameters, clocks and event order).
pub struct AdPsgd {
    params: Vec<Vec<f32>>,
    opts: Vec<Optimizer>,
    /// Gradient handed over this round, applied stale at event-pop time.
    pending: Vec<Option<(Vec<f32>, f32)>>,
    /// Cumulative simulated completion clock per node.
    clock: Vec<f64>,
    /// Members currently down (fault mode): unlike the gossip strategies,
    /// AD-PSGD picks its own random peers, so it must know who is gone —
    /// this is the state the `on_membership_change` hook maintains.
    down: Vec<bool>,
    rng: Pcg,
}

impl AdPsgd {
    /// Build per-node replicas from the shared parameters.
    pub fn new(p: &AlgoParams) -> Self {
        Self {
            params: vec![p.init.clone(); p.n],
            opts: (0..p.n).map(|_| Optimizer::new(p.optim, p.init.len())).collect(),
            pending: (0..p.n).map(|_| None).collect(),
            clock: vec![0.0; p.n],
            down: vec![false; p.n],
            rng: Pcg::new(p.seed ^ 0xad95),
        }
    }
}

/// Registry builder for `adpsgd`.
pub fn build(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    if p.topology.is_some() {
        bail!(
            "adpsgd pairs peers uniformly at random (Lian et al., 2018); \
             a topology override is not supported"
        );
    }
    Ok(Box::new(AdPsgd::new(p)))
}

impl DistributedAlgorithm for AdPsgd {
    fn name(&self) -> String {
        "AD-PSGD".into()
    }

    fn n(&self) -> usize {
        self.params.len()
    }

    fn dim(&self) -> usize {
        self.params[0].len()
    }

    fn local_view(&self, i: usize, out: &mut [f32]) {
        // The snapshot the stale gradient is computed on.
        out.copy_from_slice(&self.params[i]);
    }

    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32) {
        // Deferred: applied after this round's pairwise average, in event
        // order (the AD-PSGD staleness semantics).
        self.pending[i] = Some((grad.to_vec(), lr));
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        let n = self.params.len();
        let link = match ctx.faults {
            Some(fc) => fc.scaled_link(ctx.link, ctx.k),
            None => ctx.link.clone(),
        };
        let overhead = 0.5 * link.ptp_time(ctx.msg_bytes);
        // Order this round's updates (surviving members only) by cumulative
        // completion time. Membership is round-constant, so the sorted
        // survivor list is built once.
        let alive: Vec<usize> = (0..n).filter(|&j| !self.down[j]).collect();
        let mut queue: EventQueue<usize> = EventQueue::new();
        for &i in &alive {
            self.clock[i] += ctx.comp[i] + overhead;
            queue.push(self.clock[i], i);
        }
        while let Some(ev) = queue.pop() {
            let i = ev.payload;
            // A queued event can outlive its node: if the fault clock says
            // the node is down at `k` but no membership event reached the
            // `down` mask (e.g. a caller driving the strategy without the
            // coordinator's event delivery), the stale event must be
            // dropped — not averaged, not applied, never a panic. Its
            // snapshot gradient dies with the node.
            if ctx.faults.is_some_and(|fc| fc.is_down(i, ctx.k)) {
                self.pending[i] = None;
                continue;
            }
            if alive.len() > 1 {
                // Pairwise average with a uniformly random *live* peer
                // (atomic in the shared-memory model). With full
                // membership the skip-self index arithmetic consumes the
                // RNG exactly like the original uniform draw, so lossless
                // runs are bit-identical. An event node missing from the
                // survivor list is the same staleness case as above:
                // drop the event instead of panicking.
                let Ok(pos) = alive.binary_search(&i) else {
                    self.pending[i] = None;
                    continue;
                };
                let pick = self.rng.below(alive.len() - 1);
                let j = alive[pick + (pick >= pos) as usize];
                // A dropped exchange — or a peer the clock already marks
                // as departed — skips the averaging (the stale gradient
                // below still lands); AD-PSGD has no mass ledger.
                let dropped = ctx
                    .faults
                    .map(|fc| fc.drops(i, j, ctx.k) || fc.is_down(j, ctx.k))
                    .unwrap_or(false);
                if !dropped {
                    let (a, b) = if i < j {
                        let (l, r) = self.params.split_at_mut(j);
                        (&mut l[i], &mut r[0])
                    } else {
                        let (l, r) = self.params.split_at_mut(i);
                        (&mut r[0], &mut l[j])
                    };
                    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                        let m = 0.5 * (*x + *y);
                        *x = m;
                        *y = m;
                    }
                }
            }
            // Apply the stale gradient computed on the round-start snapshot.
            if let Some((g, lr)) = self.pending[i].take() {
                self.opts[i].step(&mut self.params[i], &g, lr);
            }
        }
        OwnedCommPattern::Async { overhead_s: overhead }
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        consensus_of(&self.params)
    }

    fn on_membership_change(&mut self, event: &MembershipEvent) {
        match *event {
            MembershipEvent::Crash { node, .. } | MembershipEvent::Leave { node, .. } => {
                self.down[node] = true;
                // The snapshot gradient dies with the crash.
                self.pending[node] = None;
            }
            MembershipEvent::Rejoin { node, .. } => {
                self.down[node] = false;
                // Rejoin-from-checkpoint: clock catches up to the cluster.
                let now = self.clock.iter().cloned().fold(0.0, f64::max);
                self.clock[node] = now;
            }
        }
    }

    fn drain(&mut self) {
        // Apply any gradient not yet flushed by a communicate() call.
        for i in 0..self.params.len() {
            if let Some((g, lr)) = self.pending[i].take() {
                self.opts[i].step(&mut self.params[i], &g, lr);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    fn ctx<'a>(
        k: u64,
        comp: &'a [f64],
        link: &'a LinkModel,
    ) -> RoundCtx<'a> {
        RoundCtx::new(k, comp, 1 << 10, link)
    }

    #[test]
    fn crashed_peer_is_never_averaged_with() {
        let p = AlgoParams::new(4, vec![0.0f32; 2], OptimKind::Sgd);
        let mut alg = AdPsgd::new(&p);
        alg.params[3] = vec![100.0, 100.0]; // poison value on the crashed node
        alg.on_membership_change(&MembershipEvent::Leave { node: 3, at: 0 });
        let link = LinkModel::ethernet_10g();
        let comp = [0.1; 4];
        for k in 0..20 {
            alg.communicate(&ctx(k, &comp, &link));
        }
        // Nobody ever pulled mass from the dead node, and its own state and
        // clock stayed frozen.
        for v in &alg.params[..3] {
            assert!(v.iter().all(|x| x.abs() < 1e-6), "{v:?}");
        }
        assert_eq!(alg.params[3], vec![100.0, 100.0]);
        assert_eq!(alg.clock[3], 0.0);
    }

    #[test]
    fn gradients_apply_stale_after_averaging() {
        // Two nodes, opposite params, zero gradients: one round of pairwise
        // averaging must bring both to the mean.
        let p = AlgoParams::new(2, vec![0.0f32; 2], OptimKind::Sgd);
        let mut alg = AdPsgd::new(&p);
        alg.params[0] = vec![1.0, 1.0];
        alg.params[1] = vec![-1.0, -1.0];
        alg.apply_step(0, &[0.0, 0.0], 0.1);
        alg.apply_step(1, &[0.0, 0.0], 0.1);
        let link = LinkModel::ethernet_10g();
        let comp = [0.1, 0.2];
        let pat = alg.communicate(&ctx(0, &comp, &link));
        assert!(matches!(pat, OwnedCommPattern::Async { .. }));
        for v in &alg.params {
            assert!(v.iter().all(|x| x.abs() < 1e-6), "{v:?}");
        }
        assert!(alg.consensus_stats().0 < 1e-9);
    }

    #[test]
    fn stragglers_fall_behind_in_event_order() {
        let p = AlgoParams::new(4, vec![0.0f32; 2], OptimKind::Sgd);
        let mut alg = AdPsgd::new(&p);
        let link = LinkModel::ethernet_10g();
        for k in 0..3 {
            for i in 0..4 {
                alg.apply_step(i, &[1.0, 1.0], 0.01);
            }
            let comp = [0.1, 0.1, 0.1, 2.0];
            alg.communicate(&ctx(k, &comp, &link));
        }
        // The straggler's cumulative clock trails the fast nodes.
        assert!(alg.clock[3] > alg.clock[0] * 2.0);
        // Every gradient was consumed.
        assert!(alg.pending.iter().all(|p| p.is_none()));
    }

    #[test]
    fn stale_event_for_departed_node_is_dropped_not_fired() {
        // Crash-then-fire: the fault clock marks node 3 down mid-run but
        // NO membership event is delivered (a caller driving the strategy
        // without the coordinator). The queued event for the departed
        // node must be dropped — frozen state, discarded gradient, no
        // panic — and nobody averages with the corpse.
        use crate::faults::{FaultClock, FaultPlan};
        let p = AlgoParams::new(4, vec![0.0f32; 2], OptimKind::Sgd);
        let mut alg = AdPsgd::new(&p);
        alg.params[3] = vec![50.0, 50.0];
        let clock = FaultClock::new(FaultPlan::lossless().with_crash(3, 0, None));
        let link = LinkModel::ethernet_10g();
        let comp = [0.1; 4];
        for k in 0..20 {
            for i in 0..4 {
                alg.apply_step(i, &[0.0, 0.0], 0.1);
            }
            let ctx = RoundCtx::new(k, &comp, 1 << 10, &link).with_faults(&clock);
            alg.communicate(&ctx);
        }
        assert_eq!(alg.params[3], vec![50.0, 50.0], "departed node frozen");
        assert!(alg.pending[3].is_none(), "stale gradient discarded");
        for v in &alg.params[..3] {
            assert!(
                v.iter().all(|x| x.abs() < 1e-6),
                "survivors never pulled mass from the corpse: {v:?}"
            );
        }
    }

    #[test]
    fn drain_flushes_unapplied_gradients() {
        let p = AlgoParams::new(2, vec![0.0f32; 1], OptimKind::Sgd);
        let mut alg = AdPsgd::new(&p);
        alg.apply_step(0, &[1.0], 0.1);
        alg.drain();
        assert!((alg.params[0][0] + 0.1).abs() < 1e-6);
    }
}

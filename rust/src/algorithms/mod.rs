//! The distributed-training algorithms compared in the paper.
//!
//! * [`Algorithm::ArSgd`] — AllReduce parallel SGD (Goyal et al., 2017):
//!   exact gradient averaging behind a global barrier.
//! * [`Algorithm::Sgp`] — Stochastic Gradient Push (this paper, Alg. 1):
//!   one local optimizer step interleaved with one PushSum gossip step
//!   over a column-stochastic (possibly directed/time-varying) schedule.
//! * [`Algorithm::Osgp`] — τ-Overlap SGP (Alg. 2): non-blocking sends,
//!   messages consumed with ≤ τ iterations of staleness; `biased = true`
//!   reproduces the Table-4 ablation that drops the push-sum weight.
//! * [`Algorithm::DPsgd`] — Decentralized parallel SGD (Lian et al., 2017):
//!   symmetric doubly-stochastic gossip (pairwise exchanges).
//! * [`Algorithm::AdPsgd`] — Asynchronous D-PSGD (Lian et al., 2018):
//!   event-driven pairwise averaging with stale gradients.
//!
//! Equivalences encoded here and checked in integration tests:
//! SGP ≡ AR-SGD when the mixing matrix is (1/n)·11ᵀ and nodes start equal;
//! SGP ≡ D-PSGD under a static symmetric doubly-stochastic schedule
//! (the push-sum weights stay ≡ 1).

use crate::topology::{HybridSchedule, Schedule, TopologyKind};

#[derive(Clone, Debug)]
pub enum Algorithm {
    /// Exact averaging every iteration (the synchronous baseline).
    ArSgd,
    /// PushSum gossip over `schedule` (possibly hybrid, Table 3).
    Sgp { schedule: HybridSchedule },
    /// Overlap SGP with delay bound `tau` (≥1); `biased` drops the weight.
    Osgp { schedule: HybridSchedule, tau: u64, biased: bool },
    /// Symmetric gossip baseline.
    DPsgd { schedule: Schedule },
    /// Asynchronous gossip baseline (event-driven).
    AdPsgd { schedule: Schedule },
}

impl Algorithm {
    /// Paper-style display name.
    pub fn name(&self) -> String {
        match self {
            Algorithm::ArSgd => "AR-SGD".into(),
            Algorithm::Sgp { schedule } => {
                let s = &schedule.phases[0].1;
                if schedule.phases.len() > 1 {
                    let s2 = &schedule.phases[1].1;
                    format!("{}/{}-SGP", phase_tag(s.kind), phase_tag(s2.kind))
                } else {
                    format!("{}-SGP", phase_tag(s.kind))
                }
            }
            Algorithm::Osgp { tau, biased, .. } => {
                if *biased {
                    format!("biased {tau}-OSGP")
                } else {
                    format!("{tau}-OSGP")
                }
            }
            Algorithm::DPsgd { .. } => "D-PSGD".into(),
            Algorithm::AdPsgd { .. } => "AD-PSGD".into(),
        }
    }

    /// Convenience constructors for the standard experiment grid.
    pub fn sgp_1peer(n: usize) -> Self {
        Algorithm::Sgp {
            schedule: HybridSchedule::single(Schedule::new(
                TopologyKind::OnePeerExp,
                n,
            )),
        }
    }

    pub fn sgp_2peer(n: usize) -> Self {
        Algorithm::Sgp {
            schedule: HybridSchedule::single(Schedule::new(
                TopologyKind::TwoPeerExp,
                n,
            )),
        }
    }

    pub fn osgp_1peer(n: usize, tau: u64) -> Self {
        Algorithm::Osgp {
            schedule: HybridSchedule::single(Schedule::new(
                TopologyKind::OnePeerExp,
                n,
            )),
            tau,
            biased: false,
        }
    }

    pub fn osgp_biased(n: usize, tau: u64) -> Self {
        Algorithm::Osgp {
            schedule: HybridSchedule::single(Schedule::new(
                TopologyKind::OnePeerExp,
                n,
            )),
            tau,
            biased: true,
        }
    }

    pub fn dpsgd(n: usize) -> Self {
        Algorithm::DPsgd { schedule: Schedule::new(TopologyKind::BipartiteExp, n) }
    }

    pub fn adpsgd(n: usize) -> Self {
        Algorithm::AdPsgd { schedule: Schedule::new(TopologyKind::BipartiteExp, n) }
    }

    /// Table 3 hybrids: dense (or 2-peer) first `switch_at` iterations,
    /// then 1-peer SGP.
    pub fn hybrid_ar_then_1p(n: usize, switch_at: u64) -> Self {
        Algorithm::Sgp {
            schedule: HybridSchedule::two_phase(
                Schedule::new(TopologyKind::Complete, n),
                switch_at,
                Schedule::new(TopologyKind::OnePeerExp, n),
            ),
        }
    }

    pub fn hybrid_2p_then_1p(n: usize, switch_at: u64) -> Self {
        Algorithm::Sgp {
            schedule: HybridSchedule::two_phase(
                Schedule::new(TopologyKind::TwoPeerExp, n),
                switch_at,
                Schedule::new(TopologyKind::OnePeerExp, n),
            ),
        }
    }
}

fn phase_tag(kind: TopologyKind) -> &'static str {
    match kind {
        TopologyKind::OnePeerExp => "1P",
        TopologyKind::TwoPeerExp => "2P",
        TopologyKind::Complete => "AR",
        _ => "X",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper_tables() {
        assert_eq!(Algorithm::ArSgd.name(), "AR-SGD");
        assert_eq!(Algorithm::sgp_1peer(8).name(), "1P-SGP");
        assert_eq!(Algorithm::sgp_2peer(8).name(), "2P-SGP");
        assert_eq!(Algorithm::osgp_1peer(8, 1).name(), "1-OSGP");
        assert_eq!(Algorithm::osgp_biased(8, 1).name(), "biased 1-OSGP");
        assert_eq!(Algorithm::dpsgd(8).name(), "D-PSGD");
        assert_eq!(Algorithm::adpsgd(8).name(), "AD-PSGD");
        assert_eq!(Algorithm::hybrid_ar_then_1p(8, 100).name(), "AR/1P-SGP");
        assert_eq!(Algorithm::hybrid_2p_then_1p(8, 100).name(), "2P/1P-SGP");
    }
}

//! Pluggable distributed-training strategies: the paper's algorithms as
//! interchangeable implementations of one node-centric trait.
//!
//! The paper's core observation is that PushSum-style gossip is one point
//! in a *family* of communication strategies. This module encodes that
//! family as the [`DistributedAlgorithm`] trait — one object owning the
//! full per-node state (parameters, push-sum weights, optimizer slots,
//! in-flight messages) — with one implementation per strategy:
//!
//! * [`arsgd::ArSgd`] — AllReduce parallel SGD (Goyal et al., 2017): a
//!   replicated state with complete mixing every round.
//! * [`sgp::Sgp`] — Stochastic Gradient Push (this paper, Alg. 1), over
//!   any column-stochastic (possibly hybrid/time-varying) schedule.
//! * [`osgp::Osgp`] — τ-Overlap SGP (Alg. 2); `biased = true` reproduces
//!   the Table-4 ablation that drops the push-sum weight.
//! * [`dpsgd::DPsgd`] — Decentralized parallel SGD (Lian et al., 2017):
//!   symmetric doubly-stochastic gossip.
//! * [`adpsgd::AdPsgd`] — Asynchronous D-PSGD (Lian et al., 2018):
//!   event-queue-ordered pairwise averaging with stale gradients.
//! * [`dasgd::DaSgd`] — DaSGD-style delayed averaging (Zhou et al., 2020):
//!   gradients applied after a fixed delay of communication rounds, on top
//!   of the τ-delayed gossip machinery.
//!
//! Equivalences encoded here and checked in `rust/tests/trait_equivalences.rs`:
//! SGP ≡ AR-SGD when the mixing matrix is (1/n)·11ᵀ and nodes start equal;
//! SGP ≡ D-PSGD under a static symmetric doubly-stochastic schedule
//! (the push-sum weights stay ≡ 1).
//!
//! # Adding an algorithm
//!
//! Write a struct holding your per-node states, implement
//! [`DistributedAlgorithm`], and append one [`AlgorithmSpec`] to
//! [`REGISTRY`]. The coordinator loop, CLI, experiment drivers, and
//! examples all resolve strategies through [`build`] by name — no other
//! file needs to change. `dasgd.rs` is the worked example (see DESIGN.md).

pub mod adpsgd;
pub mod arsgd;
pub mod dasgd;
pub mod dpsgd;
pub mod osgp;
pub mod sgp;

pub use adpsgd::AdPsgd;
pub use arsgd::ArSgd;
pub use dasgd::DaSgd;
pub use dpsgd::DPsgd;
pub use osgp::Osgp;
pub use sgp::Sgp;

use anyhow::{bail, Result};

use crate::collectives;
use crate::faults::{FaultClock, MembershipEvent};
use crate::gossip::{Compression, ExecPolicy};
use crate::net::{LinkModel, OwnedCommPattern};
use crate::optim::OptimKind;
use crate::topology::TopologyKind;

/// Everything a strategy sees about round `k` when it communicates.
pub struct RoundCtx<'a> {
    /// Round (iteration) index.
    pub k: u64,
    /// Sampled compute seconds per node for this round — the same samples
    /// the timing simulator advances with, so event-driven strategies
    /// order their updates consistently with the simulated clocks.
    pub comp: &'a [f64],
    /// Bytes one parameter message carries over the simulated network.
    pub msg_bytes: usize,
    /// The simulated fabric (for strategies that derive their own costs,
    /// e.g. AD-PSGD's partially-overlapped averaging thread).
    pub link: &'a LinkModel,
    /// Active fault scenario, if any: strategies route their gossip through
    /// the lossy/churn-aware paths when this is set. `None` (the default)
    /// is the lossless cluster.
    pub faults: Option<&'a FaultClock>,
    /// Execution policy for the round's state updates: the shard handle the
    /// coordinator threads through to every engine-owning strategy. Any
    /// policy yields bit-identical results at a fixed seed (the engine's
    /// determinism contract), so strategies apply it blindly — no
    /// algorithm-specific branches.
    pub exec: ExecPolicy,
    /// Message-compression spec for the round's gossip exchange
    /// ([`Compression::Identity`] by default). Engine-owning strategies
    /// thread it straight into
    /// [`crate::gossip::PushSumEngine::step_compressed`] and charge
    /// [`Self::wire_bytes`] in their timing pattern — again with no
    /// algorithm-specific branches. Exact-collective strategies (AR-SGD)
    /// ship dense: an exact average cannot drop coordinates.
    pub compress: Compression,
}

impl<'a> RoundCtx<'a> {
    /// A lossless-round context (the common case in tests and benches).
    pub fn new(k: u64, comp: &'a [f64], msg_bytes: usize, link: &'a LinkModel) -> Self {
        Self {
            k,
            comp,
            msg_bytes,
            link,
            faults: None,
            exec: ExecPolicy::Sequential,
            compress: Compression::Identity,
        }
    }

    /// Attach a fault scenario to the round.
    pub fn with_faults(mut self, clock: &'a FaultClock) -> Self {
        self.faults = Some(clock);
        self
    }

    /// Set the execution policy for the round's state updates.
    pub fn with_exec(mut self, exec: ExecPolicy) -> Self {
        self.exec = exec;
        self
    }

    /// Set the message-compression spec for the round's gossip exchange.
    pub fn with_compress(mut self, compress: Compression) -> Self {
        self.compress = compress;
        self
    }

    /// On-wire bytes of one gossip message of `dim` logical coordinates
    /// under the round's compression spec — what the timing simulator
    /// should be charged instead of the dense `msg_bytes`.
    pub fn wire_bytes(&self, dim: usize) -> usize {
        self.compress.encoded_bytes(dim, self.msg_bytes)
    }
}

/// Consensus statistics `(mean, min, max)` over nodes of ‖v_i − v̄‖₂ for a
/// set of per-node parameter views — shared by strategies that do not keep
/// a push-sum engine.
pub(crate) fn consensus_of(views: &[Vec<f32>]) -> (f64, f64, f64) {
    let mean = collectives::mean_of(views);
    let mut dists = Vec::with_capacity(views.len());
    for v in views {
        let d: f64 = v
            .iter()
            .zip(&mean)
            .map(|(a, b)| {
                let e = (a - b) as f64;
                e * e
            })
            .sum();
        dists.push(d.sqrt());
    }
    let sum: f64 = dists.iter().sum();
    let min = dists.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = dists.iter().cloned().fold(0.0, f64::max);
    (sum / views.len().max(1) as f64, min, max)
}

/// One distributed-training strategy: the node-centric state plus the four
/// verbs the coordinator loop speaks. The loop is strategy-agnostic; all
/// per-algorithm behaviour lives behind this trait.
///
/// Per synchronous round `k` the coordinator calls, in order:
/// 1. [`local_view`](Self::local_view) for each node — the de-biased
///    parameters `z_i` the gradient is evaluated at;
/// 2. [`apply_step`](Self::apply_step) for each node — hand the local
///    gradient to the node's own optimizer slot (strategies may defer or
///    re-route the application, e.g. delayed or stale updates);
/// 3. [`communicate`](Self::communicate) once — run the round's exchange
///    and return the timing pattern for the network simulator.
pub trait DistributedAlgorithm {
    /// Paper-style display name (used for run labels and tables).
    fn name(&self) -> String;

    /// Number of logical nodes.
    fn n(&self) -> usize;

    /// Parameter dimension.
    fn dim(&self) -> usize;

    /// Write node `i`'s de-biased parameter view `z_i` into `out`.
    fn local_view(&self, i: usize, out: &mut [f32]);

    /// Hand node `i` its local gradient for this round at step size `lr`.
    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32);

    /// Run round-`k` communication; return the pattern the timing
    /// simulator should charge for it.
    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern;

    /// Node `i`'s de-biased parameters as a fresh vector (evaluation).
    fn node_view(&self, i: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.dim()];
        self.local_view(i, &mut v);
        v
    }

    /// Network average of the de-biased parameters (the consensus model
    /// that tables evaluate).
    fn average(&self) -> Vec<f32> {
        let zs: Vec<Vec<f32>> = (0..self.n()).map(|i| self.node_view(i)).collect();
        collectives::mean_of(&zs)
    }

    /// Consensus statistics `(mean, min, max)` over nodes of ‖z_i − x̄‖₂
    /// (Fig. 2). Exact strategies return zeros.
    fn consensus_stats(&self) -> (f64, f64, f64);

    /// Whether every node's view is identical by construction (exact
    /// averaging). The coordinator skips per-node evaluation spreads for
    /// exact strategies.
    fn is_exact(&self) -> bool {
        false
    }

    /// Whether this strategy applies [`RoundCtx::compress`] to its
    /// exchange. Engine-owning gossip strategies return `true`; the
    /// default is `false` — exact collectives (AR-SGD) must ship dense,
    /// and AD-PSGD's pairwise exchange is not routed through the push-sum
    /// engine. Callers use this to report honestly (and warn) when a
    /// compression spec would be silently ignored.
    fn compresses_gossip(&self) -> bool {
        false
    }

    /// Membership-change notification under a fault scenario: the
    /// coordinator (or the fault harness) reports crashes, rejoins and
    /// permanent leaves before the round they take effect. The default is a
    /// no-op — the gossip strategies handle churn structurally (crashed
    /// nodes freeze in place and the schedule re-indexes over survivors),
    /// so only strategies with their own peer-selection state (e.g.
    /// AD-PSGD) need to react.
    fn on_membership_change(&mut self, _event: &MembershipEvent) {}

    /// Capture a durable [`crate::snapshot::Snapshot`] of the strategy's
    /// full gossip state as of `round` (node states, in-flight mail,
    /// error-feedback banks, mass ledger). The default is `None`: only the
    /// engine-owning push-sum strategies can serialize their state, and
    /// checkpointing callers (the trainer loop, the fault harness) simply
    /// skip strategies that opt out rather than erroring.
    fn snapshot(&self, _round: u64) -> Option<crate::snapshot::Snapshot> {
        None
    }

    /// Flush in-flight state (delayed messages, deferred gradients) at the
    /// end of a run so no mass or update is stranded.
    fn drain(&mut self);
}

/// Constructor parameters shared by every registered strategy. Built by
/// [`crate::coordinator::TrainerBuilder`]; also usable directly in tests.
#[derive(Clone, Debug)]
pub struct AlgoParams {
    /// Number of logical nodes.
    pub n: usize,
    /// Initial parameters, replicated to every node.
    pub init: Vec<f32>,
    /// Local optimizer family (one slot per node).
    pub optim: OptimKind,
    /// Overlap delay τ (OSGP / DaSGD communication staleness). Defaults to
    /// 0 — blocking SGP semantics — so direct constructions don't silently
    /// inherit overlap staleness; the overlap strategies (OSGP, DaSGD)
    /// clamp it to ≥ 1 at build time, and callers that want more overlap
    /// set it explicitly ([`crate::coordinator::TrainerBuilder::tau`]).
    pub tau: u64,
    /// Gradient-application delay in rounds (DaSGD).
    pub grad_delay: u64,
    /// Iteration at which two-phase hybrid schedules switch. Note the
    /// default of 0 starts the *second* phase immediately (no dense
    /// warm-up); [`crate::coordinator::TrainerBuilder`] replaces it with a
    /// third of the run, the paper's epoch-30-of-90 protocol.
    pub switch_at: u64,
    /// Seed for randomized schedules / event ordering.
    pub seed: u64,
    /// Override the strategy's default gossip topology (e.g. dense SGP for
    /// Fig. 2). `None` keeps each strategy's paper default.
    pub topology: Option<TopologyKind>,
}

impl AlgoParams {
    /// Parameters with the default knobs (τ=0, unit grad delay, seed 0).
    pub fn new(n: usize, init: Vec<f32>, optim: OptimKind) -> Self {
        Self {
            n,
            init,
            optim,
            tau: 0,
            grad_delay: 1,
            switch_at: 0,
            seed: 0,
            topology: None,
        }
    }

    /// Parameter dimension (length of `init`).
    pub fn dim(&self) -> usize {
        self.init.len()
    }
}

/// One registry row: canonical name, aliases, summary, and builder.
pub struct AlgorithmSpec {
    /// Canonical registry name (`repro train --algo <name>`).
    pub name: &'static str,
    /// Accepted aliases.
    pub aliases: &'static [&'static str],
    /// One-line description shown by `repro algos`.
    pub summary: &'static str,
    /// Strategy constructor.
    pub build: fn(&AlgoParams) -> Result<Box<dyn DistributedAlgorithm>>,
}

/// The name-keyed algorithm registry: the single place a strategy is wired
/// into the CLI (`repro train --algo <name>`), the experiment drivers, and
/// the examples.
pub static REGISTRY: &[AlgorithmSpec] = &[
    AlgorithmSpec {
        name: "ar-sgd",
        aliases: &["arsgd", "ar"],
        summary: "AllReduce parallel SGD: exact averaging behind a global barrier",
        build: arsgd::build,
    },
    AlgorithmSpec {
        name: "sgp",
        aliases: &["sgp-1p"],
        summary: "Stochastic Gradient Push over the 1-peer exponential graph",
        build: sgp::build_1peer,
    },
    AlgorithmSpec {
        name: "sgp-2p",
        aliases: &[],
        summary: "SGP over the 2-peer exponential graph",
        build: sgp::build_2peer,
    },
    AlgorithmSpec {
        name: "osgp",
        aliases: &[],
        summary: "τ-Overlap SGP: non-blocking sends, ≤ τ rounds of staleness",
        build: osgp::build,
    },
    AlgorithmSpec {
        name: "osgp-biased",
        aliases: &[],
        summary: "Overlap SGP without the push-sum weight (Table-4 ablation)",
        build: osgp::build_biased,
    },
    AlgorithmSpec {
        name: "dpsgd",
        aliases: &["d-psgd"],
        summary: "Decentralized parallel SGD: symmetric doubly-stochastic gossip",
        build: dpsgd::build,
    },
    AlgorithmSpec {
        name: "adpsgd",
        aliases: &["ad-psgd"],
        summary: "Asynchronous D-PSGD: event-ordered pairwise averaging, stale grads",
        build: adpsgd::build,
    },
    AlgorithmSpec {
        name: "hybrid-ar-1p",
        aliases: &[],
        summary: "Table-3 hybrid: dense mixing until switch_at, then 1-peer SGP",
        build: sgp::build_hybrid_ar_1p,
    },
    AlgorithmSpec {
        name: "hybrid-2p-1p",
        aliases: &[],
        summary: "Table-3 hybrid: 2-peer until switch_at, then 1-peer SGP",
        build: sgp::build_hybrid_2p_1p,
    },
    AlgorithmSpec {
        name: "dasgd",
        aliases: &["da-sgd"],
        summary: "DaSGD-style delayed averaging: gradients applied grad_delay rounds late",
        build: dasgd::build,
    },
];

/// Look up a registry row by canonical name or alias.
pub fn spec(name: &str) -> Option<&'static AlgorithmSpec> {
    REGISTRY
        .iter()
        .find(|s| s.name == name || s.aliases.contains(&name))
}

/// Build a strategy by registry name.
pub fn build(name: &str, params: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    match spec(name) {
        Some(s) => (s.build)(params),
        None => bail!(
            "unknown algorithm `{name}` (known: {})",
            names().join(", ")
        ),
    }
}

/// Canonical names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize) -> AlgoParams {
        AlgoParams::new(n, vec![0.0; 8], OptimKind::Sgd)
    }

    #[test]
    fn registry_builds_every_algorithm() {
        for s in REGISTRY {
            let a = (s.build)(&params(8)).unwrap_or_else(|e| {
                panic!("registry `{}` failed to build: {e}", s.name)
            });
            assert_eq!(a.n(), 8, "{}", s.name);
            assert_eq!(a.dim(), 8, "{}", s.name);
            assert!(!a.name().is_empty());
        }
    }

    #[test]
    fn default_params_are_blocking() {
        // τ = 0 by default: direct (non-builder) constructions get blocking
        // SGP semantics; OSGP/DaSGD clamp to ≥ 1 where they need overlap.
        let p = params(4);
        assert_eq!(p.tau, 0);
        assert_eq!(build("osgp", &p).unwrap().name(), "1-OSGP");
        assert_eq!(build("dasgd", &p).unwrap().name(), "1-DaSGD");
    }

    #[test]
    fn compresses_gossip_marks_exactly_the_engine_strategies() {
        // Banner honesty depends on this flag: the engine-owning gossip
        // strategies compress; exact collectives and AD-PSGD ship dense.
        let p = params(8);
        for (name, expect) in [
            ("sgp", true),
            ("sgp-2p", true),
            ("osgp", true),
            ("osgp-biased", true),
            ("dpsgd", true),
            ("dasgd", true),
            ("hybrid-ar-1p", true),
            ("hybrid-2p-1p", true),
            ("ar-sgd", false),
            ("adpsgd", false),
        ] {
            assert_eq!(
                build(name, &p).unwrap().compresses_gossip(),
                expect,
                "{name}"
            );
        }
    }

    #[test]
    fn lookup_by_name_and_alias() {
        assert!(spec("sgp").is_some());
        assert!(spec("sgp-1p").is_some());
        assert!(spec("ar").is_some());
        assert!(spec("da-sgd").is_some());
        assert!(spec("nope").is_none());
        assert!(build("nope", &params(4)).is_err());
    }

    #[test]
    fn names_match_paper_tables() {
        let p = params(8);
        assert_eq!(build("ar-sgd", &p).unwrap().name(), "AR-SGD");
        assert_eq!(build("sgp", &p).unwrap().name(), "1P-SGP");
        assert_eq!(build("sgp-2p", &p).unwrap().name(), "2P-SGP");
        assert_eq!(build("osgp", &p).unwrap().name(), "1-OSGP");
        assert_eq!(build("osgp-biased", &p).unwrap().name(), "biased 1-OSGP");
        assert_eq!(build("dpsgd", &p).unwrap().name(), "D-PSGD");
        assert_eq!(build("adpsgd", &p).unwrap().name(), "AD-PSGD");
        assert_eq!(build("hybrid-ar-1p", &p).unwrap().name(), "AR/1P-SGP");
        assert_eq!(build("hybrid-2p-1p", &p).unwrap().name(), "2P/1P-SGP");
        assert_eq!(build("dasgd", &p).unwrap().name(), "1-DaSGD");
    }
}

//! Decentralized parallel SGD (Lian et al., 2017) as a strategy: PushSum
//! over a static symmetric doubly-stochastic schedule. Because the mixing
//! is doubly stochastic, the push-sum weights stay ≡ 1 and the engine
//! degenerates to plain symmetric gossip — the SGP ⊇ D-PSGD containment
//! the paper points out (checked in `trait_equivalences.rs`). Timing pays
//! the pairwise handshake barrier of symmetric exchange.

use anyhow::Result;

use crate::gossip::PushSumEngine;
use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;
use crate::topology::{Schedule, TopologyKind};

use super::{AlgoParams, DistributedAlgorithm, RoundCtx};

/// Handshake multiplier of symmetric exchange (send+recv + deadlock
/// avoidance), matching the paper's D-PSGD timing discussion.
pub const HANDSHAKE: f64 = 2.0;

/// D-PSGD strategy state (weightless PushSum engine over a symmetric
/// schedule).
pub struct DPsgd {
    engine: PushSumEngine,
    schedule: Schedule,
    opts: Vec<Optimizer>,
}

impl DPsgd {
    /// D-PSGD over a symmetric schedule of the given kind.
    pub fn new(kind: TopologyKind, p: &AlgoParams) -> Self {
        // `biased = true`: real D-PSGD carries no push-sum weight, so the
        // engine's w is pinned at 1. Under a lossless symmetric schedule
        // this is a no-op (w stays 1 anyway — the SGP ⊇ D-PSGD
        // containment); under message loss it models D-PSGD's missing mass
        // accounting: a dropped message skews the symmetric average and
        // there is no weight to absorb it, which is exactly the bias the
        // fault experiments measure against SGP.
        Self {
            engine: PushSumEngine::new(vec![p.init.clone(); p.n], 0, true),
            schedule: Schedule::with_seed(kind, p.n, p.seed),
            opts: (0..p.n).map(|_| Optimizer::new(p.optim, p.init.len())).collect(),
        }
    }
}

/// Registry builder for `dpsgd` (rejects asymmetric schedules).
pub fn build(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::BipartiteExp);
    // D-PSGD is defined over symmetric doubly-stochastic mixing, and the
    // engine runs weightless (w ≡ 1, no push-sum correction) — reject
    // directed/asymmetric overrides instead of silently skewing node
    // views toward high-in-degree nodes (use sgp for directed graphs).
    let sched = Schedule::with_seed(kind, p.n, p.seed);
    anyhow::ensure!(
        (0..8).all(|k| sched.is_symmetric(k)),
        "dpsgd requires a symmetric schedule; `{kind:?}` is not \
         (use sgp for directed/asymmetric graphs)"
    );
    Ok(Box::new(DPsgd::new(kind, p)))
}

impl DistributedAlgorithm for DPsgd {
    fn name(&self) -> String {
        "D-PSGD".into()
    }

    fn n(&self) -> usize {
        self.engine.n
    }

    fn dim(&self) -> usize {
        self.engine.dim
    }

    fn local_view(&self, i: usize, out: &mut [f32]) {
        self.engine.states[i].debias_into(out);
    }

    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32) {
        self.opts[i].step(&mut self.engine.states[i].x, grad, lr);
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        self.engine
            .step_compressed(ctx.k, &self.schedule, ctx.faults, ctx.exec, ctx.compress);
        OwnedCommPattern::Symmetric {
            schedule: self.schedule.clone(),
            bytes: ctx.wire_bytes(self.engine.dim),
            handshake: HANDSHAKE,
        }
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        self.engine.consensus_distance()
    }

    fn compresses_gossip(&self) -> bool {
        true
    }

    fn drain(&mut self) {
        self.engine.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    #[test]
    fn symmetric_schedule_keeps_weights_at_one() {
        let n = 8;
        let p = AlgoParams::new(n, vec![0.5f32; 4], OptimKind::Sgd);
        let mut alg = DPsgd::new(TopologyKind::BipartiteExp, &p);
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1; n];
        for i in 0..n {
            alg.apply_step(i, &[0.1 * i as f32; 4], 0.05);
        }
        for k in 0..20 {
            let ctx = RoundCtx::new(k, &comp, 16, &link);
            match alg.communicate(&ctx) {
                OwnedCommPattern::Symmetric { handshake, .. } => {
                    assert_eq!(handshake, HANDSHAKE)
                }
                _ => panic!("wrong pattern"),
            }
            for st in &alg.engine.states {
                assert!((st.w - 1.0).abs() < 1e-9, "w drifted: {}", st.w);
            }
        }
    }
}

//! DaSGD-style delayed averaging (Zhou et al., 2020) — the algorithm that
//! proves the trait API opens the scenario space: it landed as this file
//! plus one registry row, with zero coordinator changes.
//!
//! Idea: fully overlap *both* communication and the gradient application
//! with compute. Gossip messages travel with the τ-delay buffers of the
//! PushSum engine (the Alg.-2 machinery), and the local gradient computed
//! at round `k` is only applied at round `k + grad_delay` — by which time
//! the mixing has already spread the pre-update state. The parameters a
//! gradient was computed at and the parameters it updates differ by a
//! fixed, bounded lag, the same bounded-staleness regime as τ-OSGP, so
//! Theorem 1's bounded-delay analysis still covers it.
//!
//! Timing: messages are non-blocking with staleness τ (PushSum pattern),
//! and the deferred update costs nothing on the critical path.

use std::collections::VecDeque;

use anyhow::Result;

use crate::gossip::PushSumEngine;
use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;
use crate::topology::{Schedule, TopologyKind};

use super::{AlgoParams, DistributedAlgorithm, RoundCtx};

/// DaSGD strategy state (delayed PushSum engine + per-node gradient FIFOs).
pub struct DaSgd {
    engine: PushSumEngine,
    schedule: Schedule,
    opts: Vec<Optimizer>,
    /// Per-node FIFO of deferred `(gradient, lr)` pairs; depth `grad_delay`.
    fifo: Vec<VecDeque<(Vec<f32>, f32)>>,
    grad_delay: u64,
    tau: u64,
}

impl DaSgd {
    /// DaSGD over `kind` with message delay τ and gradient lag `grad_delay`.
    pub fn new(kind: TopologyKind, tau: u64, grad_delay: u64, p: &AlgoParams) -> Self {
        Self {
            engine: PushSumEngine::new(vec![p.init.clone(); p.n], tau, false),
            schedule: Schedule::with_seed(kind, p.n, p.seed),
            opts: (0..p.n).map(|_| Optimizer::new(p.optim, p.init.len())).collect(),
            fifo: (0..p.n).map(|_| VecDeque::new()).collect(),
            grad_delay,
            tau,
        }
    }
}

/// Registry builder for `dasgd`.
pub fn build(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::OnePeerExp);
    // Overlap is DaSGD's point: clamp τ ≥ 1 (AlgoParams defaults τ to 0 =
    // blocking; the degenerate τ=0 form is reachable via DaSgd::new).
    Ok(Box::new(DaSgd::new(kind, p.tau.max(1), p.grad_delay.max(1), p)))
}

impl DistributedAlgorithm for DaSgd {
    fn name(&self) -> String {
        format!("{}-DaSGD", self.grad_delay)
    }

    fn n(&self) -> usize {
        self.engine.n
    }

    fn dim(&self) -> usize {
        self.engine.dim
    }

    fn local_view(&self, i: usize, out: &mut [f32]) {
        self.engine.states[i].debias_into(out);
    }

    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32) {
        self.fifo[i].push_back((grad.to_vec(), lr));
        // Apply the gradient that has aged `grad_delay` rounds.
        if self.fifo[i].len() as u64 > self.grad_delay {
            let (g, old_lr) = self.fifo[i].pop_front().expect("aged gradient");
            self.opts[i].step(&mut self.engine.states[i].x, &g, old_lr);
        }
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        self.engine
            .step_compressed(ctx.k, &self.schedule, ctx.faults, ctx.exec, ctx.compress);
        // Timing staleness is the *message* delay only: the gradient FIFO
        // is node-local and costless, so it earns no extra timing credit.
        OwnedCommPattern::PushSum {
            schedule: self.schedule.clone(),
            bytes: ctx.wire_bytes(self.engine.dim),
            tau: self.tau,
        }
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        self.engine.consensus_distance()
    }

    fn compresses_gossip(&self) -> bool {
        true
    }

    fn drain(&mut self) {
        // Flush deferred gradients oldest-first, then in-flight messages.
        for i in 0..self.engine.n {
            while let Some((g, lr)) = self.fifo[i].pop_front() {
                self.opts[i].step(&mut self.engine.states[i].x, &g, lr);
            }
        }
        self.engine.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    #[test]
    fn gradient_applies_exactly_grad_delay_rounds_late() {
        let p = AlgoParams::new(1, vec![0.0f32; 1], OptimKind::Sgd);
        let mut alg = DaSgd::new(TopologyKind::OnePeerExp, 0, 2, &p);
        // Rounds 0 and 1: nothing applied yet (FIFO filling).
        alg.apply_step(0, &[1.0], 0.1);
        assert_eq!(alg.node_view(0)[0], 0.0);
        alg.apply_step(0, &[1.0], 0.1);
        assert_eq!(alg.node_view(0)[0], 0.0);
        // Round 2: the round-0 gradient lands.
        alg.apply_step(0, &[1.0], 0.1);
        assert!((alg.node_view(0)[0] + 0.1).abs() < 1e-6);
        // Drain flushes the two still-deferred gradients.
        alg.drain();
        assert!((alg.node_view(0)[0] + 0.3).abs() < 1e-4);
    }

    #[test]
    fn delayed_averaging_still_reaches_consensus() {
        let n = 8;
        let mut p = AlgoParams::new(n, vec![0.0f32; 4], OptimKind::Sgd);
        p.tau = 1;
        let mut alg = DaSgd::new(TopologyKind::OnePeerExp, 1, 1, &p);
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1; n];
        for k in 0..60 {
            for i in 0..n {
                // Round 0 perturbs the nodes apart; later rounds are quiet
                // so the deferred perturbation ages out and gossip smooths.
                let g = if k == 0 { vec![i as f32; 4] } else { vec![0.0; 4] };
                alg.apply_step(i, &g, 0.1);
            }
            let ctx = RoundCtx::new(k, &comp, 16, &link);
            match alg.communicate(&ctx) {
                OwnedCommPattern::PushSum { tau, .. } => assert_eq!(tau, 1),
                _ => panic!("wrong pattern"),
            }
        }
        alg.drain();
        let (mean, _, _) = alg.consensus_stats();
        assert!(mean < 1e-2, "consensus after drain: {mean}");
    }
}

//! Stochastic Gradient Push (Alg. 1) as a strategy object: one local
//! optimizer step on the biased numerator `x_i` interleaved with one
//! blocking PushSum gossip step over a column-stochastic — possibly
//! hybrid/time-varying — schedule. The Table-3 hybrids (dense or 2-peer
//! mixing early, 1-peer later) are just schedules, not separate code.

use anyhow::{bail, Result};

use crate::gossip::PushSumEngine;
use crate::net::OwnedCommPattern;
use crate::optim::Optimizer;
use crate::topology::{HybridSchedule, Schedule, TopologyKind};

use super::{AlgoParams, DistributedAlgorithm, RoundCtx};

/// SGP strategy state (PushSum engine + per-node optimizers).
pub struct Sgp {
    engine: PushSumEngine,
    schedule: HybridSchedule,
    opts: Vec<Optimizer>,
}

impl Sgp {
    /// SGP over an arbitrary (possibly hybrid) schedule.
    pub fn new(schedule: HybridSchedule, p: &AlgoParams) -> Self {
        Self {
            engine: PushSumEngine::new(vec![p.init.clone(); p.n], 0, false),
            schedule,
            opts: (0..p.n).map(|_| Optimizer::new(p.optim, p.init.len())).collect(),
        }
    }

    /// SGP over a single static-kind schedule.
    pub fn with_topology(kind: TopologyKind, p: &AlgoParams) -> Self {
        Self::new(
            HybridSchedule::single(Schedule::with_seed(kind, p.n, p.seed)),
            p,
        )
    }
}

/// Registry builder for `sgp` (1-peer exponential graph).
pub fn build_1peer(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::OnePeerExp);
    Ok(Box::new(Sgp::with_topology(kind, p)))
}

/// Registry builder for `sgp-2p` (2-peer exponential graph).
pub fn build_2peer(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    let kind = p.topology.unwrap_or(TopologyKind::TwoPeerExp);
    Ok(Box::new(Sgp::with_topology(kind, p)))
}

/// Registry builder for `hybrid-ar-1p` (dense until `switch_at`, then 1-peer).
pub fn build_hybrid_ar_1p(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    ensure_no_topology_override(p, "hybrid-ar-1p")?;
    Ok(Box::new(Sgp::new(
        HybridSchedule::two_phase(
            Schedule::with_seed(TopologyKind::Complete, p.n, p.seed),
            p.switch_at,
            Schedule::with_seed(TopologyKind::OnePeerExp, p.n, p.seed),
        ),
        p,
    )))
}

/// Registry builder for `hybrid-2p-1p` (2-peer until `switch_at`, then 1-peer).
pub fn build_hybrid_2p_1p(p: &AlgoParams) -> Result<Box<dyn DistributedAlgorithm>> {
    ensure_no_topology_override(p, "hybrid-2p-1p")?;
    Ok(Box::new(Sgp::new(
        HybridSchedule::two_phase(
            Schedule::with_seed(TopologyKind::TwoPeerExp, p.n, p.seed),
            p.switch_at,
            Schedule::with_seed(TopologyKind::OnePeerExp, p.n, p.seed),
        ),
        p,
    )))
}

/// Hybrid schedules hard-code their two phases; reject a topology override
/// rather than silently dropping it.
fn ensure_no_topology_override(p: &AlgoParams, name: &str) -> Result<()> {
    if p.topology.is_some() {
        bail!("{name} hard-codes its schedule phases; a topology override is not supported");
    }
    Ok(())
}

/// Paper-style tag for a schedule kind ("1P", "2P", "AR", …).
pub(crate) fn phase_tag(kind: TopologyKind) -> &'static str {
    match kind {
        TopologyKind::OnePeerExp => "1P",
        TopologyKind::TwoPeerExp => "2P",
        TopologyKind::Complete => "AR",
        _ => "X",
    }
}

/// Paper-style SGP label for a (possibly hybrid) schedule: "1P-SGP",
/// "AR/1P-SGP", …
pub(crate) fn sgp_label(schedule: &HybridSchedule) -> String {
    let s = &schedule.phases[0].1;
    if schedule.phases.len() > 1 {
        let s2 = &schedule.phases[1].1;
        format!("{}/{}-SGP", phase_tag(s.kind), phase_tag(s2.kind))
    } else {
        format!("{}-SGP", phase_tag(s.kind))
    }
}

impl DistributedAlgorithm for Sgp {
    fn name(&self) -> String {
        sgp_label(&self.schedule)
    }

    fn n(&self) -> usize {
        self.engine.n
    }

    fn dim(&self) -> usize {
        self.engine.dim
    }

    fn local_view(&self, i: usize, out: &mut [f32]) {
        self.engine.states[i].debias_into(out);
    }

    fn apply_step(&mut self, i: usize, grad: &[f32], lr: f32) {
        self.opts[i].step(&mut self.engine.states[i].x, grad, lr);
    }

    fn communicate(&mut self, ctx: &RoundCtx) -> OwnedCommPattern {
        let sched = self.schedule.at(ctx.k);
        self.engine
            .step_compressed(ctx.k, sched, ctx.faults, ctx.exec, ctx.compress);
        OwnedCommPattern::PushSum {
            schedule: sched.clone(),
            bytes: ctx.wire_bytes(self.engine.dim),
            tau: 0,
        }
    }

    fn consensus_stats(&self) -> (f64, f64, f64) {
        self.engine.consensus_distance()
    }

    fn compresses_gossip(&self) -> bool {
        true
    }

    fn snapshot(&self, round: u64) -> Option<crate::snapshot::Snapshot> {
        Some(self.engine.save(round))
    }

    fn drain(&mut self) {
        self.engine.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::optim::OptimKind;

    #[test]
    fn gossip_contracts_consensus_under_the_trait() {
        let n = 8;
        let mut init = vec![0.0f32; 4];
        init[0] = 1.0;
        let mut p = AlgoParams::new(n, init, OptimKind::Sgd);
        p.seed = 3;
        let mut alg = Sgp::with_topology(TopologyKind::OnePeerExp, &p);
        // Perturb node views apart with one fake gradient each.
        for i in 0..n {
            let g = vec![i as f32; 4];
            alg.apply_step(i, &g, 0.1);
        }
        let before = alg.consensus_stats().0;
        let link = LinkModel::ethernet_10g();
        let comp = vec![0.1; n];
        for k in 0..40 {
            let ctx = RoundCtx::new(k, &comp, 16, &link);
            let pat = alg.communicate(&ctx);
            assert!(matches!(pat, OwnedCommPattern::PushSum { tau: 0, .. }));
        }
        alg.drain();
        let after = alg.consensus_stats().0;
        assert!(before > 1e-3, "{before}");
        assert!(after < before * 1e-2, "{before} → {after}");
    }

    #[test]
    fn labels_cover_hybrids() {
        let p = AlgoParams::new(8, vec![0.0; 4], OptimKind::Sgd);
        assert_eq!(Sgp::with_topology(TopologyKind::OnePeerExp, &p).name(), "1P-SGP");
        assert_eq!(build_hybrid_ar_1p(&p).unwrap().name(), "AR/1P-SGP");
        assert_eq!(build_hybrid_2p_1p(&p).unwrap().name(), "2P/1P-SGP");
    }
}

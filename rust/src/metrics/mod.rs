//! Metrics: per-iteration/epoch series recorded by the trainer and the CSV
//! emitters used to regenerate the paper's tables and figures.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// One recorded training iteration.
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// Iteration index.
    pub iter: u64,
    /// Fractional epoch of the iteration.
    pub epoch: f64,
    /// Mean training loss across nodes at this iteration.
    pub train_loss: f64,
    /// Simulated wall-clock (seconds) when this iteration completed.
    pub sim_time_s: f64,
    /// Learning rate applied this iteration.
    pub lr: f64,
}

/// One recorded evaluation point (epoch granularity).
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// Iteration the evaluation happened at.
    pub iter: u64,
    /// Fractional epoch of the evaluation.
    pub epoch: f64,
    /// Simulated wall-clock (seconds) at the evaluation.
    pub sim_time_s: f64,
    /// Validation loss of the averaged (consensus) model.
    pub val_loss: f64,
    /// Validation metric (accuracy / perplexity proxy) of the same model.
    pub val_metric: f64,
    /// Per-node validation metric spread, minimum — Fig. D.3.
    pub node_metric_min: f64,
    /// Per-node validation metric spread, mean — Fig. D.3.
    pub node_metric_mean: f64,
    /// Per-node validation metric spread, maximum — Fig. D.3.
    pub node_metric_max: f64,
    /// Consensus distance ‖zᵢ − x̄‖, mean over nodes — Fig. 2.
    pub consensus_mean: f64,
    /// Consensus distance, minimum over nodes.
    pub consensus_min: f64,
    /// Consensus distance, maximum over nodes.
    pub consensus_max: f64,
}

/// Full result of one training run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    /// Run label (`<algo>_n<nodes>`), used in CSV file names.
    pub label: String,
    /// Per-iteration series.
    pub iters: Vec<IterRecord>,
    /// Per-evaluation series.
    pub evals: Vec<EvalRecord>,
    /// Total simulated time (seconds) for the whole run.
    pub sim_total_s: f64,
    /// Real wall-clock spent executing (diagnostics only).
    pub wall_s: f64,
    /// Validation loss at the final (post-drain) evaluation.
    pub final_val_loss: f64,
    /// Validation metric at the final (post-drain) evaluation.
    pub final_val_metric: f64,
}

impl RunResult {
    /// Training loss at the last recorded iteration (NaN for empty runs).
    pub fn final_train_loss(&self) -> f64 {
        self.iters.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    /// Average simulated seconds per iteration.
    pub fn avg_iter_time(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.sim_total_s / self.iters.len() as f64
    }

    /// Write the `<label>_iters.csv` / `<label>_evals.csv` series under `dir`.
    pub fn write_csv(&self, dir: &Path) -> Result<()> {
        fs::create_dir_all(dir)?;
        let mut f = fs::File::create(dir.join(format!("{}_iters.csv", self.label)))?;
        writeln!(f, "iter,epoch,train_loss,sim_time_s,lr")?;
        for r in &self.iters {
            writeln!(
                f,
                "{},{:.4},{:.6},{:.4},{:.6}",
                r.iter, r.epoch, r.train_loss, r.sim_time_s, r.lr
            )?;
        }
        let mut f = fs::File::create(dir.join(format!("{}_evals.csv", self.label)))?;
        writeln!(
            f,
            "iter,epoch,sim_time_s,val_loss,val_metric,node_min,node_mean,node_max,\
             consensus_mean,consensus_min,consensus_max"
        )?;
        for r in &self.evals {
            writeln!(
                f,
                "{},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6e},{:.6e},{:.6e}",
                r.iter,
                r.epoch,
                r.sim_time_s,
                r.val_loss,
                r.val_metric,
                r.node_metric_min,
                r.node_metric_mean,
                r.node_metric_max,
                r.consensus_mean,
                r.consensus_min,
                r.consensus_max
            )?;
        }
        Ok(())
    }
}

/// mean ± max-absolute-deviation, the statistic of Table 2.
pub fn mean_maxdev(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let maxdev = xs
        .iter()
        .map(|x| (x - mean).abs())
        .fold(0.0, f64::max);
    (mean, maxdev)
}

/// Render an aligned ASCII table (paper-table printer).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(header.iter().map(|s| s.to_string()).collect()));
    println!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    );
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

/// Format seconds as simulated hours (tables report hours).
pub fn hours(secs: f64) -> String {
    format!("{:.2} h", secs / 3600.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_maxdev_basics() {
        let (m, d) = mean_maxdev(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((d - 1.0).abs() < 1e-12);
        let (m, d) = mean_maxdev(&[5.0]);
        assert_eq!((m, d), (5.0, 0.0));
    }

    #[test]
    fn run_result_avg_iter_time() {
        let mut r = RunResult { label: "t".into(), ..Default::default() };
        r.sim_total_s = 10.0;
        r.iters = (0..5)
            .map(|i| IterRecord {
                iter: i,
                epoch: 0.0,
                train_loss: 0.0,
                sim_time_s: 0.0,
                lr: 0.0,
            })
            .collect();
        assert!((r.avg_iter_time() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn csv_writing_roundtrip(){
        let dir = std::env::temp_dir().join("sgp_metrics_test");
        let mut r = RunResult { label: "unit".into(), ..Default::default() };
        r.iters.push(IterRecord {
            iter: 0, epoch: 0.0, train_loss: 1.5, sim_time_s: 0.1, lr: 0.1,
        });
        r.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("unit_iters.csv")).unwrap();
        assert!(text.contains("1.5"));
    }
}

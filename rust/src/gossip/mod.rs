//! The PushSum gossip engine (Alg. 1 lines 5–8 / Alg. 2 lines 5–24).
//!
//! Each node holds the push-sum numerator `x ∈ R^d`, the scalar push-sum
//! weight `w`, and exposes the de-biased parameters `z = x / w`. One gossip
//! step pre-weights `(x, w)` by the node's uniform outgoing mixing weight,
//! transmits to the schedule's out-neighbours, and aggregates whatever has
//! arrived. With `delay = τ > 0` messages land τ iterations later
//! (τ-Overlap SGP); with `biased = true` the push-sum weight is frozen at 1
//! (the ablation of Table 4 that "directly incorporates delayed messages
//! without accounting for the bias").
//!
//! The engine is the in-process substrate for n logical nodes: messages are
//! moved through per-destination delivery queues (mailboxes), which both
//! implements the semantics exactly and lets tests assert **mass
//! conservation** — the column-stochasticity invariant that Σᵢ xᵢ plus all
//! in-flight mass is constant under gossip.
//!
//! # The sharded round and the determinism contract
//!
//! Every round runs two parallel phases bridged by a deterministic merge:
//!
//! 1. **compute + send** — each node, reading *only its own state*,
//!    pre-weights its `(x, w)`, emits messages into a per-shard outbox,
//!    and scales its own state by the self-loop weight;
//! 2. **ordered merge** — outboxes are appended into the per-destination
//!    mailboxes in ascending sender order (and fault-ledger contributions
//!    are applied in the same order);
//! 3. **aggregate** — each node drains the due messages from *its own*
//!    mailbox into its state.
//!
//! Phases 1 and 3 touch disjoint per-node state, so they shard across the
//! **persistent worker pool** ([`crate::runtime::pool`]) under
//! [`ExecPolicy::Parallel`]; phase 2 is a cheap, deterministic pointer
//! merge on the coordinating thread. Because the merge reproduces exactly
//! the message ordering of the sequential loop, **any shard count — and
//! any pool thread count — produces bit-identical state** at a fixed
//! seed, including under a [`FaultClock`] replay. The contract is locked
//! in by `rust/tests/engine_equivalence.rs` and documented in
//! ARCHITECTURE.md.
//!
//! # The zero-allocation hot path
//!
//! After warm-up (one schedule cycle at steady delay), a dense-path round
//! performs **zero heap allocations**: message payloads cycle through
//! per-shard buffer pools, outboxes/mailboxes retain their capacity,
//! peer lists and top-k index scratch live in per-shard scratch, the
//! survivor list reuses one engine-owned buffer, and the round is
//! dispatched to long-lived pool workers instead of freshly spawned
//! threads. `rust/tests/alloc_regression.rs` pins this with a counting
//! global allocator for the deterministic permutation schedules (the
//! exp-graph families every experiment runs on). One sharp edge: payload
//! buffers are popped from the *sender's* shard pool but recycled into
//! the *receiver's*, so the guarantee relies on per-shard send/receive
//! counts balancing each round — true for the permutation topologies,
//! while `RandomAny`/`RandomExp` under a parallel policy can drift pools
//! apart and allocate occasionally in steady state.
//!
//! # Compressed messages
//!
//! [`Self::step_compressed`](PushSumEngine::step_compressed) applies a
//! [`Compression`] spec (top-k sparsification or stochastic quantization,
//! see [`compress`]) to every outgoing `(x, w)` share, banking the
//! withheld numerator mass — and the ℓ1-proportional slice of the
//! push-sum weight that pairs with it — in a **per-edge error-feedback
//! bank** owned by the sender. Bank state is partitioned by sender
//! exactly like `(x, w)` state, and the quantization noise is keyed by
//! `(iteration, edge)`, so compression preserves both the
//! mass-conservation invariant (states + in-flight + banks + ledger, for
//! Σx *and* Σw) and the bit-identity contract across shard counts.

pub mod compress;
pub mod event_engine;
pub mod exec;

pub use compress::Compression;
pub use event_engine::EventEngine;
pub use exec::ExecPolicy;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use compress::EdgeBank;

use crate::faults::FaultClock;
use crate::obs::{EngineObs, ObsSink, RoundRecord};
use crate::runtime::pool::{self, Pool};
use crate::snapshot::{
    EngineKind, SnapBank, SnapLedger, SnapMsg, SnapNode, Snapshot, SnapshotError,
};
use crate::topology::{PeerMemo, Schedule};

/// Per-sender error-feedback banks, keyed by destination node. A
/// `BTreeMap` so bank-mass accounting and drain walk edges in a
/// deterministic order.
type EdgeResiduals = BTreeMap<usize, EdgeBank>;

/// One in-flight push-sum message (already pre-weighted by the sender).
#[derive(Clone, Debug)]
pub struct Message {
    /// Sending node (global index).
    pub from: usize,
    /// Destination node (global index) — the mailbox this message is
    /// delivered into during the ordered merge.
    pub to: usize,
    /// Iteration the message was sent at.
    pub sent_iter: u64,
    /// Iteration the message becomes visible to the destination.
    pub deliver_iter: u64,
    /// Pre-weighted numerator share.
    pub x: Vec<f32>,
    /// Pre-weighted push-sum-weight share.
    pub w: f64,
}

/// Per-node push-sum state.
#[derive(Clone, Debug)]
pub struct NodeState {
    /// Push-sum numerator (the *biased* parameters gradients are applied to).
    pub x: Vec<f32>,
    /// Push-sum weight; stays positive, starts at 1.
    pub w: f64,
}

impl NodeState {
    /// A fresh node state with weight 1 around the given numerator.
    pub fn new(x: Vec<f32>) -> Self {
        Self { x, w: 1.0 }
    }

    /// De-biased parameters z = x / w (Alg. 1 line 8).
    pub fn debiased(&self) -> Vec<f32> {
        let inv = (1.0 / self.w) as f32;
        self.x.iter().map(|v| v * inv).collect()
    }

    /// Write z = x / w into `out` without allocating.
    pub fn debias_into(&self, out: &mut [f32]) {
        let inv = (1.0 / self.w) as f32;
        for (o, v) in out.iter_mut().zip(&self.x) {
            *o = v * inv;
        }
    }
}

/// Per-shard scratch space: the scale buffer and the recycled payload
/// pool. One per shard so workers never contend (perf: sending pops a
/// buffer instead of allocating dim-sized fresh-page Vecs per message —
/// see EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
struct ShardScratch {
    scale_buf: Vec<f32>,
    pool: Vec<Vec<f32>>,
    /// Index scratch for the top-k selection (compression).
    idx: Vec<u32>,
    /// Out-peer scratch: the schedule fills this in place each node, so
    /// the hot path never allocates a peer list.
    peers: Vec<usize>,
    /// Survivor-rank memo for fault-mode peer lookup, rebuilt only when
    /// the membership epoch changes — without it every node of every
    /// round re-derives its rank by binary search over the alive set.
    memo: PeerMemo,
}

impl ShardScratch {
    fn new(dim: usize) -> Self {
        Self {
            scale_buf: vec![0.0; dim],
            pool: Vec::new(),
            idx: Vec::new(),
            peers: Vec::new(),
            memo: PeerMemo::new(0),
        }
    }
}

/// Pop a recycled payload buffer or allocate a fresh one.
// audit: zero-alloc — the vec! refill below is the one pinned cold-path
// allocation (see analysis/allow.toml); steady state always pops.
fn take_buf(pool: &mut Vec<Vec<f32>>, dim: usize) -> Vec<f32> {
    pool.pop().unwrap_or_else(|| vec![0.0; dim])
}

/// A pooled payload holding `src` scaled by `wf` — the pre-weighted share
/// a push-sum message carries. One definition for every send/drop site so
/// the scaling arithmetic (and with it the bit-identity contract) cannot
/// drift between code paths.
// audit: zero-alloc
fn scaled_payload(pool: &mut Vec<Vec<f32>>, dim: usize, src: &[f32], wf: f32) -> Vec<f32> {
    let mut payload = take_buf(pool, dim);
    for (p, v) in payload.iter_mut().zip(src) {
        *p = v * wf;
    }
    payload
}

/// Phase-1 output of one shard, awaiting the ordered merge: outgoing
/// messages in sender order, materialized dropped shares (fault mode,
/// rescue off) in sender order, and the shard's rescue counter. The drop
/// count is `dropped.len()` — not duplicated here, so it cannot
/// desynchronize from the materialized shares.
#[derive(Debug, Default)]
struct ShardOut {
    sent: Vec<Message>,
    dropped: Vec<Message>,
    rescue_count: u64,
}

/// Everything a shard worker needs to know about the round (shared,
/// read-only). `faults` carries the clock plus the sorted survivor set.
#[derive(Clone, Copy)]
struct StepCtx<'a> {
    k: u64,
    deliver_at: u64,
    dim: usize,
    schedule: &'a Schedule,
    faults: Option<(&'a FaultClock, &'a [usize])>,
    compress: Compression,
}

/// Error-feedback compression of one outgoing `(x, w)` share: look up (or
/// create) the sender's bank for edge `(from → to)` and apply the spec to
/// the numerator payload and the weight share together. Identity skips
/// the bank table entirely.
fn compress_payload(
    payload: &mut [f32],
    msg_w: &mut f64,
    residuals: &mut EdgeResiduals,
    idx: &mut Vec<u32>,
    ctx: &StepCtx,
    from: usize,
    to: usize,
) {
    if ctx.compress.is_identity() {
        return;
    }
    let bank = residuals.entry(to).or_insert_with(|| EdgeBank::new(ctx.dim));
    ctx.compress.apply(payload, msg_w, bank, idx, ctx.k, from, to);
}

/// Phase 1 for the contiguous node range starting at global index `base`:
/// pre-weight, compress (error feedback, per edge), emit outgoing
/// messages (and fault-ledger shares) into the shard outbox, scale the
/// node's own state by its self-loop weight. Reads and writes only this
/// shard's states and residuals — safe to run on every shard
/// concurrently.
// audit: zero-alloc
fn compute_shard(
    base: usize,
    states: &mut [NodeState],
    residuals: &mut [EdgeResiduals],
    scratch: &mut ShardScratch,
    ctx: StepCtx,
    out: &mut ShardOut,
) {
    let k = ctx.k;
    match ctx.faults {
        None => {
            for (off, (st, res)) in
                states.iter_mut().zip(residuals.iter_mut()).enumerate()
            {
                let i = base + off;
                ctx.schedule.out_peers_into(i, k, &mut scratch.peers);
                let w_mix = 1.0 / (1.0 + scratch.peers.len() as f64);
                let wf = w_mix as f32;
                let msg_w = st.w * w_mix;
                if scratch.peers.len() == 1 {
                    // Dominant (1-peer) case: fused read-scale-write, no
                    // intermediate buffer.
                    let to = scratch.peers[0];
                    let mut payload = scaled_payload(&mut scratch.pool, ctx.dim, &st.x, wf);
                    let mut mw = msg_w;
                    compress_payload(
                        &mut payload,
                        &mut mw,
                        res,
                        &mut scratch.idx,
                        &ctx,
                        i,
                        to,
                    );
                    out.sent.push(Message {
                        from: i,
                        to,
                        sent_iter: k,
                        deliver_iter: ctx.deliver_at,
                        x: payload,
                        w: mw,
                    });
                } else if !scratch.peers.is_empty() {
                    for (b, v) in scratch.scale_buf.iter_mut().zip(&st.x) {
                        *b = v * wf;
                    }
                    for &j in &scratch.peers {
                        let mut payload = take_buf(&mut scratch.pool, ctx.dim);
                        payload.copy_from_slice(&scratch.scale_buf);
                        let mut mw = msg_w;
                        compress_payload(
                            &mut payload,
                            &mut mw,
                            res,
                            &mut scratch.idx,
                            &ctx,
                            i,
                            j,
                        );
                        out.sent.push(Message {
                            from: i,
                            to: j,
                            sent_iter: k,
                            deliver_iter: ctx.deliver_at,
                            x: payload,
                            w: mw,
                        });
                    }
                }
                // Self-loop share (Alg. 2 lines 7–8), scaled in place —
                // never compressed (it never leaves the node).
                for v in st.x.iter_mut() {
                    *v *= wf;
                }
                st.w *= w_mix;
            }
        }
        Some((clock, alive)) => {
            let rescue = clock.plan.rescue;
            // Rank lookups are memoized per membership epoch: the rebuild
            // below is a no-op except on the round after a crash, leave,
            // or rejoin (see `memo_invalidates_on_leave_and_rejoin_events`
            // in the topology tests).
            scratch.memo.ensure(
                clock.membership_epoch(k),
                alive,
                ctx.schedule.n,
            );
            for (off, (st, res)) in
                states.iter_mut().zip(residuals.iter_mut()).enumerate()
            {
                let i = base + off;
                // Crashed nodes freeze in place (state = checkpoint).
                if clock.is_down(i, k) {
                    continue;
                }
                ctx.schedule
                    .out_peers_among_memo(i, k, &scratch.memo, &mut scratch.peers);
                let w_mix = 1.0 / (1.0 + scratch.peers.len() as f64);
                let wf = w_mix as f32;
                let msg_w = st.w * w_mix;
                let mut rescued = 0usize;
                for &j in &scratch.peers {
                    if clock.drops(i, j, k) {
                        if rescue {
                            // Sender detects the failed send and keeps its
                            // share: nothing leaves, nothing is lost, and
                            // the edge residual is untouched (no message
                            // was encoded).
                            out.rescue_count += 1;
                            rescued += 1;
                            continue;
                        }
                        // The share leaves the sender and vanishes — the
                        // *encoded* share, so the bank keeps the withheld
                        // `(x, w)` part and only the transmitted mass is
                        // ledgered in global sender order by the merge.
                        let mut payload =
                            scaled_payload(&mut scratch.pool, ctx.dim, &st.x, wf);
                        let mut mw = msg_w;
                        compress_payload(
                            &mut payload,
                            &mut mw,
                            res,
                            &mut scratch.idx,
                            &ctx,
                            i,
                            j,
                        );
                        out.dropped.push(Message {
                            from: i,
                            to: j,
                            sent_iter: k,
                            deliver_iter: ctx.deliver_at,
                            x: payload,
                            w: mw,
                        });
                        continue;
                    }
                    let mut payload =
                        scaled_payload(&mut scratch.pool, ctx.dim, &st.x, wf);
                    let mut mw = msg_w;
                    compress_payload(
                        &mut payload,
                        &mut mw,
                        res,
                        &mut scratch.idx,
                        &ctx,
                        i,
                        j,
                    );
                    out.sent.push(Message {
                        from: i,
                        to: j,
                        sent_iter: k,
                        deliver_iter: ctx.deliver_at,
                        x: payload,
                        w: mw,
                    });
                }
                // Self-loop share; rescued shares stay too, so the node
                // keeps `w_mix · (1 + rescued)` of itself.
                let keep = (w_mix * (1 + rescued) as f64) as f32;
                for v in st.x.iter_mut() {
                    *v *= keep;
                }
                st.w *= w_mix * (1 + rescued) as f64;
            }
        }
    }
}

/// Drain every message due at `k` from one mailbox into one node state,
/// recycling payload buffers into `pool` — the swap-remove scan at the
/// heart of phase 3. **This is the bit-identity anchor for aggregation**:
/// the application order it produces (and the permutation it leaves the
/// not-yet-due survivors in, which determines *future* application
/// orders under τ ≥ 2) is part of the engine-equivalence contract, so
/// every execution mode — sequential, pooled, event-driven — must drain
/// mailboxes through this one function.
// audit: zero-alloc
fn drain_due(st: &mut NodeState, inbox: &mut Vec<Message>, k: u64, pool: &mut Vec<Vec<f32>>) {
    let mut j = 0;
    while j < inbox.len() {
        if inbox[j].deliver_iter <= k {
            let msg = inbox.swap_remove(j);
            for (a, b) in st.x.iter_mut().zip(&msg.x) {
                *a += b;
            }
            st.w += msg.w;
            pool.push(msg.x);
        } else {
            j += 1;
        }
    }
}

/// Phase 3 for the contiguous node range starting at `base`: drain every
/// message due at `k` from this shard's mailboxes into its states,
/// recycling payload buffers into the shard pool. Touches only this
/// shard's states/mailboxes — safe to run on every shard concurrently.
// audit: zero-alloc
fn aggregate_shard(
    base: usize,
    states: &mut [NodeState],
    inboxes: &mut [Vec<Message>],
    pool: &mut Vec<Vec<f32>>,
    ctx: StepCtx,
    biased: bool,
) {
    let k = ctx.k;
    for (off, (st, slot)) in states.iter_mut().zip(inboxes.iter_mut()).enumerate() {
        // Fault mode: a crashed node's inbox holds until it rejoins.
        if let Some((clock, _)) = ctx.faults {
            if clock.is_down(base + off, k) {
                continue;
            }
        }
        drain_due(st, slot, k, pool);
    }
    if biased {
        for st in states.iter_mut() {
            st.w = 1.0;
        }
    }
}

/// Raw, field-wise view of the engine's shardable state for one round —
/// what a pool worker needs to reconstruct its shard's disjoint `&mut`
/// slices without any per-round allocation (collecting per-shard borrow
/// tuples into a `Vec` would put an allocation back on the hot path).
///
/// Shard `s` owns nodes `[s·chunk, min((s+1)·chunk, n))` plus scratch and
/// outbox slot `s`; distinct shards resolve to disjoint memory, and the
/// pool runs each shard index exactly once per phase, so reconstructing
/// `&mut` slices per shard is sound.
struct ShardTable {
    states: *mut NodeState,
    residuals: *mut EdgeResiduals,
    inboxes: *mut Vec<Message>,
    scratch: *mut ShardScratch,
    outs: *mut ShardOut,
    n: usize,
    chunk: usize,
}

// SAFETY: the raw pointers target disjoint per-shard ranges (see the type
// docs); workers never touch another shard's range.
unsafe impl Send for ShardTable {}
unsafe impl Sync for ShardTable {}

impl ShardTable {
    /// Bounds of shard `s` (`lo`, length). `s` must satisfy `s·chunk < n`.
    fn range(&self, s: usize) -> (usize, usize) {
        let lo = s * self.chunk;
        debug_assert!(
            lo < self.n,
            "shard {s} out of range (chunk {}, n {})",
            self.chunk,
            self.n
        );
        (lo, self.chunk.min(self.n - lo))
    }

    /// Phase 1 for shard `s`.
    ///
    /// # Safety
    /// `s·chunk < n`, and each shard index must be executed by exactly one
    /// worker per phase (the pool's contract).
    unsafe fn compute(&self, s: usize, ctx: StepCtx) {
        let (lo, len) = self.range(s);
        compute_shard(
            lo,
            std::slice::from_raw_parts_mut(self.states.add(lo), len),
            std::slice::from_raw_parts_mut(self.residuals.add(lo), len),
            &mut *self.scratch.add(s),
            ctx,
            &mut *self.outs.add(s),
        );
    }

    /// Phase 3 for shard `s`.
    ///
    /// # Safety
    /// Same contract as [`Self::compute`].
    unsafe fn aggregate(&self, s: usize, ctx: StepCtx, biased: bool) {
        let (lo, len) = self.range(s);
        aggregate_shard(
            lo,
            std::slice::from_raw_parts_mut(self.states.add(lo), len),
            std::slice::from_raw_parts_mut(self.inboxes.add(lo), len),
            &mut (*self.scratch.add(s)).pool,
            ctx,
            biased,
        );
    }
}

/// Elapsed nanoseconds since `mark`, resetting it for the next span
/// (0 and a no-op when observability is off — `mark` is `None`).
/// `Instant` reads are vDSO `clock_gettime` calls: no allocation.
// audit: zero-alloc
fn lap_ns(mark: &mut Option<Instant>) -> u64 {
    match mark {
        Some(t) => {
            let ns = t.elapsed().as_nanos() as u64;
            *t = Instant::now();
            ns
        }
        None => 0,
    }
}

/// The synchronous multi-node PushSum engine.
///
/// ```
/// use sgp::gossip::PushSumEngine;
/// use sgp::topology::{Schedule, TopologyKind};
///
/// // Four nodes holding the values 0, 1, 2, 3; push-sum averages them.
/// let init: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32]).collect();
/// let mut eng = PushSumEngine::new(init, 0, false);
/// let sched = Schedule::new(TopologyKind::OnePeerExp, 4);
/// for k in 0..40 {
///     eng.step(k, &sched);
/// }
/// let z = eng.states[0].debiased()[0];
/// assert!((z - 1.5).abs() < 1e-4, "converged to the average: {z}");
/// ```
pub struct PushSumEngine {
    /// Number of logical nodes.
    pub n: usize,
    /// Parameter dimension d.
    pub dim: usize,
    /// Per-node `(x, w)` push-sum states, indexed by node.
    pub states: Vec<NodeState>,
    /// Overlap delay τ: 0 = blocking SGP, ≥1 = τ-OSGP.
    pub delay: u64,
    /// Table-4 ablation: ignore the push-sum weight (w ≡ 1, z = x).
    pub biased: bool,
    /// Per-destination in-flight messages (mailboxes), ordered by sender
    /// within each round.
    inboxes: Vec<Vec<Message>>,
    /// Per-shard scratch (scale buffer + payload pool); grown on demand to
    /// the largest shard count this engine has been driven with.
    scratch: Vec<ShardScratch>,
    /// Per-shard outboxes, persistent so their capacity is reused across
    /// rounds (drained empty by every ordered merge).
    outs: Vec<ShardOut>,
    /// Per-sender error-feedback residuals (compressed gossip), keyed by
    /// destination. Empty until a non-identity [`Compression`] runs.
    residuals: Vec<EdgeResiduals>,
    /// Reusable survivor-list buffer (fault mode) — filled in place each
    /// round instead of allocating.
    alive_buf: Vec<usize>,
    /// Explicit worker pool for parallel rounds; `None` dispatches to the
    /// process-global pool ([`crate::runtime::pool::global`]).
    pool: Option<Arc<Pool>>,
    /// Cumulative numerator mass lost to dropped messages (fault mode).
    dropped_x: Vec<f64>,
    /// Cumulative push-sum-weight mass lost to dropped messages.
    dropped_w: f64,
    /// Count of messages dropped (diagnostics).
    pub drop_count: u64,
    /// Count of messages rescued (re-absorbed at the sender; fault mode
    /// with `FaultPlan::rescue`).
    pub rescue_count: u64,
    /// Count of error-feedback banks folded back into their sender when a
    /// membership-epoch change orphaned their destination (see
    /// [`Self::save`] on the rejoin-from-checkpoint contract).
    pub reconciled_count: u64,
    /// Membership epoch the banks were last reconciled against. Bumped
    /// whenever a fault-mode round crosses a [`FaultClock`] epoch
    /// boundary; persisted by [`Self::save`] so a restore resumes the
    /// survivor schedule instead of the pre-crash one.
    seen_epoch: u64,
    /// Count of messages put on the wire (delivered + dropped; rescued
    /// sends never transmit). Multiply by
    /// [`Compression::encoded_bytes`] for total wire traffic.
    pub sent_count: u64,
    /// Optional observability recorder ([`Self::set_obs`]): per-round
    /// counters, per-edge traffic, and phase span timers. Boxed so an
    /// un-instrumented engine pays one pointer; all recorder storage is
    /// pre-allocated, so the instrumented hot path stays allocation-free
    /// (`rust/tests/alloc_regression.rs` runs with it attached).
    obs: Option<Box<EngineObs>>,
    /// Arrival scheduler for [`ExecPolicy::Event`] rounds
    /// ([`event_engine::ArrivalFlow`]): a priority queue of delivery
    /// notifications so aggregation visits only nodes with due mail.
    /// `None` until the first event-mode round; boxed so the other modes
    /// pay one pointer.
    arrivals: Option<Box<event_engine::ArrivalFlow>>,
}

impl PushSumEngine {
    /// Build an engine over per-node initial numerators (all weights start
    /// at 1). `delay` is the overlap τ; `biased` freezes w ≡ 1.
    pub fn new(init: Vec<Vec<f32>>, delay: u64, biased: bool) -> Self {
        let n = init.len();
        let dim = init[0].len();
        assert!(init.iter().all(|v| v.len() == dim));
        Self {
            n,
            dim,
            states: init.into_iter().map(NodeState::new).collect(),
            delay,
            biased,
            inboxes: (0..n).map(|_| Vec::new()).collect(),
            scratch: vec![ShardScratch::new(dim)],
            outs: vec![ShardOut::default()],
            residuals: (0..n).map(|_| EdgeResiduals::new()).collect(),
            alive_buf: Vec::new(),
            pool: None,
            dropped_x: vec![0.0; dim],
            dropped_w: 0.0,
            drop_count: 0,
            rescue_count: 0,
            reconciled_count: 0,
            seen_epoch: 0,
            sent_count: 0,
            obs: None,
            arrivals: None,
        }
    }

    /// Grow the per-shard scratch and outbox tables to at least `shards`
    /// entries.
    fn ensure_shards(&mut self, shards: usize) {
        while self.scratch.len() < shards {
            self.scratch.push(ShardScratch::new(self.dim));
        }
        while self.outs.len() < shards {
            self.outs.push(ShardOut::default());
        }
    }

    /// Attach an explicit worker pool for parallel rounds (sweeps and the
    /// bit-identity tests drive the thread-count axis through this);
    /// `None` restores the default — the process-global pool. Purely an
    /// execution knob: results are bit-identical for **any** pool.
    pub fn set_pool(&mut self, pool: Option<Arc<Pool>>) {
        self.pool = pool;
    }

    /// Builder-style [`Self::set_pool`].
    pub fn with_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Attach (or detach, with `None`) an observability recorder. Size it
    /// with [`EngineObs::new`] for this engine's node count; while
    /// attached, every round records counters, per-edge traffic, and
    /// phase timers into it. Purely observational: attaching a recorder
    /// never changes engine results.
    pub fn set_obs(&mut self, obs: Option<Box<EngineObs>>) {
        self.obs = obs;
    }

    /// Detach and return the recorder (e.g. to write a trace with
    /// [`crate::obs::trace::write_engine_trace`]).
    pub fn take_obs(&mut self) -> Option<Box<EngineObs>> {
        self.obs.take()
    }

    /// Borrow the attached recorder, if any.
    pub fn obs(&self) -> Option<&EngineObs> {
        self.obs.as_deref()
    }

    /// One full gossip step at iteration `k` for all nodes (Alg. 1 l. 5–7 /
    /// Alg. 2 l. 5–24): pre-weight & send, keep self-share, aggregate
    /// everything whose `deliver_iter == k`. Sequential execution; see
    /// [`Self::step_exec`] for the sharded driver.
    pub fn step(&mut self, k: u64, schedule: &Schedule) {
        self.step_exec(k, schedule, None, ExecPolicy::Sequential);
    }

    /// One gossip step under a fault scenario: only surviving members send
    /// and aggregate (the schedule re-indexes over them, staying
    /// column-stochastic), messages drop per the deterministic
    /// [`FaultClock`] history, and every dropped `(x, w)` pair is either
    /// **recorded** in the loss ledger (`dropped_mass`) or — in rescue mode
    /// — **re-absorbed** by the sender, keeping the step exactly
    /// column-stochastic.
    ///
    /// Crashed nodes freeze in place (state = checkpoint); messages already
    /// queued for them wait in their inbox and deliver on rejoin (or at
    /// [`Self::drain`]). This is why push-sum tolerates loss where
    /// symmetric averaging biases: a drop removes numerator *and* weight
    /// together, so the de-biased `z = x / w` stays a convex combination of
    /// honest values — tested against the biased engine in
    /// `rust/tests/test_faults.rs`.
    pub fn step_faulty(&mut self, k: u64, schedule: &Schedule, clock: &FaultClock) {
        self.step_exec(k, schedule, Some(clock), ExecPolicy::Sequential);
    }

    /// The sharded round driver behind [`Self::step`] / [`Self::step_faulty`]:
    /// one full gossip step at iteration `k`, optionally under a fault
    /// scenario, executed under the given [`ExecPolicy`].
    ///
    /// The round is the protocol described in the module docs: a parallel
    /// compute+send phase into per-shard outboxes, a deterministic
    /// ordered merge (messages appended to each destination mailbox in
    /// ascending sender order; fault-ledger contributions applied in the
    /// same order), then a parallel aggregate phase. The merge reproduces
    /// exactly the operation order of the sequential loop, so **every
    /// policy yields bit-identical state, mailboxes, ledger and
    /// counters** at a fixed seed — the engine-equivalence contract
    /// (`rust/tests/engine_equivalence.rs`).
    ///
    /// The policy is honored literally (clamped only to the node count):
    /// no hidden work-size heuristic second-guesses the caller, so tests
    /// can force real sharding at any size and callers pick shard counts
    /// with `repro engine-sweep` (see [`ExecPolicy::Parallel`] on the
    /// barrier-handoff cost of the persistent pool).
    pub fn step_exec(
        &mut self,
        k: u64,
        schedule: &Schedule,
        faults: Option<&FaultClock>,
        exec: ExecPolicy,
    ) {
        self.step_compressed(k, schedule, faults, exec, Compression::Identity);
    }

    /// [`Self::step_exec`] with message compression: every outgoing share
    /// is encoded per the [`Compression`] spec against its edge's
    /// error-feedback residual before it enters the mailbox (or the drop
    /// ledger). With [`Compression::Identity`] this is exactly
    /// `step_exec` — no residuals are allocated and no per-edge work
    /// runs. The determinism contract extends unchanged: residuals are
    /// sender-owned (sharded with the states) and quantization draws are
    /// keyed by `(iteration, edge)`, so any [`ExecPolicy`] produces
    /// bit-identical results at a fixed seed, including under faults.
    pub fn step_compressed(
        &mut self,
        k: u64,
        schedule: &Schedule,
        faults: Option<&FaultClock>,
        exec: ExecPolicy,
        compress: Compression,
    ) {
        let deliver_at = k + self.delay;
        let event_mode = exec == ExecPolicy::Event;
        if event_mode && self.arrivals.is_none() {
            // First event-mode round: build the arrival scheduler, seeding
            // notifications for any mail already in flight (a run may
            // switch policies mid-stream — semantics never depend on the
            // policy, only the work pattern does).
            self.arrivals =
                Some(Box::new(event_engine::ArrivalFlow::new(self.n, &self.inboxes)));
        }
        // Survivor list: filled in place into the engine-owned buffer
        // (moved out for the borrow checker's benefit, moved back below).
        let mut alive_buf = std::mem::take(&mut self.alive_buf);
        if let Some(fc) = faults {
            fc.alive_into(self.n, k, &mut alive_buf);
            // Membership-epoch boundary: fold error-feedback banks whose
            // destination has left for good back into their senders
            // *before* any state is read. A node restored from a
            // checkpoint taken after this point therefore carries banks
            // that reflect the survivor schedule, not the pre-crash one
            // (the rejoin-from-checkpoint bugfix). Runs single-threaded
            // ahead of both phases, so every exec policy sees it
            // identically.
            let epoch = fc.membership_epoch(k);
            if epoch != self.seen_epoch {
                self.reconcile_orphan_banks(fc, k);
                self.seen_epoch = epoch;
            }
        }
        let shards = exec.shards_for(self.n);
        let chunk = self.n.div_ceil(shards);
        let used = self.n.div_ceil(chunk);
        self.ensure_shards(used);
        let dim = self.dim;
        let biased = self.biased;
        let ctx = StepCtx {
            k,
            deliver_at,
            dim,
            schedule,
            faults: faults.map(|fc| (fc, alive_buf.as_slice())),
            compress,
        };

        // Observability preamble (one branch when disabled). The recorder
        // is moved out of the engine so the merge loop can feed it while
        // other fields are borrowed; everything recorded below is
        // pre-allocated scalar work — the hot path stays allocation-free.
        let mut obs = self.obs.take();
        let obs_on = obs.is_some();
        let per_msg_bytes =
            if obs_on { compress.encoded_bytes(dim, dim * 4) as u64 } else { 0 };
        let (sent0, drop0, resc0) = (self.sent_count, self.drop_count, self.rescue_count);
        let pool_wait0 = if obs_on && used > 1 {
            let p = self.pool.as_deref().unwrap_or_else(pool::global);
            // Dispatch timing is pay-per-use: unobserved engines leave
            // the pool's barrier path free of clock reads entirely.
            p.set_metered(true);
            Some(p.dispatch_stats().1)
        } else {
            None
        };
        let mut mark = if obs_on { Some(Instant::now()) } else { None };

        // Phase 1 — per-shard local compute + send into the persistent
        // shard outboxes (drained empty by the previous merge, capacity
        // retained). Multi-shard rounds dispatch to the persistent worker
        // pool: no thread spawns, no allocations, shard s pinned to
        // worker s mod W.
        if used == 1 {
            compute_shard(
                0,
                &mut self.states,
                &mut self.residuals,
                &mut self.scratch[0],
                ctx,
                &mut self.outs[0],
            );
        } else {
            let table = ShardTable {
                states: self.states.as_mut_ptr(),
                residuals: self.residuals.as_mut_ptr(),
                inboxes: self.inboxes.as_mut_ptr(),
                scratch: self.scratch.as_mut_ptr(),
                outs: self.outs.as_mut_ptr(),
                n: self.n,
                chunk,
            };
            let pool = self.pool.as_deref().unwrap_or_else(pool::global);
            // SAFETY: `used` shard indices all satisfy `s·chunk < n`, and
            // the pool runs each exactly once (ShardTable's contract).
            pool.run(used, &|s| unsafe { table.compute(s, ctx) });
        }
        let compute_ns = lap_ns(&mut mark);

        // Phase 2 — deterministic ordered merge on the coordinating
        // thread: shards hold contiguous ascending node ranges, so
        // concatenating outboxes in shard order appends every mailbox's
        // messages in ascending sender order — exactly the sequential
        // loop's insertion order. Ledger contributions are summed in the
        // same order, so the f64 accumulation is bit-identical too.
        for idx in 0..used {
            self.sent_count +=
                (self.outs[idx].sent.len() + self.outs[idx].dropped.len()) as u64;
            self.drop_count += self.outs[idx].dropped.len() as u64;
            self.rescue_count += self.outs[idx].rescue_count;
            self.outs[idx].rescue_count = 0;
            for msg in self.outs[idx].sent.drain(..) {
                if let Some(o) = obs.as_deref_mut() {
                    o.on_send(msg.from, msg.to, per_msg_bytes);
                }
                // The scheduler (if built) tracks every send so event-mode
                // aggregation knows which mailboxes have due mail — even
                // for sends made under another policy, keeping mid-run
                // policy switches lossless.
                if let Some(a) = self.arrivals.as_deref_mut() {
                    a.note_send(msg.deliver_iter, msg.to);
                }
                self.inboxes[msg.to].push(msg);
            }
            for msg in self.outs[idx].dropped.drain(..) {
                if let Some(o) = obs.as_deref_mut() {
                    o.on_drop(msg.from, msg.to, per_msg_bytes);
                }
                for (d, v) in self.dropped_x.iter_mut().zip(&msg.x) {
                    *d += *v as f64;
                }
                self.dropped_w += msg.w;
                // Recycle into the *sender's* shard pool so pools stay
                // balanced across rounds (the sender pops it back next
                // step); buffer identity never affects values.
                self.scratch[msg.from / chunk].pool.push(msg.x);
            }
        }
        let merge_ns = lap_ns(&mut mark);

        // Phase 3 — per-shard aggregation of deliveries due at k. The
        // shard table is rebuilt (pointers re-derived) because the merge
        // phase held fresh borrows of the same fields.
        if event_mode {
            // Arrival-driven aggregation: pop due delivery notifications
            // off the priority queue and drain only those mailboxes (plus
            // any parked for a crashed node that has since rejoined).
            // Mailboxes stay the source of truth, so the drained bits are
            // identical to `aggregate_shard`'s.
            let mut arrivals = self.arrivals.take().expect("arrival flow built above");
            event_engine::aggregate_event(
                &mut arrivals,
                &mut self.states,
                &mut self.inboxes,
                &mut self.scratch[0].pool,
                ctx,
                biased,
            );
            self.arrivals = Some(arrivals);
        } else if used == 1 {
            aggregate_shard(
                0,
                &mut self.states,
                &mut self.inboxes,
                &mut self.scratch[0].pool,
                ctx,
                biased,
            );
        } else {
            let table = ShardTable {
                states: self.states.as_mut_ptr(),
                residuals: self.residuals.as_mut_ptr(),
                inboxes: self.inboxes.as_mut_ptr(),
                scratch: self.scratch.as_mut_ptr(),
                outs: self.outs.as_mut_ptr(),
                n: self.n,
                chunk,
            };
            let pool = self.pool.as_deref().unwrap_or_else(pool::global);
            // SAFETY: as in phase 1 — valid shard indices, one worker per
            // shard.
            pool.run(used, &|s| unsafe { table.aggregate(s, ctx, biased) });
        }
        self.alive_buf = alive_buf;

        // Round record: counter deltas + phase spans + bank mass. Every
        // term is a scalar walk over pre-allocated storage.
        if let Some(o) = obs.as_deref_mut() {
            let aggregate_ns = lap_ns(&mut mark);
            let (mut bank_l1, mut bank_w) = (0.0f64, 0.0f64);
            if !compress.is_identity() {
                for res in &self.residuals {
                    for bank in res.values() {
                        for v in &bank.x {
                            bank_l1 += (*v as f64).abs();
                        }
                        bank_w += bank.w;
                    }
                }
            }
            // The pool's run-time counter is process-wide (the global
            // pool is shared), so the delta is an upper bound when other
            // engines dispatch concurrently.
            let pool_wait_ns = match pool_wait0 {
                Some(w0) => self
                    .pool
                    .as_deref()
                    .unwrap_or_else(pool::global)
                    .dispatch_stats()
                    .1
                    .saturating_sub(w0),
                None => 0,
            };
            let msgs = self.sent_count - sent0;
            o.on_round(&RoundRecord {
                k,
                msgs,
                dropped: self.drop_count - drop0,
                rescued: self.rescue_count - resc0,
                wire_bytes: msgs * per_msg_bytes,
                bank_l1,
                bank_w,
                compute_ns,
                merge_ns,
                aggregate_ns,
                pool_wait_ns,
            });
        }
        self.obs = obs;
    }

    /// Fold every error-feedback bank addressed to a permanently-down
    /// destination back into its sender's `(x, w)` state, in
    /// deterministic `(sender, destination)` order. Mass-conserving by
    /// construction: the bank's numerator and weight move, nothing is
    /// created or dropped, so [`Self::total_mass_with_losses`] is
    /// bit-unchanged.
    fn reconcile_orphan_banks(&mut self, clock: &FaultClock, k: u64) {
        let mut reclaimed = 0u64;
        for (st, res) in self.states.iter_mut().zip(&mut self.residuals) {
            res.retain(|&to, bank| {
                if !clock.is_permanently_down(to, k) {
                    return true;
                }
                for (a, b) in st.x.iter_mut().zip(&bank.x) {
                    *a += b;
                }
                st.w += bank.w;
                reclaimed += 1;
                false
            });
        }
        self.reconciled_count += reclaimed;
    }

    /// Capture a durable [`Snapshot`] of the full engine state: per-node
    /// `(x, w)`, the mailboxes in their exact in-memory order (the
    /// bit-identity anchor — see [`crate::snapshot`]), the per-edge
    /// error-feedback banks, the dropped-mass ledger and counters, and
    /// the membership epoch last reconciled. `round` is the iteration the
    /// restored engine executes **next** (callers checkpoint after
    /// completing round `k` and pass `k + 1`).
    ///
    /// The arrival scheduler of event-mode execution is *not* captured:
    /// it is a lossless function of the mailboxes and is rebuilt on the
    /// restored engine's first event-mode round.
    pub fn save(&self, round: u64) -> Snapshot {
        let nodes = self
            .states
            .iter()
            .map(|st| SnapNode { x: st.x.clone(), w: st.w })
            .collect();
        let mail = self
            .inboxes
            .iter()
            .map(|inbox| {
                inbox
                    .iter()
                    .map(|m| SnapMsg {
                        from: m.from as u64,
                        sent_iter: m.sent_iter,
                        deliver_iter: m.deliver_iter,
                        x: m.x.clone(),
                        w: m.w,
                    })
                    .collect()
            })
            .collect();
        let mut banks = Vec::new();
        for (from, res) in self.residuals.iter().enumerate() {
            for (to, bank) in res {
                banks.push(SnapBank {
                    from: from as u64,
                    to: *to as u64,
                    x: bank.x.clone(),
                    w: bank.w,
                });
            }
        }
        Snapshot {
            round,
            kind: EngineKind::Dense,
            biased: self.biased,
            n: self.n as u64,
            dim: self.dim as u64,
            delay: self.delay,
            epoch: self.seen_epoch,
            nodes,
            mail,
            banks,
            ledger: SnapLedger {
                dropped_x: self.dropped_x.clone(),
                dropped_w: self.dropped_w,
                drop_count: self.drop_count,
                rescue_count: self.rescue_count,
                reconciled_count: self.reconciled_count,
                sent_count: self.sent_count,
                recv_w: 0.0,
                sent_w: 0.0,
                rescued_w: 0.0,
            },
            rngs: Vec::new(),
            sparse: None,
        }
    }

    /// Rebuild an engine from a dense [`Snapshot`]. The restored engine
    /// continues **bit-identical** to the uninterrupted run under every
    /// [`ExecPolicy`], fault plan and [`Compression`] spec — the
    /// determinism contract pinned by `rust/tests/snapshot_resume.rs`.
    /// Execution scaffolding (worker pool, shard scratch, observability
    /// recorder, arrival scheduler) is rebuilt fresh; none of it affects
    /// values.
    pub fn restore(snap: &Snapshot) -> Result<Self, SnapshotError> {
        if snap.kind() != EngineKind::Dense {
            return Err(SnapshotError::EngineMismatch(
                "PushSumEngine::restore requires a dense snapshot",
            ));
        }
        Self::restore_parts(snap)
    }

    /// The kind-agnostic restore body, shared with
    /// [`EventEngine`]'s materialized-dense path.
    pub(crate) fn restore_parts(snap: &Snapshot) -> Result<Self, SnapshotError> {
        let n = snap.n();
        let dim = snap.dim();
        if snap.nodes.len() != n || snap.mail.len() != n {
            return Err(SnapshotError::Malformed("dense snapshot missing node state"));
        }
        if snap.nodes.iter().any(|nd| nd.x.len() != dim)
            || snap.ledger.dropped_x.len() != dim
        {
            return Err(SnapshotError::Malformed("snapshot dimension mismatch"));
        }
        let mut eng = Self::new(
            snap.nodes.iter().map(|nd| nd.x.clone()).collect(),
            snap.delay(),
            snap.biased(),
        );
        for (st, nd) in eng.states.iter_mut().zip(&snap.nodes) {
            st.w = nd.w;
        }
        for (to, (inbox, mailbox)) in
            eng.inboxes.iter_mut().zip(&snap.mail).enumerate()
        {
            for m in mailbox {
                if m.from as usize >= n || m.x.len() != dim {
                    return Err(SnapshotError::Malformed("message outside engine shape"));
                }
                inbox.push(Message {
                    from: m.from as usize,
                    to,
                    sent_iter: m.sent_iter,
                    deliver_iter: m.deliver_iter,
                    x: m.x.clone(),
                    w: m.w,
                });
            }
        }
        for b in &snap.banks {
            let (from, to) = (b.from as usize, b.to as usize);
            if from >= n || to >= n || b.x.len() != dim {
                return Err(SnapshotError::Malformed("bank outside engine shape"));
            }
            let mut bank = EdgeBank::new(dim);
            bank.x.copy_from_slice(&b.x);
            bank.w = b.w;
            eng.residuals[from].insert(to, bank);
        }
        eng.dropped_x.copy_from_slice(&snap.ledger.dropped_x);
        eng.dropped_w = snap.ledger.dropped_w;
        eng.drop_count = snap.ledger.drop_count;
        eng.rescue_count = snap.ledger.rescue_count;
        eng.reconciled_count = snap.ledger.reconciled_count;
        eng.sent_count = snap.ledger.sent_count;
        eng.seen_epoch = snap.epoch();
        Ok(eng)
    }

    /// Mid-run **elastic join**: admit a brand-new rank that warm-starts
    /// from `donor` with a mass-conserving φ-split (φ = ½) of the donor's
    /// `(x, w)`. Returns the new rank's index (= old `n`); the caller
    /// rebuilds its [`Schedule`] over `n + 1` ranks.
    ///
    /// Mass conservation is *bit-exact*, not merely approximate: each
    /// numerator coordinate splits as `half = x · 0.5; x −= half`, and by
    /// the Sterbenz lemma the subtraction is exact, so
    /// `x_donor + x_new` reproduces the old bits even when `x · 0.5`
    /// rounds (subnormals). The push-sum weight splits the same way:
    /// Σw is unchanged — a join *divides* existing mass, it never mints
    /// any, which is why a joining rank reaches consensus without
    /// disturbing the ledger (the `repro soak` acceptance check).
    ///
    /// The de-biased view is also preserved: the new rank starts with
    /// `z = (x/2)/(w/2) = x/w`, the donor's exact current estimate.
    /// The n-indexed scaffolding (arrival scheduler, observability
    /// recorder) is detached and rebuilt lazily at the new size.
    pub fn elastic_join(&mut self, donor: usize) -> usize {
        assert!(donor < self.n, "donor {donor} out of range (n = {})", self.n);
        let id = self.n;
        let mut x = vec![0.0f32; self.dim];
        let new_w = {
            let d = &mut self.states[donor];
            for (nx, dx) in x.iter_mut().zip(d.x.iter_mut()) {
                let half = *dx * 0.5;
                *nx = half;
                *dx -= half; // exact (Sterbenz): donor + joiner == old bits
            }
            let half_w = d.w * 0.5;
            d.w -= half_w;
            half_w
        };
        let mut st = NodeState::new(x);
        st.w = if self.biased { 1.0 } else { new_w };
        self.states.push(st);
        self.inboxes.push(Vec::new());
        self.residuals.push(EdgeResiduals::new());
        self.n += 1;
        // Both are sized to the old n; rebuilt on demand at the new size.
        self.arrivals = None;
        self.obs = None;
        id
    }

    /// Mass recorded as lost to dropped messages: `(Σ dropped x, Σ dropped w)`.
    pub fn dropped_mass(&self) -> (&[f64], f64) {
        (&self.dropped_x, self.dropped_w)
    }

    /// `(x, w)` mass currently held in the per-edge error-feedback banks
    /// (compressed gossip): the withheld numerator residuals plus the
    /// φ-split weight remainders. Zero — and allocation-free — under
    /// [`Compression::Identity`].
    pub fn residual_mass(&self) -> (Vec<f64>, f64) {
        let mut xm = vec![0.0f64; self.dim];
        let mut wm = 0.0f64;
        for res in &self.residuals {
            for bank in res.values() {
                for (a, b) in xm.iter_mut().zip(&bank.x) {
                    *a += *b as f64;
                }
                wm += bank.w;
            }
        }
        (xm, wm)
    }

    /// Total mass *including* the recorded losses and the compression
    /// banks — the quantity that stays invariant under any fault plan
    /// *and* any compression spec (the proptest anchor):
    /// Σᵢ xᵢ + in-flight + error-feedback banks + recorded-dropped, for
    /// both the numerator and the push-sum weight.
    pub fn total_mass_with_losses(&self) -> (Vec<f64>, f64) {
        let (mut xm, mut wm) = self.total_mass();
        for (a, b) in xm.iter_mut().zip(&self.dropped_x) {
            *a += b;
        }
        let (rx, rw) = self.residual_mass();
        for (a, b) in xm.iter_mut().zip(rx) {
            *a += b;
        }
        wm += self.dropped_w + rw;
        (xm, wm)
    }

    /// Flush all in-flight messages (used at the end of a run so no mass is
    /// stranded; OSGP's bounded-delay assumption guarantees this terminates).
    ///
    /// Post-drain invariant: the mailboxes are empty — [`Self::in_flight`]
    /// returns 0 and [`Self::max_staleness`] returns 0 for **every** `k` —
    /// and they stay that way until the next `step*` call. This holds in
    /// fault mode too: messages parked for a crashed node are delivered
    /// into its (frozen) state rather than left stranded. Locked in by the
    /// `drain_leaves_zero_in_flight_and_zero_staleness` test.
    pub fn drain(&mut self) {
        // The arrival scheduler's pending notifications refer to mail that
        // is about to be force-delivered below; forget them (and rewind
        // the virtual clock) so a post-drain run can restart at k = 0.
        if let Some(a) = self.arrivals.as_deref_mut() {
            a.clear();
        }
        for i in 0..self.n {
            for msg in std::mem::take(&mut self.inboxes[i]) {
                let st = &mut self.states[i];
                for (a, b) in st.x.iter_mut().zip(&msg.x) {
                    *a += b;
                }
                st.w += msg.w;
            }
        }
        // Compressed gossip: re-absorb every outstanding error-feedback
        // bank at its sender (in deterministic edge order) so no `(x, w)`
        // mass is stranded — the final metrics then account for every
        // unit of mass, mirroring what rescue mode does for undeliverable
        // shares.
        for (st, res) in self.states.iter_mut().zip(&mut self.residuals) {
            for (_, bank) in std::mem::take(res) {
                for (a, b) in st.x.iter_mut().zip(&bank.x) {
                    *a += b;
                }
                st.w += bank.w;
            }
        }
        if self.biased {
            for st in &mut self.states {
                st.w = 1.0;
            }
        }
    }

    /// Number of in-flight messages across all mailboxes (test/diagnostic).
    /// Zero immediately after [`Self::drain`]; at most `n · peers · τ`
    /// between steps of a τ-delayed run.
    pub fn in_flight(&self) -> usize {
        self.inboxes.iter().map(|b| b.len()).sum()
    }

    /// Maximum staleness among in-flight messages relative to iteration
    /// `k`: `max(k − sent_iter)` over the mailboxes, 0 when nothing is in
    /// flight — in particular, 0 for every `k` after [`Self::drain`].
    /// Bounded by τ during a delayed run (`prop_osgp_staleness_bounded_by_tau`).
    pub fn max_staleness(&self, k: u64) -> u64 {
        self.inboxes
            .iter()
            .flatten()
            .map(|m| k.saturating_sub(m.sent_iter))
            .max()
            .unwrap_or(0)
    }

    /// Total mass: (Σᵢ xᵢ + in-flight x, Σᵢ wᵢ + in-flight w). Invariant
    /// under unbiased gossip — the proptest anchor.
    pub fn total_mass(&self) -> (Vec<f64>, f64) {
        let mut xm = vec![0.0f64; self.dim];
        let mut wm = 0.0f64;
        for st in &self.states {
            for (a, b) in xm.iter_mut().zip(&st.x) {
                *a += *b as f64;
            }
            wm += st.w;
        }
        for inbox in &self.inboxes {
            for msg in inbox {
                for (a, b) in xm.iter_mut().zip(&msg.x) {
                    *a += *b as f64;
                }
                wm += msg.w;
            }
        }
        (xm, wm)
    }

    /// Node-wise average of the numerators x̄ = (1/n) Σ xᵢ (not incl.
    /// in-flight mass).
    pub fn mean_x(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.dim];
        for st in &self.states {
            for (a, b) in m.iter_mut().zip(&st.x) {
                *a += b;
            }
        }
        let inv = 1.0 / self.n as f32;
        for a in &mut m {
            *a *= inv;
        }
        m
    }

    /// Consensus statistics: (mean, min, max) over nodes of ‖zᵢ − x̄‖₂,
    /// the quantity plotted in Fig. 2.
    pub fn consensus_distance(&self) -> (f64, f64, f64) {
        let mean = self.mean_x();
        let mut dists = Vec::with_capacity(self.n);
        for st in &self.states {
            let inv = (1.0 / st.w) as f32;
            let d: f64 = st
                .x
                .iter()
                .zip(&mean)
                .map(|(x, m)| {
                    let e = (x * inv - m) as f64;
                    e * e
                })
                .sum();
            dists.push(d.sqrt());
        }
        let sum: f64 = dists.iter().sum();
        let min = dists.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = dists.iter().cloned().fold(0.0, f64::max);
        (sum / self.n as f64, min, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;
    use crate::topology::{Schedule, TopologyKind};

    fn random_init(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.gaussian_vec(d)).collect()
    }

    #[test]
    fn blocking_gossip_converges_to_average() {
        let n = 8;
        let init = random_init(n, 16, 1);
        let mut avg = vec![0.0f64; 16];
        for v in &init {
            for (a, b) in avg.iter_mut().zip(v) {
                *a += *b as f64 / n as f64;
            }
        }
        let mut eng = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..60 {
            eng.step(k, &sched);
        }
        for st in &eng.states {
            let z = st.debiased();
            for (zi, ai) in z.iter().zip(&avg) {
                assert!((*zi as f64 - ai).abs() < 1e-4, "{zi} vs {ai}");
            }
        }
    }

    #[test]
    fn exact_average_after_log2n_steps() {
        // Appendix A: deterministic exp-graph cycling averages exactly in
        // ⌊log2⌋ steps for power-of-two n.
        let n = 16;
        let init = random_init(n, 8, 2);
        let mut eng = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..4 {
            eng.step(k, &sched);
        }
        let z0 = eng.states[0].debiased();
        for st in &eng.states[1..] {
            let z = st.debiased();
            for (a, b) in z.iter().zip(&z0) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn mass_conserved_with_and_without_delay() {
        for delay in [0u64, 1, 2, 3] {
            let init = random_init(8, 8, 3);
            let mut eng = PushSumEngine::new(init, delay, false);
            let (x0, w0) = eng.total_mass();
            let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
            for k in 0..25 {
                eng.step(k, &sched);
                let (x, w) = eng.total_mass();
                for (a, b) in x.iter().zip(&x0) {
                    assert!((a - b).abs() < 1e-3, "delay={delay}");
                }
                assert!((w - w0).abs() < 1e-9, "delay={delay}");
            }
        }
    }

    #[test]
    fn delayed_gossip_has_in_flight_mass_and_bounded_staleness() {
        let init = random_init(8, 4, 4);
        let mut eng = PushSumEngine::new(init, 2, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..10 {
            eng.step(k, &sched);
            assert!(eng.max_staleness(k) <= 2);
        }
        assert!(eng.in_flight() > 0);
        eng.drain();
        assert_eq!(eng.in_flight(), 0);
    }

    #[test]
    fn drain_leaves_zero_in_flight_and_zero_staleness() {
        // The post-drain invariant the coordinator's final-eval ordering
        // relies on: after drain() the mailboxes are empty — zero in-flight
        // messages, zero staleness at ANY query iteration — including in
        // fault mode where messages were parked for a crashed node.
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 4, 21);
        let mut eng = PushSumEngine::new(init, 3, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let clock =
            FaultClock::new(FaultPlan::lossless().with_crash(2, 1, Some(50)));
        for k in 0..10 {
            eng.step_faulty(k, &sched, &clock);
        }
        assert!(eng.in_flight() > 0, "τ=3 run must have in-flight mass");
        eng.drain();
        assert_eq!(eng.in_flight(), 0, "drain must empty every mailbox");
        for k in [0u64, 5, 10, 1_000_000] {
            assert_eq!(eng.max_staleness(k), 0, "no staleness after drain");
        }
    }

    #[test]
    fn delayed_gossip_still_converges_after_drain() {
        let n = 8;
        let init = random_init(n, 8, 5);
        let mut avg = vec![0.0f64; 8];
        for v in &init {
            for (a, b) in avg.iter_mut().zip(v) {
                *a += *b as f64 / n as f64;
            }
        }
        let mut eng = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..80 {
            eng.step(k, &sched);
        }
        eng.drain();
        for st in &eng.states {
            for (zi, ai) in st.debiased().iter().zip(&avg) {
                assert!((*zi as f64 - ai).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn biased_engine_drifts_from_average() {
        // Without the push-sum weight, the de-biased values do NOT converge
        // to the initial average under an asymmetric schedule with delays —
        // the mass "lost" to in-flight scaling is never recovered.
        let n = 8;
        let init: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; 4]).collect();
        let avg = (0..n).map(|i| i as f64).sum::<f64>() / n as f64;
        let mut biased = PushSumEngine::new(init.clone(), 1, true);
        let mut unbiased = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..40 {
            biased.step(k, &sched);
            unbiased.step(k, &sched);
        }
        let zu = unbiased.states[0].debiased()[0] as f64;
        let zb = biased.states[0].debiased()[0] as f64;
        assert!((zu - avg).abs() < 0.05, "unbiased {zu} vs {avg}");
        assert!((zb - avg).abs() > (zu - avg).abs(), "biased should be worse");
    }

    #[test]
    fn weights_remain_positive() {
        let init = random_init(16, 4, 6);
        let mut eng = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 16);
        for k in 0..200 {
            eng.step(k, &sched);
            assert!(eng.states.iter().all(|s| s.w > 0.0));
        }
    }

    #[test]
    fn consensus_distance_zero_when_identical() {
        let init = vec![vec![1.0f32; 8]; 4];
        let eng = PushSumEngine::new(init, 0, false);
        let (mean, min, max) = eng.consensus_distance();
        assert!(mean < 1e-9 && min < 1e-9 && max < 1e-9);
    }

    #[test]
    fn sharded_step_bit_identical_to_sequential() {
        // The determinism contract, quick form (the exhaustive version is
        // rust/tests/engine_equivalence.rs): sequential and parallel
        // execution yield identical bits — states, mailboxes and stats.
        for shards in [2usize, 3, 8] {
            let init = random_init(10, 16, 31);
            let mut seq = PushSumEngine::new(init.clone(), 1, false);
            let mut par = PushSumEngine::new(init, 1, false);
            let sched = Schedule::new(TopologyKind::TwoPeerExp, 10);
            for k in 0..25 {
                seq.step_exec(k, &sched, None, ExecPolicy::Sequential);
                par.step_exec(k, &sched, None, ExecPolicy::parallel(shards));
                assert_eq!(seq.in_flight(), par.in_flight(), "k={k}");
            }
            for (a, b) in seq.states.iter().zip(&par.states) {
                assert_eq!(a.x, b.x, "shards={shards}");
                assert_eq!(a.w.to_bits(), b.w.to_bits(), "shards={shards}");
            }
            let (ca, cb) = (seq.consensus_distance(), par.consensus_distance());
            assert_eq!(ca.0.to_bits(), cb.0.to_bits(), "shards={shards}");
        }
    }

    #[test]
    fn sharded_faulty_step_bit_identical_to_sequential() {
        use crate::faults::{FaultClock, FaultPlan};
        let clock = FaultClock::new(
            FaultPlan::lossless()
                .with_drop(0.2)
                .with_crash(3, 5, Some(12))
                .with_seed(9),
        );
        let init = random_init(9, 8, 32);
        let mut seq = PushSumEngine::new(init.clone(), 0, false);
        let mut par = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 9);
        for k in 0..30 {
            seq.step_exec(k, &sched, Some(&clock), ExecPolicy::Sequential);
            par.step_exec(k, &sched, Some(&clock), ExecPolicy::parallel(4));
        }
        assert_eq!(seq.drop_count, par.drop_count);
        assert!(seq.drop_count > 0, "0.2 drop rate must drop something");
        for (a, b) in seq.states.iter().zip(&par.states) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.w.to_bits(), b.w.to_bits());
        }
        let (dxa, dwa) = seq.dropped_mass();
        let (dxb, dwb) = par.dropped_mass();
        assert_eq!(dwa.to_bits(), dwb.to_bits());
        for (a, b) in dxa.iter().zip(dxb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn faulty_step_with_lossless_plan_matches_step() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 11);
        let mut a = PushSumEngine::new(init.clone(), 1, false);
        let mut b = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let clock = FaultClock::new(FaultPlan::lossless());
        for k in 0..30 {
            a.step(k, &sched);
            b.step_faulty(k, &sched, &clock);
        }
        for (sa, sb) in a.states.iter().zip(&b.states) {
            assert_eq!(sa.x, sb.x, "lossless fault path must be bit-identical");
            assert_eq!(sa.w, sb.w);
        }
        assert_eq!(b.drop_count, 0);
    }

    #[test]
    fn lossy_step_ledgers_exactly_the_missing_mass() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 12);
        let mut eng = PushSumEngine::new(init, 0, false);
        let (x0, w0) = eng.total_mass();
        let clock = FaultClock::new(FaultPlan::lossless().with_drop(0.3).with_seed(4));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
            let (x, w) = eng.total_mass_with_losses();
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 1e-2, "k={k}: {a} vs {b}");
            }
            assert!((w - w0).abs() < 1e-9, "k={k}");
        }
        assert!(eng.drop_count > 0, "0.3 drop rate must drop something");
        let (_, dw) = eng.dropped_mass();
        assert!(dw > 0.0);
        // Plain total mass (without the ledger) has genuinely shrunk.
        let (_, w_now) = eng.total_mass();
        assert!(w_now < w0);
    }

    #[test]
    fn rescue_mode_conserves_mass_exactly_with_empty_ledger() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 13);
        let mut eng = PushSumEngine::new(init, 0, false);
        let (x0, w0) = eng.total_mass();
        let clock = FaultClock::new(
            FaultPlan::lossless().with_drop(0.3).with_seed(4).with_rescue(true),
        );
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
        }
        assert!(eng.rescue_count > 0);
        assert_eq!(eng.drop_count, 0);
        assert_eq!(eng.dropped_mass().1, 0.0);
        let (x, w) = eng.total_mass();
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2);
        }
        assert!((w - w0).abs() < 1e-9);
    }

    #[test]
    fn lossy_gossip_debiased_views_still_reach_consensus() {
        // The robustness mechanism: both x and w drop together, so z = x/w
        // still contracts to a common point under 10% loss.
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 14);
        let mut eng = PushSumEngine::new(init, 0, false);
        let clock = FaultClock::new(FaultPlan::lossless().with_drop(0.1).with_seed(2));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let before = eng.consensus_distance().0;
        for k in 0..120 {
            eng.step_faulty(k, &sched, &clock);
        }
        let after = eng.consensus_distance().0;
        assert!(after < before * 1e-2, "{before} → {after}");
        assert!(eng.states.iter().all(|s| s.w > 0.0));
    }

    #[test]
    fn crashed_node_freezes_and_rejoins_from_checkpoint() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 4, 15);
        let mut eng = PushSumEngine::new(init, 0, false);
        let clock =
            FaultClock::new(FaultPlan::lossless().with_crash(3, 5, Some(15)));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let (x0, w0) = eng.total_mass();
        let mut frozen: Option<NodeState> = None;
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
            if k == 5 {
                frozen = Some(eng.states[3].clone());
            }
            if (6..15).contains(&k) {
                let f = frozen.as_ref().unwrap();
                assert_eq!(eng.states[3].x, f.x, "down node must freeze (k={k})");
                assert_eq!(eng.states[3].w, f.w);
            }
        }
        // After rejoin the stale node is mixed back in; mass never leaked.
        let f = frozen.unwrap();
        assert_ne!(eng.states[3].x, f.x, "rejoined node participates again");
        eng.drain();
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x1.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2);
        }
        assert!((w1 - w0).abs() < 1e-9);
    }

    #[test]
    fn identity_compression_is_bit_identical_to_plain_step() {
        let init = random_init(8, 16, 41);
        let mut plain = PushSumEngine::new(init.clone(), 1, false);
        let mut ident = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::TwoPeerExp, 8);
        for k in 0..20 {
            plain.step(k, &sched);
            ident.step_compressed(
                k,
                &sched,
                None,
                ExecPolicy::Sequential,
                Compression::Identity,
            );
        }
        for (a, b) in plain.states.iter().zip(&ident.states) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.w.to_bits(), b.w.to_bits());
        }
        let (rx, rw) = ident.residual_mass();
        assert!(rx.iter().all(|v| *v == 0.0) && rw == 0.0);
    }

    #[test]
    fn compressed_gossip_conserves_total_mass_with_residuals() {
        for spec in [Compression::TopK { den: 8 }, Compression::Qsgd { bits: 4 }] {
            let init = random_init(8, 32, 42);
            let mut eng = PushSumEngine::new(init, 1, false);
            let (x0, w0) = eng.total_mass_with_losses();
            let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
            for k in 0..30 {
                eng.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
                let (x, w) = eng.total_mass_with_losses();
                for (a, b) in x.iter().zip(&x0) {
                    assert!((a - b).abs() < 1e-2, "{spec:?} k={k}: {a} vs {b}");
                }
                assert!((w - w0).abs() < 1e-9, "{spec:?} k={k}: w untouched");
            }
            // The bank genuinely holds mass mid-run under top-k…
            if matches!(spec, Compression::TopK { .. }) {
                let (rx, rw) = eng.residual_mass();
                assert!(rx.iter().any(|v| v.abs() > 1e-6));
                assert!(rw > 0.0, "φ-split must bank weight too");
            }
            // …and drain re-absorbs it: plain state+in-flight mass is
            // whole again, with an empty bank.
            eng.drain();
            let (rx, rw) = eng.residual_mass();
            assert!(rx.iter().all(|v| *v == 0.0) && rw == 0.0);
            let (x1, w1) = eng.total_mass();
            for (a, b) in x1.iter().zip(&x0) {
                assert!((a - b).abs() < 1e-2, "{spec:?} post-drain {a} vs {b}");
            }
            assert!((w1 - w0).abs() < 1e-9);
            assert!(eng.sent_count > 0);
        }
    }

    #[test]
    fn compressed_gossip_contracts_consensus_and_preserves_the_mean() {
        // What each scheme honestly guarantees on pure averaging:
        // fine-grained quantization (qsgd:6) still converges to the true
        // average; aggressive sparsification (topk at 1/4 density) keeps
        // the network mean EXACT (mass conservation) and contracts
        // consensus substantially, but its error-feedback bank leaves an
        // approximation floor — the quantified tradeoff the compress-sweep
        // measures end-to-end.
        let n = 8;
        let init = random_init(n, 32, 43);
        let mut avg = vec![0.0f64; 32];
        for v in &init {
            for (a, b) in avg.iter_mut().zip(v) {
                *a += *b as f64 / n as f64;
            }
        }
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);

        let mut q = PushSumEngine::new(init.clone(), 0, false);
        for k in 0..300 {
            q.step_compressed(k, &sched, None, ExecPolicy::Sequential, Compression::Qsgd {
                bits: 6,
            });
        }
        q.drain();
        for st in &q.states {
            for (zi, ai) in st.debiased().iter().zip(&avg) {
                assert!((*zi as f64 - ai).abs() < 0.1, "qsgd:6: {zi} vs {ai}");
            }
        }

        let mut t = PushSumEngine::new(init, 0, false);
        let before = t.consensus_distance().0;
        for k in 0..300 {
            t.step_compressed(k, &sched, None, ExecPolicy::Sequential, Compression::TopK {
                den: 4,
            });
        }
        t.drain();
        assert!(
            t.consensus_distance().0 < 0.35 * before,
            "topk:4 must contract consensus: {before} → {}",
            t.consensus_distance().0
        );
        for (m, a) in t.mean_x().iter().zip(&avg) {
            assert!(
                (*m as f64 - a).abs() < 1e-3,
                "sparsification must never move the network mean: {m} vs {a}"
            );
        }
    }

    #[test]
    fn compressed_sharded_step_bit_identical_to_sequential() {
        use crate::faults::{FaultClock, FaultPlan};
        let clock = FaultClock::new(
            FaultPlan::lossless().with_drop(0.15).with_crash(2, 4, Some(11)).with_seed(5),
        );
        for spec in [Compression::TopK { den: 4 }, Compression::Qsgd { bits: 4 }] {
            for shards in [2usize, 3, 7] {
                let init = random_init(9, 24, 44);
                let mut seq = PushSumEngine::new(init.clone(), 1, false);
                let mut par = PushSumEngine::new(init, 1, false);
                let sched = Schedule::new(TopologyKind::TwoPeerExp, 9);
                for k in 0..25 {
                    seq.step_compressed(
                        k,
                        &sched,
                        Some(&clock),
                        ExecPolicy::Sequential,
                        spec,
                    );
                    par.step_compressed(
                        k,
                        &sched,
                        Some(&clock),
                        ExecPolicy::parallel(shards),
                        spec,
                    );
                }
                for (a, b) in seq.states.iter().zip(&par.states) {
                    assert_eq!(a.x, b.x, "{spec:?} shards={shards}");
                    assert_eq!(a.w.to_bits(), b.w.to_bits(), "{spec:?} shards={shards}");
                }
                let ((rxa, rwa), (rxb, rwb)) = (seq.residual_mass(), par.residual_mass());
                for (a, b) in rxa.iter().zip(&rxb) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{spec:?} bank x");
                }
                assert_eq!(rwa.to_bits(), rwb.to_bits(), "{spec:?} bank w");
                assert_eq!(seq.sent_count, par.sent_count);
                assert_eq!(seq.drop_count, par.drop_count);
            }
        }
    }

    #[test]
    fn save_restore_resumes_bit_identically_mid_delayed_run() {
        // Quick form of the contract (exhaustive battery:
        // rust/tests/snapshot_resume.rs): snapshot at an arbitrary round
        // of a τ = 2 run with in-flight mail, restore, and continue —
        // states, mailbox order, and counters must be bit-identical.
        let init = random_init(9, 12, 71);
        let mut live = PushSumEngine::new(init, 2, false);
        let sched = Schedule::new(TopologyKind::TwoPeerExp, 9);
        for k in 0..13 {
            live.step(k, &sched);
        }
        assert!(live.in_flight() > 0, "τ=2 must leave in-flight mail");
        let mut back = PushSumEngine::restore(&live.save(13)).unwrap();
        for k in 13..30 {
            live.step(k, &sched);
            back.step(k, &sched);
        }
        for (a, b) in live.states.iter().zip(&back.states) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.w.to_bits(), b.w.to_bits());
        }
        assert_eq!(live.sent_count, back.sent_count);
    }

    #[test]
    fn elastic_join_conserves_mass_bit_exactly_and_converges() {
        let n = 8;
        let init = random_init(n, 8, 72);
        let mut eng = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..5 {
            eng.step(k, &sched);
        }
        let (x0, w0) = eng.total_mass_with_losses();
        let donor_z = eng.states[2].debiased();
        let id = eng.elastic_join(2);
        assert_eq!(id, n);
        assert_eq!(eng.n, n + 1);
        // φ-split: Σx reproduces the old bits, Σw is unchanged, and the
        // joiner starts at the donor's exact de-biased estimate.
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x1.iter().zip(&x0) {
            assert_eq!(a.to_bits(), b.to_bits(), "join must not move Σx bits");
        }
        assert!((w1 - w0).abs() < 1e-12, "join mints no weight: {w1} vs {w0}");
        assert_eq!(eng.states[id].debiased(), donor_z);
        // The grown network still consensuses under a rebuilt schedule.
        let sched = Schedule::new(TopologyKind::OnePeerExp, n + 1);
        for k in 5..80 {
            eng.step(k, &sched);
        }
        eng.drain();
        assert!(eng.consensus_distance().0 < 1e-3);
        let (_, w2) = eng.total_mass_with_losses();
        assert!((w2 - w0).abs() < 1e-9);
    }

    #[test]
    fn orphan_banks_reconcile_across_a_permanent_leave() {
        // The rejoin-from-checkpoint bugfix: banks addressed to a rank
        // that left for good are folded back into their senders at the
        // epoch boundary, so a snapshot taken afterwards reflects the
        // survivor schedule — and no bank mass is stranded.
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 16, 73);
        let mut eng = PushSumEngine::new(init, 0, false);
        let (x0, w0) = eng.total_mass_with_losses();
        let clock = FaultClock::new(FaultPlan::lossless().with_crash(5, 10, None));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let spec = Compression::TopK { den: 4 };
        for k in 0..30 {
            eng.step_compressed(k, &sched, Some(&clock), ExecPolicy::Sequential, spec);
        }
        assert!(eng.reconciled_count > 0, "node 5's inbound banks must fold back");
        assert!(
            eng.residuals.iter().all(|r| !r.contains_key(&5)),
            "no bank may still address the departed rank"
        );
        assert_eq!(eng.save(30).epoch(), clock.membership_epoch(29));
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x1.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2);
        }
        assert!((w1 - w0).abs() < 1e-9);
    }

    #[test]
    fn dense_schedule_tightens_consensus_faster_than_sparse() {
        // Fig. 2's mechanism: per-step contraction is stronger on the dense
        // graph.
        let init = random_init(16, 8, 7);
        let sparse_s = Schedule::new(TopologyKind::OnePeerExp, 16);
        let dense_s = Schedule::new(TopologyKind::Complete, 16);
        let mut sparse = PushSumEngine::new(init.clone(), 0, false);
        let mut dense = PushSumEngine::new(init, 0, false);
        sparse.step(0, &sparse_s);
        dense.step(0, &dense_s);
        assert!(dense.consensus_distance().0 < sparse.consensus_distance().0);
    }
}

//! The PushSum gossip engine (Alg. 1 lines 5–8 / Alg. 2 lines 5–24).
//!
//! Each node holds the push-sum numerator `x ∈ R^d`, the scalar push-sum
//! weight `w`, and exposes the de-biased parameters `z = x / w`. One gossip
//! step pre-weights `(x, w)` by the node's uniform outgoing mixing weight,
//! transmits to the schedule's out-neighbours, and aggregates whatever has
//! arrived. With `delay = τ > 0` messages land τ iterations later
//! (τ-Overlap SGP); with `biased = true` the push-sum weight is frozen at 1
//! (the ablation of Table 4 that "directly incorporates delayed messages
//! without accounting for the bias").
//!
//! The engine is the in-process substrate for n logical nodes: messages are
//! moved through per-destination delivery queues, which both implements the
//! semantics exactly and lets tests assert **mass conservation** — the
//! column-stochasticity invariant that Σᵢ xᵢ plus all in-flight mass is
//! constant under gossip.

use crate::faults::FaultClock;
use crate::topology::Schedule;

/// One in-flight push-sum message (already pre-weighted by the sender).
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub sent_iter: u64,
    pub deliver_iter: u64,
    pub x: Vec<f32>,
    pub w: f64,
}

/// Per-node push-sum state.
#[derive(Clone, Debug)]
pub struct NodeState {
    /// Push-sum numerator (the *biased* parameters gradients are applied to).
    pub x: Vec<f32>,
    /// Push-sum weight; stays positive, starts at 1.
    pub w: f64,
}

impl NodeState {
    pub fn new(x: Vec<f32>) -> Self {
        Self { x, w: 1.0 }
    }

    /// De-biased parameters z = x / w (Alg. 1 line 8).
    pub fn debiased(&self) -> Vec<f32> {
        let inv = (1.0 / self.w) as f32;
        self.x.iter().map(|v| v * inv).collect()
    }

    /// Write z = x / w into `out` without allocating.
    pub fn debias_into(&self, out: &mut [f32]) {
        let inv = (1.0 / self.w) as f32;
        for (o, v) in out.iter_mut().zip(&self.x) {
            *o = v * inv;
        }
    }
}

/// The synchronous multi-node PushSum engine.
pub struct PushSumEngine {
    pub n: usize,
    pub dim: usize,
    pub states: Vec<NodeState>,
    /// Overlap delay τ: 0 = blocking SGP, ≥1 = τ-OSGP.
    pub delay: u64,
    /// Table-4 ablation: ignore the push-sum weight (w ≡ 1, z = x).
    pub biased: bool,
    /// Per-destination in-flight messages, ordered by deliver_iter.
    inboxes: Vec<Vec<Message>>,
    /// Scratch buffer reused across steps (perf: no per-step allocation).
    scale_buf: Vec<f32>,
    /// Recycled message payload buffers (perf: delivering a message returns
    /// its `x` here; sending pops one instead of allocating dim-sized
    /// fresh-page Vecs on every message — see EXPERIMENTS.md §Perf).
    pool: Vec<Vec<f32>>,
    /// Cumulative numerator mass lost to dropped messages (fault mode).
    dropped_x: Vec<f64>,
    /// Cumulative push-sum-weight mass lost to dropped messages.
    dropped_w: f64,
    /// Count of messages dropped (diagnostics).
    pub drop_count: u64,
    /// Count of messages rescued (re-absorbed at the sender; fault mode
    /// with `FaultPlan::rescue`).
    pub rescue_count: u64,
}

impl PushSumEngine {
    pub fn new(init: Vec<Vec<f32>>, delay: u64, biased: bool) -> Self {
        let n = init.len();
        let dim = init[0].len();
        assert!(init.iter().all(|v| v.len() == dim));
        Self {
            n,
            dim,
            states: init.into_iter().map(NodeState::new).collect(),
            delay,
            biased,
            inboxes: (0..n).map(|_| Vec::new()).collect(),
            scale_buf: vec![0.0; dim],
            pool: Vec::new(),
            dropped_x: vec![0.0; dim],
            dropped_w: 0.0,
            drop_count: 0,
            rescue_count: 0,
        }
    }

    /// Pop a recycled payload buffer or allocate a fresh one.
    fn take_buf(&mut self) -> Vec<f32> {
        self.pool.pop().unwrap_or_else(|| vec![0.0; self.dim])
    }

    /// One full gossip step at iteration `k` for all nodes (Alg. 1 l. 5–7 /
    /// Alg. 2 l. 5–24): pre-weight & send, keep self-share, aggregate
    /// everything whose `deliver_iter == k`.
    pub fn step(&mut self, k: u64, schedule: &Schedule) {
        let deliver_at = k + self.delay;
        // Phase 1: every node pre-weights and enqueues its outgoing
        // messages, and scales its own state by the self-loop weight.
        // The first payload is computed fused (read x once, write scaled);
        // further peers copy it; the node's own state is scaled in place —
        // one full pass fewer than the naive scale-buffer formulation.
        for i in 0..self.n {
            let peers = schedule.out_peers(i, k);
            let w_mix = 1.0 / (1.0 + peers.len() as f64);
            let wf = w_mix as f32;
            let msg_w = self.states[i].w * w_mix;
            if peers.len() == 1 {
                // Dominant (1-peer) case: fused read-scale-write, no
                // intermediate buffer.
                let mut payload = self.take_buf();
                for (p, v) in payload.iter_mut().zip(&self.states[i].x) {
                    *p = v * wf;
                }
                self.inboxes[peers[0]].push(Message {
                    from: i,
                    sent_iter: k,
                    deliver_iter: deliver_at,
                    x: payload,
                    w: msg_w,
                });
            } else if !peers.is_empty() {
                for (b, v) in self.scale_buf.iter_mut().zip(&self.states[i].x) {
                    *b = v * wf;
                }
                for &j in &peers {
                    let mut payload = self.take_buf();
                    payload.copy_from_slice(&self.scale_buf);
                    self.inboxes[j].push(Message {
                        from: i,
                        sent_iter: k,
                        deliver_iter: deliver_at,
                        x: payload,
                        w: msg_w,
                    });
                }
            }
            // Self-loop share (Alg. 2 lines 7–8), scaled in place.
            let st = &mut self.states[i];
            for v in st.x.iter_mut() {
                *v *= wf;
            }
            st.w *= w_mix;
        }
        // Phase 2: aggregate deliveries due at k; payload buffers go back
        // to the pool.
        for i in 0..self.n {
            let mut inbox = std::mem::take(&mut self.inboxes[i]);
            let mut j = 0;
            while j < inbox.len() {
                if inbox[j].deliver_iter <= k {
                    let msg = inbox.swap_remove(j);
                    let st = &mut self.states[i];
                    for (a, b) in st.x.iter_mut().zip(&msg.x) {
                        *a += b;
                    }
                    st.w += msg.w;
                    self.pool.push(msg.x);
                } else {
                    j += 1;
                }
            }
            self.inboxes[i] = inbox;
        }
        if self.biased {
            for st in &mut self.states {
                st.w = 1.0;
            }
        }
    }

    /// One gossip step under a fault scenario: only surviving members send
    /// and aggregate (the schedule re-indexes over them, staying
    /// column-stochastic), messages drop per the deterministic
    /// [`FaultClock`] history, and every dropped `(x, w)` pair is either
    /// **recorded** in the loss ledger (`dropped_mass`) or — in rescue mode
    /// — **re-absorbed** by the sender, keeping the step exactly
    /// column-stochastic.
    ///
    /// Crashed nodes freeze in place (state = checkpoint); messages already
    /// queued for them wait in their inbox and deliver on rejoin (or at
    /// [`Self::drain`]). This is why push-sum tolerates loss where
    /// symmetric averaging biases: a drop removes numerator *and* weight
    /// together, so the de-biased `z = x / w` stays a convex combination of
    /// honest values — tested against the biased engine in
    /// `rust/tests/test_faults.rs`.
    pub fn step_faulty(&mut self, k: u64, schedule: &Schedule, clock: &FaultClock) {
        let deliver_at = k + self.delay;
        let alive = clock.alive(self.n, k);
        let rescue = clock.plan.rescue;
        for &i in &alive {
            let peers = schedule.out_peers_among(i, k, &alive);
            let w_mix = 1.0 / (1.0 + peers.len() as f64);
            let wf = w_mix as f32;
            let msg_w = self.states[i].w * w_mix;
            let mut rescued = 0usize;
            for &j in &peers {
                if clock.drops(i, j, k) {
                    if rescue {
                        // Sender detects the failed send and keeps its
                        // share: nothing leaves, nothing is lost.
                        self.rescue_count += 1;
                        rescued += 1;
                        continue;
                    }
                    // The share leaves the sender and vanishes — ledger it.
                    self.drop_count += 1;
                    for (d, v) in self.dropped_x.iter_mut().zip(&self.states[i].x) {
                        *d += (*v * wf) as f64;
                    }
                    self.dropped_w += msg_w;
                    continue;
                }
                let mut payload = self.take_buf();
                for (p, v) in payload.iter_mut().zip(&self.states[i].x) {
                    *p = v * wf;
                }
                self.inboxes[j].push(Message {
                    from: i,
                    sent_iter: k,
                    deliver_iter: deliver_at,
                    x: payload,
                    w: msg_w,
                });
            }
            // Self-loop share; rescued shares stay too, so the node keeps
            // `w_mix · (1 + rescued)` of itself.
            let keep = (w_mix * (1 + rescued) as f64) as f32;
            let st = &mut self.states[i];
            for v in st.x.iter_mut() {
                *v *= keep;
            }
            st.w *= w_mix * (1 + rescued) as f64;
        }
        // Aggregate deliveries due at k — survivors only; a crashed node's
        // inbox holds until it rejoins.
        for &i in &alive {
            let mut inbox = std::mem::take(&mut self.inboxes[i]);
            let mut j = 0;
            while j < inbox.len() {
                if inbox[j].deliver_iter <= k {
                    let msg = inbox.swap_remove(j);
                    let st = &mut self.states[i];
                    for (a, b) in st.x.iter_mut().zip(&msg.x) {
                        *a += b;
                    }
                    st.w += msg.w;
                    self.pool.push(msg.x);
                } else {
                    j += 1;
                }
            }
            self.inboxes[i] = inbox;
        }
        if self.biased {
            for st in &mut self.states {
                st.w = 1.0;
            }
        }
    }

    /// Mass recorded as lost to dropped messages: `(Σ dropped x, Σ dropped w)`.
    pub fn dropped_mass(&self) -> (&[f64], f64) {
        (&self.dropped_x, self.dropped_w)
    }

    /// Total mass *including* the recorded losses — the quantity that stays
    /// invariant under any fault plan (the fault-mode proptest anchor):
    /// Σᵢ xᵢ + in-flight + recorded-dropped.
    pub fn total_mass_with_losses(&self) -> (Vec<f64>, f64) {
        let (mut xm, mut wm) = self.total_mass();
        for (a, b) in xm.iter_mut().zip(&self.dropped_x) {
            *a += b;
        }
        wm += self.dropped_w;
        (xm, wm)
    }

    /// Flush all in-flight messages (used at the end of a run so no mass is
    /// stranded; OSGP's bounded-delay assumption guarantees this terminates).
    pub fn drain(&mut self) {
        for i in 0..self.n {
            for msg in std::mem::take(&mut self.inboxes[i]) {
                let st = &mut self.states[i];
                for (a, b) in st.x.iter_mut().zip(&msg.x) {
                    *a += b;
                }
                st.w += msg.w;
            }
        }
        if self.biased {
            for st in &mut self.states {
                st.w = 1.0;
            }
        }
    }

    /// Number of in-flight messages (test/diagnostic).
    pub fn in_flight(&self) -> usize {
        self.inboxes.iter().map(|b| b.len()).sum()
    }

    /// Maximum staleness among in-flight messages relative to iteration k.
    pub fn max_staleness(&self, k: u64) -> u64 {
        self.inboxes
            .iter()
            .flatten()
            .map(|m| k.saturating_sub(m.sent_iter))
            .max()
            .unwrap_or(0)
    }

    /// Total mass: (Σᵢ xᵢ + in-flight x, Σᵢ wᵢ + in-flight w). Invariant
    /// under unbiased gossip — the proptest anchor.
    pub fn total_mass(&self) -> (Vec<f64>, f64) {
        let mut xm = vec![0.0f64; self.dim];
        let mut wm = 0.0f64;
        for st in &self.states {
            for (a, b) in xm.iter_mut().zip(&st.x) {
                *a += *b as f64;
            }
            wm += st.w;
        }
        for inbox in &self.inboxes {
            for msg in inbox {
                for (a, b) in xm.iter_mut().zip(&msg.x) {
                    *a += *b as f64;
                }
                wm += msg.w;
            }
        }
        (xm, wm)
    }

    /// Node-wise average of the numerators x̄ = (1/n) Σ xᵢ (not incl.
    /// in-flight mass).
    pub fn mean_x(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.dim];
        for st in &self.states {
            for (a, b) in m.iter_mut().zip(&st.x) {
                *a += b;
            }
        }
        let inv = 1.0 / self.n as f32;
        for a in &mut m {
            *a *= inv;
        }
        m
    }

    /// Consensus statistics: (mean, min, max) over nodes of ‖zᵢ − x̄‖₂,
    /// the quantity plotted in Fig. 2.
    pub fn consensus_distance(&self) -> (f64, f64, f64) {
        let mean = self.mean_x();
        let mut dists = Vec::with_capacity(self.n);
        for st in &self.states {
            let inv = (1.0 / st.w) as f32;
            let d: f64 = st
                .x
                .iter()
                .zip(&mean)
                .map(|(x, m)| {
                    let e = (x * inv - m) as f64;
                    e * e
                })
                .sum();
            dists.push(d.sqrt());
        }
        let sum: f64 = dists.iter().sum();
        let min = dists.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = dists.iter().cloned().fold(0.0, f64::max);
        (sum / self.n as f64, min, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;
    use crate::topology::{Schedule, TopologyKind};

    fn random_init(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.gaussian_vec(d)).collect()
    }

    #[test]
    fn blocking_gossip_converges_to_average() {
        let n = 8;
        let init = random_init(n, 16, 1);
        let mut avg = vec![0.0f64; 16];
        for v in &init {
            for (a, b) in avg.iter_mut().zip(v) {
                *a += *b as f64 / n as f64;
            }
        }
        let mut eng = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..60 {
            eng.step(k, &sched);
        }
        for st in &eng.states {
            let z = st.debiased();
            for (zi, ai) in z.iter().zip(&avg) {
                assert!((*zi as f64 - ai).abs() < 1e-4, "{zi} vs {ai}");
            }
        }
    }

    #[test]
    fn exact_average_after_log2n_steps() {
        // Appendix A: deterministic exp-graph cycling averages exactly in
        // ⌊log2⌋ steps for power-of-two n.
        let n = 16;
        let init = random_init(n, 8, 2);
        let mut eng = PushSumEngine::new(init, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..4 {
            eng.step(k, &sched);
        }
        let z0 = eng.states[0].debiased();
        for st in &eng.states[1..] {
            let z = st.debiased();
            for (a, b) in z.iter().zip(&z0) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn mass_conserved_with_and_without_delay() {
        for delay in [0u64, 1, 2, 3] {
            let init = random_init(8, 8, 3);
            let mut eng = PushSumEngine::new(init, delay, false);
            let (x0, w0) = eng.total_mass();
            let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
            for k in 0..25 {
                eng.step(k, &sched);
                let (x, w) = eng.total_mass();
                for (a, b) in x.iter().zip(&x0) {
                    assert!((a - b).abs() < 1e-3, "delay={delay}");
                }
                assert!((w - w0).abs() < 1e-9, "delay={delay}");
            }
        }
    }

    #[test]
    fn delayed_gossip_has_in_flight_mass_and_bounded_staleness() {
        let init = random_init(8, 4, 4);
        let mut eng = PushSumEngine::new(init, 2, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..10 {
            eng.step(k, &sched);
            assert!(eng.max_staleness(k) <= 2);
        }
        assert!(eng.in_flight() > 0);
        eng.drain();
        assert_eq!(eng.in_flight(), 0);
    }

    #[test]
    fn delayed_gossip_still_converges_after_drain() {
        let n = 8;
        let init = random_init(n, 8, 5);
        let mut avg = vec![0.0f64; 8];
        for v in &init {
            for (a, b) in avg.iter_mut().zip(v) {
                *a += *b as f64 / n as f64;
            }
        }
        let mut eng = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..80 {
            eng.step(k, &sched);
        }
        eng.drain();
        for st in &eng.states {
            for (zi, ai) in st.debiased().iter().zip(&avg) {
                assert!((*zi as f64 - ai).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn biased_engine_drifts_from_average() {
        // Without the push-sum weight, the de-biased values do NOT converge
        // to the initial average under an asymmetric schedule with delays —
        // the mass "lost" to in-flight scaling is never recovered.
        let n = 8;
        let init: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; 4]).collect();
        let avg = (0..n).map(|i| i as f64).sum::<f64>() / n as f64;
        let mut biased = PushSumEngine::new(init.clone(), 1, true);
        let mut unbiased = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..40 {
            biased.step(k, &sched);
            unbiased.step(k, &sched);
        }
        let zu = unbiased.states[0].debiased()[0] as f64;
        let zb = biased.states[0].debiased()[0] as f64;
        assert!((zu - avg).abs() < 0.05, "unbiased {zu} vs {avg}");
        assert!((zb - avg).abs() > (zu - avg).abs(), "biased should be worse");
    }

    #[test]
    fn weights_remain_positive() {
        let init = random_init(16, 4, 6);
        let mut eng = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 16);
        for k in 0..200 {
            eng.step(k, &sched);
            assert!(eng.states.iter().all(|s| s.w > 0.0));
        }
    }

    #[test]
    fn consensus_distance_zero_when_identical() {
        let init = vec![vec![1.0f32; 8]; 4];
        let eng = PushSumEngine::new(init, 0, false);
        let (mean, min, max) = eng.consensus_distance();
        assert!(mean < 1e-9 && min < 1e-9 && max < 1e-9);
    }

    #[test]
    fn faulty_step_with_lossless_plan_matches_step() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 11);
        let mut a = PushSumEngine::new(init.clone(), 1, false);
        let mut b = PushSumEngine::new(init, 1, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let clock = FaultClock::new(FaultPlan::lossless());
        for k in 0..30 {
            a.step(k, &sched);
            b.step_faulty(k, &sched, &clock);
        }
        for (sa, sb) in a.states.iter().zip(&b.states) {
            assert_eq!(sa.x, sb.x, "lossless fault path must be bit-identical");
            assert_eq!(sa.w, sb.w);
        }
        assert_eq!(b.drop_count, 0);
    }

    #[test]
    fn lossy_step_ledgers_exactly_the_missing_mass() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 12);
        let mut eng = PushSumEngine::new(init, 0, false);
        let (x0, w0) = eng.total_mass();
        let clock = FaultClock::new(FaultPlan::lossless().with_drop(0.3).with_seed(4));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
            let (x, w) = eng.total_mass_with_losses();
            for (a, b) in x.iter().zip(&x0) {
                assert!((a - b).abs() < 1e-2, "k={k}: {a} vs {b}");
            }
            assert!((w - w0).abs() < 1e-9, "k={k}");
        }
        assert!(eng.drop_count > 0, "0.3 drop rate must drop something");
        let (_, dw) = eng.dropped_mass();
        assert!(dw > 0.0);
        // Plain total mass (without the ledger) has genuinely shrunk.
        let (_, w_now) = eng.total_mass();
        assert!(w_now < w0);
    }

    #[test]
    fn rescue_mode_conserves_mass_exactly_with_empty_ledger() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 13);
        let mut eng = PushSumEngine::new(init, 0, false);
        let (x0, w0) = eng.total_mass();
        let clock = FaultClock::new(
            FaultPlan::lossless().with_drop(0.3).with_seed(4).with_rescue(true),
        );
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
        }
        assert!(eng.rescue_count > 0);
        assert_eq!(eng.drop_count, 0);
        assert_eq!(eng.dropped_mass().1, 0.0);
        let (x, w) = eng.total_mass();
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2);
        }
        assert!((w - w0).abs() < 1e-9);
    }

    #[test]
    fn lossy_gossip_debiased_views_still_reach_consensus() {
        // The robustness mechanism: both x and w drop together, so z = x/w
        // still contracts to a common point under 10% loss.
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 8, 14);
        let mut eng = PushSumEngine::new(init, 0, false);
        let clock = FaultClock::new(FaultPlan::lossless().with_drop(0.1).with_seed(2));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let before = eng.consensus_distance().0;
        for k in 0..120 {
            eng.step_faulty(k, &sched, &clock);
        }
        let after = eng.consensus_distance().0;
        assert!(after < before * 1e-2, "{before} → {after}");
        assert!(eng.states.iter().all(|s| s.w > 0.0));
    }

    #[test]
    fn crashed_node_freezes_and_rejoins_from_checkpoint() {
        use crate::faults::{FaultClock, FaultPlan};
        let init = random_init(8, 4, 15);
        let mut eng = PushSumEngine::new(init, 0, false);
        let clock =
            FaultClock::new(FaultPlan::lossless().with_crash(3, 5, Some(15)));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let (x0, w0) = eng.total_mass();
        let mut frozen: Option<NodeState> = None;
        for k in 0..40 {
            eng.step_faulty(k, &sched, &clock);
            if k == 5 {
                frozen = Some(eng.states[3].clone());
            }
            if (6..15).contains(&k) {
                let f = frozen.as_ref().unwrap();
                assert_eq!(eng.states[3].x, f.x, "down node must freeze (k={k})");
                assert_eq!(eng.states[3].w, f.w);
            }
        }
        // After rejoin the stale node is mixed back in; mass never leaked.
        let f = frozen.unwrap();
        assert_ne!(eng.states[3].x, f.x, "rejoined node participates again");
        eng.drain();
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x1.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2);
        }
        assert!((w1 - w0).abs() < 1e-9);
    }

    #[test]
    fn dense_schedule_tightens_consensus_faster_than_sparse() {
        // Fig. 2's mechanism: per-step contraction is stronger on the dense
        // graph.
        let init = random_init(16, 8, 7);
        let sparse_s = Schedule::new(TopologyKind::OnePeerExp, 16);
        let dense_s = Schedule::new(TopologyKind::Complete, 16);
        let mut sparse = PushSumEngine::new(init.clone(), 0, false);
        let mut dense = PushSumEngine::new(init, 0, false);
        sparse.step(0, &sparse_s);
        dense.step(0, &dense_s);
        assert!(dense.consensus_distance().0 < sparse.consensus_distance().0);
    }
}

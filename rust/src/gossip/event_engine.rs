//! Event-driven push-sum execution: arrival scheduling for the dense
//! engine, and a sparse million-node engine over the same contract.
//!
//! Two layers share this module (ARCHITECTURE.md §7):
//!
//! 1. **[`ArrivalFlow`]** — the arrival scheduler behind
//!    [`ExecPolicy::Event`](super::ExecPolicy::Event) on the dense
//!    [`PushSumEngine`]: a priority queue ([`EventQueue`]) of delivery
//!    notifications popped in `(deliver_iter, send order)` so the
//!    aggregate phase visits **only nodes with due mail** instead of
//!    walking all N mailboxes. Mailboxes remain the source of truth — the
//!    queue carries `(time, destination)` notifications, never payloads —
//!    which is what makes the mode bit-identical to the sequential and
//!    pooled engines under *any* delay, fault plan, and compression spec
//!    (see the ordering argument on [`aggregate_event`]).
//!
//! 2. **[`EventEngine`]** — the sparse engine for N ≥ 10⁶ simulation:
//!    per-node state lives in a slab of lazily materialized boxes, every
//!    unmaterialized ("cold") node aliases one shared template state, and
//!    shares off cold nodes are delta-encoded against that template so a
//!    quiescent node costs **zero work per virtual tick** — cold→cold
//!    traffic is elided entirely as a bit-exact fixed point of the
//!    mixing. The moment the run leaves the provably-exact regime
//!    (faults, compression, delay, a non-permutation schedule), the
//!    engine materializes every node into a dense [`PushSumEngine`] and
//!    keeps stepping under [`ExecPolicy::Event`](super::ExecPolicy::Event)
//!    — same state bits, same results, different cost model.
//!
//! # Why the cold fixed point is exact
//!
//! Under a unit-shift permutation schedule
//! ([`Schedule::unit_permutation_shift`]) every node has out-degree 1, so
//! the uniform mixing weight is exactly ½ in both `f32` and `f64`. A node
//! whose state equals the template and whose in-neighbour is also cold
//! computes `x·½ + x·½` per coordinate and `w·½ + w·½` for the weight.
//! For every normal (and zero) float, halving is exact and the two halves
//! re-add to the original bit pattern, so the node's state is unchanged —
//! verified per template at construction (`halving_safe`; subnormal or
//! non-finite templates fall back to the dense path rather than risk
//! drift).

use std::collections::BTreeSet;
use std::time::Instant;

use super::{
    drain_due, lap_ns, take_buf, Compression, ExecPolicy, Message, NodeState,
    PushSumEngine, StepCtx,
};
use crate::faults::FaultClock;
use crate::obs::{EngineObs, ObsSink, RoundRecord};
use crate::sim::EventQueue;
use crate::snapshot::{EngineKind, SnapLedger, SnapSparse, Snapshot, SnapshotError};
use crate::topology::Schedule;

/// Arrival scheduler for [`ExecPolicy::Event`](super::ExecPolicy::Event)
/// rounds of the dense engine: a priority queue of `(deliver_iter, to)`
/// delivery notifications plus the bookkeeping needed to honor fault
/// semantics (mail for a crashed node parks until it rejoins).
///
/// All storage is pre-sized at construction; the steady-state round path
/// (note → pop → drain) performs no heap allocation once the queue has
/// grown to the run's in-flight high-water mark (pinned by
/// `rust/tests/alloc_regression.rs`).
pub(crate) struct ArrivalFlow {
    /// Pending delivery notifications: payload = destination node.
    queue: EventQueue<usize>,
    /// Scratch: nodes with due mail this round, deduplicated.
    due: Vec<usize>,
    /// Per-node dedup stamp (`round` counter value when last marked due).
    due_mark: Vec<u64>,
    /// Nodes that were down when their mail came due — revisited every
    /// round until they rejoin.
    parked: Vec<usize>,
    /// Membership flag for `parked` (O(1) dedup).
    is_parked: Vec<bool>,
    /// Monotone round counter for `due_mark` stamps.
    round: u64,
}

impl ArrivalFlow {
    /// A scheduler for `n` nodes, seeded with one notification per message
    /// already sitting in `inboxes` (so switching an engine into event
    /// mode mid-run loses no mail).
    pub(crate) fn new(n: usize, inboxes: &[Vec<Message>]) -> Self {
        let mut flow = Self {
            queue: EventQueue::with_capacity(2 * n),
            due: Vec::with_capacity(n),
            due_mark: vec![0; n],
            parked: Vec::with_capacity(n.min(1024)),
            is_parked: vec![false; n],
            round: 0,
        };
        for inbox in inboxes {
            for msg in inbox {
                flow.note_send(msg.deliver_iter, msg.to);
            }
        }
        flow
    }

    /// Record one sent message: its destination will be visited by the
    /// aggregate pass of round `deliver` (or parked if down then).
    pub(crate) fn note_send(&mut self, deliver: u64, to: usize) {
        self.queue.push(deliver as f64, to);
    }

    /// Forget all pending notifications and rewind the virtual clock —
    /// called by [`PushSumEngine::drain`], which force-delivers the
    /// mailboxes the notifications referred to.
    pub(crate) fn clear(&mut self) {
        self.queue.clear();
        self.due.clear();
        self.parked.clear();
        self.due_mark.iter_mut().for_each(|m| *m = 0);
        self.is_parked.iter_mut().for_each(|p| *p = false);
        self.round = 0;
    }
}

/// The event-mode aggregate phase: pop every delivery notification due at
/// `ctx.k`, then run [`drain_due`] over exactly the mailboxes named —
/// plus any mailbox parked for a crashed node that has since rejoined.
///
/// Bit-identity argument: `aggregate_shard` walks all N nodes and runs
/// the same `drain_due` per mailbox, but a mailbox with no due mail is a
/// pure no-op under it — no state change, no reordering (the swap-remove
/// scan only permutes survivors when it removes something). So visiting
/// only the notified mailboxes applies identical operations in an
/// identical per-mailbox order, and cross-node order is immaterial
/// because aggregation touches no shared state. Fault semantics match
/// because a notification for a down node parks (its mailbox holds, as
/// dense) and fires on the first round the node is back up — exactly the
/// round dense aggregation would first drain it again.
pub(super) fn aggregate_event(
    flow: &mut ArrivalFlow,
    states: &mut [NodeState],
    inboxes: &mut [Vec<Message>],
    pool: &mut Vec<Vec<f32>>,
    ctx: StepCtx,
    biased: bool,
) {
    let k = ctx.k;
    flow.round += 1;
    let stamp = flow.round;
    flow.due.clear();
    while flow.queue.peek_time().is_some_and(|t| t <= k as f64) {
        let to = flow.queue.pop().expect("peeked event exists").payload;
        if let Some((clock, _)) = ctx.faults {
            if clock.is_down(to, k) {
                if !flow.is_parked[to] {
                    flow.is_parked[to] = true;
                    flow.parked.push(to);
                }
                continue;
            }
        }
        if flow.due_mark[to] != stamp {
            flow.due_mark[to] = stamp;
            flow.due.push(to);
        }
    }
    // Parked mail fires on the first round its node is back up. (With no
    // fault clock every node counts as up — a plan can end mid-crash and
    // a later faultless round must still deliver.)
    let mut i = 0;
    while i < flow.parked.len() {
        let node = flow.parked[i];
        if ctx.faults.is_some_and(|(clock, _)| clock.is_down(node, k)) {
            i += 1;
            continue;
        }
        flow.is_parked[node] = false;
        flow.parked.swap_remove(i);
        if flow.due_mark[node] != stamp {
            flow.due_mark[node] = stamp;
            flow.due.push(node);
        }
    }
    for &node in &flow.due {
        drain_due(&mut states[node], &mut inboxes[node], k, pool);
    }
    if biased {
        for st in states.iter_mut() {
            st.w = 1.0;
        }
    }
}

/// One in-flight share of the sparse fast path. The numerator buffer is
/// dense (`dim` floats) but recycled through the engine's pool; shares
/// off *cold* nodes are never enqueued at all — they are applied as
/// template deltas at the receiver (`x += template·½`), the degenerate
/// (and dominant) delta encoding.
#[derive(Debug, PartialEq)]
struct SparseShare {
    /// Destination node.
    to: usize,
    /// Pre-weighted numerator share.
    x: Vec<f32>,
    /// Pre-weighted push-sum-weight share.
    w: f64,
}

/// The sparse fast-path core: a slab of materialized ("hot") nodes over a
/// shared cold template, and the arrival queue their shares flow through.
struct SparseCore {
    /// Lazily materialized per-node state; `None` = cold (≡ template).
    nodes: Vec<Option<Box<NodeState>>>,
    /// Materialized node set, iterated in ascending order each tick.
    hot: BTreeSet<usize>,
    /// In-flight shares (drained empty within every tick — the fast path
    /// runs at delay 0).
    queue: EventQueue<SparseShare>,
    /// Recycled share buffers (zero-alloc steady state).
    pool: Vec<Vec<f32>>,
    /// Physical messages sent (cold→cold elided traffic never counts).
    sent: u64,
}

/// Sparse event-driven push-sum engine for very large N.
///
/// Construct with [`EventEngine::with_template`] for the sparse regime —
/// all N nodes start cold at a shared template state, cost nothing until
/// touched, and are materialized on first activity (an inbound share, or
/// a direct perturbation via [`EventEngine::state_mut`]) — or with
/// [`EventEngine::from_init`] for heterogeneous initial states, which is
/// simply the dense engine under
/// [`ExecPolicy::Event`](super::ExecPolicy::Event).
///
/// The fast path runs while every exactness precondition holds (no fault
/// clock, identity compression, delay 0, a unit-permutation schedule
/// tick, halving-safe template); the first step outside that regime
/// materializes all nodes into a dense [`PushSumEngine`] — transplanting
/// states, counters, and the observability recorder — and every later
/// step routes through it. Results are bit-identical to a dense engine
/// started from the fully-materialized initial state either way
/// (`rust/tests/event_engine_equivalence.rs`).
///
/// ```
/// use sgp::gossip::EventEngine;
/// use sgp::topology::{Schedule, TopologyKind};
///
/// // A million cold nodes cost no per-tick work and no per-node memory.
/// let mut eng = EventEngine::with_template(vec![0.0f32; 4], 1_000_000, 0, false);
/// let sched = Schedule::new(TopologyKind::OnePeerExp, 1_000_000);
/// for k in 0..8 {
///     eng.step(k, &sched, None, sgp::gossip::Compression::Identity);
/// }
/// assert_eq!(eng.materialized(), 0);
///
/// // Perturb one node: activity (and memory) spreads only along the
/// // gossip edges it actually excites.
/// eng.state_mut(17).x[0] = 1.0;
/// eng.step(8, &sched, None, sgp::gossip::Compression::Identity);
/// assert_eq!(eng.materialized(), 2);
/// ```
pub struct EventEngine {
    /// Number of logical nodes.
    n: usize,
    /// Parameter dimension.
    dim: usize,
    /// Overlap delay τ (fast path requires 0).
    delay: u64,
    /// Table-4 ablation: freeze w ≡ 1.
    biased: bool,
    /// The shared cold state every unmaterialized node aliases.
    template: NodeState,
    /// Whether `template` survives ½-split-and-recombine bit-exactly.
    halving_safe: bool,
    /// Fast-path state; `None` after materialization.
    sparse: Option<SparseCore>,
    /// Dense escape hatch; `Some` after the first step outside the
    /// fast-path regime (runs under `ExecPolicy::Event`).
    dense: Option<PushSumEngine>,
    /// Observability recorder while sparse (moves into `dense` on
    /// materialization).
    obs: Option<Box<EngineObs>>,
}

/// Whether splitting `v` in half and re-adding reproduces `v` bit-exactly
/// (true for every normal float and ±0; false for subnormals that lose a
/// bit, and for non-finite values).
fn halving_exact_f32(v: f32) -> bool {
    let h = v * 0.5f32;
    h + h == v && (h != 0.0 || v == 0.0)
}

impl EventEngine {
    /// A sparse engine of `n` cold nodes sharing `template_x` (all
    /// push-sum weights start at 1). `delay`/`biased` as on
    /// [`PushSumEngine::new`]; note the sparse fast path only runs at
    /// `delay == 0` — a delayed engine materializes on its first step.
    pub fn with_template(template_x: Vec<f32>, n: usize, delay: u64, biased: bool) -> Self {
        assert!(n > 0, "need at least one node");
        let dim = template_x.len();
        let template = NodeState::new(template_x);
        let halving_safe =
            template.x.iter().copied().all(halving_exact_f32) && template.w == 1.0;
        Self {
            n,
            dim,
            delay,
            biased,
            template,
            halving_safe,
            sparse: Some(SparseCore {
                nodes: (0..n).map(|_| None).collect(),
                hot: BTreeSet::new(),
                queue: EventQueue::new(),
                pool: Vec::new(),
                sent: 0,
            }),
            dense: None,
            obs: None,
        }
    }

    /// An engine over heterogeneous per-node initial numerators: every
    /// node is hot from the start, so this is exactly the dense engine
    /// stepping under [`ExecPolicy::Event`](super::ExecPolicy::Event).
    pub fn from_init(init: Vec<Vec<f32>>, delay: u64, biased: bool) -> Self {
        assert!(!init.is_empty(), "need at least one node");
        let n = init.len();
        let dim = init[0].len();
        let template = NodeState::new(vec![0.0; dim]);
        Self {
            n,
            dim,
            delay,
            biased,
            template,
            halving_safe: false,
            sparse: None,
            dense: Some(PushSumEngine::new(init, delay, biased)),
            obs: None,
        }
    }

    /// Number of logical nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Parameter dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of nodes holding materialized (per-node) state: the hot set
    /// while sparse, all `n` after the dense fall-off.
    pub fn materialized(&self) -> usize {
        match &self.sparse {
            Some(core) => core.hot.len(),
            None => self.n,
        }
    }

    /// Whether the engine is still on the sparse fast path.
    pub fn is_sparse(&self) -> bool {
        self.sparse.is_some()
    }

    /// Physical messages sent so far (cold→cold fixed-point traffic is
    /// elided, never sent, and never counted).
    pub fn sent_count(&self) -> u64 {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => core.sent,
            (None, Some(eng)) => eng.sent_count,
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }

    /// Node `i`'s state: the template if cold, its own state if hot.
    pub fn node_state(&self, i: usize) -> &NodeState {
        match &self.sparse {
            Some(core) => core.nodes[i].as_deref().unwrap_or(&self.template),
            None => &self.dense.as_ref().expect("dense after fall-off").states[i],
        }
    }

    /// Mutable access to node `i`'s state, materializing it (with an
    /// exact template copy) if cold — the perturbation entry point: touch
    /// a node between ticks and activity spreads from it.
    pub fn state_mut(&mut self, i: usize) -> &mut NodeState {
        match &mut self.sparse {
            Some(core) => {
                if core.nodes[i].is_none() {
                    core.nodes[i] = Some(Box::new(self.template.clone()));
                    core.hot.insert(i);
                }
                core.nodes[i].as_deref_mut().expect("just materialized")
            }
            None => {
                &mut self.dense.as_mut().expect("dense after fall-off").states[i]
            }
        }
    }

    /// Attach (or detach) an observability recorder — forwarded to the
    /// dense engine once materialized; purely observational either way.
    pub fn set_obs(&mut self, obs: Option<Box<EngineObs>>) {
        match &mut self.dense {
            Some(eng) => eng.set_obs(obs),
            None => self.obs = obs,
        }
    }

    /// Detach and return the recorder, if any.
    pub fn take_obs(&mut self) -> Option<Box<EngineObs>> {
        match &mut self.dense {
            Some(eng) => eng.take_obs(),
            None => self.obs.take(),
        }
    }

    /// Borrow the attached recorder, if any.
    pub fn obs(&self) -> Option<&EngineObs> {
        match &self.dense {
            Some(eng) => eng.obs(),
            None => self.obs.as_deref(),
        }
    }

    /// One gossip tick at iteration `k`: the sparse fast path when every
    /// exactness precondition holds, otherwise the dense engine under
    /// [`ExecPolicy::Event`](super::ExecPolicy::Event) (materializing all
    /// nodes on the first such step).
    pub fn step(
        &mut self,
        k: u64,
        schedule: &Schedule,
        faults: Option<&FaultClock>,
        compress: Compression,
    ) {
        assert_eq!(schedule.n, self.n, "schedule sized for a different n");
        if self.sparse.is_some() {
            let fast = faults.is_none()
                && compress.is_identity()
                && self.delay == 0
                && self.halving_safe;
            match (fast, schedule.unit_permutation_shift(k)) {
                (true, Some(h)) => {
                    self.sparse_tick(k, h);
                    return;
                }
                _ => self.materialize_dense(),
            }
        }
        self.dense
            .as_mut()
            .expect("dense after fall-off")
            .step_compressed(k, schedule, faults, ExecPolicy::Event, compress);
    }

    /// The sparse tick under unit shift `h`: hot nodes emit and self-scale
    /// (phase 1), hot nodes with a cold in-neighbour absorb the template
    /// delta (phase 2 — evaluated before any materialization so coldness
    /// means cold *at tick start*, matching what the elided sender held),
    /// then queued shares deliver, materializing cold receivers (phase 3).
    /// Under a permutation every node has in-degree 1, so each hot node
    /// receives exactly one in-share — via phase 2 xor phase 3 — and the
    /// per-node operation order (scale, then add) is exactly the dense
    /// engine's.
    fn sparse_tick(&mut self, k: u64, h: usize) {
        let core = self.sparse.as_mut().expect("checked by caller");
        let (n, dim) = (self.n, self.dim);
        let wf = 0.5f32;
        let w_mix = 0.5f64;
        let obs_on = self.obs.is_some();
        let per_msg_bytes = if obs_on { (dim * 4) as u64 } else { 0 };
        let mut mark = if obs_on { Some(Instant::now()) } else { None };
        let sent0 = core.sent;

        // Phase 1 — every hot node emits its pre-weighted share and keeps
        // its self-loop half. Cold nodes' sends are the template fixed
        // point: elided entirely.
        for &i in &core.hot {
            let st = core.nodes[i].as_deref_mut().expect("hot nodes are materialized");
            let mut payload = take_buf(&mut core.pool, dim);
            for (p, v) in payload.iter_mut().zip(&st.x) {
                *p = v * wf;
            }
            let to = (i + h) % n;
            core.queue.push(k as f64, SparseShare { to, x: payload, w: st.w * w_mix });
            for v in st.x.iter_mut() {
                *v *= wf;
            }
            st.w *= w_mix;
            core.sent += 1;
            if let Some(o) = self.obs.as_deref_mut() {
                o.on_send(i, to, per_msg_bytes);
            }
        }
        let compute_ns = lap_ns(&mut mark);

        // Phase 2 — template deltas: a hot node whose in-neighbour is
        // still cold receives the elided sender's share `template · ½`.
        // Runs before phase 3 so receiver materializations this tick
        // cannot masquerade as hot senders.
        for &r in &core.hot {
            let s = (r + n - h) % n;
            if core.nodes[s].is_none() {
                let st = core.nodes[r].as_deref_mut().expect("hot nodes are materialized");
                for (a, t) in st.x.iter_mut().zip(&self.template.x) {
                    *a += t * wf;
                }
                st.w += self.template.w * w_mix;
            }
        }
        let merge_ns = lap_ns(&mut mark);

        // Phase 3 — deliver queued shares, materializing cold receivers
        // with a self-scaled template (the state the elided compute phase
        // would have left them in).
        while core.queue.peek_time().is_some_and(|t| t <= k as f64) {
            let share = core.queue.pop().expect("peeked event exists").payload;
            let j = share.to;
            if core.nodes[j].is_none() {
                let mut st = self.template.clone();
                for v in st.x.iter_mut() {
                    *v *= wf;
                }
                st.w *= w_mix;
                core.nodes[j] = Some(Box::new(st));
                core.hot.insert(j);
            }
            let st = core.nodes[j].as_deref_mut().expect("just ensured");
            for (a, b) in st.x.iter_mut().zip(&share.x) {
                *a += b;
            }
            st.w += share.w;
            core.pool.push(share.x);
        }
        if self.biased {
            // Cold nodes already sit at w = 1 (the template's weight), so
            // only hot weights need the reset.
            for &i in &core.hot {
                core.nodes[i].as_deref_mut().expect("hot nodes are materialized").w = 1.0;
            }
        }
        if let Some(o) = self.obs.as_deref_mut() {
            let aggregate_ns = lap_ns(&mut mark);
            let msgs = core.sent - sent0;
            o.on_round(&RoundRecord {
                k,
                msgs,
                dropped: 0,
                rescued: 0,
                wire_bytes: msgs * per_msg_bytes,
                bank_l1: 0.0,
                bank_w: 0.0,
                compute_ns,
                merge_ns,
                aggregate_ns,
                pool_wait_ns: 0,
            });
        }
    }

    /// Leave the fast path: materialize every node into a dense
    /// [`PushSumEngine`] (template for cold nodes, transplanted state for
    /// hot ones), carrying over the send counter and the recorder. The
    /// sparse queue is empty between ticks (delay 0), so nothing is in
    /// flight to migrate.
    fn materialize_dense(&mut self) {
        let core = self.sparse.take().expect("called only while sparse");
        debug_assert!(core.queue.is_empty(), "sparse queue drains within each tick");
        let mut weights: Vec<(usize, f64)> = Vec::with_capacity(core.hot.len());
        let mut init: Vec<Vec<f32>> = Vec::with_capacity(self.n);
        for (i, slot) in core.nodes.into_iter().enumerate() {
            match slot {
                Some(st) => {
                    weights.push((i, st.w));
                    init.push(st.x);
                }
                None => init.push(self.template.x.clone()),
            }
        }
        let mut eng = PushSumEngine::new(init, self.delay, self.biased);
        for (i, w) in weights {
            eng.states[i].w = w;
        }
        eng.sent_count = core.sent;
        eng.set_obs(self.obs.take());
        self.dense = Some(eng);
    }

    /// Capture a durable [`Snapshot`]. While the engine is on the sparse
    /// fast path this is the compact template + hot-set form
    /// ([`EngineKind::Sparse`] — O(hot · dim) bytes no matter how large
    /// `n` is, so a million-node simulation checkpoints in kilobytes);
    /// after the dense fall-off it is the dense engine's full snapshot
    /// with the kind rewritten to [`EngineKind::EventDense`], so a
    /// restore rebuilds an event engine rather than a bare
    /// [`PushSumEngine`]. `round` is the iteration the restored engine
    /// executes next.
    pub fn save(&self, round: u64) -> Snapshot {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => {
                // Between ticks the share queue is empty (the fast path
                // runs at delay 0), so template + hot set + send counter
                // is the complete state.
                debug_assert!(core.queue.is_empty(), "sparse queue drains per tick");
                let hot = core
                    .hot
                    .iter()
                    .filter_map(|&i| {
                        core.nodes[i]
                            .as_deref()
                            .map(|st| (i as u64, st.x.clone(), st.w))
                    })
                    .collect();
                Snapshot {
                    round,
                    kind: EngineKind::Sparse,
                    biased: self.biased,
                    n: self.n as u64,
                    dim: self.dim as u64,
                    delay: self.delay,
                    epoch: 0,
                    nodes: Vec::new(),
                    mail: Vec::new(),
                    banks: Vec::new(),
                    ledger: SnapLedger {
                        dropped_x: vec![0.0; self.dim],
                        ..SnapLedger::default()
                    },
                    rngs: Vec::new(),
                    sparse: Some(SnapSparse {
                        template_x: self.template.x.clone(),
                        template_w: self.template.w,
                        sent: core.sent,
                        hot,
                    }),
                }
            }
            (None, Some(eng)) => {
                let mut snap = eng.save(round);
                snap.kind = EngineKind::EventDense;
                snap
            }
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }

    /// Rebuild an event engine from a [`Snapshot`] captured by
    /// [`Self::save`]: the sparse form re-materializes exactly the saved
    /// hot set over the saved template (recomputing the halving-safety
    /// gate), the event-dense form wraps a restored dense core. Either
    /// way the restored engine continues **bit-identical** to the
    /// uninterrupted run (`rust/tests/snapshot_resume.rs`). A plain
    /// dense snapshot is a typed [`SnapshotError::EngineMismatch`].
    pub fn restore(snap: &Snapshot) -> Result<Self, SnapshotError> {
        match snap.kind() {
            EngineKind::Sparse => {
                let Some(sp) = snap.sparse.as_ref() else {
                    return Err(SnapshotError::Malformed(
                        "sparse snapshot missing its sparse section",
                    ));
                };
                let (n, dim) = (snap.n(), snap.dim());
                if sp.template_x.len() != dim {
                    return Err(SnapshotError::Malformed("template dimension mismatch"));
                }
                let mut eng = Self::with_template(
                    sp.template_x.clone(),
                    n,
                    snap.delay(),
                    snap.biased(),
                );
                eng.template.w = sp.template_w;
                // with_template's gate assumed w = 1; re-check against the
                // persisted weight.
                eng.halving_safe = eng.halving_safe && sp.template_w == 1.0;
                if let Some(core) = eng.sparse.as_mut() {
                    core.sent = sp.sent;
                    for (i, x, w) in &sp.hot {
                        let i = *i as usize;
                        if i >= n || x.len() != dim {
                            return Err(SnapshotError::Malformed(
                                "hot node outside engine shape",
                            ));
                        }
                        core.nodes[i] = Some(Box::new(NodeState { x: x.clone(), w: *w }));
                        core.hot.insert(i);
                    }
                }
                Ok(eng)
            }
            EngineKind::EventDense => {
                let dense = PushSumEngine::restore_parts(snap)?;
                Ok(Self {
                    n: dense.n,
                    dim: dense.dim,
                    delay: dense.delay,
                    biased: dense.biased,
                    template: NodeState::new(vec![0.0; dense.dim]),
                    halving_safe: false,
                    sparse: None,
                    dense: Some(dense),
                    obs: None,
                })
            }
            EngineKind::Dense => Err(SnapshotError::EngineMismatch(
                "EventEngine::restore requires a sparse or event-dense snapshot",
            )),
        }
    }

    /// Total mass `(Σᵢ xᵢ, Σᵢ wᵢ)` including in-flight mail — cold nodes
    /// contribute `n_cold · template` in one multiply per coordinate.
    /// Matches the dense engine's sum to f64 rounding (not bit-for-bit:
    /// the cold side is a product, not n_cold additions).
    pub fn total_mass(&self) -> (Vec<f64>, f64) {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => {
                let cold = (self.n - core.hot.len()) as f64;
                let mut xm: Vec<f64> =
                    self.template.x.iter().map(|&t| cold * t as f64).collect();
                let mut wm = cold * self.template.w;
                for &i in &core.hot {
                    let st = core.nodes[i].as_deref().expect("hot nodes are materialized");
                    for (a, b) in xm.iter_mut().zip(&st.x) {
                        *a += *b as f64;
                    }
                    wm += st.w;
                }
                for ev in core.queue.iter() {
                    for (a, b) in xm.iter_mut().zip(&ev.payload.x) {
                        *a += *b as f64;
                    }
                    wm += ev.payload.w;
                }
                (xm, wm)
            }
            (None, Some(eng)) => eng.total_mass(),
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }

    /// Total mass including recorded drop-ledger losses and compression
    /// banks — equal to [`Self::total_mass`] while sparse (the fast path
    /// never drops or banks).
    pub fn total_mass_with_losses(&self) -> (Vec<f64>, f64) {
        match &self.dense {
            Some(eng) => eng.total_mass_with_losses(),
            None => self.total_mass(),
        }
    }

    /// Mass recorded as lost to dropped messages — all zeros while sparse
    /// (the fast path cannot drop).
    pub fn dropped_mass(&self) -> (Vec<f64>, f64) {
        match &self.dense {
            Some(eng) => {
                let (x, w) = eng.dropped_mass();
                (x.to_vec(), w)
            }
            None => (vec![0.0; self.dim], 0.0),
        }
    }

    /// In-flight messages (0 between sparse ticks — the fast path drains
    /// its queue within every tick).
    pub fn in_flight(&self) -> usize {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => core.queue.len(),
            (None, Some(eng)) => eng.in_flight(),
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }

    /// Maximum staleness among in-flight messages relative to iteration
    /// `k` (0 between sparse ticks).
    pub fn max_staleness(&self, k: u64) -> u64 {
        match &self.dense {
            Some(eng) => eng.max_staleness(k),
            None => 0,
        }
    }

    /// Flush all in-flight state (a no-op while sparse: nothing is ever
    /// left in flight between ticks).
    pub fn drain(&mut self) {
        if let Some(eng) = &mut self.dense {
            eng.drain();
        }
    }

    /// Node-wise average of the numerators, `x̄ = (1/n) Σ xᵢ` — the cold
    /// block contributes `n_cold · template` in one multiply.
    pub fn mean_x(&self) -> Vec<f32> {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => {
                let cold = (self.n - core.hot.len()) as f64;
                let mut m: Vec<f64> =
                    self.template.x.iter().map(|&t| cold * t as f64).collect();
                for &i in &core.hot {
                    let st = core.nodes[i].as_deref().expect("hot nodes are materialized");
                    for (a, b) in m.iter_mut().zip(&st.x) {
                        *a += *b as f64;
                    }
                }
                let inv = 1.0 / self.n as f64;
                m.into_iter().map(|v| (v * inv) as f32).collect()
            }
            (None, Some(eng)) => eng.mean_x(),
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }

    /// Consensus statistics `(mean, min, max)` over nodes of
    /// ‖zᵢ − x̄‖₂ — the cold block's (identical) distance is computed
    /// once and weighted by the cold count, so the sparse form costs
    /// O(hot · dim) instead of O(n · dim).
    pub fn consensus_distance(&self) -> (f64, f64, f64) {
        match (&self.sparse, &self.dense) {
            (Some(core), _) => {
                let mean = self.mean_x();
                let dist = |st: &NodeState| -> f64 {
                    let inv = (1.0 / st.w) as f32;
                    st.x.iter()
                        .zip(&mean)
                        .map(|(x, m)| {
                            let e = (x * inv - m) as f64;
                            e * e
                        })
                        .sum::<f64>()
                        .sqrt()
                };
                let cold = self.n - core.hot.len();
                let (mut sum, mut min, mut max) = (0.0f64, f64::INFINITY, 0.0f64);
                if cold > 0 {
                    let d = dist(&self.template);
                    sum += cold as f64 * d;
                    min = min.min(d);
                    max = max.max(d);
                }
                for &i in &core.hot {
                    let st = core.nodes[i].as_deref().expect("hot nodes are materialized");
                    let d = dist(st);
                    sum += d;
                    min = min.min(d);
                    max = max.max(d);
                }
                (sum / self.n as f64, min, max)
            }
            (None, Some(eng)) => eng.consensus_distance(),
            (None, None) => unreachable!("engine is sparse or dense"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyKind;

    #[test]
    fn cold_graph_is_a_fixed_point_with_zero_materialization() {
        let n = 1 << 16;
        let mut eng = EventEngine::with_template(vec![0.25, -3.0, 7.5], n, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..32 {
            eng.step(k, &sched, None, Compression::Identity);
        }
        assert!(eng.is_sparse());
        assert_eq!(eng.materialized(), 0);
        assert_eq!(eng.sent_count(), 0);
        let (xm, wm) = eng.total_mass();
        assert_eq!(wm, n as f64);
        assert_eq!(xm[0], 0.25 * n as f64);
    }

    #[test]
    fn perturbation_spreads_one_edge_per_tick() {
        let n = 64;
        let mut eng = EventEngine::with_template(vec![0.0; 2], n, 0, false);
        let sched = Schedule::new(TopologyKind::Ring, n);
        eng.state_mut(5).x[0] = 1.0;
        assert_eq!(eng.materialized(), 1);
        for k in 0..4 {
            eng.step(k, &sched, None, Compression::Identity);
        }
        // A ring spreads activity to exactly one new node per tick.
        assert_eq!(eng.materialized(), 5);
        // Mass is conserved exactly: one unit of numerator, n of weight.
        let (xm, wm) = eng.total_mass();
        assert!((xm[0] - 1.0).abs() < 1e-12, "{xm:?}");
        assert!((wm - n as f64).abs() < 1e-12);
    }

    #[test]
    fn subnormal_template_declines_the_fast_path() {
        // The smallest subnormal: halving it rounds to zero (ties-to-even),
        // so ½-split-and-recombine loses the value entirely. Note most
        // subnormals *do* halve exactly — only the odd-mantissa ones lose a
        // bit — which is why the gate tests the roundtrip rather than
        // `is_normal()`.
        let odd_subnormal = f32::from_bits(1);
        let h = odd_subnormal * 0.5f32;
        assert!(h + h != odd_subnormal, "test premise");
        let mut eng = EventEngine::with_template(vec![odd_subnormal], 8, 0, false);
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        eng.step(0, &sched, None, Compression::Identity);
        assert!(!eng.is_sparse(), "subnormal halving is inexact — must go dense");
    }

    #[test]
    fn sparse_snapshot_roundtrips_and_resumes_bit_identically() {
        let n = 1 << 12;
        let mut live = EventEngine::with_template(vec![0.5f32, -1.0], n, 0, false);
        let sched = Schedule::new(TopologyKind::Ring, n);
        live.state_mut(7).x[0] = 3.0;
        live.state_mut(99).x[1] = -2.0;
        for k in 0..6 {
            live.step(k, &sched, None, Compression::Identity);
        }
        assert!(live.is_sparse());
        let bytes = live.save(6).to_bytes();
        // The sparse form is O(hot), not O(n): a few hot nodes of a
        // 4096-node engine fit well under a kilobyte.
        assert!(bytes.len() < 1024, "sparse snapshot is compact: {}", bytes.len());
        let snap = Snapshot::from_bytes(&bytes).unwrap();
        let mut back = EventEngine::restore(&snap).unwrap();
        assert!(back.is_sparse());
        assert_eq!(back.materialized(), live.materialized());
        assert_eq!(back.sent_count(), live.sent_count());
        for k in 6..20 {
            live.step(k, &sched, None, Compression::Identity);
            back.step(k, &sched, None, Compression::Identity);
        }
        assert_eq!(live.materialized(), back.materialized());
        for i in 0..n {
            let (a, b) = (live.node_state(i), back.node_state(i));
            assert_eq!(a.x, b.x, "node {i}");
            assert_eq!(a.w.to_bits(), b.w.to_bits(), "node {i}");
        }
    }

    #[test]
    fn event_dense_snapshot_restores_an_event_engine() {
        use crate::rng::Pcg;
        let mut rng = Pcg::new(61);
        let init: Vec<Vec<f32>> = (0..10).map(|_| rng.gaussian_vec(6)).collect();
        let mut live = EventEngine::from_init(init, 1, false);
        let sched = Schedule::new(TopologyKind::TwoPeerExp, 10);
        for k in 0..9 {
            live.step(k, &sched, None, Compression::Identity);
        }
        let snap = Snapshot::from_bytes(&live.save(9).to_bytes()).unwrap();
        assert_eq!(snap.kind(), crate::snapshot::EngineKind::EventDense);
        let mut back = EventEngine::restore(&snap).unwrap();
        assert!(!back.is_sparse(), "event-dense restores into the dense hatch");
        for k in 9..25 {
            live.step(k, &sched, None, Compression::Identity);
            back.step(k, &sched, None, Compression::Identity);
        }
        for i in 0..10 {
            let (a, b) = (live.node_state(i), back.node_state(i));
            assert_eq!(a.x, b.x);
            assert_eq!(a.w.to_bits(), b.w.to_bits());
        }
    }

    #[test]
    fn non_permutation_schedule_materializes() {
        let mut eng = EventEngine::with_template(vec![1.0; 4], 16, 0, false);
        let sched = Schedule::new(TopologyKind::TwoPeerExp, 16);
        eng.step(0, &sched, None, Compression::Identity);
        assert!(!eng.is_sparse());
        assert_eq!(eng.materialized(), 16);
    }
}

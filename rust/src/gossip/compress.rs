//! Message compression for push-sum gossip: top-k sparsification and
//! stochastic b-bit quantization with **per-edge error-feedback
//! residuals** (the GossipGraD / GoSGD axis — communication-efficient
//! gossip exchange).
//!
//! A [`Compression`] spec describes how the pre-weighted numerator share
//! `x · w_mix` of one push-sum message is encoded before it goes on the
//! wire. The scalar push-sum weight is never *lossily* encoded (8 exact
//! bytes against megabytes of payload) — but it is **split** in
//! proportion to the numerator mass actually delivered, so each
//! message's `(x, w)` pair stays self-consistent and the de-biasing
//! `z = x / w` survives aggressive sparsification (see below).
//!
//! # Error feedback, and why the push-sum weight must trickle with it
//!
//! Compressing a share discards numerator mass; dropping it on the floor
//! would break the Σx conservation law the engine's proptests pin. Every
//! directed edge `(i → j)` therefore carries a bank `(r_{ij}, ρ_{ij})` of
//! withheld numerator *and* withheld push-sum weight:
//!
//! ```text
//! acc   = payload + r_ij            # numerator the edge owes
//! acc_w = w_share + ρ_ij            # weight the edge owes
//! c     = C(acc)                    # top-k / quantized encoding
//! φ     = min(1, ‖c‖₁ / ‖acc‖₁)     # fraction of the mass delivered
//! send (c, φ·acc_w); bank r_ij ← acc − c, ρ_ij ← (1 − φ)·acc_w
//! ```
//!
//! The φ-split is what makes *aggressive* sparsification compatible with
//! de-biasing: a top-k message at 1/16 density ships ~a fraction of the
//! numerator share — if the full weight share rode along anyway, every
//! receiver's `z = x / w` would collapse toward zero and consensus
//! diverges (measurably: ~50× the dense consensus error in this repo's
//! harness). Splitting `w` in proportion to the delivered ℓ1 mass keeps
//! each message's `(x, w)` pair self-consistent; the banked remainder is
//! exactly a **virtual delayed node** in the push-sum sense — mass that
//! joins the mix a few rounds late, which push-sum provably tolerates.
//!
//! The classic EF recursion then guarantees mass is *delayed*, never
//! lost: `Σ states + Σ in-flight + Σ banks (+ ledgered drops)` is
//! invariant for both Σx and Σw, and
//! [`crate::gossip::PushSumEngine::drain`] re-absorbs outstanding banks
//! at the sender so end-of-run metrics account for every unit of mass.
//!
//! # Determinism
//!
//! Top-k selection is a pure function of the accumulated share (ties
//! broken by ascending coordinate via `total_cmp`), and the stochastic
//! quantization draws come from a [`Pcg`] stream keyed by
//! `(iteration, from, to)` only — never by call order — so the sequential
//! and sharded engines produce bit-identical results at a fixed seed
//! (`rust/tests/engine_equivalence.rs` extends the contract to
//! compression; see ARCHITECTURE.md §2).
//!
//! # Wire format (byte accounting)
//!
//! [`Compression::encoded_bytes`] is what the timing layer charges:
//!
//! * top-k — per kept coordinate one fp32 value plus a bit-packed index of
//!   `⌈log2 dim⌉` bits, plus an 8-byte header (count + scale);
//! * qsgd — `b` bits per coordinate (sign + magnitude level) packed,
//!   plus an 8-byte header carrying the fp32 norm scale;
//! * identity — the dense payload, unchanged.
//!
//! The byte count is a pure function of `(scheme, dim, full_bytes)` —
//! independent of the values — so makespans stay deterministic.

use crate::rng::Pcg;

/// Fixed per-message header: element count / scale factor the decoder
/// needs (8 bytes for every non-identity scheme).
const HEADER_BYTES: usize = 8;

/// How one push-sum message payload is encoded on the wire.
///
/// ```
/// use sgp::gossip::Compression;
///
/// let topk = Compression::parse("topk:16").unwrap();
/// let q4 = Compression::parse("qsgd:4").unwrap();
/// // 100 MiB dense message over 22k logical coordinates:
/// let full = 100 << 20;
/// assert!(full / topk.encoded_bytes(22_026, full) >= 8, "≥8× reduction");
/// assert!(full / q4.encoded_bytes(22_026, full) >= 7);
/// assert_eq!(Compression::Identity.encoded_bytes(22_026, full), full);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Compression {
    /// No compression: the dense fp32 payload ships as-is (the default).
    #[default]
    Identity,
    /// Top-k sparsification: keep the `⌈dim / den⌉` largest-magnitude
    /// coordinates of the accumulated share (density `1/den`), ship them
    /// as bit-packed `(index, value)` pairs.
    TopK {
        /// Density denominator: keep 1-in-`den` coordinates (≥ 1).
        den: u32,
    },
    /// QSGD-style stochastic `bits`-bit quantization: each coordinate is
    /// rounded to one of `2^(bits−1) − 1` magnitude levels of the share's
    /// ∞-norm plus a sign, randomly up or down so the expectation is
    /// exact. Sign + magnitude together fit the advertised `bits` per
    /// coordinate exactly (`2·(2^(bits−1) − 1) + 1 < 2^bits` symbols), so
    /// the byte accounting never undercounts the alphabet.
    Qsgd {
        /// Bits per coordinate, sign included (2..=16).
        bits: u8,
    },
}

impl Compression {
    /// Parse a CLI spec: `none`/`identity`, `topk:D` (keep 1-in-D
    /// coordinates) or `qsgd:B` (B bits per coordinate).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" | "identity" | "off" => return Some(Self::Identity),
            _ => {}
        }
        let (scheme, arg) = s.split_once(':')?;
        match scheme {
            "topk" => {
                let den: u32 = arg.parse().ok()?;
                (den >= 1).then_some(Self::TopK { den })
            }
            "qsgd" => {
                let bits: u8 = arg.parse().ok()?;
                // ≥ 2: one bit is the sign, so at least one magnitude bit
                // must remain.
                (2..=16).contains(&bits).then_some(Self::Qsgd { bits })
            }
            _ => None,
        }
    }

    /// Short human label (`"none"`, `"topk:16"`, `"qsgd:4"`).
    pub fn label(&self) -> String {
        match *self {
            Self::Identity => "none".to_string(),
            Self::TopK { den } => format!("topk:{den}"),
            Self::Qsgd { bits } => format!("qsgd:{bits}"),
        }
    }

    /// Whether this spec is the identity (fast-path check: no residuals,
    /// no per-edge work).
    pub fn is_identity(&self) -> bool {
        matches!(self, Self::Identity)
    }

    /// Stable `(tag, arg)` pair identifying this scheme in the deployment
    /// wire header ([`crate::net::cluster::wire`]): `0` = identity,
    /// `1` = top-k (arg = density denominator), `2` = qsgd (arg = bits).
    /// The inverse is [`Self::from_wire_tag`].
    pub fn wire_tag(&self) -> (u8, u32) {
        match *self {
            Self::Identity => (0, 0),
            Self::TopK { den } => (1, den),
            Self::Qsgd { bits } => (2, bits as u32),
        }
    }

    /// Decode a wire-header `(tag, arg)` pair back into a spec, enforcing
    /// the same argument bounds as [`Self::parse`]; `None` for unknown
    /// tags or out-of-range arguments (a decoder must treat that as a
    /// malformed frame, never trust it).
    pub fn from_wire_tag(tag: u8, arg: u32) -> Option<Self> {
        match tag {
            0 => Some(Self::Identity),
            1 => (arg >= 1).then_some(Self::TopK { den: arg }),
            2 => u8::try_from(arg)
                .ok()
                .filter(|b| (2..=16).contains(b))
                .map(|bits| Self::Qsgd { bits }),
            _ => None,
        }
    }

    /// Coordinates kept per message for a `dim`-element share (top-k
    /// density rounded up, never below 1; `dim` for the dense schemes).
    pub fn kept(&self, dim: usize) -> usize {
        match *self {
            Self::TopK { den } => dim.div_ceil(den as usize).max(1).min(dim),
            _ => dim,
        }
    }

    /// On-wire bytes of one message whose dense fp32 payload is
    /// `full_bytes` over `dim` logical coordinates. Pure function of the
    /// spec — values never change the size, so timing stays
    /// deterministic. `full_bytes` is the simulator's model-scale message
    /// size; the encoded size scales it by the scheme's bits-per-
    /// coordinate ratio (32 bits dense).
    pub fn encoded_bytes(&self, dim: usize, full_bytes: usize) -> usize {
        let d = dim.max(1) as u128;
        match *self {
            Self::Identity => full_bytes,
            Self::TopK { .. } => {
                let k = self.kept(dim.max(1)) as u128;
                // Bit-packed index: ⌈log2 dim⌉ bits (min 1) + fp32 value.
                let idx_bits = (u128::BITS - (d - 1).max(1).leading_zeros()).max(1) as u128;
                let num = full_bytes as u128 * k * (32 + idx_bits);
                HEADER_BYTES + (num.div_ceil(d * 32)) as usize
            }
            Self::Qsgd { bits } => {
                let num = full_bytes as u128 * bits as u128;
                HEADER_BYTES + (num.div_ceil(32)) as usize
            }
        }
    }

    /// Dense-to-encoded byte ratio for one message (≥ 1 means smaller on
    /// the wire) — the "reduction" column of `repro compress-sweep`.
    pub fn reduction(&self, dim: usize, full_bytes: usize) -> f64 {
        full_bytes as f64 / self.encoded_bytes(dim, full_bytes).max(1) as f64
    }

    /// The deterministic RNG stream for edge `(from → to)` at iteration
    /// `k` — keyed by coordinates only, never call order, so any shard
    /// count replays the same quantization noise.
    fn edge_rng(k: u64, from: usize, to: usize) -> Pcg {
        Pcg::with_stream(
            0xc0de_c0de ^ k.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            (((from as u64) << 32) | to as u64).wrapping_mul(2).wrapping_add(1),
        )
    }

    /// Apply error-feedback compression to one edge's pre-weighted
    /// `(x, w)` share in place: the numerator becomes the encoded
    /// `C(payload + bank.x)`, the weight share becomes the ℓ1-
    /// proportional fraction `φ · (msg_w + bank.w)`, and the bank keeps
    /// the remainders (the module-level recursion). `idx` is reusable
    /// scratch for the top-k selection. Identity is a no-op (bank
    /// untouched).
    #[allow(clippy::too_many_arguments)] // one hot-path call site, flat args beat a builder
    pub(crate) fn apply(
        &self,
        payload: &mut [f32],
        msg_w: &mut f64,
        bank: &mut EdgeBank,
        idx: &mut Vec<u32>,
        k: u64,
        from: usize,
        to: usize,
    ) {
        if self.is_identity() {
            return;
        }
        debug_assert_eq!(payload.len(), bank.x.len());
        // acc ← payload + banked residual (what this edge owes).
        for (p, r) in payload.iter_mut().zip(bank.x.iter()) {
            *p += r;
        }
        let acc_l1: f64 = payload.iter().map(|v| v.abs() as f64).sum();
        match *self {
            Self::Identity => unreachable!("identity handled above"),
            Self::TopK { .. } => {
                let dim = payload.len();
                let kk = self.kept(dim);
                if kk >= dim {
                    bank.x.fill(0.0);
                } else {
                    idx.clear();
                    idx.extend(0..dim as u32);
                    // Unique partition: strict total order (|v| desc,
                    // index asc) makes the kept set a pure function of
                    // the values.
                    idx.select_nth_unstable_by(kk - 1, |&a, &b| {
                        payload[b as usize]
                            .abs()
                            .total_cmp(&payload[a as usize].abs())
                            .then(a.cmp(&b))
                    });
                    for &i in &idx[kk..] {
                        bank.x[i as usize] = payload[i as usize];
                        payload[i as usize] = 0.0;
                    }
                    for &i in &idx[..kk] {
                        bank.x[i as usize] = 0.0;
                    }
                }
            }
            Self::Qsgd { bits } => {
                let scale = payload.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                if scale > 0.0 && scale.is_finite() {
                    // Sign + magnitude fit `bits` exactly; parse enforces
                    // bits ≥ 2 (≥ 1 magnitude level), and the clamp keeps
                    // directly-constructed degenerate specs panic-free.
                    let levels =
                        ((1u32 << bits.saturating_sub(1)) - 1).max(1) as f32;
                    let mut rng = Self::edge_rng(k, from, to);
                    for (p, r) in payload.iter_mut().zip(bank.x.iter_mut()) {
                        let acc = *p;
                        let t = acc.abs() / scale * levels;
                        let low = t.floor();
                        let up = (rng.f64() as f32) < (t - low);
                        let q = (low + up as u32 as f32) / levels * scale;
                        let qv = if acc < 0.0 { -q } else { q };
                        *p = qv;
                        *r = acc - qv;
                    }
                } else {
                    // All-zero (or degenerate) share: ships as zeros.
                    bank.x.fill(0.0);
                }
            }
        }
        // φ-split of the weight share: deliver the fraction of ℓ1 mass
        // the encoded numerator actually carries, bank the rest. An
        // all-zero share delivers the full weight (nothing to pair with).
        let sent_l1: f64 = payload.iter().map(|v| v.abs() as f64).sum();
        let phi = if acc_l1 > 0.0 { (sent_l1 / acc_l1).min(1.0) } else { 1.0 };
        let acc_w = *msg_w + bank.w;
        *msg_w = acc_w * phi;
        bank.w = acc_w * (1.0 - phi);
    }
}

/// Per-edge error-feedback bank: the withheld numerator residual plus the
/// withheld push-sum-weight mass (the φ-split remainder) — the "virtual
/// delayed node" of the module docs. Owned by the sender; shards with the
/// node states.
#[derive(Clone, Debug)]
pub(crate) struct EdgeBank {
    /// Withheld numerator mass per coordinate.
    pub x: Vec<f32>,
    /// Withheld push-sum-weight mass (≥ 0).
    pub w: f64,
}

impl EdgeBank {
    /// An empty bank for a `dim`-coordinate edge.
    pub fn new(dim: usize) -> Self {
        Self { x: vec![0.0; dim], w: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects_garbage() {
        assert_eq!(Compression::parse("none"), Some(Compression::Identity));
        assert_eq!(Compression::parse("identity"), Some(Compression::Identity));
        assert_eq!(
            Compression::parse("topk:16"),
            Some(Compression::TopK { den: 16 })
        );
        assert_eq!(
            Compression::parse("qsgd:4"),
            Some(Compression::Qsgd { bits: 4 })
        );
        assert_eq!(Compression::parse("topk:0"), None);
        assert_eq!(Compression::parse("qsgd:0"), None);
        assert_eq!(
            Compression::parse("qsgd:1"),
            None,
            "1 bit leaves no room for a magnitude next to the sign"
        );
        assert_eq!(Compression::parse("qsgd:17"), None);
        assert_eq!(Compression::parse("zip:9"), None);
        assert_eq!(Compression::parse("topk"), None);
        assert_eq!(Compression::parse("topk:x"), None);
        assert_eq!(Compression::TopK { den: 16 }.label(), "topk:16");
        assert_eq!(Compression::parse("topk:16").unwrap().label(), "topk:16");
    }

    #[test]
    fn wire_tags_roundtrip_and_reject_bad_args() {
        for spec in [
            Compression::Identity,
            Compression::TopK { den: 1 },
            Compression::TopK { den: 4096 },
            Compression::Qsgd { bits: 2 },
            Compression::Qsgd { bits: 16 },
        ] {
            let (tag, arg) = spec.wire_tag();
            assert_eq!(Compression::from_wire_tag(tag, arg), Some(spec));
        }
        assert_eq!(Compression::from_wire_tag(3, 0), None, "unknown tag");
        assert_eq!(Compression::from_wire_tag(1, 0), None, "topk den 0");
        assert_eq!(Compression::from_wire_tag(2, 1), None, "qsgd 1 bit");
        assert_eq!(Compression::from_wire_tag(2, 17), None, "qsgd 17 bits");
        assert_eq!(Compression::from_wire_tag(2, 1 << 20), None);
    }

    #[test]
    fn encoded_bytes_hit_the_advertised_ratios() {
        let full = 100 << 20;
        // topk:16 over a 15-bit index space: 1/16 of the coords at
        // (32 + 15)/32 bits each → ≈ 10.9× smaller.
        let topk = Compression::TopK { den: 16 };
        assert!(topk.reduction(22_026, full) >= 8.0, "{}", topk.reduction(22_026, full));
        // qsgd:4 → 4/32 bits per coord → ≈ 8× minus the header.
        let q4 = Compression::Qsgd { bits: 4 };
        let r = q4.reduction(22_026, full);
        assert!(r > 7.99 && r <= 8.0, "{r}");
        assert_eq!(Compression::Identity.encoded_bytes(8, 1234), 1234);
        // Monotone in aggressiveness.
        assert!(
            Compression::TopK { den: 32 }.encoded_bytes(1024, full)
                < Compression::TopK { den: 4 }.encoded_bytes(1024, full)
        );
        assert!(
            Compression::Qsgd { bits: 2 }.encoded_bytes(1024, full)
                < Compression::Qsgd { bits: 8 }.encoded_bytes(1024, full)
        );
        // Tiny dims never underflow or return zero.
        assert!(Compression::TopK { den: 16 }.encoded_bytes(1, 4) > 0);
    }

    #[test]
    fn topk_keeps_largest_and_banks_the_rest_with_weight_split() {
        let spec = Compression::TopK { den: 2 }; // keep 2 of 4
        let mut payload = vec![1.0f32, -4.0, 0.5, 3.0];
        let mut msg_w = 0.5f64;
        let mut bank = EdgeBank::new(4);
        let mut idx = Vec::new();
        spec.apply(&mut payload, &mut msg_w, &mut bank, &mut idx, 0, 0, 1);
        assert_eq!(payload, vec![0.0, -4.0, 0.0, 3.0]);
        assert_eq!(bank.x, vec![1.0, 0.0, 0.5, 0.0]);
        // φ = delivered ℓ1 / total ℓ1 = 7 / 8.5; the weight splits with it.
        let phi = 7.0 / 8.5;
        assert!((msg_w - 0.5 * phi).abs() < 1e-12, "{msg_w}");
        assert!((bank.w - 0.5 * (1.0 - phi)).abs() < 1e-12, "{}", bank.w);
        // Next round: banked x and w ride along; full delivery empties both.
        let mut payload2 = vec![0.9f32, 0.0, 0.6, 0.0];
        let mut msg_w2 = 0.5f64;
        spec.apply(&mut payload2, &mut msg_w2, &mut bank, &mut idx, 1, 0, 1);
        assert_eq!(payload2, vec![1.9, 0.0, 1.1, 0.0]);
        assert_eq!(bank.x, vec![0.0; 4]);
        assert!((msg_w2 - (0.5 + 0.5 * (1.0 - phi))).abs() < 1e-12);
        assert_eq!(bank.w, 0.0);
    }

    #[test]
    fn topk_ties_break_by_ascending_index() {
        let spec = Compression::TopK { den: 4 }; // keep 1 of 4
        let mut payload = vec![2.0f32, -2.0, 2.0, 2.0];
        let mut msg_w = 1.0f64;
        let mut bank = EdgeBank::new(4);
        let mut idx = Vec::new();
        spec.apply(&mut payload, &mut msg_w, &mut bank, &mut idx, 3, 1, 2);
        assert_eq!(payload, vec![2.0, 0.0, 0.0, 0.0], "lowest index wins the tie");
    }

    #[test]
    fn error_feedback_conserves_x_and_w_mass_exactly() {
        // payload + bank is redistributed, never created or destroyed:
        // sent + banked == accumulated for both x and w, both schemes,
        // every round.
        for spec in [Compression::TopK { den: 8 }, Compression::Qsgd { bits: 3 }] {
            let mut rng = Pcg::new(7);
            let mut bank = EdgeBank::new(64);
            let mut idx = Vec::new();
            for k in 0..20u64 {
                let payload0 = rng.gaussian_vec(64);
                let mut payload = payload0.clone();
                let acc: Vec<f32> = payload0
                    .iter()
                    .zip(&bank.x)
                    .map(|(a, b)| a + b)
                    .collect();
                let w0 = 0.5f64;
                let acc_w = w0 + bank.w;
                let mut msg_w = w0;
                spec.apply(&mut payload, &mut msg_w, &mut bank, &mut idx, k, 2, 5);
                for ((c, r), a) in payload.iter().zip(&bank.x).zip(&acc) {
                    assert!((c + r - a).abs() < 1e-5, "{spec:?} k={k}: {c}+{r} != {a}");
                }
                assert!(
                    (msg_w + bank.w - acc_w).abs() < 1e-12,
                    "{spec:?} k={k}: w mass {} + {} != {acc_w}",
                    msg_w,
                    bank.w
                );
                assert!(msg_w >= 0.0 && bank.w >= 0.0, "{spec:?} k={k}: w signs");
            }
        }
    }

    #[test]
    fn qsgd_is_deterministic_per_edge_and_unbiased_in_expectation() {
        let spec = Compression::Qsgd { bits: 3 };
        let src = vec![0.3f32, -0.7, 1.0, 0.05];
        let run = |k: u64, from: usize, to: usize| {
            let mut p = src.clone();
            let mut w = 1.0f64;
            let mut bank = EdgeBank::new(4);
            spec.apply(&mut p, &mut w, &mut bank, &mut Vec::new(), k, from, to);
            p
        };
        assert_eq!(run(4, 1, 3), run(4, 1, 3), "same edge ⇒ same bits");
        // The rounding is stochastic per (iteration, edge): over a window
        // of iterations the draws must differ somewhere (a single pair of
        // rounds can coincide by chance on a 4-coordinate share).
        assert!(
            (0..20).any(|k| run(k, 1, 3) != run(k + 100, 1, 3)),
            "iteration must change the draw"
        );
        // Unbiasedness: averaging the quantized share over many edges
        // approaches the source (the stochastic-rounding property EF
        // relies on to flush residuals instead of accumulating bias).
        let mut mean = vec![0.0f64; 4];
        let n = 4000;
        for e in 0..n {
            for (m, v) in mean.iter_mut().zip(run(0, e, e + 1)) {
                *m += v as f64 / n as f64;
            }
        }
        for (m, s) in mean.iter().zip(&src) {
            assert!((m - *s as f64).abs() < 0.02, "{m} vs {s}");
        }
    }

    #[test]
    fn identity_is_a_true_noop() {
        let mut payload = vec![1.0f32, 2.0];
        let mut msg_w = 0.25f64;
        let mut bank = EdgeBank { x: vec![9.0, 9.0], w: 0.125 };
        Compression::Identity.apply(
            &mut payload,
            &mut msg_w,
            &mut bank,
            &mut Vec::new(),
            0,
            0,
            1,
        );
        assert_eq!(payload, vec![1.0, 2.0]);
        assert_eq!(msg_w, 0.25);
        assert_eq!(bank.x, vec![9.0, 9.0]);
        assert_eq!(bank.w, 0.125);
    }

    #[test]
    fn degenerate_shares_ship_full_weight_and_never_panic() {
        for spec in [Compression::Qsgd { bits: 4 }, Compression::TopK { den: 4 }] {
            let mut payload = vec![0.0f32; 8];
            let mut msg_w = 0.5f64;
            let mut bank = EdgeBank::new(8);
            bank.w = 0.25;
            spec.apply(&mut payload, &mut msg_w, &mut bank, &mut Vec::new(), 0, 0, 1);
            assert!(payload.iter().all(|v| *v == 0.0), "{spec:?}");
            // Nothing to pair the weight with: deliver all of it (the
            // banked remainder included) instead of stranding it.
            assert_eq!(msg_w, 0.75, "{spec:?}");
            assert_eq!(bank.w, 0.0, "{spec:?}");
        }
    }
}

//! Execution policy for the sharded gossip round: how many worker shards
//! the per-node state is partitioned across when a round executes.
//!
//! The parallel engine exists to serve the paper's own scaling argument —
//! SGP's interesting regimes are dozens-to-thousands of workers, and a
//! serial per-node loop caps simulated N long before the algorithm does.
//! The policy is deliberately *only* a degree-of-parallelism knob: the
//! round semantics (what every node computes, in which order messages are
//! delivered and aggregated) are fixed by the engine's sharded round
//! protocol (compute+send → ordered merge → aggregate),
//! so any policy produces **bit-identical** results at a fixed seed (see
//! ARCHITECTURE.md §Determinism and
//! [`crate::gossip::PushSumEngine::step_exec`]).

/// Degree of parallelism for one engine round.
///
/// `Sequential` is the classic single-thread loop; `Parallel { shards }`
/// partitions the nodes into `shards` contiguous ranges executed on the
/// persistent worker pool ([`crate::runtime::pool`]), with a
/// deterministic ordered merge between the compute and aggregate phases.
/// Both produce identical bits:
///
/// ```
/// use sgp::gossip::{ExecPolicy, PushSumEngine};
/// use sgp::topology::{Schedule, TopologyKind};
///
/// let init: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32; 8]).collect();
/// let sched = Schedule::new(TopologyKind::OnePeerExp, 16);
/// let mut seq = PushSumEngine::new(init.clone(), 1, false);
/// let mut par = PushSumEngine::new(init, 1, false);
/// for k in 0..12 {
///     seq.step_exec(k, &sched, None, ExecPolicy::Sequential);
///     par.step_exec(k, &sched, None, ExecPolicy::parallel(4));
/// }
/// for (a, b) in seq.states.iter().zip(&par.states) {
///     assert_eq!(a.x, b.x);
///     assert_eq!(a.w, b.w);
/// }
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecPolicy {
    /// One shard, executed inline on the calling thread (the default).
    #[default]
    Sequential,
    /// Partition state across `shards` contiguous node ranges, executed on
    /// the **persistent worker pool** ([`crate::runtime::pool`]): shard
    /// `s` is pinned to worker `s mod W`, and a round costs one barrier
    /// handoff instead of fresh thread spawns. `shards ≤ 1` degenerates to
    /// sequential.
    ///
    /// The handoff is cheap but not free; pick a shard count whose
    /// per-shard work (≈ `n·dim / shards` elements) dwarfs it —
    /// `repro engine-sweep` measures exactly this tradeoff (with a
    /// `--threads` axis for the pool size), and small-N/small-dim
    /// configurations are often fastest sequential.
    Parallel {
        /// Number of state shards (clamped to ≥ 1 and to the node count).
        shards: usize,
    },
    /// Event-driven arrivals: sends are scheduled on a priority queue
    /// ([`crate::sim::EventQueue`]) keyed by delivery iteration and popped
    /// in (time, sequence) order, and only nodes with pending arrivals do
    /// aggregation work in a round. Runs inline on the calling thread and
    /// produces **bit-identical** results to [`ExecPolicy::Sequential`] at
    /// any N (the dense-identity contract of
    /// [`crate::gossip::event_engine`], locked by
    /// `tests/event_engine_equivalence.rs`).
    Event,
}

impl ExecPolicy {
    /// A parallel policy with `shards` workers (0 and 1 mean sequential).
    pub fn parallel(shards: usize) -> Self {
        if shards <= 1 {
            Self::Sequential
        } else {
            Self::Parallel { shards }
        }
    }

    /// A parallel policy sized to the machine: one shard per available
    /// hardware thread (sequential when parallelism cannot be queried).
    pub fn auto() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        Self::parallel(threads)
    }

    /// The configured shard count (1 for [`ExecPolicy::Sequential`]).
    pub fn shards(&self) -> usize {
        match self {
            Self::Sequential | Self::Event => 1,
            Self::Parallel { shards } => (*shards).max(1),
        }
    }

    /// Shard count actually used for `n` nodes: never more shards than
    /// nodes, never fewer than one.
    pub fn shards_for(&self, n: usize) -> usize {
        self.shards().min(n.max(1))
    }

    /// Parse a CLI engine name: `sequential`/`seq`, `parallel`/`par`, or
    /// `event`/`ev`. `shards = 0` asks for the machine-sized default in
    /// parallel mode (ignored for the other modes).
    pub fn parse(engine: &str, shards: usize) -> Option<Self> {
        match engine {
            "sequential" | "seq" => Some(Self::Sequential),
            "parallel" | "par" => Some(if shards == 0 {
                Self::auto()
            } else {
                Self::parallel(shards)
            }),
            "event" | "ev" => Some(Self::Event),
            _ => None,
        }
    }

    /// Short human label (`"sequential"`, `"parallel×K"`, or `"event"`).
    pub fn label(&self) -> String {
        match self {
            Self::Sequential => "sequential".to_string(),
            Self::Parallel { shards } => format!("parallel×{shards}"),
            Self::Event => "event".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_clamps_to_sequential() {
        assert_eq!(ExecPolicy::parallel(0), ExecPolicy::Sequential);
        assert_eq!(ExecPolicy::parallel(1), ExecPolicy::Sequential);
        assert_eq!(
            ExecPolicy::parallel(4),
            ExecPolicy::Parallel { shards: 4 }
        );
    }

    #[test]
    fn shards_for_never_exceeds_nodes() {
        let p = ExecPolicy::parallel(8);
        assert_eq!(p.shards_for(3), 3);
        assert_eq!(p.shards_for(100), 8);
        assert_eq!(ExecPolicy::Sequential.shards_for(100), 1);
        assert_eq!(p.shards_for(0), 1);
    }

    #[test]
    fn parse_cli_names() {
        assert_eq!(
            ExecPolicy::parse("sequential", 0),
            Some(ExecPolicy::Sequential)
        );
        assert_eq!(
            ExecPolicy::parse("parallel", 7),
            Some(ExecPolicy::Parallel { shards: 7 })
        );
        assert!(ExecPolicy::parse("parallel", 0).is_some());
        assert_eq!(ExecPolicy::parse("event", 0), Some(ExecPolicy::Event));
        assert_eq!(ExecPolicy::parse("ev", 4), Some(ExecPolicy::Event));
        assert_eq!(ExecPolicy::parse("nope", 2), None);
        assert_eq!(ExecPolicy::parallel(3).label(), "parallel×3");
        assert_eq!(ExecPolicy::Event.label(), "event");
        assert_eq!(ExecPolicy::Event.shards(), 1);
        assert_eq!(ExecPolicy::Event.shards_for(100), 1);
    }
}

//! # sgp — Stochastic Gradient Push for Distributed Deep Learning
//!
//! A from-scratch reproduction of Assran et al., ICML 2019, as the L3
//! coordinator of a three-layer Rust + JAX + Pallas stack. The library
//! provides:
//!
//! * [`topology`] — communication graphs (directed exponential, bipartite,
//!   complete, …), time-varying schedules and column-stochastic mixing
//!   matrices, plus spectral tools (λ₂ of mixing products, Appendix A).
//! * [`gossip`] — the PushSum engine: per-node `(x, w)` state, delayed
//!   message buffers (τ-Overlap SGP), the biased variant, and
//!   mass-conservation accounting — with a sharded parallel execution
//!   engine ([`gossip::ExecPolicy`]) that is bit-identical to the
//!   sequential loop at a fixed seed (see ARCHITECTURE.md), and pluggable
//!   message compression ([`gossip::Compression`]: top-k / stochastic
//!   quantization with per-edge error-feedback residuals).
//! * [`collectives`] — the exact-averaging substrate (ring AllReduce) with
//!   its α–β cost model, used by the AllReduce-SGD baseline.
//! * [`net`] — the cluster/network simulator standing in for the paper's
//!   32×DGX-1 testbed: 10 GbE / 100 Gb-IB link models, log-normal straggler
//!   compute model, and per-algorithm timing recursions — plus
//!   [`net::cluster`], the real multi-process deployment (TCP coordinator +
//!   gossip workers, `repro coord` / `repro worker`) that speaks the
//!   compressed push-sum shares as its literal wire format.
//! * [`faults`] — deterministic, seedable fault & churn injection
//!   ([`faults::FaultPlan`] / [`faults::FaultClock`]): per-link message
//!   loss, transient link degradation, node crash/rejoin-from-checkpoint
//!   and permanent leave, composed through every layer above — plus the
//!   offline robustness harness behind `repro faults`.
//! * [`sim`] — a discrete-event clock for the asynchronous baseline
//!   (AD-PSGD).
//! * [`optim`] — SGD / Nesterov momentum / Adam over flat `f32` vectors,
//!   plus the Goyal et al. learning-rate protocol.
//! * [`data`] — synthetic per-node data shards (Gaussian blobs, Zipf bigram
//!   LM) with controllable heterogeneity (the paper's ζ²).
//! * [`runtime`] — the PJRT bridge: loads the HLO-text artifacts emitted by
//!   `python/compile/aot.py` and executes them on the CPU PJRT client —
//!   plus [`runtime::pool`], the persistent worker pool the parallel
//!   engine dispatches to.
//! * [`benchgate`] — the CI perf-regression gate behind
//!   `repro bench-check` (microbench reports vs committed baselines).
//! * [`algorithms`] — the pluggable [`algorithms::DistributedAlgorithm`]
//!   trait, one strategy object per method (AR-SGD, SGP, Overlap-SGP,
//!   D-PSGD, AD-PSGD, DaSGD delayed averaging), and the name-keyed
//!   registry the CLI/experiments resolve through.
//! * [`coordinator`] — [`coordinator::TrainerBuilder`] and the single
//!   strategy-agnostic training loop.
//! * [`metrics`] — loss/consensus/throughput series and CSV emitters for
//!   regenerating every table and figure in the paper.
//! * [`obs`] — the unified observability layer: zero-allocation
//!   ring-buffered recorders ([`obs::ObsSink`]) threaded through the
//!   gossip engine, timing simulator, worker pool, and the real
//!   deployment; a versioned JSONL trace schema ([`obs::trace`]); and
//!   the `repro trace` analyzer ([`obs::analyze`] — straggler ranking,
//!   bytes-per-edge, mass-ledger reconciliation).
//! * [`snapshot`] — durable checkpoint/restore: a versioned, CRC'd,
//!   length-framed binary snapshot of the full push-sum state (nodes,
//!   mailboxes, error-feedback banks, mass ledger, RNG cursors,
//!   membership epoch) with bit-identical resume across every
//!   [`gossip::ExecPolicy`], a [`snapshot::SnapshotPolicy`] cadence
//!   threaded through the trainer / fault harness / cluster worker, and
//!   mass-conserving elastic join (`repro soak`).
//! * [`analysis`] — the `repro audit` static gate: a dependency-free,
//!   comment/string-aware lexer and rule engine that lints this repo's
//!   own source for determinism hazards (nondeterministic collections,
//!   wall-clock reads), unannotated `unsafe`, hot-path panics, and
//!   allocation in zero-alloc-anchored functions, against the committed
//!   allowlist `analysis/allow.toml`.
//!
//! See ARCHITECTURE.md for the layer diagram and the determinism
//! contract, DESIGN.md for the module map, the trait API contract, and
//! how to add an algorithm; EXPERIMENTS.md records paper-vs-measured
//! results.

#![warn(missing_docs)]

pub mod algorithms;
pub mod analysis;
pub mod benchgate;
pub mod benchkit;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod faults;
pub mod gossip;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod snapshot;
pub mod topology;

pub use algorithms::{AlgoParams, DistributedAlgorithm};
pub use config::TrainConfig;
pub use coordinator::{Trainer, TrainerBuilder};
pub use gossip::{Compression, ExecPolicy};

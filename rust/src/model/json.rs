//! Minimal recursive-descent JSON parser — enough for `manifest.json`.
//!
//! The offline build has no serde_json, so this ~200-line substrate covers
//! the JSON subset the AOT manifest uses (objects, arrays, strings with
//! escapes, numbers, bools, null). Strict where it matters (structure),
//! lenient where it doesn't (number grammar edge cases).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic iteration).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with byte position and reason.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable reason.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a complete JSON document (rejects trailing characters).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object member lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer (numbers truncate).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The value as an object map, if it is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (manifest strings are ASCII,
                    // but be correct anyway).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xC0 == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let j = Json::parse(
            r#"{"artifacts": {"a": {"file": "a.hlo.txt", "param_count": 12,
                "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}]}},
                "models": {}}"#,
        )
        .unwrap();
        let a = j.get("artifacts").unwrap().get("a").unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(a.get("param_count").unwrap().as_usize(), Some(12));
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
        assert_eq!(shape[1].as_usize(), Some(3));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }
}

//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! emits HLO text + initial parameters once, at build time) and the Rust
//! runtime (which loads and executes them on the training path).
//!
//! Parsed with the in-tree JSON substrate ([`json`]) — the offline build
//! has no serde_json.

pub mod json;

use std::collections::HashMap;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use json::Json;

/// Shape/dtype of one artifact input tensor.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    /// Parameter name in the HLO entry computation.
    pub name: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Element dtype (`"float32"`, `"int32"`, …).
    pub dtype: String,
}

impl TensorMeta {
    /// Number of elements (1 for scalars).
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled-artifact entry from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Artifact kind (`"train"`, `"eval"`, `"update"`, `"gossip"`, …).
    pub kind: String,
    /// Model this artifact belongs to, if any.
    pub model: Option<String>,
    /// Flat parameter count of the model function.
    pub param_count: Option<usize>,
    /// Input tensor layouts.
    pub inputs: Vec<TensorMeta>,
    /// Output names, in order.
    pub outputs: Vec<String>,
    /// Node count baked into a gossip artifact.
    pub n: Option<usize>,
    /// Per-node dimension baked into a gossip artifact.
    pub d: Option<usize>,
}

/// One model entry from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Flat parameter count.
    pub param_count: usize,
    /// Init-parameters file, relative to the artifact dir.
    pub init: String,
    /// The model's exported JAX config (batch, dims, …).
    pub config: Json,
}

/// The parsed `manifest.json`: artifact and model tables.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Compiled artifacts by name.
    pub artifacts: HashMap<String, ArtifactMeta>,
    /// Model metadata by name.
    pub models: HashMap<String, ModelMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    Ok(TensorMeta {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .context("tensor meta missing name")?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor meta missing shape")?
            .iter()
            .map(|v| v.as_usize().context("non-numeric dim"))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string(),
    })
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut artifacts = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing `artifacts`")?
        {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .context("artifact missing file")?
                        .to_string(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    model: a.get("model").and_then(Json::as_str).map(String::from),
                    param_count: a.get("param_count").and_then(Json::as_usize),
                    inputs,
                    outputs: a
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    n: a.get("n").and_then(Json::as_usize),
                    d: a.get("d").and_then(Json::as_usize),
                },
            );
        }
        let mut models = HashMap::new();
        for (name, m) in j
            .get("models")
            .and_then(Json::as_obj)
            .context("manifest missing `models`")?
        {
            models.insert(
                name.clone(),
                ModelMeta {
                    param_count: m
                        .get("param_count")
                        .and_then(Json::as_usize)
                        .context("model missing param_count")?,
                    init: m
                        .get("init")
                        .and_then(Json::as_str)
                        .context("model missing init")?
                        .to_string(),
                    config: m.get("config").cloned().unwrap_or(Json::Null),
                },
            );
        }
        Ok(Manifest { artifacts, models })
    }

    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    /// Look up an artifact by name (error names the missing entry).
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    /// Look up a model by name (error names the missing entry).
    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("model `{name}` not in manifest"))
    }

    /// Batch-config helper pulled from the model's exported JAX config.
    pub fn model_cfg_usize(&self, model: &str, key: &str) -> Result<usize> {
        let m = self.model(model)?;
        m.config
            .get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("model `{model}` config missing `{key}`"))
    }

    /// String-config helper pulled from the model's exported JAX config.
    pub fn model_cfg_str(&self, model: &str, key: &str) -> Result<&str> {
        let m = self.model(model)?;
        m.config
            .get(key)
            .and_then(Json::as_str)
            .with_context(|| format!("model `{model}` config missing `{key}`"))
    }
}

/// Read a little-endian f32 init file.
pub fn read_init(dir: &Path, manifest: &Manifest, model: &str) -> Result<Vec<f32>> {
    let meta = manifest.model(model)?;
    let path = dir.join(&meta.init);
    let mut bytes = Vec::new();
    fs::File::open(&path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut bytes)?;
    if bytes.len() != meta.param_count * 4 {
        bail!(
            "init file {path:?} has {} bytes, expected {}",
            bytes.len(),
            meta.param_count * 4
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Default artifacts directory: `$REPRO_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walks up from cwd until found).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "train_mlp": {
          "file": "train_mlp.hlo.txt", "kind": "train_step",
          "model": "mlp", "param_count": 10,
          "inputs": [
            {"name": "params", "shape": [10], "dtype": "float32"},
            {"name": "x", "shape": [4, 2], "dtype": "float32"}
          ],
          "outputs": ["loss", "grads"]
        },
        "gossip_dense_n4": {
          "file": "g.hlo.txt", "kind": "gossip_dense", "n": 4, "d": 8,
          "inputs": [], "outputs": ["x", "w", "z"]
        }
      },
      "models": {
        "mlp": {"param_count": 10, "init": "mlp.init.bin",
                "config": {"batch": 4, "in_dim": 2, "kind": "mlp"}}
      }
    }"#;

    #[test]
    fn parses_and_queries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("train_mlp").unwrap();
        assert_eq!(a.inputs[1].elements(), 8);
        assert_eq!(a.outputs, vec!["loss", "grads"]);
        assert_eq!(m.model_cfg_usize("mlp", "batch").unwrap(), 4);
        assert_eq!(m.model_cfg_str("mlp", "kind").unwrap(), "mlp");
        assert_eq!(m.artifact("gossip_dense_n4").unwrap().n, Some(4));
        assert!(m.artifact("nope").is_err());
        assert!(m.model_cfg_usize("mlp", "nope").is_err());
    }

    #[test]
    fn scalar_tensor_has_one_element() {
        let t = TensorMeta { name: "s".into(), shape: vec![], dtype: "float32".into() };
        assert_eq!(t.elements(), 1);
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::parse(r#"{"artifacts": {}}"#).is_err());
        assert!(Manifest::parse("{}").is_err());
    }
}

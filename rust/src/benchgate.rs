//! CI perf-regression gate: diff freshly produced `results/BENCH_*.json`
//! microbench reports against committed baselines under
//! `benchmarks/baselines/`, failing on a configurable throughput
//! regression — the enforcement mechanism behind the ROADMAP's "make a hot
//! path measurably faster" clause (`repro bench-check`).
//!
//! # Model
//!
//! Every microbench entry is `{name, median_ns, …}` ([`crate::benchkit`]'s
//! schema). Throughput is `1 / median_ns`, so a run **regresses** an entry
//! when
//!
//! ```text
//! fresh_median_ns > baseline_median_ns / (1 − tol)
//! ```
//!
//! i.e. throughput fell by more than `tol` (default 25%). Entries are
//! matched by name; entries present on only one side are reported but
//! never fail the gate (benches come and go as the suite evolves — only a
//! *measured regression of a tracked entry* fails). An empty or missing
//! baseline file leaves the gate **unarmed** for that report: the check
//! warns and passes, and `--update` records the fresh numbers as the new
//! baseline to arm it.
//!
//! # Refreshing baselines
//!
//! When a legitimate speedup (or an accepted tradeoff) moves the numbers,
//! regenerate and commit:
//!
//! ```text
//! SGP_BENCH_FAST=1 cargo bench --bench gossip_micro
//! cargo run --release --bin repro -- bench-check --update
//! git add benchmarks/baselines && git commit
//! ```
//!
//! Baselines are machine-dependent by nature; commit numbers produced on
//! the same class of machine that enforces them (for this repo: the CI
//! runner), and lean on the tolerance to absorb runner noise.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::print_table;
use crate::model::json::Json;

/// The report files the gate tracks, relative to both the results and the
/// baselines directory.
pub const BENCH_FILES: &[&str] =
    &["BENCH_gossip.json", "BENCH_engine.json", "BENCH_compress.json"];

/// Configuration of one `repro bench-check` invocation.
#[derive(Clone, Debug)]
pub struct BenchCheck {
    /// Directory holding the freshly produced reports (`results/`).
    pub results_dir: PathBuf,
    /// Directory holding the committed baselines
    /// (`benchmarks/baselines/`).
    pub baseline_dir: PathBuf,
    /// Allowed throughput regression per entry before the gate fails
    /// (0.25 = fail when throughput drops more than 25%).
    pub tol: f64,
    /// Record mode: overwrite the baselines with the fresh reports instead
    /// of diffing.
    pub update: bool,
}

impl Default for BenchCheck {
    fn default() -> Self {
        Self {
            results_dir: PathBuf::from("results"),
            baseline_dir: PathBuf::from("benchmarks/baselines"),
            tol: 0.25,
            update: false,
        }
    }
}

/// One compared entry (exposed for the table/diagnostics).
#[derive(Clone, Debug)]
struct EntryDiff {
    file: &'static str,
    name: String,
    base_ns: f64,
    fresh_ns: f64,
}

impl EntryDiff {
    /// fresh/base median ratio (> 1 means slower).
    fn ratio(&self) -> f64 {
        self.fresh_ns / self.base_ns.max(1e-12)
    }

    /// Does this entry regress throughput beyond `tol`?
    fn regressed(&self, tol: f64) -> bool {
        self.base_ns > 0.0 && self.fresh_ns > self.base_ns / (1.0 - tol).max(1e-9)
    }
}

/// Parse one benchkit JSON report into `name → median_ns`.
fn load_medians(path: &Path) -> Result<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    let benches = doc
        .get("benches")
        .and_then(Json::as_arr)
        .with_context(|| format!("{}: no `benches` array", path.display()))?;
    let mut out = BTreeMap::new();
    for b in benches {
        let name = b
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("{}: entry without `name`", path.display()))?;
        let median = b
            .get("median_ns")
            .and_then(Json::as_f64)
            .with_context(|| format!("{}: `{name}` without `median_ns`", path.display()))?;
        out.insert(name.to_string(), median);
    }
    Ok(out)
}

/// Run the gate (or, with `update`, record fresh baselines). Errors when a
/// tracked entry regresses beyond `cfg.tol`, when the tolerance is
/// nonsensical, or when a fresh report is missing/unreadable.
pub fn bench_check(cfg: &BenchCheck) -> Result<()> {
    if !(0.0..1.0).contains(&cfg.tol) {
        bail!("--tol {}: tolerance must lie in [0, 1)", cfg.tol);
    }
    if cfg.update {
        std::fs::create_dir_all(&cfg.baseline_dir)?;
        for &file in BENCH_FILES {
            let src = cfg.results_dir.join(file);
            let dst = cfg.baseline_dir.join(file);
            // Validate before recording — a truncated report must not
            // become the baseline.
            load_medians(&src)?;
            std::fs::copy(&src, &dst)
                .with_context(|| format!("recording {} → {}", src.display(), dst.display()))?;
            println!("recorded baseline {}", dst.display());
        }
        return Ok(());
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut offenders: Vec<EntryDiff> = Vec::new();
    let mut compared = 0usize;
    let mut unarmed: Vec<&str> = Vec::new();
    for &file in BENCH_FILES {
        let fresh = load_medians(&cfg.results_dir.join(file))?;
        let base_path = cfg.baseline_dir.join(file);
        if !base_path.exists() {
            unarmed.push(file);
            continue;
        }
        let base = load_medians(&base_path)?;
        if base.is_empty() {
            unarmed.push(file);
            continue;
        }
        for (name, &base_ns) in &base {
            let Some(&fresh_ns) = fresh.get(name) else {
                rows.push(vec![
                    file.to_string(),
                    name.clone(),
                    format!("{base_ns:.0}"),
                    "-".into(),
                    "-".into(),
                    "gone (ignored)".into(),
                ]);
                continue;
            };
            let d = EntryDiff {
                file,
                name: name.clone(),
                base_ns,
                fresh_ns,
            };
            compared += 1;
            let verdict = if d.regressed(cfg.tol) {
                "REGRESSED"
            } else if d.ratio() < 1.0 {
                "faster"
            } else {
                "ok"
            };
            rows.push(vec![
                file.to_string(),
                name.clone(),
                format!("{base_ns:.0}"),
                format!("{fresh_ns:.0}"),
                format!("{:.2}×", d.ratio()),
                verdict.into(),
            ]);
            if d.regressed(cfg.tol) {
                offenders.push(d);
            }
        }
        for name in fresh.keys().filter(|n| !base.contains_key(*n)) {
            rows.push(vec![
                file.to_string(),
                name.clone(),
                "-".into(),
                "new".into(),
                "-".into(),
                "untracked (ignored)".into(),
            ]);
        }
    }
    print_table(
        &format!(
            "bench-check — fresh vs committed baselines (tol = {:.0}% throughput)",
            cfg.tol * 100.0
        ),
        &["report", "bench", "base ns", "fresh ns", "ratio", "verdict"],
        &rows,
    );
    for file in &unarmed {
        eprintln!(
            "bench-check: no baseline for {file} under {} — gate unarmed for \
             this report; run `repro bench-check --update` after a bench run \
             and commit the result to arm it",
            cfg.baseline_dir.display()
        );
    }
    if compared == 0 && unarmed.len() == BENCH_FILES.len() {
        eprintln!(
            "bench-check: no baselines at all — nothing enforced this run"
        );
    }
    if !offenders.is_empty() {
        let worst = offenders
            .iter()
            .map(|d| format!("{}:{} ({:.2}×)", d.file, d.name, d.ratio()))
            .collect::<Vec<_>>()
            .join(", ");
        bail!(
            "{} of {} tracked benches regressed more than {:.0}% in \
             throughput: {worst}. If the slowdown is an accepted tradeoff, \
             refresh the baselines (`repro bench-check --update`, then \
             commit benchmarks/baselines/).",
            offenders.len(),
            compared,
            cfg.tol * 100.0
        );
    }
    println!(
        "bench-check: {} tracked entries within {:.0}% throughput tolerance",
        compared,
        cfg.tol * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_report(path: &Path, entries: &[(&str, u64)]) {
        let mut s = String::from("{\n  \"benches\": [\n");
        for (i, (name, med)) in entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{name}\", \"iters\": 5, \"mean_ns\": {med}, \
                 \"median_ns\": {med}, \"p95_ns\": {med}, \"min_ns\": {med}, \
                 \"max_ns\": {med}}}{}\n",
                if i + 1 == entries.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, s).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sgp-benchgate-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg_for(root: &Path, tol: f64) -> BenchCheck {
        BenchCheck {
            results_dir: root.join("results"),
            baseline_dir: root.join("baselines"),
            tol,
            update: false,
        }
    }

    /// Write all three fresh reports with a single shared entry list.
    fn write_all_fresh(root: &Path, entries: &[(&str, u64)]) {
        for f in BENCH_FILES {
            write_report(&root.join("results").join(f), entries);
        }
    }

    #[test]
    fn within_tolerance_passes_and_regression_fails() {
        let root = tmpdir("gate");
        write_all_fresh(&root, &[("a/b", 1000), ("c/d", 3000)]);
        let cfg = cfg_for(&root, 0.25);
        // Arm the baselines from the fresh run.
        bench_check(&BenchCheck { update: true, ..cfg.clone() }).unwrap();
        // Identical numbers: pass.
        bench_check(&cfg).unwrap();
        // 20% slower at 25% tolerance: ratio 1.2 < 1/(1-0.25)=1.333 → pass.
        write_all_fresh(&root, &[("a/b", 1200), ("c/d", 3000)]);
        bench_check(&cfg).unwrap();
        // 50% slower: throughput fell 33% > 25% → fail, naming the bench.
        write_all_fresh(&root, &[("a/b", 1500), ("c/d", 3000)]);
        let err = bench_check(&cfg).unwrap_err().to_string();
        assert!(err.contains("a/b"), "{err}");
        // A tighter tolerance catches the 20% case too.
        write_all_fresh(&root, &[("a/b", 1200), ("c/d", 3000)]);
        assert!(bench_check(&cfg_for(&root, 0.05)).is_err());
        // Faster never fails, at any tolerance.
        write_all_fresh(&root, &[("a/b", 10), ("c/d", 10)]);
        bench_check(&cfg_for(&root, 0.01)).unwrap();
    }

    #[test]
    fn missing_baselines_warn_but_pass_and_name_mismatches_are_ignored() {
        let root = tmpdir("unarmed");
        write_all_fresh(&root, &[("a/b", 1000)]);
        let cfg = cfg_for(&root, 0.25);
        // No baselines at all: unarmed, passes.
        bench_check(&cfg).unwrap();
        // Baseline tracks an entry the fresh run no longer has (and lacks
        // one it gained): neither fails the gate.
        write_report(&root.join("baselines").join(BENCH_FILES[0]), &[("old/gone", 500)]);
        write_report(&root.join("results").join(BENCH_FILES[0]), &[("new/born", 900)]);
        bench_check(&cfg).unwrap();
    }

    #[test]
    fn update_validates_and_records() {
        let root = tmpdir("update");
        let cfg = cfg_for(&root, 0.25);
        // Fresh reports missing entirely: update errors.
        assert!(bench_check(&BenchCheck { update: true, ..cfg.clone() }).is_err());
        write_all_fresh(&root, &[("x/y", 10)]);
        bench_check(&BenchCheck { update: true, ..cfg.clone() }).unwrap();
        for f in BENCH_FILES {
            assert!(root.join("baselines").join(f).exists(), "{f}");
        }
        bench_check(&cfg).unwrap();
    }

    #[test]
    fn bad_tolerance_is_rejected() {
        let root = tmpdir("tol");
        write_all_fresh(&root, &[("a", 1)]);
        assert!(bench_check(&cfg_for(&root, 1.0)).is_err());
        assert!(bench_check(&cfg_for(&root, -0.1)).is_err());
    }
}

//! The audit rule catalog: five token-stream rules over one lexed file.
//!
//! | rule | guards | scope |
//! |------|--------|-------|
//! | D001 | no `HashMap`/`HashSet` in deterministic modules | `gossip/`, `topology/`, `sim/`, `faults/` |
//! | D002 | no wall-clock (`Instant::now`/`SystemTime`) on deterministic paths | `gossip/`, `sim/`, `topology/`, `faults/`, `runtime/` |
//! | U001 | every `unsafe` has a `// SAFETY:` / `/// # Safety` comment ending ≤ 8 lines above | all of `rust/src` |
//! | P001 | no `.unwrap()` / `.expect()` on hot or I/O paths | `gossip/`, `runtime/`, `net/`, `snapshot/` |
//! | A001 | no allocation-capable calls inside anchor-marked functions | all of `rust/src` |
//!
//! (The A001 anchor is the comment `audit:` + `zero-alloc` on the line
//! above a `fn` — spelled out indirectly here so this very doc comment
//! does not anchor the function below it when the audit scans itself.)
//!
//! Everything inside a `#[cfg(test)]` item is exempt (tests unwrap and
//! clock freely), and the lexer guarantees comments and literals can
//! never match. A finding is a *candidate*: the caller intersects it with
//! the committed allowlist (`analysis/allow.toml`), where every pinned
//! site must carry a reason string.

use super::lexer::{lex, Comment, Lexed, Tok, TokKind};

/// How many lines above an `unsafe` token a justifying `SAFETY` comment
/// may end (doc-comment `# Safety` sections often carry a sentence or two
/// between the heading and the item).
const SAFETY_WINDOW: usize = 8;

/// One rule violation candidate in one file.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (`"D001"`, …).
    pub rule: &'static str,
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// The trimmed source line — what allowlist patterns match against.
    pub excerpt: String,
    /// Human explanation of the violation.
    pub msg: String,
}

/// Static description of one rule, for `--rule` validation and reports.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Rule id.
    pub id: &'static str,
    /// One-line summary.
    pub summary: &'static str,
}

/// The rule catalog, in report order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D001",
        summary: "nondeterministic collection (HashMap/HashSet) in a deterministic module",
    },
    RuleInfo {
        id: "D002",
        summary: "wall-clock read (Instant::now/SystemTime) on a deterministic path",
    },
    RuleInfo {
        id: "U001",
        summary: "`unsafe` without an immediately-preceding SAFETY comment",
    },
    RuleInfo {
        id: "P001",
        summary: ".unwrap()/.expect() on a gossip/pool/cluster hot path",
    },
    RuleInfo {
        id: "A001",
        summary: "allocation-capable call inside a `// audit: zero-alloc` function",
    },
];

fn in_dirs(file: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| file.starts_with(d))
}

fn d001_scope(file: &str) -> bool {
    in_dirs(
        file,
        &["rust/src/gossip/", "rust/src/topology/", "rust/src/sim/", "rust/src/faults/"],
    )
}

fn d002_scope(file: &str) -> bool {
    in_dirs(
        file,
        &[
            "rust/src/gossip/",
            "rust/src/sim/",
            "rust/src/topology/",
            "rust/src/faults/",
            "rust/src/runtime/",
        ],
    )
}

fn p001_scope(file: &str) -> bool {
    in_dirs(
        file,
        &[
            "rust/src/gossip/",
            "rust/src/runtime/",
            "rust/src/net/",
            "rust/src/snapshot/",
        ],
    )
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn is_punct(t: &Tok, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.starts_with(c)
}

/// Line spans of `#[cfg(test)]` items: from the attribute's `#` to the
/// closing brace of the item body that follows it. Findings inside these
/// spans are dropped — tests unwrap, allocate and read clocks by design.
fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 6 < toks.len() {
        let attr = is_punct(&toks[i], '#')
            && is_punct(&toks[i + 1], '[')
            && is_ident(&toks[i + 2], "cfg")
            && is_punct(&toks[i + 3], '(')
            && is_ident(&toks[i + 4], "test")
            && is_punct(&toks[i + 5], ')')
            && is_punct(&toks[i + 6], ']');
        if !attr {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 7;
        // Find the item's opening brace, then its matching close.
        while j < toks.len() && !is_punct(&toks[j], '{') {
            j += 1;
        }
        let mut depth = 0usize;
        let mut end_line = toks.last().map(|t| t.line).unwrap_or(start_line);
        while j < toks.len() {
            if is_punct(&toks[j], '{') {
                depth += 1;
            } else if is_punct(&toks[j], '}') {
                depth -= 1;
                if depth == 0 {
                    end_line = toks[j].line;
                    break;
                }
            }
            j += 1;
        }
        spans.push((start_line, end_line));
        i = j.max(i + 7);
    }
    spans
}

/// Run every rule over one file. `file` is the repo-relative path with
/// forward slashes (it selects each rule's scope); `src` is the file
/// contents. Findings come back in line order, `#[cfg(test)]` regions
/// already excluded.
pub fn check_file(file: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let excerpt = |line: usize| -> String {
        lines.get(line.saturating_sub(1)).map(|l| l.trim().to_string()).unwrap_or_default()
    };
    let mut found: Vec<Finding> = Vec::new();
    let mut push = |rule: &'static str, line: usize, msg: String| {
        found.push(Finding {
            rule,
            file: file.to_string(),
            line,
            excerpt: excerpt(line),
            msg,
        });
    };

    let toks = &lexed.tokens;
    for (i, t) in toks.iter().enumerate() {
        // D001 — nondeterministic collections in deterministic modules.
        if d001_scope(file) && (is_ident(t, "HashMap") || is_ident(t, "HashSet")) {
            push(
                "D001",
                t.line,
                format!(
                    "`{}` in a deterministic module: iteration order is unseeded \
                     process state — use BTreeMap/BTreeSet or index-keyed Vecs",
                    t.text
                ),
            );
        }
        // D002 — wall-clock reads on deterministic paths.
        if d002_scope(file) {
            if is_ident(t, "Instant")
                && toks.get(i + 1).is_some_and(|a| is_punct(a, ':'))
                && toks.get(i + 2).is_some_and(|a| is_punct(a, ':'))
                && toks.get(i + 3).is_some_and(|a| is_ident(a, "now"))
            {
                push(
                    "D002",
                    t.line,
                    "`Instant::now` on a deterministic path: clock reads must sit \
                     behind set_metered/obs gating so unobserved runs make zero \
                     clock syscalls"
                        .to_string(),
                );
            }
            if is_ident(t, "SystemTime") {
                push(
                    "D002",
                    t.line,
                    "`SystemTime` on a deterministic path: wall-clock state must \
                     never reach seeded computation"
                        .to_string(),
                );
            }
        }
        // U001 — unsafe without a SAFETY comment just above.
        if is_ident(t, "unsafe") {
            let covered = lexed.comments.iter().any(|c: &Comment| {
                c.line_end <= t.line
                    && t.line - c.line_end <= SAFETY_WINDOW
                    && (c.text.contains("SAFETY:") || c.text.contains("# Safety"))
            });
            if !covered {
                push(
                    "U001",
                    t.line,
                    format!(
                        "`unsafe` without a `// SAFETY:` (or `# Safety` doc) comment \
                         ending within {SAFETY_WINDOW} lines above — state the \
                         aliasing/lifetime invariant it relies on"
                    ),
                );
            }
        }
        // P001 — .unwrap()/.expect() on hot/IO paths. Matching `.name(`
        // exactly means `unwrap_or`, `unwrap_or_else`, `expect_err` etc.
        // are separate identifiers and never flagged.
        if p001_scope(file)
            && i > 0
            && is_punct(&toks[i - 1], '.')
            && (is_ident(t, "unwrap") || is_ident(t, "expect"))
            && toks.get(i + 1).is_some_and(|a| is_punct(a, '('))
        {
            push(
                "P001",
                t.line,
                format!(
                    "`.{}()` on a gossip/pool/cluster path: fix it, return a typed \
                     error, or allowlist it with the invariant as the reason",
                    t.text
                ),
            );
        }
    }

    // A001 — allocation-capable calls inside anchored functions.
    for c in &lexed.comments {
        if !c.text.contains("audit: zero-alloc") {
            continue;
        }
        // The anchor applies to the next `fn` item after the comment.
        let Some(fn_idx) = toks
            .iter()
            .position(|t| t.line >= c.line_end && is_ident(t, "fn"))
        else {
            continue;
        };
        let Some(open) = (fn_idx..toks.len()).find(|&j| is_punct(&toks[j], '{')) else {
            continue;
        };
        let mut depth = 0usize;
        let mut j = open;
        while j < toks.len() {
            if is_punct(&toks[j], '{') {
                depth += 1;
            } else if is_punct(&toks[j], '}') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if let Some(what) = alloc_call(toks, j) {
                push(
                    "A001",
                    toks[j].line,
                    format!(
                        "`{what}` inside a `// audit: zero-alloc` function — the \
                         zero-allocation contract (rust/tests/alloc_regression.rs) \
                         covers this body"
                    ),
                );
            }
            j += 1;
        }
    }

    let spans = test_spans(toks);
    found.retain(|f| !spans.iter().any(|&(lo, hi)| f.line >= lo && f.line <= hi));
    found.sort_by_key(|f| (f.line, f.rule));
    found
}

/// Allocation-capable call starting at token `j`, if any: the macro forms
/// (`vec!`, `format!`), the method forms (`.to_vec()`, `.to_string()`,
/// `.to_owned()`, `.collect()`), and the constructor forms (`Vec::new`,
/// `Vec::with_capacity`, `String::new`, `String::from`, `Box::new`).
fn alloc_call(toks: &[Tok], j: usize) -> Option<String> {
    let t = toks.get(j)?;
    if t.kind != TokKind::Ident {
        return None;
    }
    let next = toks.get(j + 1);
    if (t.text == "vec" || t.text == "format") && next.is_some_and(|a| is_punct(a, '!')) {
        return Some(format!("{}!", t.text));
    }
    if matches!(t.text.as_str(), "to_vec" | "to_string" | "to_owned" | "collect")
        && j > 0
        && is_punct(&toks[j - 1], '.')
        && next.is_some_and(|a| is_punct(a, '('))
    {
        return Some(format!(".{}()", t.text));
    }
    if matches!(t.text.as_str(), "Vec" | "String" | "Box")
        && next.is_some_and(|a| is_punct(a, ':'))
        && toks.get(j + 2).is_some_and(|a| is_punct(a, ':'))
        && toks.get(j + 3).is_some_and(|a| {
            a.kind == TokKind::Ident
                && matches!(a.text.as_str(), "new" | "with_capacity" | "from")
        })
    {
        return Some(format!("{}::{}", t.text, toks[j + 3].text));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_of(found: &[Finding], rule: &str) -> Vec<usize> {
        found.iter().filter(|f| f.rule == rule).map(|f| f.line).collect()
    }

    #[test]
    fn d001_flags_only_code_in_scope() {
        let src = "use std::collections::HashMap;\n// HashMap in a comment\nlet s = \"HashSet\";\nlet m: HashMap<u32, u32> = HashMap::new();\n";
        let found = check_file("rust/src/gossip/mod.rs", src);
        assert_eq!(lines_of(&found, "D001"), vec![1, 4, 4]);
        // Out of scope: same source, different module.
        assert!(lines_of(&check_file("rust/src/cli.rs", src), "D001").is_empty());
    }

    #[test]
    fn d002_flags_instant_now_but_not_bare_instant() {
        let src = "use std::time::Instant;\nfn f(m: &mut Option<Instant>) {\n    let t = Instant::now();\n    let _ = t;\n}\n";
        let found = check_file("rust/src/gossip/mod.rs", src);
        assert_eq!(lines_of(&found, "D002"), vec![3], "the use/param lines are clean");
        let sys = check_file("rust/src/sim/mod.rs", "let t = SystemTime::now();\n");
        assert_eq!(lines_of(&sys, "D002"), vec![1]);
    }

    #[test]
    fn u001_respects_the_safety_window() {
        let bad = "fn f(p: *mut u8) {\n    unsafe { p.write(0) };\n}\n";
        assert_eq!(lines_of(&check_file("rust/src/x.rs", bad), "U001"), vec![2]);
        let good = "fn f(p: *mut u8) {\n    // SAFETY: p is valid for writes (caller contract).\n    unsafe { p.write(0) };\n}\n";
        assert!(lines_of(&check_file("rust/src/x.rs", good), "U001").is_empty());
        let doc = "/// # Safety\n/// `p` must be valid.\nunsafe fn f(p: *mut u8) {}\n";
        assert!(lines_of(&check_file("rust/src/x.rs", doc), "U001").is_empty());
        let far = format!(
            "// SAFETY: too far away.\n{}unsafe fn f() {{}}\n",
            "\n".repeat(SAFETY_WINDOW + 1)
        );
        assert_eq!(lines_of(&check_file("rust/src/x.rs", &far), "U001").len(), 1);
    }

    #[test]
    fn p001_flags_unwrap_expect_but_not_unwrap_or() {
        let src = "fn f(o: Option<u32>) -> u32 {\n    let a = o.unwrap();\n    let b = o.expect(\"msg\");\n    let c = o.unwrap_or(0);\n    let d = o.unwrap_or_else(|| 0);\n    a + b + c + d\n}\n";
        let found = check_file("rust/src/gossip/mod.rs", src);
        assert_eq!(lines_of(&found, "P001"), vec![2, 3]);
        // `unwrap` in a doc comment or string never matches.
        let quiet = "/// call .unwrap() never\nfn f() { let s = \".expect(\"; let _ = s; }\n";
        assert!(lines_of(&check_file("rust/src/net/mod.rs", quiet), "P001").is_empty());
        // Out of scope for, e.g., experiment drivers.
        assert!(lines_of(&check_file("rust/src/experiments/mod.rs", src), "P001").is_empty());
    }

    #[test]
    fn a001_only_fires_inside_anchored_bodies() {
        let src = "fn free() -> Vec<u32> { (0..4).collect() }\n\n// audit: zero-alloc — hot path.\nfn hot(xs: &mut Vec<u32>) {\n    let v = vec![1, 2];\n    let s = format!(\"x\");\n    let w = xs.to_vec();\n    let n: Vec<u32> = Vec::new();\n    xs.push(1);\n}\n\nfn also_free() { let _ = String::new(); }\n";
        let found = check_file("rust/src/gossip/mod.rs", src);
        assert_eq!(lines_of(&found, "A001"), vec![5, 6, 7, 8], "push() and unanchored fns are exempt");
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "fn prod(o: Option<u32>) -> u32 { o.unwrap() }\n\n#[cfg(test)]\nmod tests {\n    fn helper(o: Option<u32>) -> u32 {\n        o.unwrap()\n    }\n    use std::collections::HashMap;\n}\n";
        let found = check_file("rust/src/gossip/mod.rs", src);
        assert_eq!(lines_of(&found, "P001"), vec![1], "only the non-test unwrap");
        assert!(lines_of(&found, "D001").is_empty(), "test-mod HashMap exempt");
    }

    #[test]
    fn seeded_proptest_random_benign_noise_never_false_positives() {
        // Assemble random files from fragments that *mention* every
        // trigger word inside comments/strings/raw strings, interleaved
        // with clean code; no fragment is a real violation, so any finding
        // is a false positive. Seeded Pcg streams, failing seed printed.
        use crate::rng::Pcg;
        const BENIGN: &[&str] = &[
            "// HashMap unwrap() unsafe Instant::now SystemTime vec![]\n",
            "/// ```\n/// m.unwrap();\n/// let h: HashMap<u8, u8> = HashMap::new();\n/// ```\n",
            "let s = \"unsafe { HashSet } .expect( Instant::now()\";\n",
            "let r = r#\"format! to_vec() \"# ;\n",
            "/* nested /* unsafe */ SystemTime */\n",
            "let c = '\\u{1F600}'; let l: &'static str = \"x\";\n",
            "fn ok(o: Option<u32>) -> u32 { o.unwrap_or_default() }\n",
            "let b = br##\"Box::new( .collect() \"# \"##;\n",
        ];
        for case in 0..24u64 {
            let mut rng = Pcg::new(9_000 + case);
            let mut src = String::new();
            for _ in 0..3 + rng.below(9) {
                src.push_str(BENIGN[rng.below(BENIGN.len())]);
            }
            let found = check_file("rust/src/gossip/mod.rs", &src);
            assert!(
                found.is_empty(),
                "seed {case}: false positives {found:?}\nsource:\n{src}"
            );
        }
    }

    #[test]
    fn seeded_proptest_injected_violations_report_exact_lines() {
        // Same generator, but with one real violation per rule spliced in
        // at a random position; the rule must fire on exactly the line
        // where the fragment landed.
        use crate::rng::Pcg;
        const NOISE: &[&str] = &[
            "// benign HashMap unwrap()\n",
            "let s = \"Instant::now()\";\n",
            "fn ok() { let _ = 1; }\n",
        ];
        const BAD: &[(&str, &str)] = &[
            ("D001", "let m: HashMap<u8, u8> = Default::default();\n"),
            ("D002", "let t = Instant::now();\n"),
            ("U001", "let u = unsafe { core::hint::unreachable_unchecked() };\n"),
            ("P001", "let v = opt.unwrap();\n"),
        ];
        for case in 0..24u64 {
            let mut rng = Pcg::new(17_000 + case);
            let (rule, frag) = BAD[rng.below(BAD.len())];
            let before = rng.below(6);
            let after = rng.below(6);
            let mut src = String::new();
            let mut line = 1usize;
            for _ in 0..before {
                let n = NOISE[rng.below(NOISE.len())];
                src.push_str(n);
                line += n.matches('\n').count();
            }
            src.push_str(frag);
            for _ in 0..after {
                src.push_str(NOISE[rng.below(NOISE.len())]);
            }
            let found = check_file("rust/src/gossip/mod.rs", &src);
            assert_eq!(
                lines_of(&found, rule),
                vec![line],
                "seed {case} rule {rule}\nsource:\n{src}"
            );
        }
    }
}

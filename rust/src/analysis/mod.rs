//! Self-contained static analysis over the repo's own source — the
//! `repro audit` subcommand.
//!
//! The engine's entire correctness story rests on a *bit-identity
//! determinism contract* (ARCHITECTURE.md §2): at a fixed seed, every
//! execution policy — sequential, pooled, event-driven, sparse — produces
//! the same bits. That contract is enforced dynamically by proptests, but
//! nothing stops the next change from introducing a `HashMap` iteration,
//! a wall-clock read, or an unannotated `unsafe` shard table into a
//! deterministic path. This module is the static gate: a
//! comment/string/raw-string-aware lexer ([`lexer`]) plus a small rule
//! engine ([`rules`]) over `rust/src`, with a **committed allowlist**
//! (`analysis/allow.toml`) where every pinned site must carry a reason
//! string — justified sites are explicit, never silently passed.
//!
//! Dependency-free by construction (the offline build vendors nothing
//! for this): file walking is `std::fs`, the allowlist parser reads the
//! small TOML subset `allow.toml` actually uses, and JSON output is
//! hand-rendered. See ARCHITECTURE.md §8 for the rule catalog and the
//! relationship to the dynamic interleaving checker
//! (`rust/tests/pool_interleaving.rs`).

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use rules::{Finding, RuleInfo, RULES};

/// What to audit and how.
#[derive(Debug, Clone)]
pub struct AuditConfig {
    /// Repository root; `rust/src` under it is scanned.
    pub root: PathBuf,
    /// Allowlist path (default `<root>/analysis/allow.toml`); a missing
    /// file is an empty allowlist, never an error — violations then
    /// simply have nothing to hide behind.
    pub allow: PathBuf,
    /// Restrict to one rule id (`--rule D001`); `None` runs all rules.
    pub rule: Option<String>,
}

impl AuditConfig {
    /// Audit the tree rooted at `root` with its committed allowlist.
    pub fn new(root: PathBuf) -> Self {
        let allow = root.join("analysis/allow.toml");
        Self { root, allow, rule: None }
    }
}

/// One `[[allow]]` entry: pins `rule` findings in `file` whose source
/// line contains `pattern`, justified by `reason`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    /// Rule id the entry applies to.
    pub rule: String,
    /// Repo-relative file (forward slashes), compared exactly.
    pub file: String,
    /// Substring the flagged source line must contain.
    pub pattern: String,
    /// Why the site is acceptable — mandatory, never empty.
    pub reason: String,
    /// 1-based line of the entry's `[[allow]]` header (for messages).
    pub line: usize,
}

/// Everything one audit run produced.
#[derive(Debug, Default)]
pub struct AuditReport {
    /// Findings not covered by any allowlist entry — real violations.
    pub violations: Vec<Finding>,
    /// Findings pinned by the allowlist, with the matching entry's reason.
    pub allowed: Vec<(Finding, String)>,
    /// Allowlist entries that matched nothing — stale pins must be
    /// deleted, or they will silently hide a future regression at the
    /// same site.
    pub stale: Vec<AllowEntry>,
    /// Number of source files scanned.
    pub files_scanned: usize,
}

impl AuditReport {
    /// Does this report pass `--deny`? (No violations, no stale entries.)
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty()
    }
}

/// Recursively collect `.rs` files under `dir`, sorted by path so the
/// report (and the JSON artifact CI diffs across PRs) is deterministic.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Parse `analysis/allow.toml` — the TOML subset the allowlist uses:
/// `#` comments, blank lines, `[[allow]]` section headers, and
/// `key = "value"` string pairs (escapes: `\"` and `\\`). Anything else
/// is an error, as is an entry missing `rule`/`file`/`pattern` or with a
/// missing/empty `reason` — every pin must say *why*.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>> {
    let mut entries: Vec<AllowEntry> = Vec::new();
    let mut current: Option<AllowEntry> = None;
    let mut finish = |e: Option<AllowEntry>, entries: &mut Vec<AllowEntry>| -> Result<()> {
        if let Some(e) = e {
            if e.rule.is_empty() || e.file.is_empty() || e.pattern.is_empty() {
                bail!(
                    "allowlist entry at line {}: `rule`, `file` and `pattern` are \
                     all required",
                    e.line
                );
            }
            if e.reason.trim().is_empty() {
                bail!(
                    "allowlist entry at line {} ({} {}): empty or missing `reason` — \
                     every pinned site must say why it is acceptable",
                    e.line,
                    e.rule,
                    e.file
                );
            }
            if !RULES.iter().any(|r| r.id == e.rule) {
                bail!("allowlist entry at line {}: unknown rule `{}`", e.line, e.rule);
            }
            entries.push(e);
        }
        Ok(())
    };
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            finish(current.take(), &mut entries)?;
            current = Some(AllowEntry {
                rule: String::new(),
                file: String::new(),
                pattern: String::new(),
                reason: String::new(),
                line: lineno,
            });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("allowlist line {lineno}: expected `[[allow]]` or `key = \"value\"`, got `{line}`");
        };
        let Some(e) = current.as_mut() else {
            bail!("allowlist line {lineno}: `{}` outside any [[allow]] section", key.trim());
        };
        let value = parse_toml_string(value.trim())
            .with_context(|| format!("allowlist line {lineno}"))?;
        match key.trim() {
            "rule" => e.rule = value,
            "file" => e.file = value,
            "pattern" => e.pattern = value,
            "reason" => e.reason = value,
            other => bail!("allowlist line {lineno}: unknown key `{other}`"),
        }
    }
    finish(current.take(), &mut entries)?;
    Ok(entries)
}

/// Parse one double-quoted TOML string with `\"` / `\\` escapes.
fn parse_toml_string(s: &str) -> Result<String> {
    let inner = s
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .with_context(|| format!("expected a double-quoted string, got `{s}`"))?;
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => bail!("unsupported escape `\\{}` in `{s}`", other.unwrap_or(' ')),
            }
        } else if c == '"' {
            bail!("unescaped `\"` inside `{s}`");
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

/// Run the audit: lex + check every file under `<root>/rust/src`, then
/// intersect the findings with the allowlist. With `cfg.rule` set, both
/// findings and allowlist entries are restricted to that rule (so pins
/// for other rules are not reported stale).
pub fn run(cfg: &AuditConfig) -> Result<AuditReport> {
    if let Some(r) = &cfg.rule {
        if !RULES.iter().any(|info| info.id == r) {
            bail!(
                "unknown rule `{r}` (known: {})",
                RULES.iter().map(|i| i.id).collect::<Vec<_>>().join(", ")
            );
        }
    }
    let src_root = cfg.root.join("rust/src");
    let mut files = Vec::new();
    walk(&src_root, &mut files)?;

    let mut entries = match fs::read_to_string(&cfg.allow) {
        Ok(text) => parse_allowlist(&text)
            .with_context(|| format!("parsing {}", cfg.allow.display()))?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(e).with_context(|| format!("reading {}", cfg.allow.display()))
        }
    };
    if let Some(r) = &cfg.rule {
        entries.retain(|e| &e.rule == r);
    }
    let mut hits = vec![0usize; entries.len()];

    let mut report = AuditReport { files_scanned: files.len(), ..Default::default() };
    for path in &files {
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(&cfg.root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        for finding in rules::check_file(&rel, &src) {
            if let Some(r) = &cfg.rule {
                if finding.rule != r {
                    continue;
                }
            }
            let pin = entries.iter().position(|e| {
                e.rule == finding.rule
                    && e.file == finding.file
                    && finding.excerpt.contains(&e.pattern)
            });
            match pin {
                Some(idx) => {
                    hits[idx] += 1;
                    report.allowed.push((finding, entries[idx].reason.clone()));
                }
                None => report.violations.push(finding),
            }
        }
    }
    report.stale = entries
        .iter()
        .zip(&hits)
        .filter(|&(_, &h)| h == 0)
        .map(|(e, _)| e.clone())
        .collect();
    Ok(report)
}

/// Render the human report: violations first (rule, location, excerpt,
/// why), then stale allowlist entries, then a one-line summary.
pub fn render_text(report: &AuditReport) -> String {
    let mut out = String::new();
    for f in &report.violations {
        out.push_str(&format!(
            "{} {}:{}\n    {}\n    {}\n",
            f.rule, f.file, f.line, f.excerpt, f.msg
        ));
    }
    for e in &report.stale {
        out.push_str(&format!(
            "STALE allowlist entry (allow.toml:{}): {} {} pattern \"{}\" matched \
             nothing — delete it\n",
            e.line, e.rule, e.file, e.pattern
        ));
    }
    out.push_str(&format!(
        "audit: {} file(s), {} violation(s), {} allowlisted, {} stale entr{}\n",
        report.files_scanned,
        report.violations.len(),
        report.allowed.len(),
        report.stale.len(),
        if report.stale.len() == 1 { "y" } else { "ies" }
    ));
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine report (`--json`): a stable, diffable document CI
/// uploads as an artifact so violations can be compared across PRs.
pub fn render_json(report: &AuditReport) -> String {
    let finding = |f: &Finding, reason: Option<&str>| -> String {
        let mut s = format!(
            "{{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"excerpt\": \"{}\", \
             \"msg\": \"{}\"",
            f.rule,
            json_escape(&f.file),
            f.line,
            json_escape(&f.excerpt),
            json_escape(&f.msg)
        );
        if let Some(r) = reason {
            s.push_str(&format!(", \"reason\": \"{}\"", json_escape(r)));
        }
        s.push('}');
        s
    };
    let violations: Vec<String> =
        report.violations.iter().map(|f| finding(f, None)).collect();
    let allowed: Vec<String> = report
        .allowed
        .iter()
        .map(|(f, r)| finding(f, Some(r)))
        .collect();
    let stale: Vec<String> = report
        .stale
        .iter()
        .map(|e| {
            format!(
                "{{\"rule\": \"{}\", \"file\": \"{}\", \"pattern\": \"{}\"}}",
                e.rule,
                json_escape(&e.file),
                json_escape(&e.pattern)
            )
        })
        .collect();
    format!(
        "{{\n  \"version\": 1,\n  \"files_scanned\": {},\n  \"clean\": {},\n  \
         \"violations\": [{}],\n  \"allowed\": [{}],\n  \"stale_allow_entries\": [{}]\n}}\n",
        report.files_scanned,
        report.clean(),
        violations.join(", "),
        allowed.join(", "),
        stale.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scratch repo tree under the target-adjacent temp dir; removed on
    /// drop. Names are keyed by pid + a label so parallel tests never
    /// collide.
    struct TempRepo {
        root: PathBuf,
    }

    impl TempRepo {
        fn new(label: &str) -> Self {
            let root = std::env::temp_dir()
                .join(format!("sgp_audit_{}_{label}", std::process::id()));
            let _ = fs::remove_dir_all(&root);
            fs::create_dir_all(root.join("rust/src/gossip")).unwrap();
            Self { root }
        }

        fn write(&self, rel: &str, contents: &str) {
            let p = self.root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, contents).unwrap();
        }

        fn audit(&self) -> AuditReport {
            run(&AuditConfig::new(self.root.clone())).unwrap()
        }
    }

    impl Drop for TempRepo {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    #[test]
    fn injected_fixture_violations_fail_one_per_rule() {
        // Acceptance fixture: one seeded violation per rule, each must
        // turn the report non-clean (the CLI exits non-zero under
        // --deny exactly when `clean()` is false).
        let fixtures: &[(&str, &str, &str)] = &[
            ("D001", "rust/src/gossip/bad.rs", "use std::collections::HashMap;\n"),
            ("D002", "rust/src/gossip/bad.rs", "fn t() -> std::time::Instant { std::time::Instant::now() }\n"),
            (
                "U001",
                "rust/src/gossip/bad.rs",
                "fn u(p: *mut u8) { unsafe { p.write(1) } }\n",
            ),
            ("P001", "rust/src/gossip/bad.rs", "fn p(o: Option<u8>) -> u8 { o.unwrap() }\n"),
            (
                "A001",
                "rust/src/gossip/bad.rs",
                "// audit: zero-alloc\nfn a() -> Vec<u8> { vec![1] }\n",
            ),
        ];
        for (rule, rel, src) in fixtures {
            let repo = TempRepo::new(&format!("fixture_{rule}"));
            repo.write(rel, src);
            let report = repo.audit();
            assert!(
                report.violations.iter().any(|f| &f.rule == rule),
                "{rule}: fixture not caught: {report:?}"
            );
            assert!(!report.clean(), "{rule}: report must fail --deny");
        }
    }

    #[test]
    fn allowlist_pins_require_reasons_and_go_stale() {
        let repo = TempRepo::new("allowlist");
        repo.write("rust/src/gossip/a.rs", "fn p(o: Option<u8>) -> u8 { o.unwrap() }\n");
        // Unpinned: one violation.
        let r = repo.audit();
        assert_eq!(r.violations.len(), 1);
        // Pinned with a reason: allowed, clean.
        repo.write(
            "analysis/allow.toml",
            "[[allow]]\nrule = \"P001\"\nfile = \"rust/src/gossip/a.rs\"\n\
             pattern = \"o.unwrap()\"\nreason = \"test pin\"\n",
        );
        let r = repo.audit();
        assert!(r.clean(), "{r:?}");
        assert_eq!(r.allowed.len(), 1);
        assert_eq!(r.allowed[0].1, "test pin");
        // A reasonless pin is a parse error, not a silent pass.
        repo.write(
            "analysis/allow.toml",
            "[[allow]]\nrule = \"P001\"\nfile = \"rust/src/gossip/a.rs\"\npattern = \"o.unwrap()\"\n",
        );
        let err = run(&AuditConfig::new(repo.root.clone()));
        assert!(err.is_err(), "missing reason must fail");
        // A pin matching nothing is stale → not clean.
        repo.write(
            "analysis/allow.toml",
            "[[allow]]\nrule = \"P001\"\nfile = \"rust/src/gossip/a.rs\"\n\
             pattern = \"o.unwrap()\"\nreason = \"test pin\"\n\n[[allow]]\n\
             rule = \"D001\"\nfile = \"rust/src/gossip/zz.rs\"\npattern = \"HashMap\"\n\
             reason = \"stale on purpose\"\n",
        );
        let r = repo.audit();
        assert_eq!(r.stale.len(), 1);
        assert!(!r.clean(), "stale entries fail --deny");
    }

    #[test]
    fn rule_filter_restricts_findings_and_staleness() {
        let repo = TempRepo::new("rulefilter");
        repo.write(
            "rust/src/gossip/a.rs",
            "use std::collections::HashMap;\nfn p(o: Option<u8>) -> u8 { o.unwrap() }\n",
        );
        repo.write(
            "analysis/allow.toml",
            "[[allow]]\nrule = \"P001\"\nfile = \"rust/src/gossip/a.rs\"\n\
             pattern = \"o.unwrap()\"\nreason = \"pin\"\n",
        );
        let mut cfg = AuditConfig::new(repo.root.clone());
        cfg.rule = Some("D001".to_string());
        let r = run(&cfg).unwrap();
        assert_eq!(r.violations.len(), 1, "only the D001 finding");
        assert_eq!(r.violations[0].rule, "D001");
        assert!(r.stale.is_empty(), "the P001 pin is out of scope, not stale");
        let mut cfg = AuditConfig::new(repo.root.clone());
        cfg.rule = Some("NOPE".to_string());
        assert!(run(&cfg).is_err(), "unknown rule ids are rejected");
    }

    #[test]
    fn json_report_is_well_formed_and_parseable() {
        let repo = TempRepo::new("json");
        repo.write(
            "rust/src/gossip/a.rs",
            "fn p(o: Option<&str>) -> &str { o.expect(\"quote \\\" and tab\") }\n",
        );
        let r = repo.audit();
        let json = render_json(&r);
        // Round-trip through the repo's own JSON parser: escaping bugs
        // (the excerpt contains a quote and a backslash) surface here.
        use crate::model::json::Json;
        let doc = Json::parse(&json).expect("valid JSON");
        assert_eq!(doc.get("version").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(doc.get("clean"), Some(&Json::Bool(false)));
        let v = doc.get("violations").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(
            v[0].get("rule").and_then(|r| r.as_str()),
            Some("P001")
        );
    }

    #[test]
    fn missing_allowlist_is_empty_not_an_error() {
        let repo = TempRepo::new("noallow");
        repo.write("rust/src/gossip/a.rs", "fn ok() {}\n");
        let r = repo.audit();
        assert!(r.clean());
        assert_eq!(r.files_scanned, 1);
    }

    #[test]
    fn self_test_repo_tree_passes_audit_deny() {
        // The acceptance gate: `repro audit --deny` on this repo's own
        // tree must pass — every finding either fixed or pinned with a
        // reason, and no pin stale. CARGO_MANIFEST_DIR is the repo root.
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let report = run(&AuditConfig::new(root)).expect("audit runs");
        assert!(
            report.clean(),
            "repo tree fails `repro audit --deny`:\n{}",
            render_text(&report)
        );
        assert!(report.files_scanned > 20, "walker found the tree");
        assert!(
            !report.allowed.is_empty(),
            "the committed allowlist pins the known justified sites"
        );
    }
}

//! Comment/string/raw-string-aware Rust lexer for the audit pass.
//!
//! Deliberately **not** a full Rust lexer: it distinguishes exactly what
//! the rule engine needs — code identifiers and punctuation, with 1-based
//! line numbers — from everything a naive `grep` would trip over, so a
//! `HashMap` mentioned in a doc comment or an `unwrap` inside a string
//! literal can never produce a finding. Handled:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments
//!   (`/* /* */ */`), collected separately with their line spans so the
//!   `SAFETY:` / anchor-comment rules can reason about them;
//! * string literals with escapes, raw strings with any number of `#`
//!   guards (`r"…"`, `r##"…"##`), byte and raw-byte strings (`b"…"`,
//!   `br#"…"#`), all possibly multi-line;
//! * char literals (`'x'`, `'\n'`, `'\u{1F600}'`, `b'q'`) vs lifetimes
//!   (`'a`, `'_`) — the classic single-quote ambiguity;
//! * raw identifiers (`r#match` lexes as the identifier `match`);
//! * numbers (consumed as opaque literals — their text is never matched).
//!
//! Literal tokens keep a placeholder text (`"str"`, `"char"`, `"num"`),
//! never their contents: rules match identifier text and punctuation
//! shapes only, so literal contents are unreachable by construction.

/// Kinds of significant token the rule engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `HashMap`, `unwrap`, …).
    Ident,
    /// One punctuation character (`.`, `!`, `:`, `{`, …).
    Punct,
    /// String/char/number literal — contents deliberately opaque.
    Literal,
}

/// One significant source token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    /// What kind of token this is.
    pub kind: TokKind,
    /// Identifier text / punctuation char; placeholder for literals.
    pub text: String,
    /// 1-based line the token starts on.
    pub line: usize,
}

/// One comment (line, doc, or block), with its text and line span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Comment {
    /// The comment text, delimiters included.
    pub text: String,
    /// 1-based line the comment starts on.
    pub line_start: usize,
    /// 1-based line the comment ends on (= `line_start` for line comments).
    pub line_end: usize,
}

/// Lexer output: the code-token stream plus every comment.
#[derive(Debug, Default)]
pub struct Lexed {
    /// Significant code tokens in source order.
    pub tokens: Vec<Tok>,
    /// All comments in source order.
    pub comments: Vec<Comment>,
}

/// Lex `src` into code tokens and comments. Total: unclosed literals and
/// comments are consumed to end-of-file rather than erroring — the audit
/// must never abort on a file `rustc` would reject anyway.
pub fn lex(src: &str) -> Lexed {
    Lexer {
        cs: src.chars().collect(),
        i: 0,
        line: 1,
        out: Lexed::default(),
    }
    .run()
}

struct Lexer {
    cs: Vec<char>,
    i: usize,
    line: usize,
    out: Lexed,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    /// Consume one char, tracking the line counter.
    fn bump(&mut self) -> Option<char> {
        let c = self.cs.get(self.i).copied();
        if let Some(c) = c {
            self.i += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push_tok(&mut self, kind: TokKind, text: String, line: usize) {
        self.out.tokens.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                self.string(TokKind::Literal);
            } else if c == '\'' {
                self.char_or_lifetime();
            } else if is_ident_start(c) {
                self.ident_or_prefixed();
            } else if c.is_ascii_digit() {
                self.number();
            } else {
                let line = self.line;
                self.bump();
                self.push_tok(TokKind::Punct, c.to_string(), line);
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { text, line_start: line, line_end: line });
    }

    /// Nested block comment; unterminated comments swallow the rest of
    /// the file (rustc rejects them; the audit just keeps lexing nothing).
    fn block_comment(&mut self) {
        let line_start = self.line;
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.out.comments.push(Comment { text, line_start, line_end: self.line });
    }

    /// A `"…"` literal with `\`-escapes (possibly multi-line).
    fn string(&mut self, kind: TokKind) {
        let line = self.line;
        self.bump(); // opening quote
        while let Some(c) = self.bump() {
            if c == '\\' {
                self.bump(); // whatever is escaped, including `"` and `\`
            } else if c == '"' {
                break;
            }
        }
        self.push_tok(kind, "str".to_string(), line);
    }

    /// A raw string starting at the current `"`, closed by `"` followed by
    /// `hashes` `#` characters.
    fn raw_string(&mut self, hashes: usize) {
        let line = self.line;
        self.bump(); // opening quote
        'scan: while let Some(c) = self.bump() {
            if c == '"' {
                for k in 0..hashes {
                    if self.peek(k) != Some('#') {
                        continue 'scan;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
        }
        self.push_tok(TokKind::Literal, "str".to_string(), line);
    }

    /// `'x'` / `'\n'` / `'\u{…}'` char literals vs `'a` / `'_` lifetimes.
    fn char_or_lifetime(&mut self) {
        let line = self.line;
        let next = self.peek(1);
        let is_char = match next {
            Some('\\') => true,
            Some(c) if is_ident_continue(c) => self.peek(2) == Some('\''),
            Some('\'') | None => false,
            Some(_) => true, // '(' and friends: a one-symbol char literal
        };
        if is_char {
            self.bump(); // opening quote
            while let Some(c) = self.bump() {
                if c == '\\' {
                    self.bump();
                } else if c == '\'' {
                    break;
                }
            }
            self.push_tok(TokKind::Literal, "char".to_string(), line);
        } else {
            // Lifetime (or a stray quote): consume the quote + ident run.
            self.bump();
            while let Some(c) = self.peek(0) {
                if !is_ident_continue(c) {
                    break;
                }
                self.bump();
            }
            self.push_tok(TokKind::Literal, "lifetime".to_string(), line);
        }
    }

    /// Identifier, or one of the identifier-prefixed literal forms:
    /// `r"…"`, `r#"…"#`, `r#ident`, `b"…"`, `b'…'`, `br#"…"#`.
    fn ident_or_prefixed(&mut self) {
        let line = self.line;
        let c = self.peek(0).unwrap_or(' ');
        if c == 'r' || c == 'b' {
            // Longest literal prefix first: br / b / r followed by a quote
            // or by `#…#"` opens a literal, not an identifier.
            let after = if c == 'b' && self.peek(1) == Some('r') { 2 } else { 1 };
            let mut hashes = 0usize;
            while self.peek(after + hashes) == Some('#') {
                hashes += 1;
            }
            let quote = self.peek(after + hashes);
            let is_raw = c == 'r' || after == 2;
            if is_raw && quote == Some('"') {
                for _ in 0..after + hashes {
                    self.bump();
                }
                self.raw_string(hashes);
                return;
            }
            if c == 'r' && hashes == 1 && quote.map(is_ident_start) == Some(true) {
                // Raw identifier r#match: lex the bare identifier.
                self.bump();
                self.bump();
                self.ident(line);
                return;
            }
            if c == 'b' && after == 1 && hashes == 0 {
                if self.peek(1) == Some('"') {
                    self.bump();
                    self.string(TokKind::Literal);
                    return;
                }
                if self.peek(1) == Some('\'') {
                    self.bump();
                    self.char_or_lifetime();
                    return;
                }
            }
        }
        self.ident(line);
    }

    fn ident(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if !is_ident_continue(c) {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push_tok(TokKind::Ident, text, line);
    }

    fn number(&mut self) {
        let line = self.line;
        while let Some(c) = self.peek(0) {
            if !is_ident_continue(c) {
                break;
            }
            self.bump();
        }
        self.push_tok(TokKind::Literal, "num".to_string(), line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<(String, usize)> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| (t.text, t.line))
            .collect()
    }

    #[test]
    fn idents_carry_exact_lines() {
        let src = "fn a() {}\n\nfn bee() {\n    call();\n}\n";
        let got = idents(src);
        assert_eq!(
            got,
            vec![
                ("fn".to_string(), 1),
                ("a".to_string(), 1),
                ("fn".to_string(), 3),
                ("bee".to_string(), 3),
                ("call".to_string(), 4),
            ]
        );
    }

    #[test]
    fn comments_hide_code_words_but_are_collected() {
        let src = "// HashMap unwrap unsafe\nlet x = 1; /* SystemTime */\n";
        let lexed = lex(src);
        assert!(lexed.tokens.iter().all(|t| t.text != "HashMap"
            && t.text != "unwrap"
            && t.text != "unsafe"
            && t.text != "SystemTime"));
        assert_eq!(lexed.comments.len(), 2);
        assert_eq!(lexed.comments[0].line_start, 1);
        assert!(lexed.comments[0].text.contains("HashMap"));
        assert_eq!(lexed.comments[1].line_start, 2);
    }

    #[test]
    fn nested_block_comments_and_spans() {
        let src = "/* outer /* inner\n */ still outer\n*/ fn after() {}\n";
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].line_start, 1);
        assert_eq!(lexed.comments[0].line_end, 3);
        let fns: Vec<_> =
            lexed.tokens.iter().filter(|t| t.text == "fn").collect();
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].line, 3);
    }

    #[test]
    fn strings_are_opaque_and_multiline_tracks_lines() {
        let src = "let s = \"unsafe { HashMap::new() }\\\" still\";\nlet t = \"a\nb\";\nafter();\n";
        let lexed = lex(src);
        assert!(lexed.tokens.iter().all(|t| t.text != "unsafe" && t.text != "HashMap"));
        let after: Vec<_> =
            lexed.tokens.iter().filter(|t| t.text == "after").collect();
        assert_eq!(after[0].line, 4, "multi-line string advanced the count");
    }

    #[test]
    fn raw_strings_with_hash_guards() {
        // The r##"…"## body contains a bare `"#` that must not close it.
        let src = "let s = r##\"unwrap() \"# not the end\"##;\nnext();\n";
        let lexed = lex(src);
        assert!(lexed.tokens.iter().all(|t| t.text != "unwrap" && t.text != "not"));
        let next: Vec<_> = lexed.tokens.iter().filter(|t| t.text == "next").collect();
        assert_eq!(next[0].line, 2);
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let src = "let a = b\"unsafe\"; let b2 = br#\"unwrap()\"#; let c = b'q';\nok();\n";
        let lexed = lex(src);
        assert!(lexed.tokens.iter().all(|t| t.text != "unsafe" && t.text != "unwrap"
            && t.text != "q"));
        assert!(lexed.tokens.iter().any(|t| t.text == "ok"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let q = '\\''; let n = '\\n'; }\n";
        let lexed = lex(src);
        // The lifetime's `a` never leaks as a bare identifier token, and
        // char contents stay opaque.
        let ids: Vec<_> =
            lexed.tokens.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
        assert!(!ids.contains(&"a"), "{ids:?}");
        assert!(ids.contains(&"str"));
        let chars =
            lexed.tokens.iter().filter(|t| t.text == "char").count();
        assert_eq!(chars, 3);
    }

    #[test]
    fn raw_identifiers_lex_as_their_bare_name() {
        let src = "let r#match = 1; let r2 = r#match;\n";
        let lexed = lex(src);
        let matches = lexed.tokens.iter().filter(|t| t.text == "match").count();
        assert_eq!(matches, 2);
    }

    #[test]
    fn doc_comment_with_code_fence_is_still_a_comment() {
        let src = "/// ```\n/// map.unwrap();\n/// ```\nfn documented() {}\n";
        let lexed = lex(src);
        assert!(lexed.tokens.iter().all(|t| t.text != "unwrap"));
        assert_eq!(lexed.comments.len(), 3);
        assert!(lexed.comments[1].text.contains("unwrap"));
    }
}

//! Small, fast, dependency-free PRNG (PCG-XSH-RR 64/32) with the handful of
//! distributions the simulator needs (uniform, Gaussian, log-normal,
//! categorical). Deterministic across platforms — every experiment is
//! reproducible from its seed.

/// PCG32: 64-bit state, 32-bit output. Reference: O'Neill, "PCG: A Family of
/// Simple Fast Space-Efficient Statistically Good Algorithms for Random
/// Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// Cached second output of the last Box–Muller draw.
    gauss_spare: Option<f64>,
}

impl Pcg {
    /// A generator seeded on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (e.g. one per node).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform u64 (two u32 draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for simulator use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Log-normal with parameters of the underlying normal (mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gaussian()).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal f32s.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() as f32).collect()
    }

    /// The generator's full position: `(state, inc, cached Box–Muller
    /// spare)`. Together with [`Self::from_cursor`] this is the durable
    /// form of the stream — a generator rebuilt at a cursor continues the
    /// exact draw sequence, including a pending Gaussian spare (which is
    /// why the spare is part of the cursor: dropping it would desync any
    /// stream snapshotted between the two halves of a Box–Muller draw).
    /// Persisted by the [`crate::snapshot`] RNG section.
    pub fn cursor(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator at a position previously captured with
    /// [`Self::cursor`] — the restore half of the snapshot contract.
    pub fn from_cursor(state: u64, inc: u64, gauss_spare: Option<f64>) -> Self {
        Self { state, inc, gauss_spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn cursor_roundtrip_continues_the_stream_bit_identically() {
        let mut a = Pcg::new(99);
        // Burn an odd number of Gaussian draws so a spare is cached —
        // the cursor must carry it.
        for _ in 0..7 {
            let _ = a.gaussian();
        }
        let (state, inc, spare) = a.cursor();
        assert!(spare.is_some(), "odd draw count leaves a cached spare");
        let mut b = Pcg::from_cursor(state, inc, spare);
        for _ in 0..100 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::with_stream(42, 1);
        let mut b = Pcg::with_stream(42, 2);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg::new(7);
        let m: f64 = (0..20_000).map(|_| rng.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg::new(3);
        let xs: Vec<f64> = (0..40_000).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Pcg::new(9);
        let mut xs: Vec<f64> = (0..10_001).map(|_| rng.lognormal(0.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[5000];
        assert!((med - 1.0).abs() < 0.05, "{med}"); // median = e^mu = 1
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Experiment drivers: one function per table/figure in the paper
//! (DESIGN.md §3 maps each to its source). Every driver writes CSV series
//! under `results/` and prints the paper-shaped table to stdout; the
//! recorded outputs live in EXPERIMENTS.md.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::TrainerBuilder;
use crate::faults::harness::{run_quadratic, FaultRunConfig, FaultRunStats};
use crate::faults::{Crash, FaultPlan};
use crate::gossip::{Compression, ExecPolicy, PushSumEngine};
use crate::metrics::{self, print_table, RunResult};
use crate::net::{self, ComputeModel, LinkModel, OwnedCommPattern};
use crate::optim::LrSchedule;
use crate::runtime::Runtime;
use crate::topology::{spectral, Schedule, TopologyKind};

/// `results/` output directory (created on first use).
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Scale factor applied to epoch counts in `--fast` mode.
fn epochs(full: f64, fast: bool) -> f64 {
    if fast {
        (full / 6.0).max(3.0)
    } else {
        full
    }
}

/// Run one configuration with a registry-named algorithm; `tune` may add
/// builder knobs (τ, switch point, topology override, …).
fn run_tuned<'rt>(
    rt: &'rt Runtime,
    mut cfg: TrainConfig,
    algo: &str,
    tune: impl FnOnce(TrainerBuilder<'rt>) -> TrainerBuilder<'rt>,
) -> Result<RunResult> {
    // Shortened (--fast) runs keep the *shape* of the Goyal protocol:
    // rescale the default 30/60/80 milestones to the actual epoch count.
    if cfg.epochs < 90.0 && cfg.lr.milestones == vec![30.0, 60.0, 80.0] {
        let s = cfg.epochs / 90.0;
        cfg.lr.milestones = vec![30.0 * s, 60.0 * s, 80.0 * s];
    }
    let builder = TrainerBuilder::new(rt).config(cfg).algorithm(algo);
    let mut t = tune(builder).build()?;
    let label = format!("{} n={}", t.algo.name(), t.cfg.n_nodes);
    eprintln!(
        "[run] {label}: {} iters × {} nodes …",
        t.cfg.total_iters(),
        t.cfg.n_nodes
    );
    let r = t.run()?;
    eprintln!(
        "[run] {label}: loss={:.4} val_metric={:.4} sim={:.1}s wall={:.1}s",
        r.final_train_loss(),
        r.final_val_metric,
        r.sim_total_s,
        r.wall_s
    );
    r.write_csv(&results_dir())?;
    Ok(r)
}

/// Registry-named run with default knobs.
fn run_one(rt: &Runtime, cfg: TrainConfig, algo: &str) -> Result<RunResult> {
    run_tuned(rt, cfg, algo, |b| b)
}

fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

// ===========================================================================
// Figure 1 (a–d) + Table 1: scaling & convergence, AR vs SGP vs D-PSGD
// ===========================================================================
/// Fig. 1a–d + Table 1: accuracy & per-iteration time scaling, AR vs
/// D-PSGD vs SGP over node counts.
pub fn fig1_table1(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let ns: &[usize] = if fast { &[4, 8] } else { &[4, 8, 16, 32] };
    let mut rows = Vec::new();
    for &n in ns {
        let mk = |seed| {
            let mut c = TrainConfig::imagenet_like(model, n, seed);
            c.epochs = epochs(90.0, fast);
            c
        };
        let runs = vec![
            run_one(rt, mk(1), "ar-sgd")?,
            run_one(rt, mk(1), "dpsgd")?,
            run_one(rt, mk(1), "sgp")?,
        ];
        for r in &runs {
            rows.push(vec![
                r.label.split("_n").next().unwrap_or("?").to_string(),
                n.to_string(),
                pct(r.final_val_metric),
                metrics::hours(r.sim_total_s),
                format!("{:.3}s", r.avg_iter_time()),
            ]);
        }
    }
    print_table(
        "Table 1 / Fig 1 — val accuracy & sim training time (10 GbE)",
        &["method", "nodes", "val acc", "train time", "s/iter"],
        &rows,
    );
    // Fig 1c/d: timing-only sweeps over both fabrics.
    fig1_timing_csv()?;
    Ok(())
}

/// Fig 1c/d + Fig D.4 substrate: avg time/iteration vs n on both fabrics.
pub fn fig1_timing_csv() -> Result<()> {
    let msg = 100 << 20; // ResNet-50-scale message
    let compute = ComputeModel::resnet50_dgx1();
    let mut csv = String::from("fabric,method,nodes,s_per_iter\n");
    let mut rows = Vec::new();
    for (fabric, link) in [
        ("ethernet", LinkModel::ethernet_10g()),
        ("infiniband", LinkModel::infiniband_100g()),
    ] {
        for n in [4usize, 8, 16, 32] {
            let ar = net::average_iteration_time(n, link.clone(), &compute, 300, 7, |_| {
                OwnedCommPattern::AllReduce { bytes: msg }
            });
            let sgp = net::average_iteration_time(n, link.clone(), &compute, 300, 7, |_| {
                OwnedCommPattern::PushSum {
                    schedule: Schedule::new(TopologyKind::OnePeerExp, n),
                    bytes: msg,
                    tau: 0,
                }
            });
            let osgp =
                net::average_iteration_time(n, link.clone(), &compute, 300, 7, |_| {
                    OwnedCommPattern::PushSum {
                        schedule: Schedule::new(TopologyKind::OnePeerExp, n),
                        bytes: msg,
                        tau: 1,
                    }
                });
            let dpsgd =
                net::average_iteration_time(n, link.clone(), &compute, 300, 7, |_| {
                    OwnedCommPattern::Symmetric {
                        schedule: Schedule::new(TopologyKind::BipartiteExp, n),
                        bytes: msg,
                        handshake: 2.0,
                    }
                });
            for (m, v) in
                [("AR-SGD", ar), ("SGP", sgp), ("1-OSGP", osgp), ("D-PSGD", dpsgd)]
            {
                csv.push_str(&format!("{fabric},{m},{n},{v:.4}\n"));
                rows.push(vec![
                    fabric.into(),
                    m.into(),
                    n.to_string(),
                    format!("{v:.3}"),
                ]);
            }
        }
    }
    std::fs::write(results_dir().join("fig1cd_timing.csv"), csv)?;
    print_table(
        "Fig 1c/d — simulated seconds/iteration (ResNet-50-scale messages)",
        &["fabric", "method", "nodes", "s/iter"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Table 2: mean ± max-abs-dev over 5 seeds (InfiniBand)
// ===========================================================================
/// Table 2: mean ± max-abs-deviation over seeds on the InfiniBand fabric.
pub fn table2(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let seeds: &[u64] = if fast { &[1, 2, 3] } else { &[1, 2, 3, 4, 5] };
    let ns: &[usize] = &[4, 16];
    let mut rows = Vec::new();
    for &n in ns {
        for (algo_name, algo) in [("AR-SGD", "ar-sgd"), ("SGP", "sgp")] {
            let mut accs = Vec::new();
            let mut times = Vec::new();
            for &seed in seeds {
                let mut cfg = TrainConfig::imagenet_like(model, n, seed);
                cfg.epochs = epochs(90.0, fast);
                cfg.link = LinkModel::infiniband_100g();
                cfg.eval_every_epochs = 0.0; // only final eval — faster
                cfg.track_consensus = false;
                let r = run_one(rt, cfg, algo)?;
                accs.push(r.final_val_metric);
                times.push(r.sim_total_s / 3600.0);
            }
            let (am, ad) = metrics::mean_maxdev(&accs);
            let (tm, td) = metrics::mean_maxdev(&times);
            rows.push(vec![
                algo_name.into(),
                n.to_string(),
                format!("{:.1} ± {:.1}%", 100.0 * am, 100.0 * ad),
                format!("{tm:.2} ± {td:.2} h"),
            ]);
        }
    }
    print_table(
        "Table 2 — mean ± max abs deviation over seeds (100 Gb IB)",
        &["method", "nodes", "val acc", "train time"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Figure 2: parameter deviations, sparse vs dense topology (16 nodes)
// ===========================================================================
/// Fig. 2: consensus distance over training, sparse vs dense topology.
pub fn fig2(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let n = 16;
    let mut rows = Vec::new();
    for (tag, kind) in [
        ("sparse-1peer", TopologyKind::OnePeerExp),
        ("dense-complete", TopologyKind::Complete),
    ] {
        let mut cfg = TrainConfig::imagenet_like(model, n, 3);
        cfg.epochs = epochs(90.0, fast);
        cfg.eval_every_epochs = epochs(90.0, fast) / 18.0;
        cfg.track_consensus = true;
        let r = run_tuned(rt, cfg, "sgp", |b| b.topology(kind))?;
        let mut csv = String::from("epoch,lr,consensus_mean,consensus_min,consensus_max\n");
        for e in &r.evals {
            csv.push_str(&format!(
                "{:.2},{:.6},{:.6e},{:.6e},{:.6e}\n",
                e.epoch,
                0.0,
                e.consensus_mean,
                e.consensus_min,
                e.consensus_max
            ));
        }
        std::fs::write(results_dir().join(format!("fig2_{tag}.csv")), csv)?;
        for e in r.evals.iter().take(6) {
            rows.push(vec![
                tag.into(),
                format!("{:.1}", e.epoch),
                format!("{:.3e}", e.consensus_mean),
            ]);
        }
        if let Some(e) = r.evals.last() {
            rows.push(vec![
                tag.into(),
                format!("{:.1}", e.epoch),
                format!("{:.3e}", e.consensus_mean),
            ]);
        }
    }
    print_table(
        "Fig 2 — mean ‖zᵢ − x̄‖ at epoch ends (16 nodes)",
        &["topology", "epoch", "consensus dist"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Table 3: communication topology vs speed/accuracy (hybrids)
// ===========================================================================
/// Table 3: topology/hybrid speed–accuracy tradeoff.
pub fn table3(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let ns: &[usize] = if fast { &[16] } else { &[16, 32] };
    let mut rows = Vec::new();
    for &n in ns {
        let mk = || {
            let mut c = TrainConfig::imagenet_like(model, n, 5);
            c.epochs = epochs(90.0, fast);
            c.track_consensus = false;
            c
        };
        let switch = (mk().total_iters() as f64 / 3.0).round() as u64; // epoch 30
        let algos = ["ar-sgd", "sgp-2p", "sgp", "hybrid-ar-1p", "hybrid-2p-1p"];
        for algo in algos {
            let r = run_tuned(rt, mk(), algo, |b| b.switch_at(switch))?;
            rows.push(vec![
                r.label.split("_n").next().unwrap_or("?").to_string(),
                n.to_string(),
                pct(r.final_val_metric),
                metrics::hours(r.sim_total_s),
            ]);
        }
    }
    print_table(
        "Table 3 — topology/hybrid speed-accuracy tradeoff (10 GbE)",
        &["method", "nodes", "val acc", "train time"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Table 4: overlap + async comparison (16 nodes)
// ===========================================================================
/// Table 4: overlap/async methods incl. the biased ablation and DaSGD.
pub fn table4(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let n = 16;
    let mk = || {
        let mut c = TrainConfig::imagenet_like(model, n, 7);
        c.epochs = epochs(90.0, fast);
        c.track_consensus = false;
        c
    };
    // The registry makes the grid a name list — DaSGD (the post-paper
    // delayed-averaging method) rides along to show the open family.
    let algos = ["ar-sgd", "dpsgd", "adpsgd", "sgp", "osgp-biased", "osgp", "dasgd"];
    let mut rows = Vec::new();
    for algo in algos {
        let mut cfg = mk();
        if algo == "adpsgd" {
            // Stale asynchronous gradients tolerate a lower peak LR than
            // the synchronous linear-scaling rule on this small workload
            // (Lian et al. 2018 note the same sensitivity).
            cfg.lr.scale = cfg.lr.scale.min(8.0);
        }
        let r = run_tuned(rt, cfg, algo, |b| b.tau(1).grad_delay(1))?;
        rows.push(vec![
            r.label.split("_n").next().unwrap_or("?").to_string(),
            format!("{:.4}", r.final_train_loss()),
            pct(r.final_val_metric),
            metrics::hours(r.sim_total_s),
        ]);
    }
    print_table(
        "Table 4 — overlap & async methods, 16 nodes (10 GbE)",
        &["method", "train loss", "val acc", "train time"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Table 5: fixed runtime budget (32 nodes; 90 vs 270 epochs)
// ===========================================================================
/// Table 5: fixed-runtime budget comparison (90 vs 270 epochs).
pub fn table5(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let n = 32;
    let e90 = epochs(90.0, fast);
    let e270 = 3.0 * e90;
    let mut rows = Vec::new();

    // The linear-scaling rule destabilizes this small-batch substitute
    // workload beyond ~8× (Goyal et al. note the same breakdown regime);
    // cap the peak LR for the whole Table-5 grid so the 90- vs 270-epoch
    // comparison isolates the runtime-budget effect the table is about.
    let cap_lr = |cfg: &mut TrainConfig| cfg.lr.scale = cfg.lr.scale.min(8.0);

    let mut cfg = TrainConfig::imagenet_like(model, n, 9);
    cfg.epochs = e90;
    cfg.track_consensus = false;
    cap_lr(&mut cfg);
    let r = run_one(rt, cfg, "ar-sgd")?;
    rows.push(vec![
        "AR-SGD".into(),
        format!("{:.4}", r.final_train_loss()),
        pct(r.final_val_metric),
        format!("{} ({} ep)", metrics::hours(r.sim_total_s), e90),
    ]);

    for (name, algo) in
        [("AD-PSGD", "adpsgd"), ("SGP", "sgp"), ("1-OSGP", "osgp")]
    {
        let mut cfg = TrainConfig::imagenet_like(model, n, 9);
        cfg.epochs = e270;
        cfg.track_consensus = false;
        // Stretched schedule: decay at 90/180/240 (Table 5 protocol).
        cfg.lr = LrSchedule::goyal_270(n, 0.05);
        if fast {
            cfg.lr.milestones = vec![e270 / 3.0, 2.0 * e270 / 3.0, 8.0 * e270 / 9.0];
        }
        cap_lr(&mut cfg);
        let r = run_tuned(rt, cfg, algo, |b| b.tau(1))?;
        rows.push(vec![
            name.into(),
            format!("{:.4}", r.final_train_loss()),
            pct(r.final_val_metric),
            format!("{} ({} ep)", metrics::hours(r.sim_total_s), e270),
        ]);
    }
    print_table(
        "Table 5 — fixed runtime budget, 32 nodes (10 GbE)",
        &["method", "train loss", "val acc", "train time"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Figure 3: NMT analogue — Adam-SGP vs AllReduce-Adam, small & large batch
// ===========================================================================
/// Fig. 3: NMT analogue, Adam-SGP vs AllReduce-Adam.
pub fn fig3(rt: &Runtime, fast: bool) -> Result<()> {
    let n = 8;
    let mut rows = Vec::new();
    let regimes: Vec<(&str, &str)> = vec![
        ("small-batch", "lm_small"),
        ("large-batch", "lm_small_b16"),
    ];
    for (regime, model) in regimes {
        if rt.manifest.models.get(model).is_none() {
            eprintln!("[fig3] model {model} missing from artifacts; skipping");
            continue;
        }
        for (name, algo) in [("AR-Adam", "ar-sgd"), ("SGP-Adam", "sgp")] {
            let mut cfg = TrainConfig::nmt_like(model, n, 11);
            cfg.epochs = 5.0;
            cfg.steps_per_epoch = 20;
            if model.ends_with("b16") {
                // Large-batch regime: 4× the tokens per step ⇒ 4× compute
                // per iteration at the same message size (Ott et al. 2018).
                cfg.compute.base_s *= 4.0;
            }
            if fast {
                cfg.epochs = 3.0;
                cfg.steps_per_epoch = 10;
            }
            let r = run_one(rt, cfg, algo)?;
            rows.push(vec![
                regime.into(),
                name.into(),
                format!("{:.4}", r.final_val_loss),
                format!("{:.4}", (r.final_val_loss).exp()),
                metrics::hours(r.sim_total_s),
            ]);
        }
    }
    print_table(
        "Fig 3 — NMT analogue: validation NLL/perplexity (8 nodes, 10 GbE)",
        &["regime", "method", "val NLL", "val ppl", "sim time"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Figure D.3: per-node error spread (4 and 32 nodes)
// ===========================================================================
/// Fig. D.3: per-node validation-metric spread over training.
pub fn figd3(rt: &Runtime, fast: bool) -> Result<()> {
    let model = "mlp_small";
    let mut rows = Vec::new();
    for n in [4usize, 32] {
        let mut cfg = TrainConfig::imagenet_like(model, n, 13);
        cfg.epochs = epochs(90.0, fast);
        cfg.track_consensus = true;
        cfg.eval_every_epochs = cfg.epochs / 9.0;
        let r = run_one(rt, cfg, "sgp")?;
        let mut csv =
            String::from("epoch,node_min,node_mean,node_max,val_metric\n");
        for e in &r.evals {
            csv.push_str(&format!(
                "{:.2},{:.6},{:.6},{:.6},{:.6}\n",
                e.epoch, e.node_metric_min, e.node_metric_mean, e.node_metric_max,
                e.val_metric
            ));
            rows.push(vec![
                n.to_string(),
                format!("{:.1}", e.epoch),
                pct(e.node_metric_min),
                pct(e.node_metric_mean),
                pct(e.node_metric_max),
            ]);
        }
        std::fs::write(results_dir().join(format!("figd3_n{n}.csv")), csv)?;
    }
    print_table(
        "Fig D.3 — per-node validation accuracy spread (SGP)",
        &["nodes", "epoch", "min", "mean", "max"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Figure D.4: throughput scaling & efficiency
// ===========================================================================
/// Fig. D.4: simulated throughput and scaling efficiency (timing-only).
pub fn figd4() -> Result<()> {
    let msg = 100 << 20;
    let compute = ComputeModel::resnet50_dgx1();
    let images_per_node_iter = 256.0; // paper's per-node batch
    let mut rows = Vec::new();
    let mut csv = String::from("fabric,method,nodes,images_per_s,efficiency\n");
    for (fabric, link) in [
        ("ethernet", LinkModel::ethernet_10g()),
        ("infiniband", LinkModel::infiniband_100g()),
    ] {
        let mut base_sgp = 0.0;
        let mut base_ar = 0.0;
        for n in [4usize, 8, 16, 32] {
            let sgp_t =
                net::average_iteration_time(n, link.clone(), &compute, 300, 17, |_| {
                    OwnedCommPattern::PushSum {
                        schedule: Schedule::new(TopologyKind::OnePeerExp, n),
                        bytes: msg,
                        tau: 0,
                    }
                });
            let ar_t =
                net::average_iteration_time(n, link.clone(), &compute, 300, 17, |_| {
                    OwnedCommPattern::AllReduce { bytes: msg }
                });
            let sgp_tp = n as f64 * images_per_node_iter / sgp_t;
            let ar_tp = n as f64 * images_per_node_iter / ar_t;
            if n == 4 {
                base_sgp = sgp_tp / 4.0;
                base_ar = ar_tp / 4.0;
            }
            let sgp_eff = sgp_tp / (base_sgp * n as f64);
            let ar_eff = ar_tp / (base_ar * n as f64);
            csv.push_str(&format!(
                "{fabric},SGP,{n},{sgp_tp:.0},{sgp_eff:.3}\n{fabric},AR-SGD,{n},{ar_tp:.0},{ar_eff:.3}\n"
            ));
            rows.push(vec![
                fabric.into(),
                n.to_string(),
                format!("{sgp_tp:.0}"),
                pct(sgp_eff),
                format!("{ar_tp:.0}"),
                pct(ar_eff),
            ]);
        }
    }
    std::fs::write(results_dir().join("figd4_throughput.csv"), csv)?;
    print_table(
        "Fig D.4 — simulated throughput (images/s) and scaling efficiency",
        &["fabric", "nodes", "SGP img/s", "SGP eff", "AR img/s", "AR eff"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Robustness sweep: algorithm × fault level (message loss / churn), offline
// ===========================================================================

/// What `repro faults` sweeps over. Fully offline — synthetic quadratic
/// gradients through the registered strategies, no HLO artifacts needed.
#[derive(Clone, Debug)]
pub struct FaultSweep {
    /// Message-drop probabilities to sweep (the x-axis).
    pub drops: Vec<f64>,
    /// Node crashes applied at every fault level.
    pub crashes: Vec<Crash>,
    /// Rescue mode: senders re-absorb undelivered mass — push-sum's local
    /// loss-recovery, ON by default (`--no-rescue` surfaces the naive-loss
    /// instability documented in DESIGN.md §Faults).
    pub rescue: bool,
    /// Number of simulated nodes.
    pub n: usize,
    /// Rounds per run.
    pub iters: u64,
    /// Seed of the deterministic scenario replay.
    pub seed: u64,
    /// Registry names to compare.
    pub algos: Vec<String>,
    /// Execution policy for the per-round state updates (`--engine` /
    /// `--shards`); bit-identical across policies, so it only changes the
    /// sweep's wall-clock.
    pub exec: ExecPolicy,
    /// Gossip message compression applied at every fault level
    /// (`--compress`); the loss/churn machinery composes with the
    /// error-feedback residuals unchanged.
    pub compress: Compression,
}

impl FaultSweep {
    /// The default sweep shape (`fast` = the CI smoke configuration).
    pub fn new(fast: bool) -> Self {
        Self {
            drops: if fast {
                vec![0.0, 0.05, 0.1]
            } else {
                vec![0.0, 0.05, 0.1, 0.15, 0.2]
            },
            crashes: Vec::new(),
            rescue: true,
            n: 16,
            iters: if fast { 80 } else { 200 },
            seed: 1,
            algos: if fast {
                vec!["ar-sgd".into(), "sgp".into()]
            } else {
                vec!["ar-sgd".into(), "dpsgd".into(), "sgp".into(), "osgp".into()]
            },
            exec: ExecPolicy::Sequential,
            compress: Compression::Identity,
        }
    }
}

/// The robustness table the paper's Section-1 claim predicts: as message
/// loss rises, SGP's consensus distance and makespan degrade gracefully
/// while AllReduce's makespan inflates (every round waits for the
/// unluckiest link, and a crashed member stalls the collective).
pub fn faults_sweep(sweep: &FaultSweep) -> Result<()> {
    let cfg = FaultRunConfig {
        n: sweep.n,
        iters: sweep.iters,
        seed: sweep.seed,
        exec: sweep.exec,
        compress: sweep.compress,
        ..Default::default()
    };
    let mut rows = Vec::new();
    let mut csv = String::from(
        "method,drop,crashes,rescue,final_err,consensus,makespan_s,slowdown\n",
    );
    let mk_plan = |drop: f64| {
        let mut plan = FaultPlan::lossless()
            .with_drop(drop)
            .with_rescue(sweep.rescue)
            .with_seed(sweep.seed);
        for c in &sweep.crashes {
            plan = plan.with_crash(c.node, c.at, c.rejoin);
        }
        plan
    };
    for algo in &sweep.algos {
        // Slowdown is always relative to the loss-free run of the same
        // scenario (same crashes/rescue), even when the user's drop list
        // does not include 0. Runs are deterministic, so the baseline is
        // reused verbatim when 0 is also a swept level.
        let base_stats = run_quadratic(algo, &cfg, &mk_plan(0.0))?;
        let baseline = base_stats.makespan;
        for &drop in &sweep.drops {
            let s = if drop == 0.0 {
                base_stats.clone()
            } else {
                run_quadratic(algo, &cfg, &mk_plan(drop))?
            };
            let slowdown = s.makespan / baseline;
            csv.push_str(&format!(
                "{},{drop},{},{},{:.6},{:.6e},{:.2},{:.3}\n",
                s.algo,
                sweep.crashes.len(),
                sweep.rescue,
                s.final_err,
                s.consensus,
                s.makespan,
                slowdown
            ));
            rows.push(vec![
                s.algo.clone(),
                pct(drop),
                format!("{:.4}", s.final_err),
                format!("{:.3e}", s.consensus),
                metrics::hours(s.makespan),
                format!("{slowdown:.2}×"),
            ]);
        }
    }
    std::fs::write(results_dir().join("faults_sweep.csv"), csv)?;
    let crash_note = if sweep.crashes.is_empty() {
        String::new()
    } else {
        format!(", {} crash(es)", sweep.crashes.len())
    };
    let compress_note = if sweep.compress.is_identity() {
        String::new()
    } else {
        format!(", {} compression", sweep.compress.label())
    };
    print_table(
        &format!(
            "Robustness — final error / consensus / makespan vs message loss \
             (n = {}, {} iters{crash_note}{}{compress_note})",
            sweep.n,
            sweep.iters,
            if sweep.rescue { ", rescue on" } else { "" }
        ),
        &["method", "drop", "‖x̄ − x*‖", "consensus", "makespan", "slowdown"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Soak: crash → durable restore → elastic join under loss (repro soak)
// ===========================================================================

/// What `repro soak` exercises: the durable-checkpoint contract end to
/// end under simulated churn. Two push-sum engines run the same lossy,
/// crash-afflicted, compressed schedule: the *reference* engine runs
/// uninterrupted while the *subject* engine is checkpointed through a
/// [`crate::snapshot::SnapshotSink`], torn down mid-run, restored from
/// its on-disk file, and must continue **bit-identically**; then both
/// admit a brand-new rank via the mass-conserving φ-split
/// ([`PushSumEngine::elastic_join`]). Σw is audited against n₀ to 1e-9
/// every round — a join divides mass, it never mints it — and the run is
/// written as a `"soak"` JSONL trace that `repro trace` re-audits.
#[derive(Clone, Debug)]
pub struct SoakRun {
    /// Nodes at the start of the run — the Σw budget for the whole soak.
    pub n: usize,
    /// Parameter dimension per node.
    pub dim: usize,
    /// Gossip rounds.
    pub iters: u64,
    /// Per-message drop probability of the lossy fabric (rescue is always
    /// on, so the mass ledger must still balance exactly).
    pub drop: f64,
    /// Snapshot cadence: capture after every `every`-th round and on
    /// membership transitions.
    pub every: u64,
    /// Node that crashes mid-run (and rejoins from its frozen state).
    pub crash_node: usize,
    /// Crash round.
    pub crash_at: u64,
    /// Rejoin round.
    pub rejoin_at: u64,
    /// Round after which the subject engine is dropped and restored from
    /// its snapshot file.
    pub restore_at: u64,
    /// Round before which a brand-new rank joins via the φ-split.
    pub join_at: u64,
    /// Donor whose `(x, w)` is split with the joiner.
    pub donor: usize,
    /// Seed for initialization, fault replay and gradient noise.
    pub seed: u64,
    /// Execution policy for the state updates (bit-identical across all).
    pub exec: ExecPolicy,
    /// Gossip compression (the error-feedback banks ride in the snapshot).
    pub compress: Compression,
    /// JSONL trace output path.
    pub trace: PathBuf,
    /// Snapshot directory.
    pub ckpt_dir: PathBuf,
}

impl SoakRun {
    /// Default soak shape (`fast` = the CI smoke configuration).
    pub fn new(fast: bool) -> Self {
        Self {
            n: if fast { 16 } else { 32 },
            dim: if fast { 64 } else { 256 },
            iters: if fast { 120 } else { 300 },
            drop: 0.02,
            every: if fast { 20 } else { 50 },
            crash_node: 5,
            crash_at: if fast { 25 } else { 60 },
            rejoin_at: if fast { 45 } else { 120 },
            restore_at: if fast { 59 } else { 149 },
            join_at: if fast { 80 } else { 200 },
            donor: 2,
            seed: 11,
            exec: ExecPolicy::Sequential,
            compress: Compression::TopK { den: 8 },
            trace: results_dir().join("soak_trace.jsonl"),
            ckpt_dir: results_dir().join("soak_ckpt"),
        }
    }
}

/// Run the soak scenario; fails if the restored engine ever diverges from
/// the reference, if Σw drifts past 1e-9, or if the post-join network
/// fails to contract to consensus. Writes the `"soak"` trace and leaves
/// the snapshot files under [`SoakRun::ckpt_dir`] as run artifacts.
pub fn soak(cfg: &SoakRun) -> Result<()> {
    use crate::faults::FaultClock;
    use crate::obs::trace::{TraceWriter, GLOBAL_RANK};
    use crate::rng::Pcg;
    use crate::snapshot::{RngCursor, Snapshot, SnapshotPolicy, SnapshotSink};

    const TOL: f64 = 1e-9;
    anyhow::ensure!(
        cfg.crash_node < cfg.n && cfg.donor < cfg.n,
        "crash_node/donor must be < n"
    );
    anyhow::ensure!(
        cfg.rejoin_at < cfg.restore_at
            && cfg.restore_at < cfg.join_at
            && cfg.join_at < cfg.iters,
        "soak phases must be ordered: rejoin < restore < join < iters"
    );
    let n0 = cfg.n;
    let expected_w = n0 as f64;
    let mut rng = Pcg::new(cfg.seed);
    let init: Vec<Vec<f32>> = (0..n0).map(|_| rng.gaussian_vec(cfg.dim)).collect();
    let plan = FaultPlan::lossless()
        .with_drop(cfg.drop)
        .with_rescue(true)
        .with_crash(cfg.crash_node, cfg.crash_at, Some(cfg.rejoin_at))
        .with_seed(cfg.seed);
    let clock = FaultClock::new(plan);
    let sink = SnapshotSink::new(
        SnapshotPolicy::every(cfg.every).and_on_membership_change(),
        cfg.ckpt_dir.clone(),
    );

    // τ = 1 so the checkpoint always carries in-flight mail.
    let mut a = PushSumEngine::new(init.clone(), 1, false); // reference
    let mut b = PushSumEngine::new(init, 1, false); // subject
    let mut pa = Pcg::new(cfg.seed ^ 0x50a4);
    let mut pb = Pcg::new(cfg.seed ^ 0x50a4);
    let sched0 = Schedule::with_seed(TopologyKind::OnePeerExp, n0, cfg.seed);
    let sched1 = Schedule::with_seed(TopologyKind::OnePeerExp, n0 + 1, cfg.seed);
    let mut tw = TraceWriter::create(&cfg.trace, "soak", n0 + 1, cfg.iters)?;

    let mut restored = false;
    let mut joined = false;
    let mut grad = vec![0.0f32; cfg.dim];
    for k in 0..cfg.iters {
        // Identical gradient-noise perturbations on both engines (the
        // quadratic-harness stand-in), stopped at the join so the tail of
        // the run demonstrates post-join consensus contraction. Only Σx
        // moves; Σw is untouched, so the mass audit below stays exact.
        if k < cfg.join_at {
            for i in 0..n0 {
                if clock.is_down(i, k) {
                    continue;
                }
                for g in grad.iter_mut() {
                    *g = 0.01 * pa.gaussian() as f32;
                }
                for (x, g) in a.states[i].x.iter_mut().zip(&grad) {
                    *x -= *g;
                }
                for g in grad.iter_mut() {
                    *g = 0.01 * pb.gaussian() as f32;
                }
                for (x, g) in b.states[i].x.iter_mut().zip(&grad) {
                    *x -= *g;
                }
            }
        }
        let sched = if joined { &sched1 } else { &sched0 };
        a.step_compressed(k, sched, Some(&clock), cfg.exec, cfg.compress);
        b.step_compressed(k, sched, Some(&clock), cfg.exec, cfg.compress);

        // Checkpoint the subject on the policy cadence (and at the forced
        // teardown round), with the perturbation-RNG cursor riding along.
        let due = sink.policy.due(k, clock.membership_changed_at(k));
        if due || k == cfg.restore_at {
            let mut snap = b.save(k + 1);
            snap.set_rngs(vec![RngCursor::of(&pb)]);
            let path = sink.store("soak", &snap)?;
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            tw.event(k, "snapshot", GLOBAL_RANK, k, &[("bytes", bytes as f64)]);
        }

        // Forced teardown: drop the subject entirely and resurrect it from
        // the file just written. Everything after this round doubles as a
        // bit-identity check of durable restore.
        if k == cfg.restore_at {
            let path = sink.path_for("soak", k + 1);
            let snap = Snapshot::read_file(&path)?;
            b = PushSumEngine::restore(&snap)?;
            anyhow::ensure!(
                snap.rngs().len() == 1,
                "soak snapshot must carry the perturbation-RNG cursor"
            );
            pb = snap.rngs()[0].to_pcg();
            restored = true;
            tw.event(k, "restore", GLOBAL_RANK, k, &[("round", (k + 1) as f64)]);
        }

        // Elastic scale-up: a brand-new rank warm-starts from the donor's
        // φ-split on both engines; the schedule is rebuilt over n₀ + 1.
        if k + 1 == cfg.join_at {
            let ja = a.elastic_join(cfg.donor);
            let jb = b.elastic_join(cfg.donor);
            anyhow::ensure!(ja == jb && ja == n0, "join must assign rank n₀");
            joined = true;
            tw.event(k, "join", ja as u32, k, &[("donor", cfg.donor as f64)]);
        }

        // Per-round audits: Σw (states + in-flight + banks + ledger) must
        // hold at n₀ bit-for-bit-ish (1e-9), and the subject must track
        // the reference exactly.
        let (_, wa) = a.total_mass_with_losses();
        let (_, wb) = b.total_mass_with_losses();
        anyhow::ensure!(
            (wa - expected_w).abs() <= TOL && (wb - expected_w).abs() <= TOL,
            "round {k}: Σw drifted (ref {wa}, subject {wb}, expected {expected_w})"
        );
        tw.event(k, "mass", GLOBAL_RANK, k, &[
            ("sum_w", wb),
            ("expected_w", expected_w),
        ]);
        let identical = a
            .states
            .iter()
            .zip(&b.states)
            .all(|(sa, sb)| sa.x == sb.x && sa.w.to_bits() == sb.w.to_bits());
        anyhow::ensure!(
            identical,
            "round {k}: subject diverged from reference (restored = {restored})"
        );
    }

    a.drain();
    b.drain();
    let (_, wa) = a.total_mass_with_losses();
    let (_, wb) = b.total_mass_with_losses();
    anyhow::ensure!(
        (wa - expected_w).abs() <= TOL && (wb - expected_w).abs() <= TOL,
        "post-drain Σw drifted (ref {wa}, subject {wb})"
    );
    // Post-join contraction bar: top-k error-feedback gossip moves only
    // dim/den coordinates per message, so the clean tail contracts slower
    // than dense gossip — 1e-2 is the compressed-rate bound for the tail
    // length; the exact contracts above (bit-identity, Σw) are the gates.
    let (cons, _, _) = b.consensus_distance();
    anyhow::ensure!(
        cons < 1e-2,
        "post-join network failed to contract: consensus {cons}"
    );
    anyhow::ensure!(
        a.sent_count == b.sent_count && a.drop_count == b.drop_count,
        "ledger counters diverged after restore"
    );
    tw.event(cfg.iters, "audit", GLOBAL_RANK, cfg.iters.saturating_sub(1), &[
        ("sum_w", wb),
        ("expected_w", expected_w),
        ("consensus", cons),
        ("bit_identical", 1.0),
    ]);
    drop(tw);

    print_table(
        &format!(
            "Soak — crash→restore→elastic join (n₀ = {}, {} iters, drop {:.0}%, {})",
            n0,
            cfg.iters,
            100.0 * cfg.drop,
            cfg.compress.label()
        ),
        &["phase", "round", "check"],
        &[
            vec![
                "crash/rejoin".into(),
                format!("{}/{}", cfg.crash_at, cfg.rejoin_at),
                "Σw held through churn".into(),
            ],
            vec![
                "disk restore".into(),
                format!("{}", cfg.restore_at + 1),
                "bit-identical resume".into(),
            ],
            vec![
                "elastic join".into(),
                format!("{}", cfg.join_at),
                format!("rank {} via φ-split of node {}", n0, cfg.donor),
            ],
            vec![
                "final".into(),
                format!("{}", cfg.iters),
                format!("Σw = {wb:.9}, consensus {cons:.2e}"),
            ],
        ],
    );
    println!("soak trace written to {}", cfg.trace.display());
    println!("snapshots under {}", cfg.ckpt_dir.display());
    Ok(())
}

// ===========================================================================
// Execution-engine scaling sweep: sequential vs sharded-parallel gossip
// ===========================================================================

/// What `repro engine-sweep` measures: wall-clock of the gossip round loop
/// at large N — the regime the paper's scaling claim lives in — run once
/// sequentially and once per shard count × pool-thread count, with a
/// built-in bit-identity check between the engines. Fully offline (pure
/// gossip, no HLO artifacts).
#[derive(Clone, Debug)]
pub struct EngineSweep {
    /// Node counts to sweep; the default tops out at the large-N regime
    /// (4096 nodes) where per-iteration gossip cost must stay independent
    /// of n for the paper's scaling argument to hold.
    pub ns: Vec<usize>,
    /// Parameter dimension per node.
    pub dim: usize,
    /// Gossip rounds per measurement.
    pub steps: u64,
    /// Shard counts to compare against the sequential baseline.
    pub shards: Vec<usize>,
    /// Worker-pool sizes to sweep (the threads axis). `0` means the
    /// machine-default global pool; any other value builds a private
    /// [`crate::runtime::pool::Pool`] of that many workers. Results are
    /// bit-identical across the whole axis — it moves wall-clock only.
    pub threads: Vec<usize>,
    /// Seed of the node initialization.
    pub seed: u64,
}

impl EngineSweep {
    /// Default sweep shape (`fast` = the CI smoke configuration).
    pub fn new(fast: bool) -> Self {
        Self {
            ns: if fast { vec![64, 256] } else { vec![64, 256, 1024, 4096] },
            dim: 1024,
            steps: if fast { 20 } else { 50 },
            shards: vec![2, 4, 8],
            threads: vec![0],
            seed: 1,
        }
    }
}

/// Run the engine scaling sweep: per `(n, threads, shards)`, wall-clock of
/// the pooled round loop vs the sequential baseline, asserting the engines
/// end bit-identical (the determinism contract, exercised at sweep scale
/// across the full thread axis). Writes `results/engine_sweep.csv`.
pub fn engine_sweep(cfg: &EngineSweep) -> Result<()> {
    use crate::rng::Pcg;
    use crate::runtime::pool::{self, Pool};
    use std::sync::Arc;
    let mut rows = Vec::new();
    let mut divergences: Vec<(usize, usize, usize)> = Vec::new();
    let mut csv =
        String::from("n,dim,steps,engine,shards,threads,wall_s,speedup,identical\n");
    for &n in &cfg.ns {
        let mut rng = Pcg::new(cfg.seed);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(cfg.dim)).collect();
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let run = |exec: ExecPolicy, pool: Option<Arc<Pool>>| -> (f64, PushSumEngine) {
            let mut eng = PushSumEngine::new(init.clone(), 1, false);
            eng.set_pool(pool);
            let t0 = std::time::Instant::now();
            for k in 0..cfg.steps {
                eng.step_exec(k, &sched, None, exec);
            }
            eng.drain();
            (t0.elapsed().as_secs_f64(), eng)
        };
        let (base_s, base_eng) = run(ExecPolicy::Sequential, None);
        csv.push_str(&format!(
            "{n},{},{},sequential,1,1,{base_s:.6},1.000,-\n",
            cfg.dim, cfg.steps
        ));
        rows.push(vec![
            n.to_string(),
            "sequential".into(),
            "1".into(),
            format!("{:.1}ms", base_s * 1e3),
            "1.00×".into(),
            "-".into(),
        ]);
        for &t in &cfg.threads {
            let pool: Option<Arc<Pool>> =
                if t == 0 { None } else { Some(Arc::new(Pool::new(t))) };
            let workers =
                pool.as_deref().map_or_else(|| pool::global().workers(), Pool::workers);
            for &s in &cfg.shards {
                if s <= 1 {
                    continue;
                }
                let exec = ExecPolicy::parallel(s);
                let (wall, eng) = run(exec, pool.clone());
                let identical = base_eng
                    .states
                    .iter()
                    .zip(&eng.states)
                    .all(|(a, b)| a.x == b.x && a.w.to_bits() == b.w.to_bits());
                if !identical {
                    divergences.push((n, s, workers));
                }
                let speedup = base_s / wall.max(1e-12);
                csv.push_str(&format!(
                    "{n},{},{},parallel,{s},{workers},{wall:.6},{speedup:.3},{identical}\n",
                    cfg.dim, cfg.steps
                ));
                rows.push(vec![
                    n.to_string(),
                    exec.label(),
                    workers.to_string(),
                    format!("{:.1}ms", wall * 1e3),
                    format!("{speedup:.2}×"),
                    if identical { "yes".into() } else { "NO".into() },
                ]);
            }
        }
    }
    // Emit the artifact and the table even when a divergence was found —
    // the "bit-identical" column IS the diagnostic — then fail the sweep.
    std::fs::write(results_dir().join("engine_sweep.csv"), csv)?;
    print_table(
        &format!(
            "Execution engine — sequential vs pool-sharded gossip, dim = {}, {} steps",
            cfg.dim, cfg.steps
        ),
        &["nodes", "engine", "threads", "wall", "speedup", "bit-identical"],
        &rows,
    );
    anyhow::ensure!(
        divergences.is_empty(),
        "parallel engine diverged from sequential at {divergences:?} \
         (n, shards, threads) — determinism contract violated"
    );
    Ok(())
}

// ===========================================================================
// Event-engine scale sweep: nodes vs wall-clock and peak RSS, offline
// ===========================================================================

/// What `repro scale-sweep` measures: wall-clock per configuration and
/// process peak RSS as the node count grows toward 10^6, for the sparse
/// [`crate::gossip::EventEngine`] in its quiescent (all nodes cold on the
/// shared template) and active (a perturbed hot set spreading along gossip
/// edges) modes, with a dense-engine reference at the node counts where
/// dense state still fits comfortably. Fully offline (pure gossip on the
/// quadratic-harness parameter shape, no HLO artifacts).
///
/// Writes `results/BENCH_event.json` — deliberately *outside* the
/// `bench-check` perf gate: absolute wall-clock and RSS at 10^6 nodes are
/// too machine-bound to gate, but the curves are the artifact reviewers
/// diff by eye. In that file, `bytes_per_iter` on the event entries
/// carries the **peak-RSS reading in bytes** after that node count ran
/// (the kernel's high-water mark is cumulative, which is why the sweep
/// runs in ascending `n` order).
#[derive(Clone, Debug)]
pub struct ScaleSweep {
    /// Node counts to sweep, ascending; the default tops out at 2^20.
    pub ns: Vec<usize>,
    /// Parameter dimension per node.
    pub dim: usize,
    /// Gossip ticks per measured run.
    pub steps: u64,
    /// Nodes perturbed to seed the hot set of the active curve.
    pub active: usize,
    /// Largest node count the dense reference engine runs at.
    pub dense_cap: usize,
    /// Seed of the perturbation magnitudes.
    pub seed: u64,
}

impl ScaleSweep {
    /// Default sweep shape (`fast` = the CI smoke configuration).
    pub fn new(fast: bool) -> Self {
        Self {
            ns: if fast {
                vec![256, 4096]
            } else {
                vec![1024, 16_384, 262_144, 1_048_576]
            },
            dim: if fast { 32 } else { 64 },
            steps: if fast { 16 } else { 64 },
            active: if fast { 8 } else { 64 },
            dense_cap: if fast { 256 } else { 4096 },
            seed: 1,
        }
    }
}

/// Run the event-engine scale sweep (see [`ScaleSweep`]): per node count,
/// the quiescent and active sparse-engine wall-clocks (asserting zero
/// materialization on the quiescent curve — the cold-template fixed point
/// checked at every scale), a sequential dense reference at small N, and
/// the peak-RSS curve. Fails if the process high-water mark exceeds 8 GiB
/// — the acceptance bound that makes "million-node simulation" a tested
/// claim rather than a slogan. Writes `results/BENCH_event.json`.
pub fn scale_sweep(cfg: &ScaleSweep) -> Result<()> {
    use crate::benchkit::{bench_for, fmt, peak_rss_bytes, JsonReport};
    use crate::gossip::EventEngine;
    use crate::rng::Pcg;
    use std::time::Duration;

    const RSS_CAP_BYTES: u64 = 8 << 30;
    anyhow::ensure!(
        cfg.ns.windows(2).all(|w| w[0] < w[1]),
        "scale-sweep node counts must be ascending (peak RSS is cumulative)"
    );
    let budget = Duration::from_millis(if cfg.steps <= 16 { 200 } else { 600 });
    // 0.25 splits and recombines bit-exactly, so the all-cold graph is a
    // fixed point and the quiescent curve measures pure engine overhead.
    let template = || vec![0.25f32; cfg.dim];
    let mut report = JsonReport::new();
    let mut rows = Vec::new();
    for &n in &cfg.ns {
        anyhow::ensure!(n >= 2, "scale-sweep needs at least 2 nodes (got {n})");
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);

        // Quiescent: every node cold. A tick must do no per-node work.
        let mut materialized = usize::MAX;
        let quiescent = bench_for(&format!("event/quiescent/n={n}"), budget, || {
            let mut eng = EventEngine::with_template(template(), n, 0, false);
            for k in 0..cfg.steps {
                eng.step(k, &sched, None, Compression::Identity);
            }
            materialized = eng.materialized();
        });
        anyhow::ensure!(
            materialized == 0,
            "quiescent sweep materialized {materialized} nodes at n = {n} — \
             the cold-template fixed point broke"
        );

        // Active: perturb a small hot set and let activity spread along
        // the gossip edges it actually excites.
        let mut rng = Pcg::new(cfg.seed);
        let active = cfg.active.min(n);
        let stride = (n / active).max(1);
        let mut hot = 0usize;
        let active_stats = bench_for(&format!("event/active/n={n}"), budget, || {
            let mut eng = EventEngine::with_template(template(), n, 0, false);
            for j in 0..active {
                eng.state_mut(j * stride).x[0] += rng.gaussian() as f32;
            }
            for k in 0..cfg.steps {
                eng.step(k, &sched, None, Compression::Identity);
            }
            hot = eng.materialized();
        });

        // Dense reference: the same workload on the dense engine, only
        // where materializing n states is still cheap.
        let dense_wall = if n <= cfg.dense_cap {
            let init: Vec<Vec<f32>> = (0..n).map(|_| template()).collect();
            let d = bench_for(&format!("event/dense_ref/n={n}"), budget, || {
                let mut eng = PushSumEngine::new(init.clone(), 0, false);
                for k in 0..cfg.steps {
                    eng.step_exec(k, &sched, None, ExecPolicy::Sequential);
                }
            });
            let wall = fmt(d.median);
            report.push(d);
            wall
        } else {
            "-".to_string()
        };

        let rss = peak_rss_bytes().unwrap_or(0);
        anyhow::ensure!(
            rss < RSS_CAP_BYTES,
            "peak RSS {rss} bytes at n = {n} exceeds the 8 GiB sparse-engine \
             budget"
        );
        rows.push(vec![
            n.to_string(),
            fmt(quiescent.median),
            fmt(active_stats.median),
            hot.to_string(),
            dense_wall,
            if rss == 0 {
                "n/a".into()
            } else {
                format!("{:.1} MiB", rss as f64 / (1 << 20) as f64)
            },
        ]);
        report.push(quiescent.with_bytes(rss));
        report.push(active_stats.with_bytes(rss));
    }
    let out = results_dir().join("BENCH_event.json");
    report.write(&out)?;
    print_table(
        &format!(
            "Event-engine scaling — dim = {}, {} ticks, {} perturbed nodes",
            cfg.dim, cfg.steps, cfg.active
        ),
        &["nodes", "quiescent", "active", "hot after", "dense ref", "peak RSS"],
        &rows,
    );
    println!("bench report: {}", out.display());
    Ok(())
}

// ===========================================================================
// Compression sweep: wire-byte reduction × heterogeneity, offline
// ===========================================================================

/// What `repro compress-sweep` measures: for each compression scheme ×
/// gradient-heterogeneity level, the wire-byte reduction, the final-error
/// delta against uncompressed SGP, and the simulated makespan — plus a
/// built-in bit-identity check of compressed runs across engine shard
/// counts (the determinism contract extended to compression). Fully
/// offline (quadratic harness, no HLO artifacts).
#[derive(Clone, Debug)]
pub struct CompressSweep {
    /// Compression schemes to sweep (the uncompressed baseline is always
    /// run and need not be listed).
    pub schemes: Vec<Compression>,
    /// Heterogeneity levels ζ of the node-local quadratics.
    pub hets: Vec<f64>,
    /// Number of simulated nodes.
    pub n: usize,
    /// Rounds per run.
    pub iters: u64,
    /// Dimension of the per-node quadratic (also the logical coordinate
    /// count the wire format packs indices for).
    pub dim: usize,
    /// Seed of the deterministic run.
    pub seed: u64,
    /// Shard counts of the bit-identity check (`1` = the sequential
    /// reference itself).
    pub shards: Vec<usize>,
}

impl CompressSweep {
    /// Default sweep shape (`fast` = the CI smoke configuration).
    pub fn new(fast: bool) -> Self {
        Self {
            schemes: if fast {
                vec![Compression::TopK { den: 16 }, Compression::Qsgd { bits: 4 }]
            } else {
                vec![
                    Compression::TopK { den: 4 },
                    Compression::TopK { den: 16 },
                    Compression::Qsgd { bits: 8 },
                    Compression::Qsgd { bits: 4 },
                ]
            },
            hets: if fast { vec![0.5] } else { vec![0.25, 0.5, 0.75] },
            n: 32,
            iters: if fast { 150 } else { 300 },
            dim: 256,
            seed: 1,
            shards: vec![1, 2, 7],
        }
    }
}

/// Run the compression sweep: per `(scheme, heterogeneity)`, byte
/// reduction / final error vs dense / consensus / makespan speedup, then
/// the cross-shard bit-identity check at the first heterogeneity level.
/// Writes `results/compress_sweep.csv`; fails if any compressed run
/// diverges across shard counts.
pub fn compress_sweep(sweep: &CompressSweep) -> Result<()> {
    let cfg = |h: f64, c: Compression, exec: ExecPolicy| FaultRunConfig {
        n: sweep.n,
        iters: sweep.iters,
        dim: sweep.dim,
        seed: sweep.seed,
        heterogeneity: h,
        compress: c,
        exec,
        ..Default::default()
    };
    let full_bytes = FaultRunConfig::default().msg_bytes;
    let mut rows = Vec::new();
    let mut csv = String::from(
        "scheme,heterogeneity,full_bytes,encoded_bytes,reduction,\
         final_loss,loss_vs_dense_pct,final_err,consensus,makespan_s,speedup\n",
    );
    // Sequential per-scheme stats at the first heterogeneity level,
    // cached so the determinism check below does not redo those runs.
    let mut seq_at_h0: Vec<(Compression, FaultRunStats)> = Vec::new();
    for &h in &sweep.hets {
        let dense = run_quadratic(
            "sgp",
            &cfg(h, Compression::Identity, ExecPolicy::Sequential),
            &FaultPlan::lossless(),
        )?;
        let mut push = |label: String, enc: usize, s: &FaultRunStats| {
            let reduction = full_bytes as f64 / enc as f64;
            // Guarded denominator: at ζ = 0 every node shares one
            // objective and the dense loss collapses to ~0 — a raw ratio
            // would print astronomically scaled noise.
            let loss_delta = 100.0 * (s.final_loss - dense.final_loss)
                / dense.final_loss.max(1e-9);
            csv.push_str(&format!(
                "{label},{h},{full_bytes},{enc},{reduction:.3},{:.6},{loss_delta:.3},{:.6},{:.6e},{:.2},{:.3}\n",
                s.final_loss,
                s.final_err,
                s.consensus,
                s.makespan,
                dense.makespan / s.makespan
            ));
            rows.push(vec![
                label,
                format!("{h}"),
                format!("{reduction:.1}×"),
                format!("{:.4}", s.final_loss),
                format!("{loss_delta:+.3}%"),
                format!("{:.3e}", s.consensus),
                metrics::hours(s.makespan),
                format!("{:.2}×", dense.makespan / s.makespan),
            ]);
        };
        push("none".into(), full_bytes, &dense);
        for &scheme in &sweep.schemes {
            let s = run_quadratic(
                "sgp",
                &cfg(h, scheme, ExecPolicy::Sequential),
                &FaultPlan::lossless(),
            )?;
            push(scheme.label(), scheme.encoded_bytes(sweep.dim, full_bytes), &s);
            if Some(&h) == sweep.hets.first() {
                seq_at_h0.push((scheme, s));
            }
        }
    }

    // Determinism check: every compressed run must be bit-identical
    // across engine shard counts — the contract the parallel engine
    // extends to compression (error-feedback residuals are sender-owned,
    // quantization noise is keyed by (iteration, edge)). The sequential
    // references were already computed by the sweep loop above.
    let h = sweep.hets.first().copied().unwrap_or(0.5);
    let mut divergences = Vec::new();
    for &scheme in &sweep.schemes {
        let base = match seq_at_h0.iter().find(|(sc, _)| *sc == scheme) {
            Some((_, s)) => s.clone(),
            None => run_quadratic(
                "sgp",
                &cfg(h, scheme, ExecPolicy::Sequential),
                &FaultPlan::lossless(),
            )?,
        };
        for &shards in &sweep.shards {
            if shards <= 1 {
                continue;
            }
            let par = run_quadratic(
                "sgp",
                &cfg(h, scheme, ExecPolicy::parallel(shards)),
                &FaultPlan::lossless(),
            )?;
            let identical = base.final_err.to_bits() == par.final_err.to_bits()
                && base.final_loss.to_bits() == par.final_loss.to_bits()
                && base.consensus.to_bits() == par.consensus.to_bits()
                && base.makespan.to_bits() == par.makespan.to_bits();
            if !identical {
                divergences.push((scheme.label(), shards));
            }
            rows.push(vec![
                scheme.label(),
                format!("{h}"),
                "-".into(),
                format!("{:.4}", par.final_loss),
                format!("shards={shards}"),
                "-".into(),
                "-".into(),
                if identical { "bit-identical".into() } else { "DIVERGED".into() },
            ]);
        }
    }

    std::fs::write(results_dir().join("compress_sweep.csv"), csv)?;
    print_table(
        &format!(
            "Compressed gossip — byte reduction × heterogeneity \
             (SGP, n = {}, dim = {}, {} iters; dense baseline per ζ)",
            sweep.n, sweep.dim, sweep.iters
        ),
        &["scheme", "ζ", "reduction", "loss", "vs dense", "consensus", "makespan", "speedup"],
        &rows,
    );
    anyhow::ensure!(
        divergences.is_empty(),
        "compressed runs diverged across shard counts at {divergences:?} \
         — determinism contract violated"
    );
    Ok(())
}

// ===========================================================================
// Appendix A: decentralized averaging errors (λ₂ of mixing products)
// ===========================================================================
/// Appendix A: λ₂ of 5-step mixing products per peer-selection scheme.
pub fn appendix_a() -> Result<()> {
    let n = 32;
    let window = 5; // ⌊log2(31)⌋ = 4; paper quotes 5 iterations for n=32
    let mut rows = Vec::new();
    let mut csv = String::from("scheme,window,lambda2\n");

    let det = |kind| {
        let s = Schedule::new(kind, n);
        let mats: Vec<_> = (0..window as u64).map(|k| s.mixing_matrix(k)).collect();
        spectral::lambda2_of_product(&mats)
    };
    let exp_cycle = det(TopologyKind::OnePeerExp);
    let complete_cycle = det(TopologyKind::CompleteCycling);
    let rand_exp = spectral::expected_lambda2(
        &Schedule::with_seed(TopologyKind::RandomExp, n, 1),
        window,
        20,
    );
    let rand_any = spectral::expected_lambda2(
        &Schedule::with_seed(TopologyKind::RandomAny, n, 1),
        window,
        20,
    );
    for (name, v, paper) in [
        ("exp-graph cycling (det)", exp_cycle, "0"),
        ("complete-graph cycling", complete_cycle, "≈0.6"),
        ("random exp-graph peer", rand_exp, "≈0.4"),
        ("random any peer", rand_any, "≈0.2"),
    ] {
        csv.push_str(&format!("{name},{window},{v:.4}\n"));
        rows.push(vec![name.into(), format!("{v:.4}"), paper.into()]);
    }
    std::fs::write(results_dir().join("appendix_a_lambda2.csv"), csv)?;
    print_table(
        "Appendix A — λ₂ of 5-step mixing products, n = 32 (paper values right)",
        &["scheme", "λ₂ (ours)", "paper"],
        &rows,
    );
    Ok(())
}

// ===========================================================================
// Pure averaging demo over the PJRT dense-gossip artifact
// ===========================================================================
/// PushSum averaging demo through the Pallas dense-gossip HLO artifact.
pub fn averaging(rt: &Runtime, n: usize, rounds: u64) -> Result<()> {
    use crate::rng::Pcg;
    let meta = rt.manifest.artifact(&format!("gossip_dense_n{n}"))?;
    let d = meta.d.unwrap_or(1024);
    let mut rng = Pcg::new(1);
    let mut x: Vec<f32> = rng.gaussian_vec(n * d);
    let mut w = vec![1.0f32; n];
    let target: Vec<f64> = (0..d)
        .map(|j| (0..n).map(|i| x[i * d + j] as f64).sum::<f64>() / n as f64)
        .collect();
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let mut rows = Vec::new();
    for k in 0..rounds {
        let p = sched.mixing_matrix(k);
        let pf: Vec<f32> =
            (0..n * n).map(|idx| p.at(idx / n, idx % n) as f32).collect();
        let (xn, wn, z) = rt.gossip_dense(n, &pf, &x, &w)?;
        x = xn;
        w = wn;
        let err: f64 = (0..n)
            .map(|i| {
                (0..d)
                    .map(|j| {
                        let e = z[i * d + j] as f64 - target[j];
                        e * e
                    })
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / n as f64;
        rows.push(vec![(k + 1).to_string(), format!("{err:.3e}")]);
    }
    print_table(
        &format!("PushSum averaging via Pallas dense-gossip artifact (n={n}, d={d})"),
        &["rounds", "mean ‖zᵢ − ȳ‖"],
        &rows,
    );
    Ok(())
}

/// One `convergence_demo` report row: ‖x̄ − x*‖ and consensus distance at
/// iteration `k`.
fn push_report_row(
    engine: &crate::gossip::PushSumEngine,
    k: u64,
    opt: &[f64],
    rows: &mut Vec<Vec<String>>,
) {
    let mean = engine.mean_x();
    let gnorm: f64 = mean
        .iter()
        .zip(opt)
        .map(|(m, o)| {
            let e = *m as f64 - o;
            e * e
        })
        .sum::<f64>()
        .sqrt();
    let (cons, _, _) = engine.consensus_distance();
    rows.push(vec![
        k.to_string(),
        format!("{gnorm:.4}"),
        format!("{cons:.2e}"),
    ]);
}

/// Sanity check for Theorems 1–2 trends: SGP on a synthetic least-squares
/// objective — mean gradient norm decays and consensus error vanishes.
/// With `trace` set, an [`crate::obs::EngineObs`] recorder rides along
/// and an `"engine"` JSONL trace (per-round counters, phase timers,
/// bytes-per-edge) is written there for `repro trace`.
pub fn convergence_demo(n: usize, iters: u64, trace: Option<&std::path::Path>) -> Result<()> {
    use crate::gossip::PushSumEngine;
    use crate::rng::Pcg;
    let d = 32;
    let mut rng = Pcg::new(5);
    // Node-local quadratic f_i(x) = ½‖x − c_i‖², global optimum = mean c_i.
    let centers: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut opt = vec![0.0f64; d];
    for c in &centers {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / n as f64;
        }
    }
    let mut engine =
        PushSumEngine::new(vec![rng.gaussian_vec(d); n].to_vec(), 0, false);
    if trace.is_some() {
        let cap = iters.min(4096) as usize;
        engine.set_obs(Some(Box::new(crate::obs::EngineObs::new(n, cap))));
    }
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let gamma = (n as f64 / iters as f64).sqrt().min(0.5) as f32;
    let mut rows = Vec::new();
    for k in 0..iters {
        for i in 0..n {
            let z = engine.states[i].debiased();
            // Stochastic gradient: (z − cᵢ) + noise.
            let g: Vec<f32> = z
                .iter()
                .zip(&centers[i])
                .map(|(zi, ci)| zi - ci + 0.1 * rng.gaussian() as f32)
                .collect();
            for (x, gi) in engine.states[i].x.iter_mut().zip(&g) {
                *x -= gamma * gi;
            }
        }
        engine.step(k, &sched);
        if (k + 1) % (iters / 8).max(1) == 0 && k + 1 != iters {
            push_report_row(&engine, k + 1, &opt, &mut rows);
        }
    }
    // Drain-audit: flush in-flight mass before the final report point so
    // the printed trend never strands messages (the engine here is
    // blocking, but the audit keeps the driver honest if someone turns
    // the delay knob) — unconditionally, whatever --iters is.
    engine.drain();
    push_report_row(&engine, iters, &opt, &mut rows);
    print_table(
        &format!("Theorem 1/2 sanity — SGP on least squares (n={n}, γ=√(n/K))"),
        &["iter", "‖∇f(x̄)‖ (≈‖x̄−x*‖)", "consensus dist"],
        &rows,
    );
    if let (Some(path), Some(obs)) = (trace, engine.take_obs()) {
        crate::obs::trace::write_engine_trace(path, &obs, iters)?;
        println!("engine trace written to {}", path.display());
    }
    Ok(())
}

/// Run everything (the `repro bench all` entry used for EXPERIMENTS.md).
pub fn all(rt: &Runtime, fast: bool) -> Result<()> {
    appendix_a()?;
    fig1_table1(rt, fast)?;
    table2(rt, fast)?;
    fig2(rt, fast)?;
    table3(rt, fast)?;
    table4(rt, fast)?;
    table5(rt, fast)?;
    fig3(rt, fast)?;
    figd3(rt, fast)?;
    figd4()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_dir_created() {
        let d = results_dir();
        assert!(d.exists());
    }

    #[test]
    fn soak_fast_passes_end_to_end() {
        // The CI smoke shape, routed to a temp dir so parallel test runs
        // never contend on results/.
        let tmp = std::env::temp_dir()
            .join(format!("sgp_soak_test_{}", std::process::id()));
        let mut cfg = SoakRun::new(true);
        cfg.trace = tmp.join("trace.jsonl");
        cfg.ckpt_dir = tmp.join("ckpt");
        soak(&cfg).unwrap();
        assert!(cfg.trace.exists());
        assert!(std::fs::read_dir(&cfg.ckpt_dir).unwrap().count() >= 2);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn epochs_fast_mode_scales_down() {
        assert_eq!(epochs(90.0, false), 90.0);
        assert!(epochs(90.0, true) < 20.0);
        assert!(epochs(6.0, true) >= 3.0);
    }
}

//! Optimizers over flat `f32` parameter vectors, plus the Goyal et al.
//! (2017) learning-rate protocol used throughout the paper's ImageNet
//! experiments.
//!
//! In SGP (Alg. 3), the optimizer step is applied to the **biased**
//! push-sum numerator `x` using gradients evaluated at the de-biased
//! `z = x/w`. The implementations here are the pure-Rust hot path (simple
//! indexed loops the compiler auto-vectorizes); the `optim_ablation` bench
//! compares them against the PJRT fused-update artifacts compiled from the
//! L1 Pallas kernels.

/// Which optimizer the run uses (matches the paper: Nesterov for ImageNet,
/// Adam for NMT).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    /// Plain SGD with weight decay.
    Sgd,
    /// Nesterov momentum, default m=0.9, weight decay 1e-4 (Goyal).
    Nesterov,
    /// Adam with the Transformer defaults (β₁=0.9, β₂=0.98, ε=1e-9).
    Adam,
}

/// Per-node optimizer state.
#[derive(Clone, Debug)]
pub enum Optimizer {
    /// Plain SGD (stateless beyond the decay constant).
    Sgd {
        /// L2 weight-decay coefficient.
        weight_decay: f32,
    },
    /// Nesterov momentum.
    Nesterov {
        /// Momentum coefficient m.
        momentum: f32,
        /// L2 weight-decay coefficient.
        weight_decay: f32,
        /// Velocity buffer.
        u: Vec<f32>,
    },
    /// Adam.
    Adam {
        /// First-moment decay β₁.
        beta1: f32,
        /// Second-moment decay β₂.
        beta2: f32,
        /// Numerical-stability ε.
        eps: f32,
        /// First-moment estimate.
        m: Vec<f32>,
        /// Second-moment estimate.
        v: Vec<f32>,
        /// Step counter (bias correction).
        t: u64,
    },
}

impl Optimizer {
    /// Fresh optimizer state of the given family for a `dim`-sized vector.
    pub fn new(kind: OptimKind, dim: usize) -> Self {
        match kind {
            OptimKind::Sgd => Optimizer::Sgd { weight_decay: 1e-4 },
            OptimKind::Nesterov => Optimizer::Nesterov {
                momentum: 0.9,
                weight_decay: 1e-4,
                u: vec![0.0; dim],
            },
            OptimKind::Adam => Optimizer::Adam {
                beta1: 0.9,
                beta2: 0.98,
                eps: 1e-9,
                m: vec![0.0; dim],
                v: vec![0.0; dim],
                t: 0,
            },
        }
    }

    /// Apply one update: `x ← x − lr·step(g)`. Matches the fused Pallas
    /// kernels in `python/compile/kernels/fused_update.py` bit-for-bit in
    /// exact arithmetic (checked in integration tests via PJRT).
    pub fn step(&mut self, x: &mut [f32], g: &[f32], lr: f32) {
        debug_assert_eq!(x.len(), g.len());
        match self {
            Optimizer::Sgd { weight_decay } => {
                let wd = *weight_decay;
                for (xi, gi) in x.iter_mut().zip(g) {
                    *xi -= lr * (gi + wd * *xi);
                }
            }
            Optimizer::Nesterov { momentum, weight_decay, u } => {
                let (m, wd) = (*momentum, *weight_decay);
                for ((xi, ui), gi) in x.iter_mut().zip(u.iter_mut()).zip(g) {
                    let geff = gi + wd * *xi;
                    let unew = m * *ui + geff;
                    *ui = unew;
                    *xi -= lr * (m * unew + geff);
                }
            }
            Optimizer::Adam { beta1, beta2, eps, m, v, t } => {
                *t += 1;
                let (b1, b2, e) = (*beta1, *beta2, *eps);
                let c1 = 1.0 - b1.powi(*t as i32);
                let c2 = 1.0 - b2.powi(*t as i32);
                for (((xi, mi), vi), gi) in
                    x.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g)
                {
                    let mn = b1 * *mi + (1.0 - b1) * gi;
                    let vn = b2 * *vi + (1.0 - b2) * gi * gi;
                    *mi = mn;
                    *vi = vn;
                    *xi -= lr * (mn / c1) / ((vn / c2).sqrt() + e);
                }
            }
        }
    }

    /// Slices of mutable optimizer state that exact-averaging baselines
    /// (AllReduce) keep synchronized across nodes.
    pub fn state_mut(&mut self) -> Vec<&mut Vec<f32>> {
        match self {
            Optimizer::Sgd { .. } => vec![],
            Optimizer::Nesterov { u, .. } => vec![u],
            Optimizer::Adam { m, v, .. } => vec![m, v],
        }
    }
}

/// The Goyal et al. (2017) schedule: linear warmup from the single-node
/// reference LR to n× over the first `warmup_epochs`, then step decays by
/// 10× at the milestone epochs. Epochs are fractional (per-iteration LR).
#[derive(Clone, Debug)]
pub struct LrSchedule {
    /// Reference LR for one node (paper: 0.1 per 256-sample batch).
    pub base_lr: f64,
    /// Linear-scaling target multiplier (paper: n nodes ⇒ n×).
    pub scale: f64,
    /// Epochs of linear warmup from `base_lr` to `base_lr × scale`.
    pub warmup_epochs: f64,
    /// Epochs at which the LR step-decays.
    pub milestones: Vec<f64>,
    /// Multiplicative decay applied at each milestone (paper: 0.1).
    pub decay: f64,
}

impl LrSchedule {
    /// The paper's 90-epoch ImageNet protocol scaled to n nodes.
    pub fn goyal(n: usize, base_lr: f64) -> Self {
        Self {
            base_lr,
            scale: n as f64,
            warmup_epochs: 5.0,
            milestones: vec![30.0, 60.0, 80.0],
            decay: 0.1,
        }
    }

    /// The stretched 270-epoch schedule of Table 5 (decay at 90/180/240).
    pub fn goyal_270(n: usize, base_lr: f64) -> Self {
        Self {
            base_lr,
            scale: n as f64,
            warmup_epochs: 5.0,
            milestones: vec![90.0, 180.0, 240.0],
            decay: 0.1,
        }
    }

    /// Constant LR (NMT-Adam runs use their own scheme; constant is the
    /// simple stand-in, configurable by the caller).
    pub fn constant(lr: f64) -> Self {
        Self { base_lr: lr, scale: 1.0, warmup_epochs: 0.0, milestones: vec![], decay: 1.0 }
    }

    /// The learning rate at a (fractional) epoch.
    pub fn lr_at(&self, epoch: f64) -> f64 {
        let peak = self.base_lr * self.scale;
        let mut lr = if self.warmup_epochs > 0.0 && epoch < self.warmup_epochs {
            // Linear warmup from base_lr to peak.
            self.base_lr + (peak - self.base_lr) * (epoch / self.warmup_epochs)
        } else {
            peak
        };
        for m in &self.milestones {
            if epoch >= *m {
                lr *= self.decay;
            }
        }
        lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_matches_closed_form() {
        let mut o = Optimizer::Sgd { weight_decay: 0.0 };
        let mut x = vec![1.0, 2.0];
        o.step(&mut x, &[0.5, -1.0], 0.1);
        assert!((x[0] - 0.95).abs() < 1e-7);
        assert!((x[1] - 2.1).abs() < 1e-7);
    }

    #[test]
    fn nesterov_matches_manual_recursion() {
        let mut o = Optimizer::new(OptimKind::Nesterov, 1);
        if let Optimizer::Nesterov { weight_decay, .. } = &mut o {
            *weight_decay = 0.0;
        }
        let mut x = vec![0.0f32];
        let (m, lr) = (0.9f32, 0.1f32);
        let (mut xe, mut ue) = (0.0f32, 0.0f32);
        for step in 0..5 {
            let g = 1.0 + step as f32;
            o.step(&mut x, &[g], lr);
            ue = m * ue + g;
            xe -= lr * (m * ue + g);
            assert!((x[0] - xe).abs() < 1e-5, "step {step}");
        }
    }

    #[test]
    fn adam_first_step_is_lr_signed_gradient() {
        // With bias correction, |Δx| of step 1 ≈ lr (ε small).
        let mut o = Optimizer::new(OptimKind::Adam, 2);
        let mut x = vec![0.0f32, 0.0];
        o.step(&mut x, &[3.0, -0.2], 0.01);
        assert!((x[0] + 0.01).abs() < 1e-4, "{}", x[0]);
        assert!((x[1] - 0.01).abs() < 1e-4, "{}", x[1]);
    }

    #[test]
    fn adam_zero_grad_is_noop() {
        let mut o = Optimizer::new(OptimKind::Adam, 3);
        let mut x = vec![1.0, -1.0, 0.5];
        let before = x.clone();
        o.step(&mut x, &[0.0; 3], 0.1);
        for (a, b) in x.iter().zip(&before) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        // f(x) = ½‖x‖² ⇒ g = x; plain GD converges geometrically.
        let mut o = Optimizer::Sgd { weight_decay: 0.0 };
        let mut x = vec![10.0f32, -4.0, 2.5];
        for _ in 0..200 {
            let g = x.clone();
            o.step(&mut x, &g, 0.1);
        }
        assert!(x.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn goyal_schedule_shape() {
        let s = LrSchedule::goyal(8, 0.1);
        assert!((s.lr_at(0.0) - 0.1).abs() < 1e-12); // starts at base
        assert!((s.lr_at(5.0) - 0.8).abs() < 1e-12); // warm to n×
        assert!((s.lr_at(29.9) - 0.8).abs() < 1e-12);
        assert!((s.lr_at(30.0) - 0.08).abs() < 1e-12);
        assert!((s.lr_at(60.0) - 0.008).abs() < 1e-12);
        assert!((s.lr_at(80.0) - 0.0008).abs() < 1e-12);
        // Warmup is monotone increasing.
        assert!(s.lr_at(1.0) < s.lr_at(2.0));
    }

    #[test]
    fn goyal_270_decays_later() {
        let s90 = LrSchedule::goyal(4, 0.1);
        let s270 = LrSchedule::goyal_270(4, 0.1);
        assert!(s270.lr_at(45.0) > s90.lr_at(45.0));
        assert!((s270.lr_at(100.0) - s90.lr_at(35.0)).abs() < 1e-12);
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(3e-4);
        assert_eq!(s.lr_at(0.0), 3e-4);
        assert_eq!(s.lr_at(500.0), 3e-4);
    }
}

//! The training coordinator: ONE strategy-agnostic loop that drives any
//! [`DistributedAlgorithm`] over the PJRT runtime and the simulated
//! cluster. The loop contains zero per-algorithm branches — AllReduce-SGD,
//! the gossip family, the asynchronous baseline, and anything added to the
//! registry later all run through the same four trait verbs.
//!
//! Per round `k` (Alg. 1 / Alg. 2 / baselines):
//!   1. every node evaluates its mini-batch gradient at its **de-biased**
//!      view `z_i` ([`DistributedAlgorithm::local_view`]) through the
//!      `train_<model>` artifact;
//!   2. the gradient is handed to the node's strategy slot
//!      ([`DistributedAlgorithm::apply_step`]) — strategies may apply it
//!      immediately (SGP), average it exactly (AR-SGD), defer it (DaSGD),
//!      or apply it stale in event order (AD-PSGD);
//!   3. the strategy communicates ([`DistributedAlgorithm::communicate`])
//!      and returns the timing pattern;
//!   4. the timing recursion attaches simulated wall-clock (the paper's
//!      10 GbE / IB testbed) to the round.
//!
//! Construction goes through [`TrainerBuilder`]: pick an algorithm by
//! registry name (plus knobs like τ, gradient delay, topology override) or
//! inject a custom strategy object.
//!
//! This module coordinates *simulated* nodes inside one process. Its
//! real-socket counterpart is [`crate::net::cluster`]: `repro coord`
//! plays the role of the builder/loop across OS processes (registration,
//! rank assignment, membership, audit), with the same seeds, schedules
//! and compressed share encodings on an actual TCP wire.

use anyhow::{bail, Result};

use crate::algorithms::{self, AlgoParams, DistributedAlgorithm, RoundCtx};
use crate::config::TrainConfig;
use crate::data::{Batch, BigramLm, Blobs, DataSource};
use crate::faults::{FaultClock, FaultPlan};
use crate::gossip::{Compression, ExecPolicy};
use crate::metrics::{EvalRecord, IterRecord, RunResult};
use crate::net::TimingSim;
use crate::rng::Pcg;
use crate::runtime::Runtime;
use crate::snapshot::SnapshotSink;
use crate::topology::TopologyKind;

/// Fluent constructor for [`Trainer`] — replaces the old positional
/// `Trainer::new(rt, cfg, algo)`.
///
/// ```ignore
/// let mut trainer = TrainerBuilder::new(&rt)
///     .config(cfg)
///     .algorithm("osgp")
///     .tau(2)
///     .build()?;
/// let result = trainer.run()?;
/// ```
pub struct TrainerBuilder<'rt> {
    rt: &'rt Runtime,
    cfg: Option<TrainConfig>,
    algo_name: String,
    tau: Option<u64>,
    grad_delay: Option<u64>,
    switch_at: Option<u64>,
    topology: Option<TopologyKind>,
    custom: Option<Box<dyn DistributedAlgorithm>>,
    faults: Option<FaultPlan>,
    exec: ExecPolicy,
    compress: Compression,
    snapshots: Option<SnapshotSink>,
}

impl<'rt> TrainerBuilder<'rt> {
    /// Start building a trainer over the given runtime.
    pub fn new(rt: &'rt Runtime) -> Self {
        Self {
            rt,
            cfg: None,
            algo_name: "sgp".to_string(),
            tau: None,
            grad_delay: None,
            switch_at: None,
            topology: None,
            custom: None,
            faults: None,
            exec: ExecPolicy::Sequential,
            compress: Compression::Identity,
            snapshots: None,
        }
    }

    /// The run configuration (required).
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Pick the algorithm by registry name (see `algorithms::names()`).
    pub fn algorithm(mut self, name: &str) -> Self {
        self.algo_name = name.to_string();
        self
    }

    /// Overlap delay τ for the overlap/delayed strategies.
    pub fn tau(mut self, tau: u64) -> Self {
        self.tau = Some(tau);
        self
    }

    /// Gradient-application delay in rounds (DaSGD).
    pub fn grad_delay(mut self, d: u64) -> Self {
        self.grad_delay = Some(d);
        self
    }

    /// Switch iteration for the two-phase hybrid schedules. Defaults to a
    /// third of the run (the paper's epoch-30-of-90 protocol).
    pub fn switch_at(mut self, k: u64) -> Self {
        self.switch_at = Some(k);
        self
    }

    /// Override the strategy's default gossip topology (e.g. dense SGP for
    /// Fig. 2).
    pub fn topology(mut self, kind: TopologyKind) -> Self {
        self.topology = Some(kind);
        self
    }

    /// Inject a pre-built strategy object instead of a registry name —
    /// the escape hatch for experiments with bespoke schedules.
    pub fn strategy(mut self, algo: Box<dyn DistributedAlgorithm>) -> Self {
        self.custom = Some(algo);
        self
    }

    /// Run the training under a fault scenario: message loss, degraded
    /// links, node crash/rejoin (see [`crate::faults`]). Replayed
    /// deterministically from the plan's seed.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Select the execution engine for the per-round state updates:
    /// [`ExecPolicy::Sequential`] (the default) or a sharded-parallel
    /// gossip round ([`ExecPolicy::parallel`]) on the persistent worker
    /// pool ([`crate::runtime::pool`]). Any policy produces bit-identical
    /// results at a fixed seed — including under a fault plan and at any
    /// pool size — so this is purely a wall-clock knob for large-N runs
    /// (see ARCHITECTURE.md §Determinism).
    pub fn engine(mut self, exec: ExecPolicy) -> Self {
        self.exec = exec;
        self
    }

    /// Compress the gossip messages of the run ([`Compression::parse`]
    /// accepts the CLI spellings `topk:D` / `qsgd:B`). Gossip strategies
    /// encode every outgoing share against per-edge error-feedback
    /// residuals and the timing simulator is charged the actual encoded
    /// bytes; exact-collective strategies (AR-SGD) ship dense. The
    /// default is [`Compression::Identity`].
    pub fn compressor(mut self, compress: Compression) -> Self {
        self.compress = compress;
        self
    }

    /// Persist durable checkpoints of the strategy's gossip state through
    /// `sink` whenever its [`crate::snapshot::SnapshotPolicy`] is due —
    /// on the every-K cadence and/or on membership transitions of the
    /// fault plan. Strategies that cannot serialize their state
    /// ([`DistributedAlgorithm::snapshot`] returns `None`) are skipped
    /// silently; the run itself is unaffected either way.
    pub fn snapshots(mut self, sink: SnapshotSink) -> Self {
        self.snapshots = Some(sink);
        self
    }

    /// Resolve the configuration into a ready-to-run [`Trainer`]. Fails at
    /// build time (not mid-run) on unknown names or shape mismatches.
    pub fn build(self) -> Result<Trainer<'rt>> {
        let Some(cfg) = self.cfg else {
            bail!("TrainerBuilder: .config(..) is required");
        };
        let rt = self.rt;
        let m = rt.manifest.model(&cfg.model)?;
        let kind = m
            .config
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or("mlp")
            .to_string();
        let batch = rt.manifest.model_cfg_usize(&cfg.model, "batch")?;
        let data = if kind == "transformer" {
            let vocab = rt.manifest.model_cfg_usize(&cfg.model, "vocab")?;
            let seq = rt.manifest.model_cfg_usize(&cfg.model, "seq_len")?;
            DataSource::Lm(BigramLm::new(
                vocab,
                seq,
                batch,
                cfg.n_nodes,
                cfg.heterogeneity,
                cfg.seed,
            ))
        } else {
            let in_dim = rt.manifest.model_cfg_usize(&cfg.model, "in_dim")?;
            let classes = rt.manifest.model_cfg_usize(&cfg.model, "classes")?;
            DataSource::Blobs(Blobs::new(
                in_dim,
                classes,
                batch,
                cfg.n_nodes,
                cfg.heterogeneity,
                cfg.seed,
            ))
        };
        let msg_bytes = rt.message_bytes(&cfg.model)?;
        let dim = m.param_count;

        let algo = match self.custom {
            Some(a) => a,
            None => {
                let init = crate::model::read_init(&rt.dir, &rt.manifest, &cfg.model)?;
                let mut params = AlgoParams::new(cfg.n_nodes, init, cfg.optim);
                params.seed = cfg.seed;
                params.topology = self.topology;
                if let Some(t) = self.tau {
                    params.tau = t;
                }
                if let Some(d) = self.grad_delay {
                    params.grad_delay = d;
                }
                params.switch_at =
                    self.switch_at.unwrap_or(cfg.total_iters() / 3);
                algorithms::build(&self.algo_name, &params)?
            }
        };
        // Fail at build time (not mid-run) if an injected strategy does not
        // match the run shape; registry-built strategies match by
        // construction.
        anyhow::ensure!(
            algo.n() == cfg.n_nodes,
            "strategy `{}` has {} nodes but the config has {}",
            algo.name(),
            algo.n(),
            cfg.n_nodes
        );
        anyhow::ensure!(
            algo.dim() == dim,
            "strategy `{}` has dim {} but model `{}` has {} parameters",
            algo.name(),
            algo.dim(),
            cfg.model,
            dim
        );

        let faults = self.faults.map(FaultClock::new);
        Ok(Trainer {
            rt,
            cfg,
            algo,
            data,
            msg_bytes,
            dim,
            faults,
            exec: self.exec,
            compress: self.compress,
            snapshots: self.snapshots,
        })
    }
}

/// A fully-assembled training run: the runtime bridge, the resolved
/// strategy object, the data shards and the per-round simulated cluster —
/// built by [`TrainerBuilder`], driven by [`Trainer::run`].
pub struct Trainer<'rt> {
    /// The PJRT runtime the gradients execute on.
    pub rt: &'rt Runtime,
    /// The run configuration.
    pub cfg: TrainConfig,
    /// The distributed strategy under training.
    pub algo: Box<dyn DistributedAlgorithm>,
    /// Per-node synthetic data shards.
    pub data: DataSource,
    msg_bytes: usize,
    dim: usize,
    faults: Option<FaultClock>,
    exec: ExecPolicy,
    compress: Compression,
    snapshots: Option<SnapshotSink>,
}

impl<'rt> Trainer<'rt> {
    /// Evaluate `(mean val loss, mean val metric)` of a parameter vector
    /// over the shared validation batches.
    pub fn evaluate(&self, params: &[f32], batches: &[Batch]) -> Result<(f64, f64)> {
        let mut loss = 0.0;
        let mut metric = 0.0;
        for b in batches {
            let (l, m) = self.rt.eval_step(&self.cfg.model, params, b)?;
            loss += l as f64;
            metric += m as f64;
        }
        let n = batches.len().max(1) as f64;
        Ok((loss / n, metric / n))
    }

    /// Execute the full training run and return its recorded series.
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_synchronous()
    }

    /// The single strategy-agnostic round loop.
    fn run_synchronous(&mut self) -> Result<RunResult> {
        let cfg = self.cfg.clone();
        let n = cfg.n_nodes;
        let total = cfg.total_iters();
        let wall_start = std::time::Instant::now();
        let val = self.data.val_batches(cfg.val_batches);

        let mut timing = TimingSim::new(n, cfg.link.clone());
        timing.set_shards(self.exec.shards_for(n));
        let mut rng = Pcg::new(cfg.seed ^ 0x7131);
        let mut result = RunResult {
            label: format!("{}_n{}", self.algo.name().replace([' ', '/'], "-"), n),
            ..Default::default()
        };

        let mut zbuf = vec![0.0f32; self.dim];
        let eval_every = if cfg.eval_every_epochs > 0.0 {
            (cfg.eval_every_epochs * cfg.steps_per_epoch as f64).round().max(1.0)
                as u64
        } else {
            u64::MAX
        };

        let mut last_sim = 0.0;
        for k in 0..total {
            let epoch = cfg.epoch_of(k);
            let lr = cfg.lr.lr_at(epoch) as f32;

            // Fault scenario: surface this round's membership transitions
            // to the strategy before anything else happens at k.
            if let Some(fc) = &self.faults {
                for ev in fc.events_at(k) {
                    self.algo.on_membership_change(&ev);
                }
            }
            let is_down =
                |i: usize| self.faults.as_ref().is_some_and(|fc| fc.is_down(i, k));

            // 1–2: local gradient at each surviving node's view, handed to
            // the strategy's per-node slot (crashed nodes compute nothing).
            let mut mean_loss = 0.0f64;
            let mut alive_n = 0usize;
            for i in 0..n {
                if is_down(i) {
                    continue;
                }
                let batch = self.data.train_batch(i, k);
                self.algo.local_view(i, &mut zbuf);
                let (l, g) = self.rt.train_step(&cfg.model, &zbuf, &batch)?;
                mean_loss += l as f64;
                self.algo.apply_step(i, &g, lr);
                alive_n += 1;
            }
            mean_loss /= alive_n.max(1) as f64;

            // 3: communication (strategy-owned) + 4: timing.
            let comp = cfg.compute.sample_all(n, &mut rng);
            let ctx = RoundCtx {
                k,
                comp: &comp,
                msg_bytes: self.msg_bytes,
                link: &cfg.link,
                faults: self.faults.as_ref(),
                exec: self.exec,
                compress: self.compress,
            };
            let pattern = self.algo.communicate(&ctx);
            let sim_now = timing.advance_with_faults(
                &pattern.borrowed(),
                &comp,
                self.faults.as_ref(),
            );
            last_sim = sim_now;

            // Durable checkpoint: when the snapshot policy is due (every-K
            // cadence and/or a membership transition this round), pull the
            // strategy's state as of the *completed* round k and persist it.
            if let Some(sink) = &self.snapshots {
                let epoch_changed = self
                    .faults
                    .as_ref()
                    .is_some_and(|fc| fc.membership_changed_at(k));
                if sink.policy.due(k, epoch_changed) {
                    if let Some(snap) = self.algo.snapshot(k + 1) {
                        sink.store(&result.label, &snap).map_err(|e| {
                            anyhow::anyhow!("snapshot store failed: {e}")
                        })?;
                    }
                }
            }

            result.iters.push(IterRecord {
                iter: k,
                epoch,
                train_loss: mean_loss,
                sim_time_s: sim_now,
                lr: lr as f64,
            });

            // Mid-run evaluation at epoch ends; the final point is emitted
            // after the drain below so it never strands in-flight mass.
            if (k + 1) % eval_every == 0 && k + 1 != total {
                let rec = self.eval_point(
                    k,
                    epoch + 1.0 / cfg.steps_per_epoch as f64,
                    sim_now,
                    &val,
                )?;
                result.evals.push(rec);
            }
        }

        // Flush in-flight state (τ-delayed messages, deferred gradients)
        // *before* the final evaluation — the metrics the sweeps and tables
        // report must account for every message that was still travelling.
        self.algo.drain();
        if total > 0 {
            let rec =
                self.eval_point(total - 1, cfg.epoch_of(total), last_sim, &val)?;
            result.evals.push(rec);
        }
        result.sim_total_s = timing.makespan();
        result.wall_s = wall_start.elapsed().as_secs_f64();
        if let Some(e) = result.evals.last() {
            result.final_val_loss = e.val_loss;
            result.final_val_metric = e.val_metric;
        }
        Ok(result)
    }

    fn eval_point(
        &self,
        k: u64,
        epoch: f64,
        sim_now: f64,
        val: &[Batch],
    ) -> Result<EvalRecord> {
        let n = self.cfg.n_nodes;
        // Fault mode: a crashed/departed node's frozen checkpoint is not
        // part of the consensus model — evaluate over survivors only,
        // matching the offline harness (`faults::harness::run_quadratic`).
        let is_down =
            |i: usize| self.faults.as_ref().is_some_and(|fc| fc.is_down(i, k));
        let survivor_views: Option<Vec<Vec<f32>>> =
            if self.faults.is_some() && !self.algo.is_exact() {
                Some(
                    (0..n)
                        .filter(|&i| !is_down(i))
                        .map(|i| self.algo.node_view(i))
                        .collect(),
                )
            } else {
                None
            };
        let consensus = if self.cfg.track_consensus {
            match &survivor_views {
                Some(views) if !views.is_empty() => {
                    crate::algorithms::consensus_of(views)
                }
                _ => self.algo.consensus_stats(),
            }
        } else {
            (0.0, 0.0, 0.0)
        };
        // Per-node validation metric spread (Fig. D.3). Exact strategies
        // hold byte-equal views on every node, so the n evaluations would
        // be wasted — match the old AR-SGD behaviour and report zeros.
        let node_stats = if self.cfg.track_consensus && !self.algo.is_exact() {
            let mut metrics = Vec::with_capacity(n);
            for i in 0..n {
                if is_down(i) {
                    continue;
                }
                let z = self.algo.node_view(i);
                let (_, m) = self.evaluate(&z, &val[..val.len().min(2)])?;
                metrics.push(m);
            }
            if metrics.is_empty() {
                (0.0, 0.0, 0.0)
            } else {
                (
                    metrics.iter().cloned().fold(f64::INFINITY, f64::min),
                    metrics.iter().sum::<f64>() / metrics.len() as f64,
                    metrics.iter().cloned().fold(0.0, f64::max),
                )
            }
        } else {
            (0.0, 0.0, 0.0)
        };
        let avg_params = match &survivor_views {
            Some(views) if !views.is_empty() => {
                crate::collectives::mean_of_exec(views, self.exec)
            }
            _ => self.algo.average(),
        };
        let (val_loss, val_metric) = self.evaluate(&avg_params, val)?;
        Ok(EvalRecord {
            iter: k,
            epoch,
            sim_time_s: sim_now,
            val_loss,
            val_metric,
            node_metric_min: node_stats.0,
            node_metric_mean: node_stats.1,
            node_metric_max: node_stats.2,
            consensus_mean: consensus.0,
            consensus_min: consensus.1,
            consensus_max: consensus.2,
        })
    }
}

//! The training coordinator: one event-driven loop that runs every
//! algorithm in the paper over the PJRT runtime and the simulated cluster.
//!
//! Per synchronous iteration (Alg. 1 / Alg. 2 / baselines):
//!   1. every node evaluates its mini-batch gradient at its **de-biased**
//!      parameters `z_i = x_i / w_i` through the `train_<model>` artifact;
//!   2. the local optimizer (Nesterov/Adam) applies the step to the
//!      **biased** numerator `x_i` (Alg. 3);
//!   3. the algorithm's communication runs: exact AllReduce, PushSum
//!      gossip (optionally τ-delayed / biased), or symmetric gossip;
//!   4. the timing recursion attaches simulated wall-clock (the paper's
//!      10 GbE / IB testbed timing) to the iteration.
//!
//! AD-PSGD runs on the discrete-event queue instead: nodes compute
//! gradients on snapshots and apply them stale after pairwise averaging,
//! exactly the staleness semantics of Lian et al. (2018).

use anyhow::Result;

use crate::algorithms::Algorithm;
use crate::collectives;
use crate::config::TrainConfig;
use crate::data::{Batch, Blobs, BigramLm, DataSource};
use crate::gossip::PushSumEngine;
use crate::metrics::{EvalRecord, IterRecord, RunResult};
use crate::net::{CommPattern, TimingSim};
use crate::optim::Optimizer;
use crate::rng::Pcg;
use crate::runtime::Runtime;
use crate::sim::EventQueue;

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: TrainConfig,
    pub algo: Algorithm,
    pub data: DataSource,
    msg_bytes: usize,
    dim: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig, algo: Algorithm) -> Result<Self> {
        let m = rt.manifest.model(&cfg.model)?;
        let kind = m
            .config
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or("mlp")
            .to_string();
        let batch = rt.manifest.model_cfg_usize(&cfg.model, "batch")?;
        let data = if kind == "transformer" {
            let vocab = rt.manifest.model_cfg_usize(&cfg.model, "vocab")?;
            let seq = rt.manifest.model_cfg_usize(&cfg.model, "seq_len")?;
            DataSource::Lm(BigramLm::new(
                vocab,
                seq,
                batch,
                cfg.n_nodes,
                cfg.heterogeneity,
                cfg.seed,
            ))
        } else {
            let in_dim = rt.manifest.model_cfg_usize(&cfg.model, "in_dim")?;
            let classes = rt.manifest.model_cfg_usize(&cfg.model, "classes")?;
            DataSource::Blobs(Blobs::new(
                in_dim,
                classes,
                batch,
                cfg.n_nodes,
                cfg.heterogeneity,
                cfg.seed,
            ))
        };
        let msg_bytes = rt.message_bytes(&cfg.model)?;
        let dim = m.param_count;
        Ok(Self { rt, cfg, algo, data, msg_bytes, dim })
    }

    /// Evaluate `(mean val loss, mean val metric)` of a parameter vector
    /// over the shared validation batches.
    pub fn evaluate(&self, params: &[f32], batches: &[Batch]) -> Result<(f64, f64)> {
        let mut loss = 0.0;
        let mut metric = 0.0;
        for b in batches {
            let (l, m) = self.rt.eval_step(&self.cfg.model, params, b)?;
            loss += l as f64;
            metric += m as f64;
        }
        let n = batches.len().max(1) as f64;
        Ok((loss / n, metric / n))
    }

    pub fn run(&self) -> Result<RunResult> {
        match &self.algo {
            Algorithm::AdPsgd { schedule } => self.run_adpsgd(schedule.clone()),
            _ => self.run_synchronous(),
        }
    }

    // ---------------------------------------------------------------------
    // Synchronous algorithms: AR-SGD, SGP, OSGP, D-PSGD
    // ---------------------------------------------------------------------
    fn run_synchronous(&self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = cfg.n_nodes;
        let total = cfg.total_iters();
        let wall_start = std::time::Instant::now();
        let val = self.data.val_batches(cfg.val_batches);

        let init = crate::model::read_init(&self.rt.dir, &self.rt.manifest, &cfg.model)?;

        // AR-SGD keeps a single replicated state; gossip methods keep the
        // PushSum engine (D-PSGD is PushSum over a symmetric schedule — the
        // weights stay ≡ 1, see algorithms/mod.rs).
        let is_ar = matches!(self.algo, Algorithm::ArSgd);
        let (tau, biased) = match &self.algo {
            Algorithm::Osgp { tau, biased, .. } => (*tau, *biased),
            _ => (0, false),
        };
        let mut engine = if is_ar {
            None
        } else {
            Some(PushSumEngine::new(vec![init.clone(); n], tau, biased))
        };
        let mut ar_params = init.clone();
        let mut opts: Vec<Optimizer> = if is_ar {
            vec![Optimizer::new(cfg.optim, self.dim)]
        } else {
            (0..n).map(|_| Optimizer::new(cfg.optim, self.dim)).collect()
        };

        let mut timing = TimingSim::new(n, cfg.link.clone());
        let mut rng = Pcg::new(cfg.seed ^ 0x7131);
        let mut result = RunResult {
            label: format!("{}_n{}", self.algo.name().replace([' ', '/'], "-"), n),
            ..Default::default()
        };

        let mut zbuf = vec![0.0f32; self.dim];
        let eval_every = if cfg.eval_every_epochs > 0.0 {
            (cfg.eval_every_epochs * cfg.steps_per_epoch as f64).round().max(1.0)
                as u64
        } else {
            u64::MAX
        };

        for k in 0..total {
            let epoch = cfg.epoch_of(k);
            let lr = cfg.lr.lr_at(epoch) as f32;

            // 1–2: local gradient at z, optimizer step on x.
            let mut mean_loss = 0.0f64;
            if is_ar {
                let mut gsum = vec![0.0f32; self.dim];
                for i in 0..n {
                    let batch = self.data.train_batch(i, k);
                    let (l, g) = self.rt.train_step(&cfg.model, &ar_params, &batch)?;
                    mean_loss += l as f64;
                    for (a, b) in gsum.iter_mut().zip(&g) {
                        *a += b;
                    }
                }
                let inv = 1.0 / n as f32;
                for a in &mut gsum {
                    *a *= inv;
                }
                opts[0].step(&mut ar_params, &gsum, lr);
            } else {
                let engine = engine.as_mut().unwrap();
                for i in 0..n {
                    let batch = self.data.train_batch(i, k);
                    engine.states[i].debias_into(&mut zbuf);
                    let (l, g) = self.rt.train_step(&cfg.model, &zbuf, &batch)?;
                    mean_loss += l as f64;
                    opts[i].step(&mut engine.states[i].x, &g, lr);
                }
            }
            mean_loss /= n as f64;

            // 3: communication.
            let pattern = match &self.algo {
                Algorithm::ArSgd => CommPattern::AllReduce { bytes: self.msg_bytes },
                Algorithm::Sgp { schedule } | Algorithm::Osgp { schedule, .. } => {
                    let engine = engine.as_mut().unwrap();
                    let sched = schedule.at(k);
                    engine.step(k, sched);
                    CommPattern::PushSum {
                        schedule: sched,
                        bytes: self.msg_bytes,
                        tau,
                    }
                }
                Algorithm::DPsgd { schedule } => {
                    let engine = engine.as_mut().unwrap();
                    engine.step(k, schedule);
                    CommPattern::Symmetric {
                        schedule,
                        bytes: self.msg_bytes,
                        handshake: 2.0,
                    }
                }
                Algorithm::AdPsgd { .. } => unreachable!(),
            };

            // 4: timing.
            let comp = cfg.compute.sample_all(n, &mut rng);
            let sim_now = timing.advance(&pattern, &comp);

            result.iters.push(IterRecord {
                iter: k,
                epoch,
                train_loss: mean_loss,
                sim_time_s: sim_now,
                lr: lr as f64,
            });

            // Evaluation (end of epoch points + final iteration).
            if (k + 1) % eval_every == 0 || k + 1 == total {
                let rec = self.eval_point(
                    k,
                    epoch + 1.0 / cfg.steps_per_epoch as f64,
                    sim_now,
                    is_ar.then_some(&ar_params),
                    engine.as_ref(),
                    &val,
                )?;
                result.evals.push(rec);
            }
        }

        if let Some(engine) = engine.as_mut() {
            engine.drain();
        }
        result.sim_total_s = timing.makespan();
        result.wall_s = wall_start.elapsed().as_secs_f64();
        if let Some(e) = result.evals.last() {
            result.final_val_loss = e.val_loss;
            result.final_val_metric = e.val_metric;
        }
        Ok(result)
    }

    fn eval_point(
        &self,
        k: u64,
        epoch: f64,
        sim_now: f64,
        ar_params: Option<&Vec<f32>>,
        engine: Option<&PushSumEngine>,
        val: &[Batch],
    ) -> Result<EvalRecord> {
        let n = self.cfg.n_nodes;
        let (consensus, node_stats, avg_params) = if let Some(engine) = engine {
            let consensus = if self.cfg.track_consensus {
                engine.consensus_distance()
            } else {
                (0.0, 0.0, 0.0)
            };
            // Per-node validation metric spread (Fig. D.3).
            let mut metrics = Vec::with_capacity(n);
            if self.cfg.track_consensus {
                for st in &engine.states {
                    let z = st.debiased();
                    let (_, m) = self.evaluate(&z, &val[..val.len().min(2)])?;
                    metrics.push(m);
                }
            }
            let avg = {
                let zs: Vec<Vec<f32>> =
                    engine.states.iter().map(|s| s.debiased()).collect();
                collectives::mean_of(&zs)
            };
            let stats = if metrics.is_empty() {
                (0.0, 0.0, 0.0)
            } else {
                (
                    metrics.iter().cloned().fold(f64::INFINITY, f64::min),
                    metrics.iter().sum::<f64>() / metrics.len() as f64,
                    metrics.iter().cloned().fold(0.0, f64::max),
                )
            };
            (consensus, stats, avg)
        } else {
            let p = ar_params.unwrap().clone();
            ((0.0, 0.0, 0.0), (0.0, 0.0, 0.0), p)
        };
        let (val_loss, val_metric) = self.evaluate(&avg_params, val)?;
        Ok(EvalRecord {
            iter: k,
            epoch,
            sim_time_s: sim_now,
            val_loss,
            val_metric,
            node_metric_min: node_stats.0,
            node_metric_mean: node_stats.1,
            node_metric_max: node_stats.2,
            consensus_mean: consensus.0,
            consensus_min: consensus.1,
            consensus_max: consensus.2,
        })
    }

    // ---------------------------------------------------------------------
    // AD-PSGD: event-driven asynchronous gossip
    // ---------------------------------------------------------------------
    fn run_adpsgd(&self, _schedule: crate::topology::Schedule) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = cfg.n_nodes;
        let total = cfg.total_iters();
        let total_updates = total * n as u64;
        let wall_start = std::time::Instant::now();
        let val = self.data.val_batches(cfg.val_batches);
        let init = crate::model::read_init(&self.rt.dir, &self.rt.manifest, &cfg.model)?;

        let mut params: Vec<Vec<f32>> = vec![init; n];
        let mut opts: Vec<Optimizer> =
            (0..n).map(|_| Optimizer::new(cfg.optim, self.dim)).collect();
        let mut steps = vec![0u64; n];
        let mut rng = Pcg::new(cfg.seed ^ 0xad95);

        // Pending gradient per node, computed on the snapshot taken when
        // its compute slot began (the AD-PSGD staleness semantics).
        let mut pending: Vec<Option<(f32, Vec<f32>)>> = vec![None; n];
        let mut queue: EventQueue<usize> = EventQueue::new();
        let ptp = cfg.link.ptp_time(self.msg_bytes);
        // Partial overlap of the averaging thread with compute (App. C of
        // Lian et al.: communication runs on its own thread).
        let comm_overhead = 0.5 * ptp;

        // Prime: every node starts computing at t=0 on its initial params.
        for (i, p) in params.iter().enumerate() {
            let batch = self.data.train_batch(i, 0);
            pending[i] = Some(self.rt.train_step(&cfg.model, p, &batch)?);
            queue.push(cfg.compute.sample(&mut rng), i);
        }

        let mut result = RunResult {
            label: format!("AD-PSGD_n{n}"),
            ..Default::default()
        };
        let mut done = 0u64;
        let eval_every = (total_updates
            / ((cfg.epochs / cfg.eval_every_epochs.max(0.1)).ceil() as u64).max(1))
        .max(1);

        while done < total_updates {
            let ev = queue.pop().expect("queue exhausted early");
            let i = ev.payload;
            let now = ev.time;

            // Pairwise average with a random peer (atomic in shared memory).
            let j = {
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                j
            };
            if i != j {
                // Split borrows to average the two vectors in place.
                let (a, b) = if i < j {
                    let (l, r) = params.split_at_mut(j);
                    (&mut l[i], &mut r[0])
                } else {
                    let (l, r) = params.split_at_mut(i);
                    (&mut r[0], &mut l[j])
                };
                for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                    let m = 0.5 * (*x + *y);
                    *x = m;
                    *y = m;
                }
            }

            // Apply the stale gradient.
            let (loss, grad) = pending[i].take().expect("no pending grad");
            let epoch = done as f64 / (n as u64 * cfg.steps_per_epoch) as f64;
            let lr = cfg.lr.lr_at(epoch) as f32;
            opts[i].step(&mut params[i], &grad, lr);
            steps[i] += 1;
            done += 1;

            result.iters.push(IterRecord {
                iter: done / n as u64,
                epoch,
                train_loss: loss as f64,
                sim_time_s: now,
                lr: lr as f64,
            });

            if done % eval_every == 0 || done == total_updates {
                let avg = collectives::mean_of(&params);
                let (val_loss, val_metric) = self.evaluate(&avg, &val)?;
                result.evals.push(EvalRecord {
                    iter: done / n as u64,
                    epoch,
                    sim_time_s: now,
                    val_loss,
                    val_metric,
                    node_metric_min: 0.0,
                    node_metric_mean: 0.0,
                    node_metric_max: 0.0,
                    consensus_mean: 0.0,
                    consensus_min: 0.0,
                    consensus_max: 0.0,
                });
            }

            // Kick off the next compute on the *current* (fresh) params.
            if steps[i] < total {
                let batch = self.data.train_batch(i, steps[i]);
                pending[i] =
                    Some(self.rt.train_step(&cfg.model, &params[i], &batch)?);
                queue.push(now + comm_overhead + cfg.compute.sample(&mut rng), i);
            }
        }

        result.sim_total_s = queue.now();
        result.wall_s = wall_start.elapsed().as_secs_f64();
        if let Some(e) = result.evals.last() {
            result.final_val_loss = e.val_loss;
            result.final_val_metric = e.val_metric;
        }
        Ok(result)
    }
}

//! Micro-benchmark harness (the offline build has no criterion): warmup +
//! timed iterations, robust statistics, and a criterion-style report line.
//! Used by every target under `rust/benches/` (all `harness = false`).

use std::time::{Duration, Instant};

/// Robust statistics of one benchmarked closure.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark name (slash-separated convention: `group/case/param`).
    pub name: String,
    /// Timed iterations.
    pub iters: u64,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Median per-iteration time.
    pub median: Duration,
    /// 95th-percentile per-iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
    /// Simulated wire bytes one iteration puts on the network, when the
    /// bench tracks it (the compression scaling curve pairs ns with
    /// bytes); `None` for pure-CPU benches.
    pub bytes_per_iter: Option<u64>,
}

impl BenchStats {
    /// Attach the per-iteration wire-byte count (emitted as
    /// `bytes_per_iter` in the JSON report).
    pub fn with_bytes(mut self, bytes: u64) -> Self {
        self.bytes_per_iter = Some(bytes);
        self
    }
}

impl BenchStats {
    /// Print the criterion-style report line.
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters, min {}, max {})",
            self.name,
            format!("mean {}", fmt(self.mean)),
            format!("med {}", fmt(self.median)),
            format!("p95 {}", fmt(self.p95)),
            self.iters,
            fmt(self.min),
            fmt(self.max),
        );
    }
}

/// Human-readable duration (ns/µs/ms/s auto-scaled).
pub fn fmt(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill ~`budget`.
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().max(Duration::from_nanos(50));
    let target = (budget.as_nanos() / first.as_nanos().max(1)).clamp(5, 10_000) as u64;

    let mut samples = Vec::with_capacity(target as usize);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters: target,
        mean: total / target as u32,
        median: samples[samples.len() / 2],
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min: samples[0],
        max: *samples.last().unwrap(),
        bytes_per_iter: None,
    };
    stats.report();
    stats
}

/// Benchmark with a fixed default budget of 2 seconds.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench_for(name, Duration::from_secs(2), f)
}

/// Collects [`BenchStats`] and writes them as machine-readable JSON — the
/// artifact CI and perf-trajectory tooling diff across commits (e.g.
/// `results/BENCH_gossip.json`). Hand-rolled emitter: the offline build has
/// no serde, and the schema is flat.
#[derive(Default)]
pub struct JsonReport {
    entries: Vec<BenchStats>,
}

impl JsonReport {
    /// An empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one benchmark's statistics.
    pub fn push(&mut self, stats: BenchStats) {
        self.entries.push(stats);
    }

    /// Render the flat `{"benches": [...]}` JSON document.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"benches\": [\n");
        for (i, b) in self.entries.iter().enumerate() {
            let bytes = b
                .bytes_per_iter
                .map(|v| format!(", \"bytes_per_iter\": {v}"))
                .unwrap_or_default();
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \
                 \"median_ns\": {}, \"p95_ns\": {}, \"min_ns\": {}, \"max_ns\": {}{bytes}}}{}\n",
                b.name.replace('"', "'"),
                b.iters,
                b.mean.as_nanos(),
                b.median.as_nanos(),
                b.p95.as_nanos(),
                b.min.as_nanos(),
                b.max.as_nanos(),
                if i + 1 == self.entries.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the report, creating parent directories as needed.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
    }
}

/// Peak resident set size of the current process in bytes (the `VmHWM`
/// high-water mark from `/proc/self/status`). `None` where procfs is
/// unavailable (non-Linux) — callers must treat the measurement as
/// best-effort. Note the kernel never lowers the mark, so per-phase
/// readings in one process are cumulative maxima: measure configurations
/// in ascending memory order for meaningful curves.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Prevent the optimizer from discarding a value (std::hint::black_box is
/// stable; thin alias so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header between benchmark groups.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::json::Json;

    #[test]
    fn peak_rss_is_positive_where_procfs_exists() {
        if let Some(bytes) = peak_rss_bytes() {
            assert!(bytes > 0);
        }
    }

    #[test]
    fn json_report_is_parseable_and_complete() {
        let mut rep = JsonReport::new();
        rep.push(BenchStats {
            name: "a/b\"c".into(),
            iters: 7,
            mean: Duration::from_nanos(1500),
            median: Duration::from_nanos(1400),
            p95: Duration::from_nanos(2000),
            min: Duration::from_nanos(1000),
            max: Duration::from_nanos(3000),
            bytes_per_iter: None,
        });
        rep.push(
            BenchStats {
                name: "second".into(),
                iters: 3,
                mean: Duration::from_micros(2),
                median: Duration::from_micros(2),
                p95: Duration::from_micros(2),
                min: Duration::from_micros(1),
                max: Duration::from_micros(4),
                bytes_per_iter: None,
            }
            .with_bytes(4096),
        );
        let parsed = Json::parse(&rep.to_json()).expect("valid JSON");
        let benches = parsed.get("benches").and_then(|b| b.as_arr()).unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(
            benches[1].get("mean_ns").and_then(|v| v.as_f64()),
            Some(2000.0)
        );
        assert_eq!(
            benches[0].get("name").and_then(|v| v.as_str()),
            Some("a/b'c")
        );
        // bytes_per_iter is emitted only where tracked.
        assert!(benches[0].get("bytes_per_iter").is_none());
        assert_eq!(
            benches[1].get("bytes_per_iter").and_then(|v| v.as_f64()),
            Some(4096.0)
        );
    }
}

//! Offline robustness harness: drive any registered algorithm under a
//! [`FaultPlan`] with synthetic least-squares gradients — no HLO artifacts
//! needed, so the robustness sweep (`repro faults`) and its tier-1
//! regression tests run everywhere the crate builds.
//!
//! Each node owns the quadratic `f_i(x) = ½‖x − c_i‖²` (global optimum =
//! mean of the `c_i`), the same objective as the Theorem-1/2 sanity
//! checks, driven through the exact coordinator round protocol:
//! membership events → per-survivor gradients → `communicate` →
//! fault-aware timing. Everything is deterministic given the config and
//! plan seeds — the determinism proptest asserts bit-identical reruns.

use anyhow::Result;

use crate::algorithms::{self, AlgoParams, RoundCtx};
use crate::gossip::{Compression, ExecPolicy};
use crate::net::{ComputeModel, LinkModel, TimingSim};
use crate::optim::OptimKind;
use crate::rng::Pcg;

use super::{FaultClock, FaultPlan};

/// Shape of one offline fault run.
#[derive(Clone, Debug)]
pub struct FaultRunConfig {
    /// Number of simulated nodes.
    pub n: usize,
    /// Rounds to run.
    pub iters: u64,
    /// Dimension of the per-node quadratic.
    pub dim: usize,
    /// Step size.
    pub lr: f32,
    /// Simulated message size (paper-scale by default so the timing story
    /// is visible).
    pub msg_bytes: usize,
    /// The simulated fabric.
    pub link: LinkModel,
    /// The per-node compute-time model.
    pub compute: ComputeModel,
    /// Seed for centers, compute jitter and event ordering.
    pub seed: u64,
    /// Execution policy for the per-round state updates (bit-identical
    /// across policies — the sweep's numbers do not depend on it).
    pub exec: ExecPolicy,
    /// Gossip message compression (top-k / quantized with error
    /// feedback); [`Compression::Identity`] ships dense.
    pub compress: Compression,
    /// Gradient-heterogeneity knob ζ ∈ [0, 1]: each node's quadratic
    /// center is pulled toward the shared mean center by `1 − ζ`
    /// (`c_i = mean + ζ·(raw_i − mean)`). The default 1.0 reproduces the
    /// original independent-center draws **bit-exactly** (the raw draws
    /// are used untouched), so existing sweeps and their regression
    /// baselines are unchanged.
    pub heterogeneity: f64,
    /// When set, attach a [`crate::obs::TimingObs`] recorder to the
    /// timing simulator and write a `"sim"` JSONL trace here after the
    /// run (per-iteration makespans, straggler counts) for `repro
    /// trace`. `None` (the default) records nothing — the numbers above
    /// are unaffected either way.
    pub trace: Option<std::path::PathBuf>,
    /// When set, persist durable snapshots of the strategy's gossip state
    /// through this sink whenever its [`crate::snapshot::SnapshotPolicy`]
    /// is due (every-K cadence and/or a membership transition of the
    /// plan). The harness stashes the cursor of its compute-jitter RNG in
    /// each capture, so a run restored from the file resamples the
    /// identical compute sequence. `None` (the default) checkpoints
    /// nothing; the run's numbers are unaffected either way.
    pub snapshots: Option<crate::snapshot::SnapshotSink>,
}

impl Default for FaultRunConfig {
    fn default() -> Self {
        Self {
            n: 16,
            iters: 150,
            dim: 32,
            lr: 0.05,
            msg_bytes: 100 << 20,
            link: LinkModel::ethernet_10g(),
            compute: ComputeModel::resnet50_dgx1(),
            seed: 1,
            exec: ExecPolicy::Sequential,
            compress: Compression::Identity,
            heterogeneity: 1.0,
            trace: None,
            snapshots: None,
        }
    }
}

/// Outcome of one offline fault run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRunStats {
    /// Display name of the algorithm that ran.
    pub algo: String,
    /// ‖x̄ − x*‖ over the surviving members (distance of the consensus
    /// model from the optimum of the full objective).
    pub final_err: f64,
    /// Training loss of the consensus model over the full objective,
    /// `(1/n) Σᵢ ½‖x̄ − cᵢ‖²` — the harness analogue of "final loss"; its
    /// floor is the irreducible spread `(1/n) Σᵢ ½‖x* − cᵢ‖²`, so
    /// relative comparisons between runs are meaningful.
    pub final_loss: f64,
    /// Mean consensus distance ‖z_i − x̄‖ over surviving members.
    pub consensus: f64,
    /// Simulated makespan of the whole run (seconds).
    pub makespan: f64,
}

/// Pull each raw center toward the shared mean by `1 − zeta` (the
/// heterogeneity knob). `zeta ≥ 1` returns the raw draws untouched —
/// bit-exact with the pre-knob behaviour, which fixed-seed regression
/// baselines depend on.
fn blend_centers(raw: Vec<Vec<f32>>, zeta: f64) -> Vec<Vec<f32>> {
    if zeta >= 1.0 || raw.is_empty() {
        return raw;
    }
    let zeta = zeta.max(0.0);
    let n = raw.len() as f64;
    let dim = raw[0].len();
    let mut mean = vec![0.0f64; dim];
    for c in &raw {
        for (m, v) in mean.iter_mut().zip(c) {
            *m += *v as f64 / n;
        }
    }
    raw.into_iter()
        .map(|c| {
            c.iter()
                .zip(&mean)
                .map(|(v, m)| (m + zeta * (*v as f64 - m)) as f32)
                .collect()
        })
        .collect()
}

/// Run `algo_name` on the node-local quadratics under `plan`; fully
/// deterministic given `(cfg.seed, plan.seed)`.
pub fn run_quadratic(
    algo_name: &str,
    cfg: &FaultRunConfig,
    plan: &FaultPlan,
) -> Result<FaultRunStats> {
    let mut rng = Pcg::new(cfg.seed);
    let raw: Vec<Vec<f32>> = (0..cfg.n).map(|_| rng.gaussian_vec(cfg.dim)).collect();
    let centers = blend_centers(raw, cfg.heterogeneity);
    let mut opt = vec![0.0f64; cfg.dim];
    for c in &centers {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / cfg.n as f64;
        }
    }

    let mut params =
        AlgoParams::new(cfg.n, vec![0.0f32; cfg.dim], OptimKind::Sgd);
    params.seed = cfg.seed;
    let mut algo = algorithms::build(algo_name, &params)?;
    let clock = FaultClock::new(plan.clone());
    let mut timing = TimingSim::new(cfg.n, cfg.link.clone());
    timing.set_shards(cfg.exec.shards_for(cfg.n));
    if cfg.trace.is_some() {
        let cap = cfg.iters.min(4096) as usize;
        timing.set_obs(Some(Box::new(crate::obs::TimingObs::new(cfg.n, cap))));
    }
    let mut comp_rng = Pcg::new(cfg.seed ^ 0xfa17);
    let mut view = vec![0.0f32; cfg.dim];

    for k in 0..cfg.iters {
        for ev in clock.events_at(k) {
            algo.on_membership_change(&ev);
        }
        for i in 0..cfg.n {
            if clock.is_down(i, k) {
                continue;
            }
            algo.local_view(i, &mut view);
            let g: Vec<f32> =
                view.iter().zip(&centers[i]).map(|(z, c)| z - c).collect();
            algo.apply_step(i, &g, cfg.lr);
        }
        let comp = cfg.compute.sample_all(cfg.n, &mut comp_rng);
        let ctx = RoundCtx::new(k, &comp, cfg.msg_bytes, &cfg.link)
            .with_faults(&clock)
            .with_exec(cfg.exec)
            .with_compress(cfg.compress);
        let pattern = algo.communicate(&ctx);
        timing.advance_with_faults(&pattern.borrowed(), &comp, Some(&clock));

        // Durable checkpoint: capture the strategy's post-round state when
        // the sink's policy is due, with the compute-jitter RNG cursor
        // riding along so a restored run resamples identically.
        if let Some(sink) = &cfg.snapshots {
            if sink.policy.due(k, clock.membership_changed_at(k)) {
                if let Some(mut snap) = algo.snapshot(k + 1) {
                    snap.set_rngs(vec![crate::snapshot::RngCursor::of(&comp_rng)]);
                    sink.store(algo_name, &snap).map_err(|e| {
                        anyhow::anyhow!("snapshot store failed: {e}")
                    })?;
                }
            }
        }
    }
    algo.drain();

    // Final statistics over the surviving members only: a permanently-left
    // node's frozen checkpoint is not part of the consensus model.
    let alive = clock.alive(cfg.n, cfg.iters.saturating_sub(1));
    let views: Vec<Vec<f32>> = alive.iter().map(|&i| algo.node_view(i)).collect();
    let m = views.len().max(1) as f64;
    let mut mean = vec![0.0f64; cfg.dim];
    for v in &views {
        for (a, b) in mean.iter_mut().zip(v) {
            *a += *b as f64 / m;
        }
    }
    let final_err = mean
        .iter()
        .zip(&opt)
        .map(|(a, o)| (a - o) * (a - o))
        .sum::<f64>()
        .sqrt();
    let final_loss = centers
        .iter()
        .map(|c| {
            0.5 * mean
                .iter()
                .zip(c)
                .map(|(a, b)| {
                    let e = a - *b as f64;
                    e * e
                })
                .sum::<f64>()
        })
        .sum::<f64>()
        / cfg.n as f64;
    let consensus = views
        .iter()
        .map(|v| {
            v.iter()
                .zip(&mean)
                .map(|(a, b)| {
                    let e = *a as f64 - b;
                    e * e
                })
                .sum::<f64>()
                .sqrt()
        })
        .sum::<f64>()
        / m;
    if let (Some(path), Some(obs)) = (cfg.trace.as_deref(), timing.take_obs()) {
        crate::obs::trace::write_sim_trace(path, &obs, cfg.iters)?;
    }

    Ok(FaultRunStats {
        algo: algo.name(),
        final_err,
        final_loss,
        consensus,
        makespan: timing.makespan(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_harness_converges_for_core_algorithms() {
        let cfg = FaultRunConfig { n: 8, iters: 120, ..Default::default() };
        for algo in ["ar-sgd", "sgp", "osgp", "dpsgd"] {
            let s = run_quadratic(algo, &cfg, &FaultPlan::lossless()).unwrap();
            assert!(s.final_err < 0.2, "{algo}: err {}", s.final_err);
            // The gossip consensus equilibrium sits at O(lr · gradient
            // heterogeneity) ≈ 0.2–0.35 here; exact strategies report 0.
            assert!(s.consensus < 0.5, "{algo}: consensus {}", s.consensus);
            assert!(s.makespan > 0.0);
        }
    }

    #[test]
    fn full_heterogeneity_is_bit_exact_with_the_raw_draws() {
        // ζ = 1.0 must not even round-trip the centers through the blend
        // arithmetic — the fixed-seed fault baselines depend on it.
        let a = run_quadratic(
            "sgp",
            &FaultRunConfig { n: 8, iters: 40, ..Default::default() },
            &FaultPlan::lossless(),
        )
        .unwrap();
        let b = run_quadratic(
            "sgp",
            &FaultRunConfig { n: 8, iters: 40, heterogeneity: 1.0, ..Default::default() },
            &FaultPlan::lossless(),
        )
        .unwrap();
        assert_eq!(a.final_err.to_bits(), b.final_err.to_bits());
        assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
    }

    #[test]
    fn heterogeneity_knob_scales_the_gradient_dissimilarity() {
        let run = |h: f64| {
            run_quadratic(
                "sgp",
                &FaultRunConfig { n: 8, iters: 100, heterogeneity: h, ..Default::default() },
                &FaultPlan::lossless(),
            )
            .unwrap()
        };
        // The consensus equilibrium is O(lr · ζ): quartering ζ must
        // visibly shrink it, and ζ = 0 (identical objectives) collapses it.
        let (h0, h25, h100) = (run(0.0), run(0.25), run(1.0));
        assert!(h25.consensus < h100.consensus * 0.6, "{} vs {}", h25.consensus, h100.consensus);
        assert!(h0.consensus < h100.consensus * 1e-2, "{}", h0.consensus);
    }

    #[test]
    fn compressed_sgp_tracks_dense_within_five_percent() {
        // The compress-sweep acceptance pin, at its default shape: top-k
        // 1/16 (≥ 8× fewer wire bytes) and qsgd:4 both keep the final
        // consensus-model loss within 5% of uncompressed SGP at
        // heterogeneity 0.5 — the error-feedback bank delivers the
        // withheld `(x, w)` mass instead of biasing the fix point (an
        // equivalent offline simulation of these dynamics puts topk:16 at
        // ≈ +2% for n = 32 and qsgd:4 at ≈ +0.001%).
        let cfg = |c: Compression| FaultRunConfig {
            n: 32,
            dim: 256,
            iters: 300,
            heterogeneity: 0.5,
            compress: c,
            ..Default::default()
        };
        let dense = run_quadratic("sgp", &cfg(Compression::Identity), &FaultPlan::lossless())
            .unwrap();
        for spec in [Compression::TopK { den: 16 }, Compression::Qsgd { bits: 4 }] {
            let c = run_quadratic("sgp", &cfg(spec), &FaultPlan::lossless()).unwrap();
            let rel = (c.final_loss - dense.final_loss).abs() / dense.final_loss;
            assert!(
                rel <= 0.05,
                "{spec:?}: loss {} vs dense {} ({:.2}% off)",
                c.final_loss,
                dense.final_loss,
                100.0 * rel
            );
            // Fewer wire bytes ⇒ strictly smaller simulated makespan.
            assert!(c.makespan < dense.makespan, "{spec:?} must be faster");
        }
    }

    #[test]
    fn harness_writes_snapshots_on_the_policy_cadence() {
        use crate::snapshot::{Snapshot, SnapshotPolicy, SnapshotSink};
        let dir = std::env::temp_dir()
            .join(format!("sgp_harness_snap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FaultRunConfig {
            n: 8,
            iters: 20,
            snapshots: Some(SnapshotSink::new(SnapshotPolicy::every(8), dir.clone())),
            ..Default::default()
        };
        run_quadratic("sgp", &cfg, &FaultPlan::lossless()).unwrap();
        // every(8) over 20 rounds fires after rounds 7 and 15.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 2);
        let snap = Snapshot::read_file(&dir.join("sgp.r00000008.snap")).unwrap();
        assert_eq!(snap.n(), 8);
        assert_eq!(snap.rngs().len(), 1, "compute-jitter cursor rides along");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn harness_is_deterministic_given_seeds() {
        let cfg = FaultRunConfig { n: 8, iters: 60, ..Default::default() };
        let plan = FaultPlan::lossless()
            .with_drop(0.1)
            .with_rescue(true)
            .with_crash(2, 20, Some(40))
            .with_seed(5);
        let a = run_quadratic("sgp", &cfg, &plan).unwrap();
        let b = run_quadratic("sgp", &cfg, &plan).unwrap();
        assert_eq!(a, b, "same seeds must replay bit-identically");
    }
}

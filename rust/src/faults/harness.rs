//! Offline robustness harness: drive any registered algorithm under a
//! [`FaultPlan`] with synthetic least-squares gradients — no HLO artifacts
//! needed, so the robustness sweep (`repro faults`) and its tier-1
//! regression tests run everywhere the crate builds.
//!
//! Each node owns the quadratic `f_i(x) = ½‖x − c_i‖²` (global optimum =
//! mean of the `c_i`), the same objective as the Theorem-1/2 sanity
//! checks, driven through the exact coordinator round protocol:
//! membership events → per-survivor gradients → `communicate` →
//! fault-aware timing. Everything is deterministic given the config and
//! plan seeds — the determinism proptest asserts bit-identical reruns.

use anyhow::Result;

use crate::algorithms::{self, AlgoParams, RoundCtx};
use crate::gossip::ExecPolicy;
use crate::net::{ComputeModel, LinkModel, TimingSim};
use crate::optim::OptimKind;
use crate::rng::Pcg;

use super::{FaultClock, FaultPlan};

/// Shape of one offline fault run.
#[derive(Clone, Debug)]
pub struct FaultRunConfig {
    /// Number of simulated nodes.
    pub n: usize,
    /// Rounds to run.
    pub iters: u64,
    /// Dimension of the per-node quadratic.
    pub dim: usize,
    /// Step size.
    pub lr: f32,
    /// Simulated message size (paper-scale by default so the timing story
    /// is visible).
    pub msg_bytes: usize,
    /// The simulated fabric.
    pub link: LinkModel,
    /// The per-node compute-time model.
    pub compute: ComputeModel,
    /// Seed for centers, compute jitter and event ordering.
    pub seed: u64,
    /// Execution policy for the per-round state updates (bit-identical
    /// across policies — the sweep's numbers do not depend on it).
    pub exec: ExecPolicy,
}

impl Default for FaultRunConfig {
    fn default() -> Self {
        Self {
            n: 16,
            iters: 150,
            dim: 32,
            lr: 0.05,
            msg_bytes: 100 << 20,
            link: LinkModel::ethernet_10g(),
            compute: ComputeModel::resnet50_dgx1(),
            seed: 1,
            exec: ExecPolicy::Sequential,
        }
    }
}

/// Outcome of one offline fault run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRunStats {
    /// Display name of the algorithm that ran.
    pub algo: String,
    /// ‖x̄ − x*‖ over the surviving members (distance of the consensus
    /// model from the optimum of the full objective).
    pub final_err: f64,
    /// Mean consensus distance ‖z_i − x̄‖ over surviving members.
    pub consensus: f64,
    /// Simulated makespan of the whole run (seconds).
    pub makespan: f64,
}

/// Run `algo_name` on the node-local quadratics under `plan`; fully
/// deterministic given `(cfg.seed, plan.seed)`.
pub fn run_quadratic(
    algo_name: &str,
    cfg: &FaultRunConfig,
    plan: &FaultPlan,
) -> Result<FaultRunStats> {
    let mut rng = Pcg::new(cfg.seed);
    let centers: Vec<Vec<f32>> = (0..cfg.n).map(|_| rng.gaussian_vec(cfg.dim)).collect();
    let mut opt = vec![0.0f64; cfg.dim];
    for c in &centers {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / cfg.n as f64;
        }
    }

    let mut params =
        AlgoParams::new(cfg.n, vec![0.0f32; cfg.dim], OptimKind::Sgd);
    params.seed = cfg.seed;
    let mut algo = algorithms::build(algo_name, &params)?;
    let clock = FaultClock::new(plan.clone());
    let mut timing = TimingSim::new(cfg.n, cfg.link.clone());
    timing.set_shards(cfg.exec.shards_for(cfg.n));
    let mut comp_rng = Pcg::new(cfg.seed ^ 0xfa17);
    let mut view = vec![0.0f32; cfg.dim];

    for k in 0..cfg.iters {
        for ev in clock.events_at(k) {
            algo.on_membership_change(&ev);
        }
        for i in 0..cfg.n {
            if clock.is_down(i, k) {
                continue;
            }
            algo.local_view(i, &mut view);
            let g: Vec<f32> =
                view.iter().zip(&centers[i]).map(|(z, c)| z - c).collect();
            algo.apply_step(i, &g, cfg.lr);
        }
        let comp = cfg.compute.sample_all(cfg.n, &mut comp_rng);
        let ctx = RoundCtx::new(k, &comp, cfg.msg_bytes, &cfg.link)
            .with_faults(&clock)
            .with_exec(cfg.exec);
        let pattern = algo.communicate(&ctx);
        timing.advance_with_faults(&pattern.borrowed(), &comp, Some(&clock));
    }
    algo.drain();

    // Final statistics over the surviving members only: a permanently-left
    // node's frozen checkpoint is not part of the consensus model.
    let alive = clock.alive(cfg.n, cfg.iters.saturating_sub(1));
    let views: Vec<Vec<f32>> = alive.iter().map(|&i| algo.node_view(i)).collect();
    let m = views.len().max(1) as f64;
    let mut mean = vec![0.0f64; cfg.dim];
    for v in &views {
        for (a, b) in mean.iter_mut().zip(v) {
            *a += *b as f64 / m;
        }
    }
    let final_err = mean
        .iter()
        .zip(&opt)
        .map(|(a, o)| (a - o) * (a - o))
        .sum::<f64>()
        .sqrt();
    let consensus = views
        .iter()
        .map(|v| {
            v.iter()
                .zip(&mean)
                .map(|(a, b)| {
                    let e = *a as f64 - b;
                    e * e
                })
                .sum::<f64>()
                .sqrt()
        })
        .sum::<f64>()
        / m;
    Ok(FaultRunStats {
        algo: algo.name(),
        final_err,
        consensus,
        makespan: timing.makespan(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_harness_converges_for_core_algorithms() {
        let cfg = FaultRunConfig { n: 8, iters: 120, ..Default::default() };
        for algo in ["ar-sgd", "sgp", "osgp", "dpsgd"] {
            let s = run_quadratic(algo, &cfg, &FaultPlan::lossless()).unwrap();
            assert!(s.final_err < 0.2, "{algo}: err {}", s.final_err);
            // The gossip consensus equilibrium sits at O(lr · gradient
            // heterogeneity) ≈ 0.2–0.35 here; exact strategies report 0.
            assert!(s.consensus < 0.5, "{algo}: consensus {}", s.consensus);
            assert!(s.makespan > 0.0);
        }
    }

    #[test]
    fn harness_is_deterministic_given_seeds() {
        let cfg = FaultRunConfig { n: 8, iters: 60, ..Default::default() };
        let plan = FaultPlan::lossless()
            .with_drop(0.1)
            .with_rescue(true)
            .with_crash(2, 20, Some(40))
            .with_seed(5);
        let a = run_quadratic("sgp", &cfg, &plan).unwrap();
        let b = run_quadratic("sgp", &cfg, &plan).unwrap();
        assert_eq!(a, b, "same seeds must replay bit-identically");
    }
}

//! Fault & churn injection: the scenario layer that turns the fixed,
//! lossless simulated cluster into the messy one the paper argues SGP is
//! robust to ("approaches that synchronize nodes using exact distributed
//! averaging are sensitive to stragglers and communication delays").
//!
//! A [`FaultPlan`] declares *what* goes wrong — per-link message-drop
//! probability, transient link-degradation windows that scale the
//! [`crate::net::LinkModel`] α/β, node crashes at an iteration with an
//! optional rejoin-from-checkpoint, permanent leaves — and a [`FaultClock`]
//! replays the plan **deterministically from a seed**: every layer that
//! asks "does message (i→j) at iteration k drop?" or "is node i down at
//! k?" gets the same answer, so the gossip semantics
//! ([`crate::gossip::PushSumEngine::step_faulty`]), the timing recursion
//! ([`crate::net::TimingSim::advance_with_faults`]) and the membership
//! re-indexing ([`crate::topology::Schedule::out_peers_among`]) stay
//! mutually consistent without sharing mutable state.
//!
//! Crash semantics: a crashed node freezes in place — its `(x, w)` state
//! *is* the checkpoint (and, since PR 10, can also be persisted as a
//! durable one: [`crate::snapshot`] captures the frozen state, the parked
//! inbox, and the banks together). While down it neither computes, sends,
//! nor receives (messages addressed to it wait in its inbox; the schedule
//! re-indexes over survivors so mixing stays column-stochastic). On rejoin
//! it resumes from the frozen state as a merely *stale* peer — exactly the
//! situation push-sum's weight accounting tolerates. A `rejoin: None`
//! crash is a permanent leave ([`FaultClock::is_permanently_down`]); at
//! each membership-epoch boundary the engine folds error-feedback banks
//! addressed to permanently-departed ranks back into their senders, so a
//! checkpoint taken after the boundary reflects the survivor schedule
//! rather than the pre-crash one.
//!
//! See DESIGN.md §Faults for the plan format and per-layer interactions,
//! and [`harness`] for the offline robustness harness behind
//! `repro faults`.

pub mod harness;

use crate::net::LinkModel;
use crate::rng::Pcg;

/// A transient link-degradation window: within `[from, until)` iterations
/// the fabric's latency is multiplied by `alpha_mult` and its bandwidth
/// divided by `beta_div` (both ≥ 1 for a degradation; windows compose
/// multiplicatively when they overlap).
#[derive(Clone, Debug)]
pub struct Degradation {
    /// First iteration the window covers.
    pub from: u64,
    /// First iteration after the window (exclusive end).
    pub until: u64,
    /// Latency multiplier (≥ 1 degrades).
    pub alpha_mult: f64,
    /// Bandwidth divisor (≥ 1 degrades).
    pub beta_div: f64,
}

/// One node fault: crash at iteration `at`, optionally rejoining from its
/// frozen checkpoint at `rejoin`. `rejoin: None` is a permanent leave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Crash {
    /// The crashing node.
    pub node: usize,
    /// Iteration the node goes down.
    pub at: u64,
    /// Iteration it rejoins from its checkpoint (`None` = permanent leave).
    pub rejoin: Option<u64>,
}

/// A membership transition the coordinator reports to the strategy via
/// [`crate::algorithms::DistributedAlgorithm::on_membership_change`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    /// Node went down at `at` and is expected back at `rejoin`.
    Crash {
        /// The crashing node.
        node: usize,
        /// Iteration of the crash.
        at: u64,
        /// Iteration the node is expected back.
        rejoin: u64,
    },
    /// Node came back from its checkpoint at `at`.
    Rejoin {
        /// The rejoining node.
        node: usize,
        /// Iteration of the rejoin.
        at: u64,
    },
    /// Node left permanently at `at`.
    Leave {
        /// The leaving node.
        node: usize,
        /// Iteration of the departure.
        at: u64,
    },
}

impl MembershipEvent {
    /// The node this event is about.
    pub fn node(&self) -> usize {
        match *self {
            Self::Crash { node, .. } | Self::Rejoin { node, .. } | Self::Leave { node, .. } => node,
        }
    }

    /// The iteration (simulator) or round (deployment) the event fires at.
    pub fn at(&self) -> u64 {
        match *self {
            Self::Crash { at, .. } | Self::Rejoin { at, .. } | Self::Leave { at, .. } => at,
        }
    }

    /// Stable lower-case tag, shared with the deployment coordinator's
    /// membership event log (`crate::net::cluster::coord`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Crash { .. } => "crash",
            Self::Rejoin { .. } => "rejoin",
            Self::Leave { .. } => "leave",
        }
    }
}

/// Declarative fault scenario. `lossless()` is the identity plan — running
/// any algorithm under it is bit-identical to running without faults.
///
/// ```
/// use sgp::faults::{FaultClock, FaultPlan};
///
/// let plan = FaultPlan::lossless()
///     .with_drop(0.10)              // 10% per-link message loss
///     .with_crash(3, 40, Some(80))  // node 3 down for iterations 40..80
///     .with_rescue(true)            // senders re-absorb undelivered mass
///     .with_seed(7);
/// let clock = FaultClock::new(plan);
/// // Replay is deterministic: the same query always answers the same.
/// assert_eq!(clock.drops(0, 1, 12), clock.drops(0, 1, 12));
/// assert!(clock.is_down(3, 50) && !clock.is_down(3, 80));
/// assert_eq!(clock.alive(4, 50), vec![0, 1, 2]);
/// ```
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Baseline per-link, per-iteration message-drop probability.
    pub drop: f64,
    /// Per-link overrides `(from, to, p)` taking precedence over `drop`.
    pub link_drops: Vec<(usize, usize, f64)>,
    /// Transient link-degradation windows (compose multiplicatively).
    pub degradations: Vec<Degradation>,
    /// Node crash / rejoin / permanent-leave events.
    pub crashes: Vec<Crash>,
    /// Rescue mode: a sender detects its undelivered message and re-absorbs
    /// the `(x, w)` mass locally instead of losing it — push-sum stays
    /// *exactly* column-stochastic under loss. This is the loss-tolerant
    /// configuration (`repro faults` defaults to it): without rescue, lost
    /// mass shrinks unlucky nodes' push-sum weights and the gradient
    /// applied at `z = x/w` has effective step `lr/w` — long runs
    /// destabilize (see DESIGN.md §Faults for the full account).
    pub rescue: bool,
    /// Failure-detection timeout charged to collectives when membership
    /// changes mid-run (abort + re-form with survivors).
    pub timeout_s: f64,
    /// Seed of the deterministic replay.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::lossless()
    }
}

impl FaultPlan {
    /// The identity plan: no drops, no degradations, no churn.
    pub fn lossless() -> Self {
        Self {
            drop: 0.0,
            link_drops: Vec::new(),
            degradations: Vec::new(),
            crashes: Vec::new(),
            rescue: false,
            timeout_s: 5.0,
            seed: 0,
        }
    }

    /// Set the baseline per-link drop probability (must lie in [0, 1]).
    pub fn with_drop(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "drop probability {p} out of [0,1]");
        self.drop = p;
        self
    }

    /// Override the drop probability of the directed link `from → to`.
    pub fn with_link_drop(mut self, from: usize, to: usize, p: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&p),
            "link drop probability {p} out of [0,1]"
        );
        self.link_drops.push((from, to, p));
        self
    }

    /// Add a transient link-degradation window.
    pub fn with_degradation(mut self, d: Degradation) -> Self {
        self.degradations.push(d);
        self
    }

    /// Crash `node` at iteration `at`, optionally rejoining at `rejoin`
    /// (`None` = permanent leave).
    pub fn with_crash(mut self, node: usize, at: u64, rejoin: Option<u64>) -> Self {
        if let Some(r) = rejoin {
            assert!(r > at, "rejoin {r} must come after crash {at}");
        }
        self.crashes.push(Crash { node, at, rejoin });
        self
    }

    /// Toggle rescue mode (senders re-absorb undelivered push-sum mass).
    pub fn with_rescue(mut self, rescue: bool) -> Self {
        self.rescue = rescue;
        self
    }

    /// Set the seed of the deterministic replay.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Whether the plan is the identity (fast-path check for callers that
    /// want to skip the fault machinery entirely).
    pub fn is_lossless(&self) -> bool {
        self.drop == 0.0
            && self.link_drops.is_empty()
            && self.degradations.is_empty()
            && self.crashes.is_empty()
    }
}

/// Deterministic replay of a [`FaultPlan`]: pure functions of
/// `(plan.seed, iteration, endpoints)`, so every layer sees one consistent
/// fault history and the same seed reproduces it bit-for-bit.
#[derive(Clone, Debug)]
pub struct FaultClock {
    /// The scenario being replayed.
    pub plan: FaultPlan,
}

impl FaultClock {
    /// A clock replaying the given plan.
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan }
    }

    /// Drop probability of the directed link `from → to`.
    pub fn drop_prob(&self, from: usize, to: usize) -> f64 {
        self.plan
            .link_drops
            .iter()
            .find(|(f, t, _)| *f == from && *t == to)
            .map(|(_, _, p)| *p)
            .unwrap_or(self.plan.drop)
    }

    /// Does the message `from → to` sent at iteration `k` drop?
    /// Deterministic per `(seed, from, to, k)`.
    pub fn drops(&self, from: usize, to: usize, k: u64) -> bool {
        let p = self.drop_prob(from, to);
        if p <= 0.0 {
            return false;
        }
        let mut rng = self.round_rng(k, ((from as u64) << 32) | to as u64);
        rng.f64() < p
    }

    /// Is node `i` down (crashed / left) at iteration `k`?
    pub fn is_down(&self, node: usize, k: u64) -> bool {
        self.plan.crashes.iter().any(|c| {
            c.node == node
                && k >= c.at
                && match c.rejoin {
                    Some(r) => k < r,
                    None => true,
                }
        })
    }

    /// Is node `i` down at `k` with **no future rejoin scheduled** — i.e.
    /// gone for the rest of the plan? Distinguishes a permanent leave
    /// (safe to reconcile state addressed to it, e.g. orphaned
    /// error-feedback banks) from a transient crash whose inbox and banks
    /// must be held for the rejoin.
    pub fn is_permanently_down(&self, node: usize, k: u64) -> bool {
        self.is_down(node, k)
            && self
                .plan
                .crashes
                .iter()
                .filter(|c| c.node == node)
                .all(|c| c.rejoin.map_or(true, |r| r <= k))
    }

    /// Sorted surviving members at iteration `k`.
    pub fn alive(&self, n: usize, k: u64) -> Vec<usize> {
        let mut out = Vec::new();
        self.alive_into(n, k, &mut out);
        out
    }

    /// [`Self::alive`] into a caller-owned buffer (cleared first) — the
    /// allocation-free form the gossip hot path uses every fault-mode
    /// round.
    pub fn alive_into(&self, n: usize, k: u64, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..n).filter(|&i| !self.is_down(i, k)));
    }

    /// Membership transitions occurring exactly at iteration `k`, in plan
    /// order. Events are consistent with [`Self::is_down`] even when crash
    /// windows for one node overlap: a down→down "rejoin" (another window
    /// still covers the node) or an already-down "crash" is suppressed,
    /// and at most one event per node fires per iteration.
    pub fn events_at(&self, k: u64) -> Vec<MembershipEvent> {
        let mut evs: Vec<MembershipEvent> = Vec::new();
        let seen = |evs: &[MembershipEvent], node: usize| {
            evs.iter().any(|e| match *e {
                MembershipEvent::Crash { node: n, .. }
                | MembershipEvent::Rejoin { node: n, .. }
                | MembershipEvent::Leave { node: n, .. } => n == node,
            })
        };
        for c in &self.plan.crashes {
            let was_up = k == 0 || !self.is_down(c.node, k - 1);
            if c.at == k && was_up && !seen(&evs, c.node) {
                evs.push(match c.rejoin {
                    Some(r) => MembershipEvent::Crash { node: c.node, at: k, rejoin: r },
                    None => MembershipEvent::Leave { node: c.node, at: k },
                });
            }
            if c.rejoin == Some(k)
                && !self.is_down(c.node, k)
                && !was_up
                && !seen(&evs, c.node)
            {
                evs.push(MembershipEvent::Rejoin { node: c.node, at: k });
            }
        }
        evs
    }

    /// Did any membership transition happen at `k` (crash, leave, rejoin)?
    pub fn membership_changed_at(&self, k: u64) -> bool {
        !self.events_at(k).is_empty()
    }

    /// Monotone membership-epoch counter: the number of crash/rejoin
    /// boundaries at iterations `≤ k`. Equal epochs at two iterations
    /// guarantee identical alive sets over the whole interval (membership
    /// only changes at a boundary), which makes the value a sound
    /// invalidation key for [`crate::topology::PeerMemo`]. With
    /// overlapping crash windows the count can tick on a *suppressed*
    /// event, costing at most one spurious memo rebuild — safe, where a
    /// missed rebuild would not be. Allocation-free, unlike
    /// [`Self::events_at`], so engines may call it every round.
    pub fn membership_epoch(&self, k: u64) -> u64 {
        let mut epoch = 0u64;
        for c in &self.plan.crashes {
            if c.at <= k {
                epoch += 1;
            }
            if c.rejoin.is_some_and(|r| r <= k) {
                epoch += 1;
            }
        }
        epoch
    }

    /// Effective drop probability a collective over the `alive` members
    /// sees: the mean directed-link drop probability across survivor
    /// pairs. Collectives stripe chunks over every link, so per-link
    /// overrides dilute into the average — finer per-transfer attribution
    /// is below the α–β model's resolution.
    pub fn collective_drop_prob(&self, alive: &[usize]) -> f64 {
        if self.plan.link_drops.is_empty() || alive.len() < 2 {
            return self.plan.drop;
        }
        let mut sum = 0.0;
        let mut cnt = 0u64;
        for &a in alive {
            for &b in alive {
                if a != b {
                    sum += self.drop_prob(a, b);
                    cnt += 1;
                }
            }
        }
        sum / cnt as f64
    }

    /// Cumulative `(alpha_mult, beta_div)` of the degradation windows
    /// active at iteration `k`.
    pub fn link_scale(&self, k: u64) -> (f64, f64) {
        let mut am = 1.0;
        let mut bd = 1.0;
        for d in &self.plan.degradations {
            if k >= d.from && k < d.until {
                am *= d.alpha_mult;
                bd *= d.beta_div;
            }
        }
        (am, bd)
    }

    /// The fabric as seen at iteration `k` (degradation windows applied).
    pub fn scaled_link(&self, base: &LinkModel, k: u64) -> LinkModel {
        let (am, bd) = self.link_scale(k);
        if am == 1.0 && bd == 1.0 {
            return base.clone();
        }
        LinkModel {
            alpha_s: base.alpha_s * am,
            beta_bps: base.beta_bps / bd,
            ..base.clone()
        }
    }

    /// A deterministic per-(iteration, salt) RNG stream — used for fault
    /// draws that are not tied to a single directed link (e.g. collective
    /// retransmissions).
    pub fn round_rng(&self, k: u64, salt: u64) -> Pcg {
        Pcg::with_stream(
            self.plan.seed ^ k.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            salt.wrapping_mul(2).wrapping_add(1),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_plan_is_identity() {
        let c = FaultClock::new(FaultPlan::lossless());
        assert!(c.plan.is_lossless());
        for k in 0..50 {
            assert!(!c.drops(0, 1, k));
            assert!(!c.is_down(3, k));
            assert_eq!(c.link_scale(k), (1.0, 1.0));
            assert!(c.events_at(k).is_empty());
        }
        assert_eq!(c.alive(4, 10), vec![0, 1, 2, 3]);
    }

    #[test]
    fn drops_deterministic_and_rate_close_to_p() {
        let c = FaultClock::new(FaultPlan::lossless().with_drop(0.15).with_seed(9));
        let mut hits = 0usize;
        let total = 20_000;
        for k in 0..total as u64 {
            let d = c.drops(2, 5, k);
            assert_eq!(d, c.drops(2, 5, k), "same query, same answer");
            hits += d as usize;
        }
        let rate = hits as f64 / total as f64;
        assert!((rate - 0.15).abs() < 0.01, "empirical drop rate {rate}");
        // A different seed yields a different history.
        let c2 = FaultClock::new(FaultPlan::lossless().with_drop(0.15).with_seed(10));
        assert!((0..100).any(|k| c.drops(2, 5, k) != c2.drops(2, 5, k)));
    }

    #[test]
    fn per_link_override_beats_baseline() {
        let c = FaultClock::new(
            FaultPlan::lossless().with_drop(0.0).with_link_drop(1, 2, 1.0),
        );
        assert!(c.drops(1, 2, 7));
        assert!(!c.drops(2, 1, 7));
        assert_eq!(c.drop_prob(1, 2), 1.0);
        assert_eq!(c.drop_prob(0, 3), 0.0);
    }

    #[test]
    fn crash_rejoin_windows_and_events() {
        let c = FaultClock::new(
            FaultPlan::lossless()
                .with_crash(3, 10, Some(20))
                .with_crash(5, 15, None),
        );
        assert!(!c.is_down(3, 9));
        assert!(c.is_down(3, 10) && c.is_down(3, 19));
        assert!(!c.is_down(3, 20));
        assert!(c.is_down(5, 1000), "permanent leave never rejoins");
        assert_eq!(
            c.events_at(10),
            vec![MembershipEvent::Crash { node: 3, at: 10, rejoin: 20 }]
        );
        assert_eq!(c.events_at(15), vec![MembershipEvent::Leave { node: 5, at: 15 }]);
        assert_eq!(c.events_at(20), vec![MembershipEvent::Rejoin { node: 3, at: 20 }]);
        assert_eq!(c.alive(8, 16), vec![0, 1, 2, 4, 6, 7]);
        assert!(c.membership_changed_at(10) && !c.membership_changed_at(11));
    }

    #[test]
    fn permanent_down_distinguishes_leave_from_transient_crash() {
        let c = FaultClock::new(
            FaultPlan::lossless()
                .with_crash(3, 10, Some(20))
                .with_crash(3, 30, None)
                .with_crash(5, 15, None),
        );
        // Transient window: down but a rejoin is still scheduled.
        assert!(c.is_down(3, 12) && !c.is_permanently_down(3, 12));
        assert!(!c.is_permanently_down(3, 25), "up nodes are never 'down'");
        // After the second (terminal) crash there is no future rejoin.
        assert!(c.is_permanently_down(3, 30) && c.is_permanently_down(3, 1000));
        // A plain leave is permanent from its first down iteration.
        assert!(c.is_permanently_down(5, 15));
        assert!(!c.is_permanently_down(5, 14));
    }

    #[test]
    fn membership_epoch_ticks_exactly_at_boundaries() {
        let c = FaultClock::new(
            FaultPlan::lossless()
                .with_crash(3, 10, Some(20))
                .with_crash(5, 15, None),
        );
        let epochs: Vec<u64> = (0..25).map(|k| c.membership_epoch(k)).collect();
        // Boundaries at k = 10 (crash), 15 (leave), 20 (rejoin).
        assert_eq!(epochs[9], 0);
        assert_eq!(epochs[10], 1);
        assert_eq!(epochs[14], 1);
        assert_eq!(epochs[15], 2);
        assert_eq!(epochs[19], 2);
        assert_eq!(epochs[20], 3);
        assert_eq!(epochs[24], 3);
        // Monotone, and constant between boundaries: a sound memo key.
        assert!(epochs.windows(2).all(|w| w[0] <= w[1]));
        for k in 0..24u64 {
            let changed = c.membership_changed_at(k + 1);
            assert_eq!(
                epochs[k as usize] != epochs[k as usize + 1],
                changed,
                "k={k}"
            );
        }
    }

    #[test]
    fn degradation_windows_scale_the_link() {
        let c = FaultClock::new(FaultPlan::lossless().with_degradation(Degradation {
            from: 5,
            until: 10,
            alpha_mult: 4.0,
            beta_div: 2.0,
        }));
        let base = LinkModel::ethernet_10g();
        let l4 = c.scaled_link(&base, 4);
        let l7 = c.scaled_link(&base, 7);
        assert_eq!(l4.alpha_s, base.alpha_s);
        assert_eq!(l7.alpha_s, base.alpha_s * 4.0);
        assert_eq!(l7.beta_bps, base.beta_bps / 2.0);
        assert!(l7.ptp_time(1 << 20) > l4.ptp_time(1 << 20));
    }
}

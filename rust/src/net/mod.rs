//! Cluster/network simulator — the substrate standing in for the paper's
//! 32×DGX-1 testbed (DESIGN.md §2).
//!
//! Timing of the synchronous algorithms does not depend on gradient
//! *values*, only on (a) per-node compute times (with stragglers), (b) the
//! point-to-point message cost, and (c) the synchronization pattern:
//!
//! * AllReduce-SGD — a **global barrier** every iteration plus the ring
//!   collective cost: one straggler stalls everyone, and the latency term
//!   grows with n.
//! * SGP — each node blocks only on its (one or two) in-neighbours: a
//!   straggler delays a single peer, and the point-to-point cost is
//!   independent of n.
//! * τ-OSGP — in-neighbour messages may be up to τ iterations stale, so
//!   communication hides behind compute almost entirely.
//! * D-PSGD — a **pairwise barrier** (symmetric exchange) plus handshake
//!   overhead for deadlock avoidance.
//!
//! [`TimingSim`] implements these recursions incrementally so the trainer
//! can attach simulated wall-clock to a real training run, and timing-only
//! sweeps (Fig. 1c/d, Fig. D.4) can run them standalone.
//!
//! The [`cluster`] submodule is the exception to "simulated": it deploys
//! the same push-sum gossip over real TCP sockets (`repro coord` /
//! `repro worker`), reusing the compressed share encodings as the literal
//! on-the-wire format.

pub mod cluster;

use std::collections::VecDeque;

use crate::collectives;
use crate::faults::FaultClock;
use crate::obs::{ObsSink, TimingObs};
use crate::rng::Pcg;
use crate::runtime::pool;
use crate::topology::Schedule;

/// An α–β link model with a collective-efficiency factor capturing how far
/// real allreduce implementations run from link peak on that fabric.
#[derive(Clone, Debug)]
pub struct LinkModel {
    /// One-way small-message latency (seconds).
    pub alpha_s: f64,
    /// Peak point-to-point bandwidth (bytes/second).
    pub beta_bps: f64,
    /// Efficiency of collective (AllReduce) traffic relative to peak —
    /// TCP-over-Ethernet collectives run far from line rate (incast,
    /// congestion control); RDMA/IB collectives run close to it.
    pub collective_efficiency: f64,
    /// Human-readable fabric name (CSV/table labels).
    pub name: &'static str,
}

impl LinkModel {
    /// 10 Gbps Ethernet (data-center TCP): the paper's low-bandwidth rig.
    pub fn ethernet_10g() -> Self {
        Self {
            alpha_s: 75e-6,
            beta_bps: 1.25e9,
            collective_efficiency: 0.22,
            name: "ethernet-10g",
        }
    }

    /// 100 Gbps InfiniBand with GPUDirect RDMA: the high-bandwidth rig.
    pub fn infiniband_100g() -> Self {
        Self {
            alpha_s: 2e-6,
            beta_bps: 12.5e9,
            collective_efficiency: 0.85,
            name: "infiniband-100g",
        }
    }

    /// Point-to-point time for one message of `bytes`.
    pub fn ptp_time(&self, bytes: usize) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bps
    }

    /// Link as seen by collectives (derated bandwidth).
    pub fn collective_link(&self) -> LinkModel {
        LinkModel {
            beta_bps: self.beta_bps * self.collective_efficiency,
            ..self.clone()
        }
    }
}

/// Per-node compute-time model: shifted log-normal jitter around a base
/// iteration time, plus rare straggler events — the empirical shape of
/// multi-tenant GPU-cluster step times.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    /// Mean compute time per iteration (seconds).
    pub base_s: f64,
    /// Log-normal sigma of the multiplicative jitter (0 = deterministic).
    pub jitter_sigma: f64,
    /// Probability a step is a straggler event.
    pub p_slow: f64,
    /// Multiplier applied on straggler events.
    pub slow_factor: f64,
}

impl ComputeModel {
    /// The paper's ResNet-50 server-scale iteration profile.
    pub fn resnet50_dgx1() -> Self {
        Self { base_s: 0.30, jitter_sigma: 0.08, p_slow: 0.01, slow_factor: 2.5 }
    }

    /// Jitter-free profile: every step takes exactly `base_s` seconds.
    pub fn deterministic(base_s: f64) -> Self {
        Self { base_s, jitter_sigma: 0.0, p_slow: 0.0, slow_factor: 1.0 }
    }

    /// Draw one node's compute time for one iteration.
    pub fn sample(&self, rng: &mut Pcg) -> f64 {
        let mut t = if self.jitter_sigma > 0.0 {
            // Normalize so E[t] = base_s: E[lognormal(µ,σ)] = e^{µ+σ²/2}.
            let mu = -0.5 * self.jitter_sigma * self.jitter_sigma;
            self.base_s * rng.lognormal(mu, self.jitter_sigma)
        } else {
            self.base_s
        };
        if self.p_slow > 0.0 && rng.f64() < self.p_slow {
            t *= self.slow_factor;
        }
        t
    }

    /// Draw all n nodes' compute times for one iteration.
    pub fn sample_all(&self, n: usize, rng: &mut Pcg) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// The per-iteration communication pattern, decided by the algorithm.
#[derive(Clone, Debug)]
pub enum CommPattern<'a> {
    /// Global barrier + collective of `bytes` (AllReduce-SGD).
    AllReduce {
        /// Bytes reduced per node.
        bytes: usize,
    },
    /// Directed push messages along the schedule; receives from iteration
    /// `k − tau` must have arrived (SGP: τ=0, OSGP: τ≥1).
    PushSum {
        /// The round's out-peer schedule.
        schedule: &'a Schedule,
        /// Bytes per message **as put on the wire**: strategies running
        /// compressed gossip charge the encoded size
        /// ([`crate::gossip::Compression::encoded_bytes`]), not the dense
        /// payload, so makespans reflect the actual traffic.
        bytes: usize,
        /// Overlap delay τ.
        tau: u64,
    },
    /// Symmetric pairwise exchange (D-PSGD). `handshake` multiplies the
    /// point-to-point cost to model the send+recv + deadlock-avoidance
    /// ordering of symmetric gossip.
    Symmetric {
        /// The round's pairing schedule.
        schedule: &'a Schedule,
        /// Bytes per direction.
        bytes: usize,
        /// Point-to-point cost multiplier of the symmetric handshake.
        handshake: f64,
    },
    /// Barrier-free asynchronous round (AD-PSGD): every node's clock
    /// advances independently by its own compute plus a fixed per-round
    /// `overhead_s` (the partially-overlapped averaging thread of Lian et
    /// al., App. C). No node ever waits on a peer.
    Async {
        /// Per-round overhead of the averaging thread (seconds).
        overhead_s: f64,
    },
    /// No communication (single node / local SGD).
    None,
}

/// Below this many nodes per shard the arrival computation stays
/// sequential: the pool's barrier handoff costs more than the loop saves.
const MIN_NODES_PER_TIMING_SHARD: usize = 64;

/// Per-shard scratch of the sharded arrival computation: the partial
/// deadline vector plus a peer-list buffer, reused round after round so
/// the steady-state recursion allocates nothing.
#[derive(Clone, Debug, Default)]
struct ArrivalScratch {
    arrive: Vec<f64>,
    peers: Vec<usize>,
}

/// Incremental timing recursion over iterations.
#[derive(Clone, Debug)]
pub struct TimingSim {
    /// Number of simulated nodes.
    pub n: usize,
    /// The simulated fabric.
    pub link: LinkModel,
    /// Completion time of each node's last finished iteration.
    pub t: Vec<f64>,
    /// Ring buffer of per-destination arrival deadlines for τ-delayed
    /// push-sum messages (front = oldest iteration still unconsumed).
    pending: VecDeque<Vec<f64>>,
    iter: u64,
    /// Worker shards for the per-destination arrival computation (1 =
    /// sequential). Sharding merges partial results with elementwise
    /// `f64::max` — associative and commutative — so every shard count
    /// produces bit-identical clocks. Shards execute on the persistent
    /// worker pool ([`crate::runtime::pool`]).
    shards: usize,
    /// Recycled deadline vectors (consumed `pending` entries come back
    /// here instead of being dropped).
    spare: Vec<Vec<f64>>,
    /// Per-shard arrival scratch (partials + peer lists).
    shard_scratch: Vec<ArrivalScratch>,
    /// Reusable per-round buffers: down mask, send clocks, symmetric
    /// exchange clocks, survivor list, peer list.
    down_buf: Vec<bool>,
    send_buf: Vec<f64>,
    newt_buf: Vec<f64>,
    alive_buf: Vec<usize>,
    peers_buf: Vec<usize>,
    /// Optional observability recorder ([`Self::set_obs`]): per-iteration
    /// makespan + straggler identity. Pre-allocated; recording is a
    /// scalar argmax scan per advance, so the hot path stays
    /// allocation-free.
    obs: Option<Box<TimingObs>>,
}

impl TimingSim {
    /// A fresh simulator with every node clock at 0 (sequential execution).
    pub fn new(n: usize, link: LinkModel) -> Self {
        Self {
            n,
            link,
            t: vec![0.0; n],
            pending: VecDeque::new(),
            iter: 0,
            shards: 1,
            spare: Vec::new(),
            shard_scratch: Vec::new(),
            down_buf: Vec::new(),
            send_buf: Vec::new(),
            newt_buf: Vec::new(),
            alive_buf: Vec::new(),
            peers_buf: Vec::new(),
            obs: None,
        }
    }

    /// Shard the arrival computation across `shards` workers for large-N
    /// sweeps. Bit-identical to sequential for every value (max-merge).
    pub fn set_shards(&mut self, shards: usize) {
        self.shards = shards.max(1);
    }

    /// Attach (or detach, with `None`) an observability recorder. While
    /// attached, every [`Self::advance_with_faults`] records the
    /// iteration's makespan and straggler (argmax node clock). Purely
    /// observational: simulated times are unchanged.
    pub fn set_obs(&mut self, obs: Option<Box<TimingObs>>) {
        self.obs = obs;
    }

    /// Detach and return the recorder (e.g. to write a trace with
    /// [`crate::obs::trace::write_sim_trace`]).
    pub fn take_obs(&mut self) -> Option<Box<TimingObs>> {
        self.obs.take()
    }

    /// Advance one iteration given sampled compute times; returns the
    /// simulated makespan (max node clock) after this iteration.
    pub fn advance(&mut self, pattern: &CommPattern, comp: &[f64]) -> f64 {
        self.advance_with_faults(pattern, comp, None)
    }

    /// Fault-aware advance: crashed nodes' clocks freeze (and fast-forward
    /// to the cluster's current makespan on rejoin), degradation windows
    /// scale the fabric's α/β for the round, and drops hit each pattern
    /// where it hurts in reality:
    ///
    /// * **AllReduce** — a membership change at `k` costs the plan's
    ///   failure-detection timeout (abort + re-form with survivors), and
    ///   message loss inflates the collective via capped retransmissions
    ///   ([`collectives::allreduce_time_faulty`]): everyone waits for the
    ///   unluckiest link.
    /// * **PushSum** — a dropped message simply never constrains its
    ///   destination: the receiver proceeds on what arrived (mass
    ///   accounting happens in the gossip engine, not here).
    /// * **Symmetric** — each dropped direction of the pairwise exchange
    ///   costs the pair one extra handshake (retry), on top of the barrier.
    ///
    /// With `faults: None` (or a lossless plan) this is bit-identical to
    /// the plain recursion.
    pub fn advance_with_faults(
        &mut self,
        pattern: &CommPattern,
        comp: &[f64],
        faults: Option<&FaultClock>,
    ) -> f64 {
        assert_eq!(comp.len(), self.n);
        let k = self.iter;
        let mut down = std::mem::take(&mut self.down_buf);
        down.clear();
        match faults {
            Some(fc) => down.extend((0..self.n).map(|i| fc.is_down(i, k))),
            None => down.resize(self.n, false),
        }
        if let Some(fc) = faults {
            if k > 0 {
                // Rejoining nodes sync their clock to the cluster's "now".
                let now = self.makespan();
                for i in 0..self.n {
                    if !down[i] && fc.is_down(i, k - 1) {
                        self.t[i] = self.t[i].max(now);
                    }
                }
            }
        }
        let link = match faults {
            Some(fc) => fc.scaled_link(&self.link, k),
            None => self.link.clone(),
        };
        match pattern {
            CommPattern::None => {
                for i in 0..self.n {
                    if !down[i] {
                        self.t[i] += comp[i];
                    }
                }
            }
            CommPattern::Async { overhead_s } => {
                for i in 0..self.n {
                    if !down[i] {
                        self.t[i] += comp[i] + overhead_s;
                    }
                }
            }
            CommPattern::AllReduce { bytes } => {
                let alive: Vec<usize> =
                    (0..self.n).filter(|&i| !down[i]).collect();
                let ready = alive
                    .iter()
                    .map(|&i| self.t[i] + comp[i])
                    .fold(0.0, f64::max);
                let cost = match faults {
                    Some(fc) => {
                        let mut c = if fc.membership_changed_at(k) {
                            fc.plan.timeout_s
                        } else {
                            0.0
                        };
                        let mut rng = fc.round_rng(k, 0xA11D);
                        c += collectives::allreduce_time_faulty(
                            alive.len(),
                            *bytes,
                            &link.collective_link(),
                            fc.collective_drop_prob(&alive),
                            &mut rng,
                        );
                        c
                    }
                    None => collectives::allreduce_time(
                        self.n,
                        *bytes,
                        &link.collective_link(),
                    ),
                };
                let done = ready + cost;
                for i in alive {
                    self.t[i] = done;
                }
            }
            CommPattern::PushSum { schedule, bytes, tau } => {
                // Send times: node i transmits right after its local step;
                // a down node's clock is frozen.
                let mut send = std::mem::take(&mut self.send_buf);
                send.clear();
                send.extend(
                    (0..self.n)
                        .map(|i| if down[i] { self.t[i] } else { self.t[i] + comp[i] }),
                );
                // Arrival deadline per destination for messages sent at k
                // (sharded over pool workers when configured; bit-identical).
                let cost = link.ptp_time(*bytes);
                let arrive = self.pushsum_arrivals(k, schedule, &send, cost, faults);
                self.pending.push_back(arrive);
                // Node j's iteration k completes once it has done its local
                // compute AND received the messages sent at k − τ.
                let constraint: Option<Vec<f64>> =
                    if self.pending.len() as u64 > *tau {
                        self.pending.pop_front()
                    } else {
                        None // first τ iterations: nothing due yet
                    };
                for j in 0..self.n {
                    if down[j] {
                        continue;
                    }
                    let mut tj = send[j];
                    if let Some(c) = &constraint {
                        tj = tj.max(c[j]);
                    }
                    self.t[j] = tj;
                }
                // Consumed deadline vectors are recycled, not dropped.
                if let Some(c) = constraint {
                    self.spare.push(c);
                }
                self.send_buf = send;
            }
            CommPattern::Symmetric { schedule, bytes, handshake } => {
                let mut send = std::mem::take(&mut self.send_buf);
                send.clear();
                send.extend(
                    (0..self.n)
                        .map(|i| if down[i] { self.t[i] } else { self.t[i] + comp[i] }),
                );
                let cost = handshake * link.ptp_time(*bytes);
                let mut new_t = std::mem::take(&mut self.newt_buf);
                new_t.clear();
                new_t.extend_from_slice(&send);
                let mut peers = std::mem::take(&mut self.peers_buf);
                match faults {
                    None => {
                        for i in 0..self.n {
                            schedule.out_peers_into(i, k, &mut peers);
                            for &j in &peers {
                                // Pairwise barrier: both wait for the slower.
                                let done = send[i].max(send[j]) + cost;
                                new_t[i] = new_t[i].max(done);
                                new_t[j] = new_t[j].max(done);
                            }
                        }
                    }
                    Some(fc) => {
                        let mut alive = std::mem::take(&mut self.alive_buf);
                        fc.alive_into(self.n, k, &mut alive);
                        for &i in &alive {
                            schedule.out_peers_among_into(i, k, &alive, &mut peers);
                            for &j in &peers {
                                // Each dropped direction costs the pair one
                                // extra handshake attempt.
                                let attempts = 1
                                    + fc.drops(i, j, k) as u32
                                    + fc.drops(j, i, k) as u32;
                                let done = send[i].max(send[j])
                                    + attempts as f64 * cost;
                                new_t[i] = new_t[i].max(done);
                                new_t[j] = new_t[j].max(done);
                            }
                        }
                        self.alive_buf = alive;
                    }
                }
                self.peers_buf = peers;
                for i in 0..self.n {
                    if !down[i] {
                        self.t[i] = new_t[i];
                    }
                }
                self.newt_buf = new_t;
                self.send_buf = send;
            }
        }
        self.down_buf = down;
        if let Some(o) = self.obs.as_deref_mut() {
            let (mut slowest, mut makespan) = (0usize, f64::NEG_INFINITY);
            for (i, &ti) in self.t.iter().enumerate() {
                if ti > makespan {
                    makespan = ti;
                    slowest = i;
                }
            }
            o.on_iter(k, makespan.max(0.0), slowest);
        }
        self.iter += 1;
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// The current simulated wall-clock: the slowest node's completion time.
    pub fn makespan(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Per-destination arrival deadlines for the push-sum messages sent at
    /// `k`. With `shards > 1` and enough nodes, the sender range is
    /// partitioned across the persistent worker pool and the partial
    /// deadline vectors are merged with elementwise `f64::max` in shard
    /// order — max is associative and commutative (and these values are
    /// never NaN), so every shard count yields the same bits as the
    /// sequential fold. The returned vector and all scratch are recycled
    /// buffers: the steady-state round allocates nothing.
    fn pushsum_arrivals(
        &mut self,
        k: u64,
        schedule: &Schedule,
        send: &[f64],
        cost: f64,
        faults: Option<&FaultClock>,
    ) -> Vec<f64> {
        let n = self.n;
        let mut arrive = self.spare.pop().unwrap_or_default();
        arrive.clear();
        arrive.resize(n, 0.0);
        let mut alive = std::mem::take(&mut self.alive_buf);
        if let Some(fc) = faults {
            fc.alive_into(n, k, &mut alive);
        }
        let shards = self.shards.min(n.max(1));
        if shards <= 1 || n < shards * MIN_NODES_PER_TIMING_SHARD {
            let mut peers = std::mem::take(&mut self.peers_buf);
            range_arrivals(
                0,
                n,
                &mut arrive,
                &mut peers,
                k,
                schedule,
                send,
                cost,
                faults,
                &alive,
            );
            self.peers_buf = peers;
        } else {
            let chunk = n.div_ceil(shards);
            let used = n.div_ceil(chunk);
            while self.shard_scratch.len() < used {
                self.shard_scratch.push(ArrivalScratch::default());
            }
            for sc in self.shard_scratch[..used].iter_mut() {
                sc.arrive.clear();
                sc.arrive.resize(n, 0.0);
            }
            let table = ArrivalTable {
                scratch: self.shard_scratch.as_mut_ptr(),
                n,
                chunk,
                k,
                schedule,
                send,
                cost,
                faults,
                alive: &alive,
            };
            // SAFETY: shard s touches only scratch slot s (disjoint), and
            // the pool runs each shard index exactly once.
            pool::global().run(used, &|s| unsafe { table.run(s) });
            for sc in &self.shard_scratch[..used] {
                for (a, p) in arrive.iter_mut().zip(&sc.arrive) {
                    *a = a.max(*p);
                }
            }
        }
        self.alive_buf = alive;
        arrive
    }
}

/// Arrival deadlines contributed by senders `lo..hi` (shared kernel of the
/// sequential and sharded paths — one definition, identical bits).
#[allow(clippy::too_many_arguments)] // internal kernel, flat args beat a builder
fn range_arrivals(
    lo: usize,
    hi: usize,
    arrive: &mut [f64],
    peers: &mut Vec<usize>,
    k: u64,
    schedule: &Schedule,
    send: &[f64],
    cost: f64,
    faults: Option<&FaultClock>,
    alive: &[usize],
) {
    match faults {
        Some(fc) => {
            for i in lo..hi {
                if fc.is_down(i, k) {
                    continue;
                }
                schedule.out_peers_among_into(i, k, alive, peers);
                for &j in peers.iter() {
                    // A dropped message never constrains its destination —
                    // the receiver moves on.
                    if !fc.drops(i, j, k) {
                        arrive[j] = arrive[j].max(send[i] + cost);
                    }
                }
            }
        }
        None => {
            for i in lo..hi {
                schedule.out_peers_into(i, k, peers);
                for &j in peers.iter() {
                    arrive[j] = arrive[j].max(send[i] + cost);
                }
            }
        }
    }
}

/// Raw per-shard view of the arrival scratch for the pool workers; shard
/// `s` resolves to scratch slot `s` only (see `pushsum_arrivals`).
struct ArrivalTable<'a> {
    scratch: *mut ArrivalScratch,
    n: usize,
    chunk: usize,
    k: u64,
    schedule: &'a Schedule,
    send: &'a [f64],
    cost: f64,
    faults: Option<&'a FaultClock>,
    alive: &'a [usize],
}

// SAFETY: workers touch disjoint scratch slots; everything else is shared
// read-only data.
unsafe impl Send for ArrivalTable<'_> {}
unsafe impl Sync for ArrivalTable<'_> {}

impl ArrivalTable<'_> {
    /// # Safety
    /// `s·chunk < n` and each shard index runs on exactly one worker.
    unsafe fn run(&self, s: usize) {
        let lo = s * self.chunk;
        debug_assert!(
            lo < self.n,
            "arrival shard {s} out of range (chunk {}, n {})",
            self.chunk,
            self.n
        );
        let hi = (lo + self.chunk).min(self.n);
        let sc = &mut *self.scratch.add(s);
        range_arrivals(
            lo,
            hi,
            &mut sc.arrive,
            &mut sc.peers,
            self.k,
            self.schedule,
            self.send,
            self.cost,
            self.faults,
            self.alive,
        );
    }
}

/// Run a timing-only sweep: average seconds/iteration for `iters`
/// iterations of the given pattern-producing closure.
pub fn average_iteration_time(
    n: usize,
    link: LinkModel,
    compute: &ComputeModel,
    iters: u64,
    seed: u64,
    mut pattern_at: impl FnMut(u64) -> OwnedCommPattern,
) -> f64 {
    let mut sim = TimingSim::new(n, link);
    let mut rng = Pcg::new(seed);
    for k in 0..iters {
        let comp = compute.sample_all(n, &mut rng);
        let p = pattern_at(k);
        sim.advance(&p.borrowed(), &comp);
    }
    sim.makespan() / iters as f64
}

/// Owned variant of [`CommPattern`] for returning from closures.
#[derive(Clone, Debug)]
pub enum OwnedCommPattern {
    /// See [`CommPattern::AllReduce`].
    AllReduce {
        /// Bytes reduced per node.
        bytes: usize,
    },
    /// See [`CommPattern::PushSum`].
    PushSum {
        /// The round's out-peer schedule.
        schedule: Schedule,
        /// Bytes per message.
        bytes: usize,
        /// Overlap delay τ.
        tau: u64,
    },
    /// See [`CommPattern::Symmetric`].
    Symmetric {
        /// The round's pairing schedule.
        schedule: Schedule,
        /// Bytes per direction.
        bytes: usize,
        /// Point-to-point cost multiplier of the symmetric handshake.
        handshake: f64,
    },
    /// See [`CommPattern::Async`].
    Async {
        /// Per-round overhead of the averaging thread (seconds).
        overhead_s: f64,
    },
    /// See [`CommPattern::None`].
    None,
}

impl OwnedCommPattern {
    /// The borrowed view the timing recursion consumes.
    pub fn borrowed(&self) -> CommPattern<'_> {
        match self {
            OwnedCommPattern::AllReduce { bytes } => {
                CommPattern::AllReduce { bytes: *bytes }
            }
            OwnedCommPattern::PushSum { schedule, bytes, tau } => {
                CommPattern::PushSum { schedule, bytes: *bytes, tau: *tau }
            }
            OwnedCommPattern::Symmetric { schedule, bytes, handshake } => {
                CommPattern::Symmetric {
                    schedule,
                    bytes: *bytes,
                    handshake: *handshake,
                }
            }
            OwnedCommPattern::Async { overhead_s } => {
                CommPattern::Async { overhead_s: *overhead_s }
            }
            OwnedCommPattern::None => CommPattern::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyKind;

    const MSG: usize = 100 << 20; // ~ResNet-50 fp32 message

    fn sgp_avg(n: usize, link: LinkModel, tau: u64) -> f64 {
        let compute = ComputeModel::resnet50_dgx1();
        average_iteration_time(n, link, &compute, 200, 1, |_k| {
            OwnedCommPattern::PushSum {
                schedule: Schedule::new(TopologyKind::OnePeerExp, n),
                bytes: MSG,
                tau,
            }
        })
    }

    fn ar_avg(n: usize, link: LinkModel) -> f64 {
        let compute = ComputeModel::resnet50_dgx1();
        average_iteration_time(n, link, &compute, 200, 1, |_k| {
            OwnedCommPattern::AllReduce { bytes: MSG }
        })
    }

    #[test]
    fn ethernet_allreduce_slows_with_n_sgp_flat() {
        // Fig. 1c: over 10 GbE, AR per-iteration time grows markedly with n
        // while SGP stays nearly constant.
        let e = LinkModel::ethernet_10g;
        let (ar4, ar32) = (ar_avg(4, e()), ar_avg(32, e()));
        let (sgp4, sgp32) = (sgp_avg(4, e(), 0), sgp_avg(32, e(), 0));
        assert!(ar32 > ar4 * 1.2, "ar4={ar4} ar32={ar32}");
        assert!(sgp32 < sgp4 * 1.25, "sgp4={sgp4} sgp32={sgp32}");
        assert!(ar32 > 2.0 * sgp32, "paper shows ≈3× at n=32");
    }

    #[test]
    fn infiniband_near_linear_for_all() {
        // Fig. 1d: on 100 Gb IB, both methods are compute-bound.
        let ib = LinkModel::infiniband_100g;
        let ar32 = ar_avg(32, ib());
        let sgp32 = sgp_avg(32, ib(), 0);
        let base = ComputeModel::resnet50_dgx1().base_s;
        assert!(ar32 < 2.0 * base, "{ar32}");
        assert!(sgp32 < 1.8 * base, "{sgp32}");
    }

    #[test]
    fn overlap_hides_communication() {
        // Table 4: 1-OSGP ≈ compute-bound even on Ethernet.
        let e = LinkModel::ethernet_10g;
        let sgp = sgp_avg(16, e(), 0);
        let osgp = sgp_avg(16, e(), 1);
        assert!(osgp < sgp, "osgp={osgp} sgp={sgp}");
        let base = ComputeModel::resnet50_dgx1().base_s;
        assert!(osgp < 1.35 * base, "{osgp}");
    }

    #[test]
    fn dpsgd_slower_than_sgp() {
        // Sec. 6.1: SGP ≈1.5× faster than D-PSGD over Ethernet.
        let e = LinkModel::ethernet_10g;
        let compute = ComputeModel::resnet50_dgx1();
        let dpsgd = average_iteration_time(16, e(), &compute, 200, 1, |_k| {
            OwnedCommPattern::Symmetric {
                schedule: Schedule::new(TopologyKind::BipartiteExp, 16),
                bytes: MSG,
                handshake: 2.0,
            }
        });
        let sgp = sgp_avg(16, e(), 0);
        assert!(dpsgd > 1.2 * sgp, "dpsgd={dpsgd} sgp={sgp}");
    }

    #[test]
    fn compute_model_mean_close_to_base() {
        let m = ComputeModel { base_s: 1.0, jitter_sigma: 0.2, p_slow: 0.0, slow_factor: 1.0 };
        let mut rng = Pcg::new(5);
        let mean: f64 =
            (0..20_000).map(|_| m.sample(&mut rng)).sum::<f64>() / 20_000.0;
        assert!((mean - 1.0).abs() < 0.02, "{mean}");
    }

    #[test]
    fn straggler_events_increase_tail() {
        let m = ComputeModel { base_s: 1.0, jitter_sigma: 0.0, p_slow: 0.05, slow_factor: 3.0 };
        let mut rng = Pcg::new(6);
        let max = (0..1000).map(|_| m.sample(&mut rng)).fold(0.0, f64::max);
        assert!((max - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ptp_time_monotone_in_bytes() {
        let link = LinkModel::ethernet_10g();
        assert!(link.ptp_time(1 << 20) < link.ptp_time(1 << 24));
    }

    #[test]
    fn async_rounds_never_block_on_stragglers() {
        // AD-PSGD's clocks are independent: one slow node does not move
        // anyone else's clock, unlike the AllReduce global barrier.
        let mut sim = TimingSim::new(4, LinkModel::ethernet_10g());
        let comp = [0.1, 0.1, 0.1, 5.0];
        sim.advance(&CommPattern::Async { overhead_s: 0.01 }, &comp);
        assert!((sim.t[0] - 0.11).abs() < 1e-12);
        assert!((sim.t[3] - 5.01).abs() < 1e-12);
        let mut barrier = TimingSim::new(4, LinkModel::ethernet_10g());
        barrier.advance(&CommPattern::AllReduce { bytes: 8 }, &comp);
        assert!(barrier.t[0] > 5.0, "barrier drags everyone to the straggler");
    }

    #[test]
    fn faulty_advance_with_lossless_plan_is_bit_identical() {
        use crate::faults::{FaultClock, FaultPlan};
        let clock = FaultClock::new(FaultPlan::lossless());
        let sched = Schedule::new(TopologyKind::OnePeerExp, 8);
        let mut a = TimingSim::new(8, LinkModel::ethernet_10g());
        let mut b = TimingSim::new(8, LinkModel::ethernet_10g());
        let mut rng = Pcg::new(1);
        let compute = ComputeModel::resnet50_dgx1();
        for k in 0..30u64 {
            let comp = compute.sample_all(8, &mut rng);
            let pattern = match k % 4 {
                0 => CommPattern::AllReduce { bytes: MSG },
                1 => CommPattern::PushSum { schedule: &sched, bytes: MSG, tau: 1 },
                2 => CommPattern::Symmetric { schedule: &sched, bytes: MSG, handshake: 2.0 },
                _ => CommPattern::Async { overhead_s: 0.01 },
            };
            let ma = a.advance(&pattern, &comp);
            let mb = b.advance_with_faults(&pattern, &comp, Some(&clock));
            assert_eq!(ma, mb, "k={k}");
            assert_eq!(a.t, b.t, "k={k}");
        }
    }

    #[test]
    fn crashed_member_freezes_clock_and_allreduce_pays_timeout() {
        use crate::faults::{FaultClock, FaultPlan};
        let clock =
            FaultClock::new(FaultPlan::lossless().with_crash(3, 2, Some(5)));
        let mut sim = TimingSim::new(4, LinkModel::ethernet_10g());
        let comp = [0.1; 4];
        let mut prev = 0.0;
        for k in 0..8u64 {
            let before3 = sim.t[3];
            let m = sim.advance_with_faults(
                &CommPattern::AllReduce { bytes: 1 << 20 },
                &comp,
                Some(&clock),
            );
            if (2..5).contains(&k) {
                assert_eq!(sim.t[3], before3, "down node clock frozen at k={k}");
            }
            if k == 2 || k == 5 {
                // Abort + re-form: the detection timeout lands on the round
                // of the membership change (crash and rejoin alike).
                assert!(m - prev > clock.plan.timeout_s, "k={k}: {prev} → {m}");
            }
            prev = m;
        }
        // After rejoin the returning clock fast-forwarded to the cluster.
        assert_eq!(sim.t[3], sim.t[0]);
    }

    #[test]
    fn pushsum_makespan_flat_under_drops_while_allreduce_inflates() {
        use crate::faults::{FaultClock, FaultPlan};
        let n = 16;
        let compute = ComputeModel::resnet50_dgx1();
        let run = |pattern_of: &dyn Fn(u64) -> OwnedCommPattern, drop: f64| {
            let clock = FaultClock::new(FaultPlan::lossless().with_drop(drop));
            let mut sim = TimingSim::new(n, LinkModel::ethernet_10g());
            let mut rng = Pcg::new(7);
            for k in 0..150u64 {
                let comp = compute.sample_all(n, &mut rng);
                let p = pattern_of(k);
                sim.advance_with_faults(&p.borrowed(), &comp, Some(&clock));
            }
            sim.makespan()
        };
        let sgp = |_k: u64| OwnedCommPattern::PushSum {
            schedule: Schedule::new(TopologyKind::OnePeerExp, n),
            bytes: MSG,
            tau: 0,
        };
        let ar = |_k: u64| OwnedCommPattern::AllReduce { bytes: MSG };
        let sgp_ratio = run(&sgp, 0.05) / run(&sgp, 0.0);
        let ar_ratio = run(&ar, 0.05) / run(&ar, 0.0);
        assert!(sgp_ratio < 1.05, "SGP must stay flat under loss: {sgp_ratio}");
        assert!(ar_ratio > 1.2, "AllReduce must inflate under loss: {ar_ratio}");
    }

    #[test]
    fn degradation_window_slows_the_round() {
        use crate::faults::{Degradation, FaultClock, FaultPlan};
        let clock = FaultClock::new(FaultPlan::lossless().with_degradation(
            Degradation { from: 1, until: 2, alpha_mult: 1.0, beta_div: 10.0 },
        ));
        let sched = Schedule::new(TopologyKind::OnePeerExp, 4);
        let mut sim = TimingSim::new(4, LinkModel::ethernet_10g());
        let comp = [0.0; 4];
        let p = CommPattern::PushSum { schedule: &sched, bytes: MSG, tau: 0 };
        let m0 = sim.advance_with_faults(&p, &comp, Some(&clock));
        let m1 = sim.advance_with_faults(&p, &comp, Some(&clock)) - m0;
        assert!(m1 > 5.0 * m0, "degraded round {m1} vs clean {m0}");
    }

    #[test]
    fn compressed_wire_bytes_shrink_the_pushsum_makespan() {
        // Byte-accurate link costs: charging the encoded size of a
        // topk:16 message (≥ 8× smaller) must cut the bandwidth-bound
        // Ethernet makespan accordingly; identity charges dense bytes.
        use crate::gossip::Compression;
        let n = 16;
        let dim = 25 << 20; // 100 MiB of fp32 → 25 Mi coordinates
        let run = |spec: Compression| {
            // Communication-bound round (zero compute) so the ratio of
            // makespans is the ratio of wire bytes, up to latency.
            let compute = ComputeModel::deterministic(0.0);
            average_iteration_time(n, LinkModel::ethernet_10g(), &compute, 50, 3, |_| {
                OwnedCommPattern::PushSum {
                    schedule: Schedule::new(TopologyKind::OnePeerExp, n),
                    bytes: spec.encoded_bytes(dim, MSG),
                    tau: 0,
                }
            })
        };
        let dense = run(Compression::Identity);
        let topk = run(Compression::TopK { den: 16 });
        let q4 = run(Compression::Qsgd { bits: 4 });
        assert!(topk < dense * 0.2, "topk {topk} vs dense {dense}");
        assert!(q4 < dense * 0.2, "qsgd {q4} vs dense {dense}");
        assert_eq!(
            Compression::Identity.encoded_bytes(dim, MSG),
            MSG,
            "identity charges the dense payload"
        );
    }

    #[test]
    fn deterministic_compute_no_jitter() {
        let m = ComputeModel::deterministic(0.25);
        let mut rng = Pcg::new(7);
        for _ in 0..10 {
            assert_eq!(m.sample(&mut rng), 0.25);
        }
    }
}

//! The gossip worker process behind `repro worker`: one rank of a real
//! multi-process push-sum deployment, speaking the framed wire protocol
//! of [`super::wire`] over loopback/LAN TCP.
//!
//! The worker runs the same round protocol as the in-process trainer
//! ([`crate::coordinator::Trainer`]) and the offline quadratic harness
//! ([`crate::faults::harness`]) — membership events, a local gradient
//! step on the de-biased view, one push-sum gossip exchange — except the
//! "communicate" phase is real sockets instead of [`crate::net::TimingSim`]:
//!
//! 1. apply membership events broadcast by the coordinator (Leave ⇒
//!    drop the rank from the sorted alive set — subsequent schedules are
//!    re-indexed among survivors via
//!    [`crate::topology::Schedule::out_peers_among_into`]);
//! 2. for the gradient phase, take one SGD step on the node-local
//!    quadratic `f_i(x) = ½‖x − c_i‖²` (centers drawn exactly like the
//!    offline harness, so a deployed run is comparable to
//!    `run_quadratic` at the same seed);
//! 3. compress each outgoing share with the assigned
//!    [`Compression`] spec (per-edge error-feedback banks, φ-split
//!    weight — the same `apply` the simulator uses), encode it with
//!    [`wire::encode_share`] and push it framed to the round's
//!    out-neighbours;
//! 4. wait (bounded) for the expected in-neighbour messages and absorb
//!    every arrived share with round ≤ k.
//!
//! **Rescue mode is real**: a failed send (peer crashed, connection
//! reset) re-absorbs the encoded `(x, w)` share into the sender's own
//! state instead of losing it, exactly like the simulator's rescue path —
//! so each worker maintains the mass-conservation ledger
//! `w_final = 1 + w_received − w_sent` to f64 round-off, kill or no kill.
//!
//! The run ends with a dense **cool-down**: the last `cooldown` rounds
//! skip the gradient and ship identity-coded shares (error-feedback
//! banks are flushed to their peers at the boundary), which drives the
//! survivors to consensus — push-sum averaging contracts geometrically
//! once the gradient forcing stops. After a short linger for stragglers
//! the worker drains any remaining bank mass into its own state and
//! reports a [`DoneReport`] to the coordinator.

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::gossip::compress::EdgeBank;
use crate::gossip::Compression;
use crate::obs::trace::TraceWriter;
use crate::rng::Pcg;
use crate::snapshot::{
    EngineKind, SnapBank, SnapLedger, SnapNode, Snapshot, SnapshotPolicy, SnapshotSink,
};
use crate::topology::{Schedule, TopologyKind};

use super::wire::{
    self, Assignment, DoneReport, Envelope, Frame, FrameReader, WireEvent, UNASSIGNED,
};

/// Knobs of one worker process (everything else arrives in the
/// coordinator's [`Assignment`]).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Coordinator address (`host:port`).
    pub coord: String,
    /// Bind address for the gossip listener (`127.0.0.1:0` = any port).
    pub bind: String,
    /// Heartbeat period in milliseconds.
    pub hb_ms: u64,
    /// Per-connection read/write timeout in milliseconds — every socket
    /// operation is bounded, so a wedged peer cannot hang the run.
    pub io_timeout_ms: u64,
    /// Mirror structured events as human-readable stderr lines.
    pub verbose: bool,
    /// Optional JSONL trace output ([`crate::obs::trace`] schema,
    /// source `"worker"`): per-edge byte/message counters, send
    /// failures, membership observations, and the final ledger.
    pub trace: Option<PathBuf>,
    /// Optional durable-checkpoint directory. When set, the worker
    /// warm-restores its latest `worker{rank}.r*.snap` capture after the
    /// coordinator's assignment (resuming its prior mass, banks, ledger
    /// and survivor view instead of a cold `w = 1` start), and writes a
    /// fresh capture every [`Self::checkpoint_every`] rounds and on every
    /// observed membership change.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint cadence in rounds (`0` = only on membership changes).
    /// Ignored unless [`Self::checkpoint_dir`] is set.
    pub checkpoint_every: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            coord: "127.0.0.1:7000".to_string(),
            bind: "127.0.0.1:0".to_string(),
            hb_ms: 50,
            io_timeout_ms: 5000,
            verbose: false,
            trace: None,
            checkpoint_dir: None,
            checkpoint_every: 50,
        }
    }
}

/// What a finished worker hands back to its caller (the CLI prints it).
#[derive(Clone, Debug)]
pub struct WorkerReport {
    /// Rank this worker was assigned.
    pub rank: u32,
    /// Rounds actually run.
    pub rounds: u64,
    /// The final state + ledger also sent to the coordinator.
    pub done: DoneReport,
}

/// One received (not yet absorbed) push-sum message.
struct PushMsg {
    from: u32,
    round: u64,
    scheme: Compression,
    w: f64,
    share: Vec<u8>,
}

/// Shared state the socket reader threads feed and the round loop
/// consumes, with a condvar for bounded waits.
#[derive(Default)]
struct Mailbox {
    msgs: Vec<PushMsg>,
    events: Vec<WireEvent>,
    shutdown: bool,
    coord_closed: bool,
}

type Shared = Arc<(Mutex<Mailbox>, Condvar)>;

/// Lock with panic-poisoning recovery. Mailbox and coordinator-stream
/// critical sections only move plain data (a panic cannot leave an
/// invariant half-updated), so a poisoned mutex is safe to re-enter —
/// a panicked reader thread must degrade the run, never abort it.
fn guard<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Lazily-connected, timeout-bounded gossip send links to peer workers.
struct Links {
    peers: Vec<String>,
    conns: HashMap<usize, TcpStream>,
    timeout: Duration,
}

impl Links {
    fn new(peers: Vec<String>, timeout: Duration) -> Self {
        Self { peers, conns: HashMap::new(), timeout }
    }

    /// Write one frame to `peer`, connecting on first use. Any error
    /// invalidates the cached connection (the next send re-dials). A
    /// peer rank outside the assignment's table (remote-controlled data)
    /// is a typed error, never a panic — the caller's send-failure path
    /// rescues the share's mass.
    fn send(&mut self, peer: usize, bytes: &[u8]) -> std::io::Result<()> {
        if !self.conns.contains_key(&peer) {
            let addr_str = self.peers.get(peer).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "peer rank outside the assignment's peer table",
                )
            })?;
            let addr: SocketAddr = addr_str.parse().map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidInput, "bad peer address")
            })?;
            let s = TcpStream::connect_timeout(&addr, self.timeout)?;
            s.set_nodelay(true)?;
            s.set_write_timeout(Some(self.timeout))?;
            self.conns.insert(peer, s);
        }
        let res = match self.conns.get_mut(&peer) {
            Some(conn) => conn.write_all(bytes),
            None => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::NotConnected,
                    "peer connection vanished between insert and write",
                ))
            }
        };
        if res.is_err() {
            self.conns.remove(&peer);
        }
        res
    }
}

/// Feed a socket into the shared mailbox until EOF/error. `from_coord`
/// routes membership/shutdown control frames; gossip connections only
/// ever contribute `Push` frames.
fn reader_loop(mut stream: TcpStream, shared: Shared, from_coord: bool) {
    let mut fr = FrameReader::new();
    let mut buf = [0u8; 64 * 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                fr.extend(&buf[..n]);
                loop {
                    match fr.next_frame() {
                        Ok(None) => break,
                        Err(_) => {
                            // Corrupted stream: drop the connection. The
                            // sender's ledger treats the write as sent;
                            // the coordinator's global accounting
                            // surfaces the loss.
                            notify(&shared, |mb| {
                                if from_coord {
                                    mb.coord_closed = true;
                                }
                            });
                            return;
                        }
                        Ok(Some(env)) => match env.msg {
                            Frame::Push { w, share } => notify(&shared, |mb| {
                                mb.msgs.push(PushMsg {
                                    from: env.sender,
                                    round: env.round,
                                    scheme: env.scheme,
                                    w,
                                    share,
                                });
                            }),
                            Frame::Membership(ev) => {
                                notify(&shared, |mb| mb.events.push(ev))
                            }
                            Frame::Shutdown => notify(&shared, |mb| mb.shutdown = true),
                            _ => {}
                        },
                    }
                }
            }
        }
    }
    if from_coord {
        notify(&shared, |mb| mb.coord_closed = true);
    }
}

fn notify(shared: &Shared, f: impl FnOnce(&mut Mailbox)) {
    let (lock, cv) = &**shared;
    let mut mb = guard(lock);
    f(&mut mb);
    cv.notify_all();
}

/// Connect to the coordinator, retrying for up to `total` (the
/// coordinator may still be binding when the worker starts).
fn connect_retry(addr: &str, total: Duration, each: Duration) -> Result<TcpStream> {
    let sock: SocketAddr =
        addr.parse().with_context(|| format!("bad coordinator address `{addr}`"))?;
    let deadline = Instant::now() + total;
    loop {
        match TcpStream::connect_timeout(&sock, each) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to coordinator {addr}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Block until the coordinator's `Assign` arrives on `stream` (bounded).
fn read_assignment(stream: &mut TcpStream, deadline: Instant) -> Result<Assignment> {
    let mut fr = FrameReader::new();
    let mut buf = [0u8; 4096];
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    loop {
        if let Some(env) = fr.next_frame()? {
            if let Frame::Assign(a) = env.msg {
                return Ok(a);
            }
            continue; // ignore anything else pre-assignment
        }
        if Instant::now() >= deadline {
            bail!("timed out waiting for the coordinator's rank assignment");
        }
        match stream.read(&mut buf) {
            Ok(0) => bail!("coordinator closed the connection before assigning a rank"),
            Ok(n) => fr.extend(&buf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e).context("reading rank assignment"),
        }
    }
}

/// Sorted-vec removal; no-op if absent.
fn remove_rank(alive: &mut Vec<usize>, rank: usize) {
    if let Ok(i) = alive.binary_search(&rank) {
        alive.remove(i);
    }
}

/// The expected in-neighbours of `me` at round `k` under the survivor
/// schedule: every alive rank whose re-indexed out-peer set contains
/// `me`.
fn in_peers(
    sched: &Schedule,
    me: usize,
    k: u64,
    alive: &[usize],
    scratch: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    for &i in alive {
        if i == me {
            continue;
        }
        sched.out_peers_among_into(i, k, alive, scratch);
        if scratch.contains(&me) {
            out.push(i);
        }
    }
}

/// The latest `worker{rank}.r*.snap` in `dir`, by file name — the
/// fixed-width round field in [`SnapshotSink::path_for`] names makes
/// lexical order chronological. Unreadable directories yield `None`
/// (cold start), never an error.
fn latest_checkpoint(dir: &Path, rank: usize) -> Option<PathBuf> {
    let prefix = format!("worker{rank}.r");
    let mut best: Option<PathBuf> = None;
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with(&prefix) || !name.ends_with(".snap") {
            continue;
        }
        let path = entry.path();
        if best.as_ref().map_or(true, |b| b.as_path() < path.as_path()) {
            best = Some(path);
        }
    }
    best
}

/// Encode this worker's durable state as a world-shaped dense
/// [`Snapshot`]: only row `rank` carries real mass; every other row is a
/// membership hint (`w = 1` alive, `w = 0` written off) so a warm restore
/// realigns its survivor schedule before any fresh Leave event arrives.
/// The ledger section carries the worker's mass-flow counters, keeping
/// `w = 1 + recv_w − sent_w` meaningful across the restart.
#[allow(clippy::too_many_arguments)] // flat capture of the round loop's state
fn capture_worker_snapshot(
    round: u64,
    rank: usize,
    world: usize,
    dim: usize,
    x: &[f32],
    w: f64,
    banks: &BTreeMap<usize, EdgeBank>,
    alive: &[usize],
    recv_w: f64,
    sent_w: f64,
    rescued_w: f64,
    rescues: u32,
) -> Snapshot {
    let mut nodes = Vec::with_capacity(world);
    for r in 0..world {
        if r == rank {
            nodes.push(SnapNode { x: x.to_vec(), w });
        } else {
            let hint = if alive.binary_search(&r).is_ok() { 1.0 } else { 0.0 };
            nodes.push(SnapNode { x: vec![0.0; dim], w: hint });
        }
    }
    let snap_banks = banks
        .iter()
        .map(|(&peer, b)| SnapBank {
            from: rank as u64,
            to: peer as u64,
            x: b.x.clone(),
            w: b.w,
        })
        .collect();
    Snapshot {
        round,
        kind: EngineKind::Dense,
        biased: false,
        n: world as u64,
        dim: dim as u64,
        delay: 0,
        epoch: (world - alive.len()) as u64,
        nodes,
        mail: vec![Vec::new(); world],
        banks: snap_banks,
        ledger: SnapLedger {
            dropped_x: vec![0.0; dim],
            rescue_count: rescues as u64,
            recv_w,
            sent_w,
            rescued_w,
            ..SnapLedger::default()
        },
        rngs: Vec::new(),
        sparse: None,
    }
}

/// Warm-restore `(x, w, banks, alive, ledger)` from the latest checkpoint
/// for `rank`, if one exists and matches the run's shape. Returns the
/// snapshot's round on success; any mismatch or decode failure degrades
/// to a cold start (with a stderr note), never an abort.
#[allow(clippy::too_many_arguments)] // mirrors capture_worker_snapshot
fn try_warm_restore(
    dir: &Path,
    rank: usize,
    world: usize,
    dim: usize,
    x: &mut Vec<f32>,
    w: &mut f64,
    banks: &mut BTreeMap<usize, EdgeBank>,
    alive: &mut Vec<usize>,
    recv_w: &mut f64,
    sent_w: &mut f64,
    rescued_w: &mut f64,
    rescues: &mut u32,
) -> Option<u64> {
    let path = latest_checkpoint(dir, rank)?;
    let snap = match Snapshot::read_file(&path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "[worker {rank}] ignoring unreadable checkpoint {}: {e}",
                path.display()
            );
            return None;
        }
    };
    if snap.n() != world || snap.dim() != dim {
        eprintln!(
            "[worker {rank}] ignoring checkpoint {} shaped {}x{} (run is {world}x{dim})",
            path.display(),
            snap.n(),
            snap.dim()
        );
        return None;
    }
    let me = snap.nodes.get(rank)?;
    *x = me.x.clone();
    *w = me.w;
    banks.clear();
    for b in &snap.banks {
        if b.from as usize == rank && (b.to as usize) < world {
            let bank = banks.entry(b.to as usize).or_insert_with(|| EdgeBank::new(dim));
            bank.x.copy_from_slice(&b.x);
            bank.w = b.w;
        }
    }
    *alive = (0..world)
        .filter(|&r| r == rank || snap.nodes[r].w != 0.0)
        .collect();
    *recv_w = snap.ledger.recv_w;
    *sent_w = snap.ledger.sent_w;
    *rescued_w = snap.ledger.rescued_w;
    *rescues = snap.ledger.rescue_count.min(u64::from(u32::MAX)) as u32;
    Some(snap.round())
}

/// Worker-side observability: the optional trace writer plus
/// pre-allocated per-peer wire counters (payload bytes and message
/// counts, both directions). One instance per run, created right after
/// the assignment fixes `world`.
struct Telemetry {
    verbose: bool,
    trace: TraceWriter,
    start: Instant,
    sent_msgs: Vec<u64>,
    sent_bytes: Vec<u64>,
    recv_msgs: Vec<u64>,
    recv_bytes: Vec<u64>,
    malformed: u64,
}

impl Telemetry {
    fn new(cfg: &WorkerConfig, rank: u32, world: usize, rounds: u64) -> Self {
        let trace = match &cfg.trace {
            None => TraceWriter::disabled(),
            Some(path) => match TraceWriter::create(path, "worker", world, rounds) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("[worker {rank}] cannot open trace {}: {e}", path.display());
                    TraceWriter::disabled()
                }
            },
        };
        Self {
            verbose: cfg.verbose,
            trace,
            start: Instant::now(),
            sent_msgs: vec![0; world],
            sent_bytes: vec![0; world],
            recv_msgs: vec![0; world],
            recv_bytes: vec![0; world],
            malformed: 0,
        }
    }

    fn event(&mut self, kind: &str, rank: u32, round: u64, extras: &[(&str, f64)]) {
        let t_ms = self.start.elapsed().as_millis() as u64;
        self.trace.event(t_ms, kind, rank, round, extras);
    }

    fn on_sent(&mut self, peer: usize, frame_bytes: usize) {
        self.sent_msgs[peer] += 1;
        self.sent_bytes[peer] += frame_bytes as u64;
    }
}

/// Run one worker to completion: register, gossip, drain, report. All
/// socket operations are timeout-bounded, so the call terminates even if
/// peers or the coordinator die at any point.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerReport> {
    let io_timeout = Duration::from_millis(cfg.io_timeout_ms.max(100));

    // Gossip listener first: its port rides in the Join registration.
    let listener =
        TcpListener::bind(&cfg.bind).with_context(|| format!("binding {}", cfg.bind))?;
    let listen_port = listener.local_addr()?.port();

    let mut coord =
        connect_retry(&cfg.coord, Duration::from_secs(15), Duration::from_millis(500))?;
    coord.set_nodelay(true)?;
    coord.set_write_timeout(Some(io_timeout))?;

    let mut out_buf = Vec::new();
    wire::encode_frame(
        &Envelope::control(UNASSIGNED, 0, Frame::Join { listen_port }),
        &mut out_buf,
    );
    coord.write_all(&out_buf).context("sending Join")?;

    let a = read_assignment(&mut coord, Instant::now() + Duration::from_secs(120))?;
    let rank = a.rank as usize;
    let world = a.world as usize;
    let dim = a.dim as usize;
    if rank >= world || a.peers.len() != world || dim == 0 {
        bail!("malformed assignment: rank {rank}, world {world}, {} peers", a.peers.len());
    }
    let mut tel = Telemetry::new(cfg, a.rank, world, a.rounds);
    if tel.verbose {
        eprintln!(
            "[worker {rank}] assigned: world={world} rounds={} cooldown={} dim={dim} \
             scheme={} peers on {:?}",
            a.rounds,
            a.cooldown,
            a.scheme.label(),
            a.peers
        );
    }
    tel.event(
        "assigned",
        a.rank,
        0,
        &[("cooldown", a.cooldown as f64), ("dim", dim as f64)],
    );

    let shared: Shared = Arc::new((Mutex::new(Mailbox::default()), Condvar::new()));

    // Reader threads: gossip acceptor (one reader per inbound peer
    // connection) and the coordinator control stream.
    {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                let _ = stream.set_nodelay(true);
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || reader_loop(stream, shared, false));
            }
        });
    }
    {
        let shared = Arc::clone(&shared);
        let coord_read = coord.try_clone().context("cloning coordinator stream")?;
        coord_read.set_read_timeout(None)?;
        std::thread::spawn(move || reader_loop(coord_read, shared, true));
    }

    // Heartbeat thread: a liveness beacon every `hb_ms` carrying the
    // current round (the coordinator's two-threshold monitor feeds on
    // these; see super::heartbeat).
    let round_now = Arc::new(AtomicU64::new(0));
    let coord_w = Arc::new(Mutex::new(coord));
    {
        let round_now = Arc::clone(&round_now);
        let coord_w = Arc::clone(&coord_w);
        let my_rank = a.rank;
        let hb_ms = cfg.hb_ms.max(5);
        std::thread::spawn(move || {
            let mut buf = Vec::new();
            loop {
                std::thread::sleep(Duration::from_millis(hb_ms));
                let k = round_now.load(Ordering::Relaxed);
                buf.clear();
                wire::encode_frame(&Envelope::control(my_rank, k, Frame::Heartbeat), &mut buf);
                if guard(&coord_w).write_all(&buf).is_err() {
                    break;
                }
            }
        });
    }

    // --- Node state: exactly the offline harness's objective. ---------
    let mut rng = Pcg::new(a.seed);
    let centers: Vec<Vec<f32>> = (0..world).map(|_| rng.gaussian_vec(dim)).collect();
    let center = centers[rank].clone();
    let mut x = vec![0.0f32; dim];
    let mut w = 1.0f64;

    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, world, a.seed);
    let mut alive: Vec<usize> = (0..world).collect();
    let mut degraded = vec![false; world];
    // BTreeMap, not HashMap: the cool-down bank flush and the final
    // drain iterate this map, and their order decides the f64 send /
    // absorb order — sorted keys keep the worker's arithmetic (and its
    // ledger residual) reproducible run-to-run.
    let mut banks: BTreeMap<usize, EdgeBank> = BTreeMap::new();
    let mut idx_scratch: Vec<u32> = Vec::new();
    let mut links = Links::new(a.peers.clone(), io_timeout);

    let mut recv_w = 0.0f64;
    let mut sent_w = 0.0f64;
    let mut rescued_w = 0.0f64;
    let mut rescues = 0u32;
    let mut timeouts = 0u32;

    // Durable checkpoints: warm-restore the latest capture for this rank
    // (a restarted process resumes its prior mass instead of a cold
    // `w = 1` start), then re-capture on the configured cadence below.
    let ckpt = cfg.checkpoint_dir.as_ref().map(|dir| {
        SnapshotSink::new(
            SnapshotPolicy::every(cfg.checkpoint_every).and_on_membership_change(),
            dir.clone(),
        )
    });
    if let Some(dir) = cfg.checkpoint_dir.as_deref() {
        if let Some(r0) = try_warm_restore(
            dir,
            rank,
            world,
            dim,
            &mut x,
            &mut w,
            &mut banks,
            &mut alive,
            &mut recv_w,
            &mut sent_w,
            &mut rescued_w,
            &mut rescues,
        ) {
            if tel.verbose {
                eprintln!(
                    "[worker {rank}] warm-restored round-{r0} checkpoint: w={w:.6} \
                     survivors={}",
                    alive.len()
                );
            }
            tel.event("restore", a.rank, r0, &[("w", w), ("survivors", alive.len() as f64)]);
        }
    }

    let grad_rounds = a.rounds.saturating_sub(a.cooldown);
    let round_timeout = Duration::from_millis(a.round_timeout_ms.max(1) as u64);
    let round_pace = Duration::from_millis(a.round_ms as u64);

    let mut outs: Vec<usize> = Vec::new();
    let mut scratch: Vec<usize> = Vec::new();
    let mut expected: Vec<usize> = Vec::new();
    let mut frame_buf: Vec<u8> = Vec::new();
    let mut share_buf: Vec<u8> = Vec::new();
    let mut evicted = false;
    let mut rounds_run = 0u64;

    'rounds: for k in 0..a.rounds {
        round_now.store(k, Ordering::Relaxed);
        let round_start = Instant::now();
        let mut membership_changed = false;

        // 1. Membership events (and control-plane state) first.
        {
            let (lock, _) = &*shared;
            let mut mb = guard(lock);
            if mb.shutdown {
                break 'rounds;
            }
            if mb.coord_closed {
                bail!("[worker {rank}] coordinator connection lost at round {k}");
            }
            let events = std::mem::take(&mut mb.events);
            drop(mb);
            for ev in events {
                let r = ev.rank() as usize;
                if r >= world {
                    continue; // refuse out-of-range ranks outright
                }
                match ev {
                    WireEvent::Leave { .. } => {
                        if r == rank {
                            // The coordinator wrote us off (we were too
                            // slow): stop pushing mass the survivors
                            // will refuse anyway.
                            evicted = true;
                            break 'rounds;
                        }
                        remove_rank(&mut alive, r);
                        membership_changed = true;
                        if tel.verbose {
                            eprintln!(
                                "[worker {rank}] peer {r} left; {} survivors",
                                alive.len()
                            );
                        }
                        tel.event(
                            "peer_leave",
                            r as u32,
                            k,
                            &[("survivors", alive.len() as f64)],
                        );
                    }
                    WireEvent::Degraded { .. } => degraded[r] = true,
                    WireEvent::Recovered { .. } => degraded[r] = false,
                }
            }
        }

        // 2. Gradient phase: one SGD step (same update as the offline
        // harness's optimizer, weight decay included) on the de-biased
        // view z = x / w.
        if k < grad_rounds && a.lr > 0.0 {
            let wf32 = w as f32;
            for (xi, ci) in x.iter_mut().zip(&center) {
                let z = *xi / wf32;
                let g = z - ci;
                *xi -= a.lr * (g + 1e-4 * *xi);
            }
        }

        // Cool-down boundary: flush every error-feedback bank to its
        // edge's peer as a dense push, so the withheld mass mixes
        // instead of sitting out the consensus tail.
        let scheme_k =
            if k < grad_rounds { a.scheme } else { Compression::Identity };
        if k == grad_rounds && !a.scheme.is_identity() {
            for (&peer, bank) in banks.iter_mut() {
                if bank.w == 0.0 && bank.x.iter().all(|v| *v == 0.0) {
                    continue;
                }
                share_buf.clear();
                wire::encode_share(Compression::Identity, &bank.x, &mut share_buf);
                frame_buf.clear();
                wire::encode_frame(
                    &Envelope {
                        sender: a.rank,
                        round: k,
                        scheme: Compression::Identity,
                        msg: Frame::Push { w: bank.w, share: share_buf.clone() },
                    },
                    &mut frame_buf,
                );
                if links.send(peer, &frame_buf).is_ok() {
                    sent_w += bank.w;
                    tel.on_sent(peer, frame_buf.len());
                } else {
                    tel.event("send_failed", peer as u32, k, &[("w", bank.w)]);
                    for (xi, bi) in x.iter_mut().zip(&bank.x) {
                        *xi += bi;
                    }
                    w += bank.w;
                    rescued_w += bank.w;
                    rescues += 1;
                }
                bank.x.fill(0.0);
                bank.w = 0.0;
            }
        }

        // 3. Push: compress, encode, frame, send — failed sends rescue
        // their mass back into the local state.
        sched.out_peers_among_into(rank, k, &alive, &mut outs);
        let wf = 1.0 / (outs.len() as f64 + 1.0);
        let wf32 = wf as f32;
        let mut rescued_this_round: Vec<(Vec<f32>, f64)> = Vec::new();
        for &peer in &outs {
            let mut payload: Vec<f32> = x.iter().map(|v| v * wf32).collect();
            let mut msg_w = w * wf;
            if !scheme_k.is_identity() {
                let bank =
                    banks.entry(peer).or_insert_with(|| EdgeBank::new(dim));
                scheme_k.apply(
                    &mut payload,
                    &mut msg_w,
                    bank,
                    &mut idx_scratch,
                    k,
                    rank,
                    peer,
                );
            }
            share_buf.clear();
            wire::encode_share(scheme_k, &payload, &mut share_buf);
            frame_buf.clear();
            wire::encode_frame(
                &Envelope {
                    sender: a.rank,
                    round: k,
                    scheme: scheme_k,
                    msg: Frame::Push { w: msg_w, share: share_buf.clone() },
                },
                &mut frame_buf,
            );
            match links.send(peer, &frame_buf) {
                Ok(()) => {
                    sent_w += msg_w;
                    tel.on_sent(peer, frame_buf.len());
                }
                Err(e) => {
                    if tel.verbose {
                        eprintln!(
                            "[worker {rank}] round {k}: send to {peer} failed ({e}); rescuing"
                        );
                    }
                    tel.event("send_failed", peer as u32, k, &[("w", msg_w)]);
                    rescued_this_round.push((payload, msg_w));
                }
            }
        }
        // Keep the self share, then re-absorb any rescued mass (after
        // the scale: rescued shares were already cut out of x·wf).
        for xi in x.iter_mut() {
            *xi *= wf32;
        }
        w *= wf;
        for (payload, msg_w) in rescued_this_round {
            for (xi, pi) in x.iter_mut().zip(&payload) {
                *xi += pi;
            }
            w += msg_w;
            rescued_w += msg_w;
            rescues += 1;
        }

        // 4. Receive: bounded wait for this round's expected
        // in-neighbours, then absorb everything that has arrived for
        // rounds ≤ k (later frames stay queued for their round).
        in_peers(&sched, rank, k, &alive, &mut scratch, &mut expected);
        let patience = if expected.iter().any(|&p| degraded[p]) { 4 } else { 1 };
        let deadline = Instant::now() + round_timeout * patience;
        let complete = {
            let (lock, cv) = &*shared;
            let mut mb = guard(lock);
            loop {
                let all = expected.iter().all(|&p| {
                    mb.msgs.iter().any(|m| m.from as usize == p && m.round == k)
                });
                if all || mb.shutdown || mb.coord_closed {
                    break all;
                }
                let now = Instant::now();
                if now >= deadline {
                    break false;
                }
                let (g, _) = cv
                    .wait_timeout(mb, deadline - now)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                mb = g;
            }
        };
        if !complete && !expected.is_empty() {
            timeouts += 1;
        }
        absorb_up_to(&shared, k, &alive, dim, &mut x, &mut w, &mut recv_w, rank, &mut tel);

        // Durable capture: cadence rounds and every observed membership
        // change. Best-effort — a full disk degrades durability, not the
        // run itself.
        if let Some(sink) = &ckpt {
            if sink.policy.due(k, membership_changed) {
                let snap = capture_worker_snapshot(
                    k + 1, rank, world, dim, &x, w, &banks, &alive, recv_w, sent_w,
                    rescued_w, rescues,
                );
                match sink.store(&format!("worker{rank}"), &snap) {
                    Ok(path) => {
                        tel.event("checkpoint", a.rank, k, &[("w", w)]);
                        if tel.verbose {
                            eprintln!(
                                "[worker {rank}] checkpointed round {} to {}",
                                k + 1,
                                path.display()
                            );
                        }
                    }
                    Err(e) => {
                        eprintln!("[worker {rank}] checkpoint failed at round {k}: {e}");
                    }
                }
            }
        }

        rounds_run = k + 1;
        let elapsed = round_start.elapsed();
        if elapsed < round_pace {
            std::thread::sleep(round_pace - elapsed);
        }
    }

    // Linger for stragglers (in-flight last-round shares of slightly
    // slower peers), then drain outstanding bank mass into the local
    // state — the deployment mirror of `PushSumEngine::drain`.
    if !evicted {
        std::thread::sleep(round_timeout.max(Duration::from_millis(250)) * 2);
        absorb_up_to(&shared, a.rounds, &alive, dim, &mut x, &mut w, &mut recv_w, rank, &mut tel);
    }
    for bank in banks.values_mut() {
        for (xi, bi) in x.iter_mut().zip(&bank.x) {
            *xi += bi;
        }
        w += bank.w;
        bank.x.fill(0.0);
        bank.w = 0.0;
    }

    let done = DoneReport {
        w,
        recv_w,
        sent_w,
        rescued_w,
        rescues,
        timeouts,
        x: x.clone(),
    };
    let ledger_residual = w - (1.0 + recv_w - sent_w);
    if tel.verbose {
        eprintln!(
            "[worker {rank}] done after {rounds_run} rounds: w={w:.6} recv_w={recv_w:.6} \
             sent_w={sent_w:.6} rescued_w={rescued_w:.6} ledger_residual={ledger_residual:.3e}"
        );
    }
    for peer in 0..world {
        if tel.sent_msgs[peer] > 0 || tel.recv_msgs[peer] > 0 {
            let extras = [
                ("to", peer as f64),
                ("sent_msgs", tel.sent_msgs[peer] as f64),
                ("sent_bytes", tel.sent_bytes[peer] as f64),
                ("recv_msgs", tel.recv_msgs[peer] as f64),
                ("recv_bytes", tel.recv_bytes[peer] as f64),
            ];
            tel.event("edge", a.rank, rounds_run, &extras);
        }
    }
    tel.event(
        "done",
        a.rank,
        rounds_run,
        &[
            ("w", w),
            ("recv_w", recv_w),
            ("sent_w", sent_w),
            ("rescued_w", rescued_w),
            ("rescues", rescues as f64),
            ("timeouts", timeouts as f64),
            ("malformed", tel.malformed as f64),
            ("evicted", u8::from(evicted) as f64),
            ("ledger_residual", ledger_residual),
        ],
    );

    frame_buf.clear();
    wire::encode_frame(
        &Envelope::control(a.rank, rounds_run, Frame::Done(done.clone())),
        &mut frame_buf,
    );
    guard(&coord_w)
        .write_all(&frame_buf)
        .context("sending Done report")?;

    // Wait (bounded) for the coordinator's Shutdown so late peers can
    // still reach our listener while the group finishes.
    let deadline = Instant::now() + Duration::from_secs(15);
    {
        let (lock, cv) = &*shared;
        let mut mb = guard(lock);
        while !mb.shutdown && !mb.coord_closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = cv
                .wait_timeout(mb, deadline - now)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            mb = g;
        }
    }

    Ok(WorkerReport { rank: a.rank, rounds: rounds_run, done })
}

/// Absorb every queued message with round ≤ `k` from senders still in
/// the alive set (mass from written-off ranks is refused — their ledger
/// left the group with them).
#[allow(clippy::too_many_arguments)] // flat hot-path call, mirrors Compression::apply
fn absorb_up_to(
    shared: &Shared,
    k: u64,
    alive: &[usize],
    dim: usize,
    x: &mut [f32],
    w: &mut f64,
    recv_w: &mut f64,
    rank: usize,
    tel: &mut Telemetry,
) {
    let ready: Vec<PushMsg> = {
        let (lock, _) = &**shared;
        let mut mb = guard(lock);
        let msgs = std::mem::take(&mut mb.msgs);
        let (ready, later): (Vec<_>, Vec<_>) =
            msgs.into_iter().partition(|m| m.round <= k);
        mb.msgs = later;
        ready
    };
    for m in ready {
        if alive.binary_search(&(m.from as usize)).is_err() {
            continue;
        }
        match wire::decode_share(m.scheme, dim, &m.share) {
            Ok(vals) => {
                for (xi, vi) in x.iter_mut().zip(&vals) {
                    *xi += vi;
                }
                *w += m.w;
                *recv_w += m.w;
                let from = m.from as usize;
                if from < tel.recv_msgs.len() {
                    tel.recv_msgs[from] += 1;
                    tel.recv_bytes[from] += m.share.len() as u64;
                }
            }
            Err(e) => {
                tel.malformed += 1;
                tel.event("malformed_share", m.from, m.round, &[]);
                if tel.verbose {
                    eprintln!(
                        "[worker {rank}] dropping malformed share from {} round {}: {e}",
                        m.from, m.round
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_peers_matches_the_survivor_schedule() {
        let sched = Schedule::with_seed(TopologyKind::OnePeerExp, 4, 1);
        let alive = vec![0usize, 1, 3];
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        for k in 0..16u64 {
            // The 1-peer exponential schedule is a permutation among the
            // survivors: everyone alive has exactly one in-peer.
            for &me in &alive {
                in_peers(&sched, me, k, &alive, &mut scratch, &mut out);
                assert_eq!(out.len(), 1, "round {k} rank {me}: {out:?}");
                assert!(alive.contains(&out[0]));
                assert_ne!(out[0], me);
            }
        }
    }

    #[test]
    fn worker_checkpoint_roundtrips_state_banks_and_membership() {
        let dir =
            std::env::temp_dir().join(format!("sgp_worker_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (world, dim, rank) = (4usize, 6usize, 1usize);
        let x: Vec<f32> = (0..dim).map(|i| i as f32 * 0.5).collect();
        let w = 0.8125f64;
        let mut banks: BTreeMap<usize, EdgeBank> = BTreeMap::new();
        let bank = banks.entry(3).or_insert_with(|| EdgeBank::new(dim));
        bank.x[2] = 1.5;
        bank.w = 0.0625;
        let alive = vec![0usize, 1, 3]; // rank 2 written off
        let snap = capture_worker_snapshot(
            7, rank, world, dim, &x, w, &banks, &alive, 2.5, 3.25, 0.125, 4,
        );
        let sink = SnapshotSink::new(SnapshotPolicy::every(1), &dir);
        sink.store("worker1", &snap).unwrap();

        let (mut x2, mut w2) = (vec![0.0f32; dim], 1.0f64);
        let mut banks2: BTreeMap<usize, EdgeBank> = BTreeMap::new();
        let mut alive2: Vec<usize> = (0..world).collect();
        let (mut recv, mut sent, mut resc) = (0.0f64, 0.0f64, 0.0f64);
        let mut n_resc = 0u32;
        let r0 = try_warm_restore(
            &dir, rank, world, dim, &mut x2, &mut w2, &mut banks2, &mut alive2,
            &mut recv, &mut sent, &mut resc, &mut n_resc,
        );
        assert_eq!(r0, Some(7));
        assert_eq!(x2, x);
        assert_eq!(w2.to_bits(), w.to_bits());
        assert_eq!(alive2, alive, "membership hint rows restore the survivor view");
        assert_eq!(banks2.len(), 1);
        assert_eq!(banks2.get(&3).map(|b| (b.x[2], b.w)), Some((1.5, 0.0625)));
        assert_eq!((recv, sent, resc, n_resc), (2.5, 3.25, 0.125, 4));

        // No capture for rank 0 → cold start; shape mismatch → cold start.
        assert!(try_warm_restore(
            &dir, 0, world, dim, &mut x2, &mut w2, &mut banks2, &mut alive2,
            &mut recv, &mut sent, &mut resc, &mut n_resc,
        )
        .is_none());
        assert!(try_warm_restore(
            &dir, rank, world + 1, dim, &mut x2, &mut w2, &mut banks2, &mut alive2,
            &mut recv, &mut sent, &mut resc, &mut n_resc,
        )
        .is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remove_rank_keeps_the_vec_sorted() {
        let mut alive = vec![0usize, 1, 2, 3];
        remove_rank(&mut alive, 2);
        assert_eq!(alive, vec![0, 1, 3]);
        remove_rank(&mut alive, 2);
        assert_eq!(alive, vec![0, 1, 3], "double-leave is a no-op");
        remove_rank(&mut alive, 0);
        remove_rank(&mut alive, 3);
        remove_rank(&mut alive, 1);
        assert!(alive.is_empty());
    }
}

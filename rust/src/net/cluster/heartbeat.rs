//! Two-threshold heartbeat tracking for the deployment coordinator:
//! distinguishing a **slow** worker (degraded — keep it in the schedule,
//! give its peers more patience) from a **dead** one (membership event,
//! survivor re-indexing).
//!
//! A single timeout cannot make that distinction: set it tight and a GC
//! pause evicts a healthy worker (push-sum mass gone for nothing), set it
//! loose and every real crash stalls the survivors for the whole window.
//! The monitor therefore runs two clocks per worker:
//!
//! ```text
//!             silence < slow_after        → Healthy
//! slow_after ≤ silence < dead_after       → Degraded  (recoverable)
//!             silence ≥ dead_after        → Dead      (absorbing)
//! ```
//!
//! `Degraded` is fully recoverable: a heartbeat arriving between the two
//! thresholds flips the worker straight back to `Healthy` and emits
//! [`Transition::Recovered`] so the coordinator can broadcast the
//! all-clear. `Dead` is absorbing — a late heartbeat from an evicted
//! worker is ignored (its mass has already been written off and the
//! survivor schedules re-indexed; an un-leave would fork the group view).
//!
//! The monitor is pure state over caller-supplied millisecond timestamps
//! — no `Instant`, no wall clock — so the edge cases (recovery between
//! the thresholds, late beacons after eviction) are unit-testable without
//! sleeping.

/// The two silence thresholds, in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct HeartbeatPolicy {
    /// Silence after which a worker is declared slow (degraded).
    pub slow_after_ms: u64,
    /// Silence after which a worker is declared dead. Must exceed
    /// `slow_after_ms` for the degraded band to exist.
    pub dead_after_ms: u64,
}

impl Default for HeartbeatPolicy {
    fn default() -> Self {
        Self { slow_after_ms: 500, dead_after_ms: 2000 }
    }
}

/// Liveness verdict for one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Heartbeats arriving within the slow threshold.
    Healthy,
    /// Silent past `slow_after_ms` but not yet written off: stays in the
    /// gossip schedule, peers wait longer for its messages.
    Degraded,
    /// Silent past `dead_after_ms` (or its connection closed): evicted.
    /// Absorbing — late beacons do not resurrect it.
    Dead,
}

/// A state change produced by [`HeartbeatMonitor::observe`] /
/// [`HeartbeatMonitor::sweep`]; the coordinator turns these into
/// membership broadcasts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transition {
    /// Healthy → Degraded (crossed the slow threshold).
    Degraded(usize),
    /// Degraded → Healthy (beacon arrived before the dead threshold).
    Recovered(usize),
    /// → Dead (crossed the dead threshold, or connection closed).
    Dead(usize),
}

/// Per-worker two-threshold liveness state over injected timestamps.
#[derive(Clone, Debug)]
pub struct HeartbeatMonitor {
    policy: HeartbeatPolicy,
    last_seen_ms: Vec<u64>,
    health: Vec<Health>,
}

impl HeartbeatMonitor {
    /// A monitor for `n` workers, all healthy and last seen at `now_ms`.
    pub fn new(n: usize, policy: HeartbeatPolicy, now_ms: u64) -> Self {
        debug_assert!(policy.dead_after_ms > policy.slow_after_ms);
        Self {
            policy,
            last_seen_ms: vec![now_ms; n],
            health: vec![Health::Healthy; n],
        }
    }

    /// Current verdict for `rank`.
    pub fn health(&self, rank: usize) -> Health {
        self.health[rank]
    }

    /// Record a heartbeat from `rank` at `now_ms`. Returns
    /// `Some(Transition::Recovered)` when this beacon pulls the worker
    /// back from the degraded band; `None` otherwise (including beacons
    /// from already-dead workers, which are ignored — dead is absorbing).
    pub fn observe(&mut self, rank: usize, now_ms: u64) -> Option<Transition> {
        match self.health[rank] {
            Health::Dead => None,
            state => {
                self.last_seen_ms[rank] = now_ms;
                if state == Health::Degraded {
                    self.health[rank] = Health::Healthy;
                    Some(Transition::Recovered(rank))
                } else {
                    None
                }
            }
        }
    }

    /// Declare `rank` dead immediately (connection closed / EOF) —
    /// stronger evidence than silence, so it bypasses the thresholds.
    /// Returns the transition unless the worker was already dead.
    pub fn mark_dead(&mut self, rank: usize) -> Option<Transition> {
        if self.health[rank] == Health::Dead {
            None
        } else {
            self.health[rank] = Health::Dead;
            Some(Transition::Dead(rank))
        }
    }

    /// Advance the clocks to `now_ms` and collect every threshold
    /// crossing (in rank order): Healthy workers past `slow_after_ms`
    /// degrade, any non-dead worker past `dead_after_ms` dies.
    pub fn sweep(&mut self, now_ms: u64) -> Vec<Transition> {
        let mut out = Vec::new();
        for rank in 0..self.health.len() {
            let silence = now_ms.saturating_sub(self.last_seen_ms[rank]);
            match self.health[rank] {
                Health::Dead => {}
                _ if silence >= self.policy.dead_after_ms => {
                    self.health[rank] = Health::Dead;
                    out.push(Transition::Dead(rank));
                }
                Health::Healthy if silence >= self.policy.slow_after_ms => {
                    self.health[rank] = Health::Degraded;
                    out.push(Transition::Degraded(rank));
                }
                _ => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HeartbeatPolicy {
        HeartbeatPolicy { slow_after_ms: 100, dead_after_ms: 300 }
    }

    #[test]
    fn a_worker_that_recovers_between_the_thresholds_is_not_evicted() {
        // The satellite's edge case: silence crosses the slow threshold,
        // the worker degrades — then a beacon lands *before* the dead
        // threshold and it must come back as Recovered, not Leave.
        let mut m = HeartbeatMonitor::new(2, policy(), 0);
        assert_eq!(m.sweep(150), vec![Transition::Degraded(0), Transition::Degraded(1)]);
        assert_eq!(m.health(0), Health::Degraded);
        // Rank 0 revives at t=250 (inside the 100..300 band).
        assert_eq!(m.observe(0, 250), Some(Transition::Recovered(0)));
        assert_eq!(m.health(0), Health::Healthy);
        // Rank 1 stays silent and dies at the dead threshold; rank 0,
        // freshly observed, survives the same sweep.
        assert_eq!(m.sweep(310), vec![Transition::Dead(1)]);
        assert_eq!(m.health(0), Health::Healthy);
        assert_eq!(m.health(1), Health::Dead);
    }

    #[test]
    fn silence_past_the_dead_threshold_skips_straight_to_dead() {
        // A sweep that only runs after the dead threshold must not emit a
        // spurious Degraded first.
        let mut m = HeartbeatMonitor::new(1, policy(), 0);
        assert_eq!(m.sweep(1000), vec![Transition::Dead(0)]);
    }

    #[test]
    fn dead_is_absorbing_even_for_late_beacons() {
        let mut m = HeartbeatMonitor::new(1, policy(), 0);
        assert_eq!(m.sweep(400), vec![Transition::Dead(0)]);
        assert_eq!(m.observe(0, 401), None, "late beacon ignored");
        assert_eq!(m.health(0), Health::Dead);
        assert_eq!(m.sweep(800), vec![], "no repeated death events");
        assert_eq!(m.mark_dead(0), None, "EOF after death is idempotent");
    }

    #[test]
    fn steady_heartbeats_keep_everyone_healthy() {
        let mut m = HeartbeatMonitor::new(3, policy(), 0);
        for t in (50..1000).step_by(50) {
            for r in 0..3 {
                assert_eq!(m.observe(r, t), None);
            }
            assert_eq!(m.sweep(t), vec![]);
        }
        assert!((0..3).all(|r| m.health(r) == Health::Healthy));
    }

    #[test]
    fn eof_marks_dead_immediately() {
        let mut m = HeartbeatMonitor::new(2, policy(), 0);
        assert_eq!(m.mark_dead(1), Some(Transition::Dead(1)));
        assert_eq!(m.health(1), Health::Dead);
        assert_eq!(m.health(0), Health::Healthy);
    }
}

//! Length-framed wire protocol for the real (multi-process) deployment:
//! a versioned frame header plus byte-level encoders/decoders for the
//! compressed push-sum payloads of [`crate::gossip::Compression`].
//!
//! # Frame layout
//!
//! Every frame is a 4-byte little-endian body length followed by the
//! body; the body is a fixed 25-byte header, the payload, and a trailing
//! CRC-32 over everything before it:
//!
//! ```text
//! u32 body_len            # bytes that follow (header + payload + crc)
//! ── body ───────────────────────────────────────────────────────────
//! u16 magic   = 0x5347    # "SG"
//! u8  version = 1
//! u8  kind                # frame kind (join / assign / push / …)
//! u32 sender              # rank of the sender (u32::MAX = unassigned)
//! u64 round               # gossip round the frame belongs to
//! u8  scheme_tag          # Compression::wire_tag().0
//! u32 scheme_arg          # Compression::wire_tag().1
//! u32 payload_len
//! ..  payload             # kind-specific, see Frame
//! u32 crc                 # CRC-32 (IEEE) of body[..len-4]
//! ```
//!
//! The header is deliberately fixed-size so a reader can validate magic /
//! version / kind before trusting any length, and `body_len` is bounded
//! by [`MAX_BODY_BYTES`] so a corrupted length prefix can never trigger
//! an unbounded allocation.
//!
//! # Share encoding (the compressed payload bytes)
//!
//! [`encode_share`] / [`decode_share`] are the byte-level realization of
//! the bit-packed format that [`crate::gossip::Compression::encoded_bytes`]
//! charges in the simulator:
//!
//! * identity — `dim` little-endian fp32 values;
//! * top-k — `u32 count | u32 idx_bits | count × idx_bits-bit packed
//!   indices (ascending) | count × fp32 values`, where `idx_bits =
//!   ⌈log2 dim⌉` (min 1) and only coordinates with a non-zero bit
//!   pattern ship (so `count ≤ kept(dim)` after top-k selection);
//! * qsgd — `f32 scale | u32 count(= dim) | dim × bits-bit packed
//!   symbols`, each symbol a sign bit plus a `bits−1`-bit magnitude
//!   level; the decoder computes `±(level / levels) · scale` with the
//!   exact arithmetic of the simulator's quantizer, so decoding the
//!   bytes of an already-quantized share is bit-identical
//!   (`decode ∘ encode` is idempotent).
//!
//! All multi-byte integers are little-endian; bit-packing is LSB-first
//! within the byte stream. Decoders validate every length, index bound,
//! ordering and the CRC — malformed bytes produce a [`WireError`], never
//! a panic (pinned by `rust/tests/wire_roundtrip.rs`).

use crate::gossip::Compression;

/// Frame magic: "SG" little-endian.
pub const MAGIC: u16 = 0x5347;
/// Wire-protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Fixed body-header size in bytes (everything before the payload).
pub const HEADER_BYTES: usize = 25;
/// Upper bound on one frame body — a corrupted length prefix errors
/// instead of allocating gigabytes.
pub const MAX_BODY_BYTES: usize = 64 << 20;
/// Sender value of frames sent before a rank was assigned.
pub const UNASSIGNED: u32 = u32::MAX;

/// Errors produced by the framed codec. Every malformed input maps to a
/// variant here — the decoders never panic on wire bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Body did not start with [`MAGIC`].
    BadMagic(u16),
    /// Unknown protocol version.
    BadVersion(u8),
    /// Unknown frame kind byte.
    BadKind(u8),
    /// CRC mismatch (bit corruption somewhere in the body).
    BadCrc {
        /// CRC computed over the received body.
        computed: u32,
        /// CRC carried by the frame.
        carried: u32,
    },
    /// Length prefix exceeds [`MAX_BODY_BYTES`] or undershoots the
    /// fixed header.
    BadLength(usize),
    /// Unknown compression scheme tag/argument in the header.
    BadScheme {
        /// Scheme tag byte.
        tag: u8,
        /// Scheme argument.
        arg: u32,
    },
    /// Payload bytes inconsistent with the frame kind (short buffer,
    /// out-of-range index, bad count, …). The string names the check.
    BadPayload(&'static str),
    /// A stream ended with a partial frame still buffered.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::BadMagic(m) => write!(f, "bad frame magic {m:#06x}"),
            Self::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            Self::BadKind(k) => write!(f, "unknown frame kind {k}"),
            Self::BadCrc { computed, carried } => {
                write!(f, "crc mismatch: computed {computed:#010x}, frame carries {carried:#010x}")
            }
            Self::BadLength(n) => write!(f, "implausible frame body length {n}"),
            Self::BadScheme { tag, arg } => {
                write!(f, "unknown compression scheme tag {tag} arg {arg}")
            }
            Self::BadPayload(what) => write!(f, "malformed payload: {what}"),
            Self::TrailingBytes(n) => {
                write!(f, "stream ended mid-frame with {n} bytes buffered")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// CRC-32 (IEEE 802.3, reflected) nibble table.
const CRC_TABLE: [u32; 16] = {
    let mut t = [0u32; 16];
    let mut i = 0;
    while i < 16 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 4 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
};

/// CRC-32 (IEEE) of `bytes` — the checksum every frame carries.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xF) as usize] ^ (c >> 4);
        c = CRC_TABLE[((c ^ ((b as u32) >> 4)) & 0xF) as usize] ^ (c >> 4);
    }
    !c
}

// Frame-kind bytes.
const K_JOIN: u8 = 1;
const K_ASSIGN: u8 = 2;
const K_HEARTBEAT: u8 = 3;
const K_MEMBERSHIP: u8 = 4;
const K_PUSH: u8 = 5;
const K_DONE: u8 = 6;
const K_SHUTDOWN: u8 = 7;

/// A membership event as broadcast by the coordinator: the wire-level
/// mirror of [`crate::faults::MembershipEvent`], restricted to what a
/// live deployment can actually observe (plus the degraded/recovered
/// pair of the two-threshold heartbeat monitor).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireEvent {
    /// Worker declared dead: remove it from every survivor's schedule.
    Leave {
        /// Rank of the dead worker.
        rank: u32,
        /// Last gossip round the coordinator heard from it.
        at: u64,
    },
    /// Worker is slow but alive: keep it in the schedule, wait longer.
    Degraded {
        /// Rank of the slow worker.
        rank: u32,
        /// Round at which it was declared slow.
        at: u64,
    },
    /// A degraded worker caught up again: normal patience applies.
    Recovered {
        /// Rank of the recovered worker.
        rank: u32,
        /// Round at which it recovered.
        at: u64,
    },
}

impl WireEvent {
    /// The rank the event is about.
    pub fn rank(&self) -> u32 {
        match *self {
            Self::Leave { rank, .. }
            | Self::Degraded { rank, .. }
            | Self::Recovered { rank, .. } => rank,
        }
    }

    /// Short lowercase label (`"leave"`, `"degraded"`, `"recovered"`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Leave { .. } => "leave",
            Self::Degraded { .. } => "degraded",
            Self::Recovered { .. } => "recovered",
        }
    }
}

/// Everything a worker needs to run, pushed by the coordinator after all
/// registrations arrived (the rank/world assignment of the tentpole).
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    /// This worker's rank in `0..world`.
    pub rank: u32,
    /// Total number of workers.
    pub world: u32,
    /// Shared seed: quadratic centers, topology schedule.
    pub seed: u64,
    /// Total gossip rounds (gradient phase + dense cool-down).
    pub rounds: u64,
    /// Rounds of the trailing dense cool-down (no gradient, identity
    /// compression) that flushes error-feedback banks and drives the
    /// survivors to consensus.
    pub cooldown: u64,
    /// Share dimension.
    pub dim: u32,
    /// Step size of the local quadratic objective (0 disables the
    /// gradient entirely — pure push-sum averaging).
    pub lr: f32,
    /// Pacing: minimum milliseconds per gossip round.
    pub round_ms: u32,
    /// Read patience: milliseconds a worker waits for one round's
    /// expected in-neighbour messages before moving on.
    pub round_timeout_ms: u32,
    /// Gossip compression spec for the gradient phase.
    pub scheme: Compression,
    /// Gossip listen addresses of all workers, indexed by rank.
    pub peers: Vec<String>,
}

/// Final report a worker sends the coordinator after draining: its
/// push-sum state plus the mass-conservation ledger counters.
#[derive(Clone, Debug, PartialEq)]
pub struct DoneReport {
    /// Final push-sum weight (after re-absorbing banks).
    pub w: f64,
    /// Total push-sum weight received from peers.
    pub recv_w: f64,
    /// Total push-sum weight successfully sent to peers.
    pub sent_w: f64,
    /// Weight of failed sends re-absorbed locally (rescue mode).
    pub rescued_w: f64,
    /// Number of rescued (failed) sends.
    pub rescues: u32,
    /// Number of rounds that timed out waiting for an expected peer.
    pub timeouts: u32,
    /// Final numerator vector (biased; the consensus view is `x / w`).
    pub x: Vec<f32>,
}

/// One decoded frame body (the `kind`-specific part).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker → coordinator: register; `listen_port` is the worker's
    /// gossip listener on its source address.
    Join {
        /// TCP port the worker's gossip listener is bound to.
        listen_port: u16,
    },
    /// Coordinator → worker: rank/world assignment plus the run config.
    Assign(Assignment),
    /// Worker → coordinator: liveness beacon; the envelope round carries
    /// the worker's current gossip round.
    Heartbeat,
    /// Coordinator → workers: membership change broadcast.
    Membership(WireEvent),
    /// Worker → worker: one push-sum share. `share` is the bit-packed
    /// payload of [`encode_share`] under the envelope's scheme.
    Push {
        /// Push-sum weight share riding with the numerator (exact, never
        /// lossily encoded — 8 bytes against the compressed payload).
        w: f64,
        /// Encoded numerator share bytes.
        share: Vec<u8>,
    },
    /// Worker → coordinator: final state + ledger.
    Done(DoneReport),
    /// Coordinator → worker: run is over, exit cleanly.
    Shutdown,
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Self::Join { .. } => K_JOIN,
            Self::Assign(_) => K_ASSIGN,
            Self::Heartbeat => K_HEARTBEAT,
            Self::Membership(_) => K_MEMBERSHIP,
            Self::Push { .. } => K_PUSH,
            Self::Done(_) => K_DONE,
            Self::Shutdown => K_SHUTDOWN,
        }
    }
}

/// A frame plus its routing header: who sent it and for which round.
/// The compression scheme of `Push`/`Assign` frames rides in the header's
/// scheme fields and surfaces here as [`Envelope::scheme`].
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    /// Sender rank ([`UNASSIGNED`] before assignment).
    pub sender: u32,
    /// Gossip round the frame belongs to (0 where meaningless).
    pub round: u64,
    /// Compression scheme of the payload (identity for control frames).
    pub scheme: Compression,
    /// The decoded frame body.
    pub msg: Frame,
}

impl Envelope {
    /// A control envelope (identity scheme) from `sender` at `round`.
    pub fn control(sender: u32, round: u64, msg: Frame) -> Self {
        Self { sender, round, scheme: Compression::Identity, msg }
    }
}

// ---------------------------------------------------------------------
// Little-endian write helpers.

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    put_u16(out, b.len().min(u16::MAX as usize) as u16);
    out.extend_from_slice(&b[..b.len().min(u16::MAX as usize)]);
}

/// Bounds-checked little-endian reader over a payload slice.
struct Cursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, off: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .off
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or(WireError::BadPayload("payload shorter than a field"))?;
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| WireError::BadPayload("address is not utf-8"))
    }
    fn done(&self) -> Result<(), WireError> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(WireError::BadPayload("payload longer than its frame kind"))
        }
    }
}

// ---------------------------------------------------------------------
// Frame encode / decode.

/// Append the full wire bytes of `env` (length prefix included) to `out`.
pub fn encode_frame(env: &Envelope, out: &mut Vec<u8>) {
    let mut payload = Vec::new();
    match &env.msg {
        Frame::Join { listen_port } => put_u16(&mut payload, *listen_port),
        Frame::Assign(a) => {
            put_u32(&mut payload, a.rank);
            put_u32(&mut payload, a.world);
            put_u64(&mut payload, a.seed);
            put_u64(&mut payload, a.rounds);
            put_u64(&mut payload, a.cooldown);
            put_u32(&mut payload, a.dim);
            put_f32(&mut payload, a.lr);
            put_u32(&mut payload, a.round_ms);
            put_u32(&mut payload, a.round_timeout_ms);
            put_u32(&mut payload, a.peers.len() as u32);
            for p in &a.peers {
                put_str(&mut payload, p);
            }
        }
        Frame::Heartbeat | Frame::Shutdown => {}
        Frame::Membership(ev) => {
            let (code, rank, at) = match *ev {
                WireEvent::Leave { rank, at } => (0u8, rank, at),
                WireEvent::Degraded { rank, at } => (1, rank, at),
                WireEvent::Recovered { rank, at } => (2, rank, at),
            };
            payload.push(code);
            put_u32(&mut payload, rank);
            put_u64(&mut payload, at);
        }
        Frame::Push { w, share } => {
            put_f64(&mut payload, *w);
            payload.extend_from_slice(share);
        }
        Frame::Done(d) => {
            put_f64(&mut payload, d.w);
            put_f64(&mut payload, d.recv_w);
            put_f64(&mut payload, d.sent_w);
            put_f64(&mut payload, d.rescued_w);
            put_u32(&mut payload, d.rescues);
            put_u32(&mut payload, d.timeouts);
            put_u32(&mut payload, d.x.len() as u32);
            for &v in &d.x {
                put_f32(&mut payload, v);
            }
        }
    }

    // Assign frames carry the gradient-phase scheme; Push frames carry
    // the scheme their share bytes were encoded under.
    let scheme = match &env.msg {
        Frame::Assign(a) => a.scheme,
        _ => env.scheme,
    };
    let (tag, arg) = scheme.wire_tag();

    let body_len = HEADER_BYTES + payload.len() + 4;
    put_u32(out, body_len as u32);
    let body_start = out.len();
    put_u16(out, MAGIC);
    out.push(VERSION);
    out.push(env.msg.kind());
    put_u32(out, env.sender);
    put_u64(out, env.round);
    out.push(tag);
    put_u32(out, arg);
    put_u32(out, payload.len() as u32);
    out.extend_from_slice(&payload);
    let crc = crc32(&out[body_start..]);
    put_u32(out, crc);
}

fn decode_body(body: &[u8]) -> Result<Envelope, WireError> {
    debug_assert!(body.len() >= HEADER_BYTES + 4, "caller checks the length");
    let crc_off = body.len() - 4;
    let carried = u32::from_le_bytes(body[crc_off..].try_into().unwrap());
    let mut c = Cursor::new(&body[..crc_off]);
    let magic = c.u16()?;
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = c.u8()?;
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let kind = c.u8()?;
    let sender = c.u32()?;
    let round = c.u64()?;
    let tag = c.u8()?;
    let arg = c.u32()?;
    let payload_len = c.u32()? as usize;
    // Validate the CRC before interpreting the payload: a flipped bit in
    // any header field or the payload must surface as corruption, not as
    // a semantically different (but well-formed) frame.
    let computed = crc32(&body[..crc_off]);
    if computed != carried {
        return Err(WireError::BadCrc { computed, carried });
    }
    if payload_len != crc_off - HEADER_BYTES {
        return Err(WireError::BadPayload("payload length disagrees with frame length"));
    }
    let scheme =
        Compression::from_wire_tag(tag, arg).ok_or(WireError::BadScheme { tag, arg })?;
    let mut p = Cursor::new(c.take(payload_len)?);

    let msg = match kind {
        K_JOIN => Frame::Join { listen_port: p.u16()? },
        K_ASSIGN => {
            let rank = p.u32()?;
            let world = p.u32()?;
            let seed = p.u64()?;
            let rounds = p.u64()?;
            let cooldown = p.u64()?;
            let dim = p.u32()?;
            let lr = p.f32()?;
            let round_ms = p.u32()?;
            let round_timeout_ms = p.u32()?;
            let n = p.u32()? as usize;
            if n > (1 << 20) {
                return Err(WireError::BadPayload("implausible peer count"));
            }
            let mut peers = Vec::with_capacity(n);
            for _ in 0..n {
                peers.push(p.str()?);
            }
            Frame::Assign(Assignment {
                rank,
                world,
                seed,
                rounds,
                cooldown,
                dim,
                lr,
                round_ms,
                round_timeout_ms,
                scheme,
                peers,
            })
        }
        K_HEARTBEAT => Frame::Heartbeat,
        K_MEMBERSHIP => {
            let code = p.u8()?;
            let rank = p.u32()?;
            let at = p.u64()?;
            Frame::Membership(match code {
                0 => WireEvent::Leave { rank, at },
                1 => WireEvent::Degraded { rank, at },
                2 => WireEvent::Recovered { rank, at },
                _ => return Err(WireError::BadPayload("unknown membership event code")),
            })
        }
        K_PUSH => {
            let w = p.f64()?;
            let share = p.take(payload_len - 8)?.to_vec();
            Frame::Push { w, share }
        }
        K_DONE => {
            let w = p.f64()?;
            let recv_w = p.f64()?;
            let sent_w = p.f64()?;
            let rescued_w = p.f64()?;
            let rescues = p.u32()?;
            let timeouts = p.u32()?;
            let n = p.u32()? as usize;
            if n > MAX_BODY_BYTES / 4 {
                return Err(WireError::BadPayload("implausible state dimension"));
            }
            let mut x = Vec::with_capacity(n);
            for _ in 0..n {
                x.push(p.f32()?);
            }
            Frame::Done(DoneReport { w, recv_w, sent_w, rescued_w, rescues, timeouts, x })
        }
        K_SHUTDOWN => Frame::Shutdown,
        other => return Err(WireError::BadKind(other)),
    };
    p.done()?;
    Ok(Envelope { sender, round, scheme, msg })
}

/// Incremental frame parser: feed it bytes in arbitrary chunks (however
/// the socket delivered them) and pull complete frames out. Framing is a
/// pure function of the byte stream — any split of the same bytes yields
/// the same frame sequence (pinned by the round-trip fuzz tests).
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    /// An empty reader.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes from the stream.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Try to parse the next complete frame. `Ok(None)` means "need more
    /// bytes"; errors are sticky in the sense that the caller should drop
    /// the connection (resynchronizing a corrupted length-framed stream
    /// is not attempted).
    pub fn next_frame(&mut self) -> Result<Option<Envelope>, WireError> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let body_len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if body_len < HEADER_BYTES + 4 || body_len > MAX_BODY_BYTES {
            return Err(WireError::BadLength(body_len));
        }
        if self.buf.len() < 4 + body_len {
            return Ok(None);
        }
        let env = decode_body(&self.buf[4..4 + body_len])?;
        self.buf.drain(..4 + body_len);
        Ok(Some(env))
    }

    /// Bytes currently buffered (a partial frame, if non-zero).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Assert the stream ended cleanly: errors with
    /// [`WireError::TrailingBytes`] if a partial frame is still buffered
    /// (the truncated-stream case of the fuzz suite).
    pub fn finish(&self) -> Result<(), WireError> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(WireError::TrailingBytes(self.buf.len()))
        }
    }
}

// ---------------------------------------------------------------------
// Share (compressed payload) byte codecs.

/// LSB-first bit-packer: append `vals`, `bits` bits each, to `out`.
fn pack_bits(out: &mut Vec<u8>, vals: impl Iterator<Item = u32>, bits: u32) {
    debug_assert!((1..=32).contains(&bits));
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for v in vals {
        debug_assert!(bits == 32 || v < (1u32 << bits));
        acc |= (v as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
}

/// Inverse of [`pack_bits`]: read `count` values of `bits` bits each.
/// `None` if `bytes` is too short.
fn unpack_bits(bytes: &[u8], bits: u32, count: usize) -> Option<Vec<u32>> {
    debug_assert!((1..=32).contains(&bits));
    let need = (count as u64 * bits as u64).div_ceil(8) as usize;
    if bytes.len() < need {
        return None;
    }
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    let mut vals = Vec::with_capacity(count);
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut it = bytes.iter();
    for _ in 0..count {
        while nbits < bits {
            acc |= (*it.next()? as u64) << nbits;
            nbits += 8;
        }
        vals.push((acc & mask) as u32);
        acc >>= bits;
        nbits -= bits;
    }
    Some(vals)
}

/// Bits per packed top-k index for a `dim`-coordinate share:
/// `⌈log2 dim⌉`, min 1 — the same count
/// [`Compression::encoded_bytes`] charges.
fn index_bits(dim: usize) -> u32 {
    let d = dim.max(2) as u64;
    (u64::BITS - (d - 1).leading_zeros()).max(1)
}

/// QSGD magnitude levels for a `bits`-bit symbol (sign included) — the
/// same alphabet as the simulator's quantizer.
fn qsgd_levels(bits: u8) -> u32 {
    ((1u32 << bits.saturating_sub(1)) - 1).max(1)
}

/// Encode one share under `spec` into `out` (cleared first). The input
/// is expected to be the post-compression payload (what
/// `Compression::apply` produced): top-k shares are mostly zero, qsgd
/// shares are already on the quantization grid — for such inputs
/// [`decode_share`] reproduces the values bit-exactly.
pub fn encode_share(spec: Compression, x: &[f32], out: &mut Vec<u8>) {
    out.clear();
    match spec {
        Compression::Identity => {
            out.reserve(4 * x.len());
            for &v in x {
                put_f32(out, v);
            }
        }
        Compression::TopK { .. } => {
            // Ship every coordinate with a non-zero bit pattern (so an
            // explicit -0.0 survives); after top-k selection that is at
            // most `kept(dim)` entries.
            let nz: Vec<u32> = (0..x.len() as u32)
                .filter(|&i| x[i as usize].to_bits() != 0)
                .collect();
            put_u32(out, nz.len() as u32);
            let bits = index_bits(x.len());
            put_u32(out, bits);
            pack_bits(out, nz.iter().copied(), bits);
            for &i in &nz {
                put_f32(out, x[i as usize]);
            }
        }
        Compression::Qsgd { bits } => {
            let levels = qsgd_levels(bits);
            let scale = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = if scale.is_finite() { scale } else { 0.0 };
            put_f32(out, scale);
            put_u32(out, x.len() as u32);
            let lf = levels as f32;
            let sym = x.iter().map(|&v| {
                let sign = v.is_sign_negative() as u32;
                let level = if scale > 0.0 {
                    ((v.abs() / scale * lf).round() as u32).min(levels)
                } else {
                    0
                };
                sign | (level << 1)
            });
            pack_bits(out, sym, bits as u32);
        }
    }
}

/// Decode one `dim`-coordinate share encoded by [`encode_share`] under
/// `spec`. Validates every length, bound and ordering; malformed bytes
/// error, they never panic or read out of bounds.
pub fn decode_share(
    spec: Compression,
    dim: usize,
    bytes: &[u8],
) -> Result<Vec<f32>, WireError> {
    match spec {
        Compression::Identity => {
            if bytes.len() != 4 * dim {
                return Err(WireError::BadPayload("identity share length != 4·dim"));
            }
            let mut c = Cursor::new(bytes);
            (0..dim).map(|_| c.f32()).collect()
        }
        Compression::TopK { .. } => {
            let mut c = Cursor::new(bytes);
            let count = c.u32()? as usize;
            let bits = c.u32()?;
            if count > dim {
                return Err(WireError::BadPayload("top-k count exceeds dim"));
            }
            if bits != index_bits(dim) {
                return Err(WireError::BadPayload("top-k index width disagrees with dim"));
            }
            let packed = (count as u64 * bits as u64).div_ceil(8) as usize;
            let idx = unpack_bits(c.take(packed)?, bits, count)
                .ok_or(WireError::BadPayload("top-k index block too short"))?;
            let mut x = vec![0.0f32; dim];
            let mut prev: Option<u32> = None;
            for &i in &idx {
                if i as usize >= dim {
                    return Err(WireError::BadPayload("top-k index out of range"));
                }
                if prev.is_some_and(|p| p >= i) {
                    return Err(WireError::BadPayload("top-k indices not ascending"));
                }
                prev = Some(i);
                x[i as usize] = c.f32()?;
            }
            c.done()?;
            Ok(x)
        }
        Compression::Qsgd { bits } => {
            let mut c = Cursor::new(bytes);
            let scale = c.f32()?;
            if !scale.is_finite() || scale < 0.0 {
                return Err(WireError::BadPayload("qsgd scale not finite"));
            }
            let count = c.u32()? as usize;
            if count != dim {
                return Err(WireError::BadPayload("qsgd count != dim"));
            }
            let levels = qsgd_levels(bits);
            let lf = levels as f32;
            let sym = unpack_bits(c.take(c.b.len() - c.off)?, bits as u32, dim)
                .ok_or(WireError::BadPayload("qsgd symbol block too short"))?;
            let x = sym
                .iter()
                .map(|&s| {
                    let level = s >> 1;
                    if level > levels {
                        return Err(WireError::BadPayload("qsgd level out of range"));
                    }
                    // Exact mirror of the simulator's dequantization
                    // arithmetic — decoding an already-quantized share is
                    // bit-identical.
                    let q = level as f32 / lf * scale;
                    Ok(if s & 1 != 0 { -q } else { q })
                })
                .collect::<Result<Vec<f32>, WireError>>()?;
            Ok(x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_reference_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn control_frames_roundtrip() {
        let frames = vec![
            Envelope::control(UNASSIGNED, 0, Frame::Join { listen_port: 40123 }),
            Envelope::control(0, 7, Frame::Heartbeat),
            Envelope::control(
                0,
                9,
                Frame::Membership(WireEvent::Leave { rank: 2, at: 9 }),
            ),
            Envelope::control(
                0,
                9,
                Frame::Membership(WireEvent::Degraded { rank: 1, at: 4 }),
            ),
            Envelope::control(
                0,
                10,
                Frame::Membership(WireEvent::Recovered { rank: 1, at: 10 }),
            ),
            Envelope::control(3, 99, Frame::Shutdown),
            Envelope::control(
                2,
                100,
                Frame::Done(DoneReport {
                    w: 1.25,
                    recv_w: 3.5,
                    sent_w: 3.75,
                    rescued_w: 0.25,
                    rescues: 1,
                    timeouts: 2,
                    x: vec![1.0, -2.5, 0.0],
                }),
            ),
        ];
        let mut bytes = Vec::new();
        for f in &frames {
            encode_frame(f, &mut bytes);
        }
        let mut r = FrameReader::new();
        r.extend(&bytes);
        for f in &frames {
            assert_eq!(r.next_frame().unwrap().as_ref(), Some(f));
        }
        assert_eq!(r.next_frame().unwrap(), None);
        r.finish().unwrap();
    }

    #[test]
    fn assign_roundtrips_with_scheme_in_the_header() {
        let a = Assignment {
            rank: 3,
            world: 4,
            seed: 42,
            rounds: 400,
            cooldown: 100,
            dim: 32,
            lr: 0.05,
            round_ms: 2,
            round_timeout_ms: 250,
            scheme: Compression::TopK { den: 4 },
            peers: vec!["127.0.0.1:5000".into(), "127.0.0.1:5001".into()],
        };
        let env = Envelope {
            sender: UNASSIGNED,
            round: 0,
            scheme: a.scheme,
            msg: Frame::Assign(a.clone()),
        };
        let mut bytes = Vec::new();
        encode_frame(&env, &mut bytes);
        let mut r = FrameReader::new();
        r.extend(&bytes);
        let got = r.next_frame().unwrap().unwrap();
        assert_eq!(got.scheme, Compression::TopK { den: 4 });
        assert_eq!(got.msg, Frame::Assign(a));
    }

    #[test]
    fn corrupted_bytes_error_and_never_panic() {
        let env = Envelope {
            sender: 1,
            round: 5,
            scheme: Compression::Qsgd { bits: 4 },
            msg: Frame::Push { w: 0.5, share: vec![1, 2, 3, 4, 5, 6, 7, 8, 9] },
        };
        let mut bytes = Vec::new();
        encode_frame(&env, &mut bytes);
        // Flip every single byte position in turn: each variant must
        // decode to an error or (for length-prefix bytes) a partial
        // frame — never panic, never mis-decode silently as the original.
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            let mut r = FrameReader::new();
            r.extend(&bad);
            match r.next_frame() {
                Ok(Some(env2)) => assert_ne!(env2, env, "flip at {i} must not be silent"),
                Ok(None) | Err(_) => {}
            }
        }
    }

    #[test]
    fn truncated_streams_are_incomplete_not_panics() {
        let env = Envelope::control(0, 1, Frame::Heartbeat);
        let mut bytes = Vec::new();
        encode_frame(&env, &mut bytes);
        for cut in 0..bytes.len() {
            let mut r = FrameReader::new();
            r.extend(&bytes[..cut]);
            assert_eq!(r.next_frame().unwrap(), None, "cut at {cut}");
            if cut > 0 {
                assert!(matches!(r.finish(), Err(WireError::TrailingBytes(_))));
            }
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut r = FrameReader::new();
        r.extend(&(u32::MAX).to_le_bytes());
        assert!(matches!(r.next_frame(), Err(WireError::BadLength(_))));
        let mut r = FrameReader::new();
        r.extend(&3u32.to_le_bytes());
        assert!(matches!(r.next_frame(), Err(WireError::BadLength(3))));
    }

    #[test]
    fn identity_share_roundtrips_exactly() {
        let x = vec![1.5f32, -2.25, 0.0, -0.0, f32::MIN_POSITIVE];
        let mut b = Vec::new();
        encode_share(Compression::Identity, &x, &mut b);
        assert_eq!(b.len(), 4 * x.len());
        let y = decode_share(Compression::Identity, x.len(), &b).unwrap();
        for (a, c) in x.iter().zip(&y) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn topk_share_roundtrips_sparse_vectors_exactly() {
        let spec = Compression::TopK { den: 4 };
        let mut x = vec![0.0f32; 37];
        x[0] = 3.5;
        x[9] = -1.25;
        x[36] = -0.0; // negative zero has a non-zero bit pattern: ships.
        let mut b = Vec::new();
        encode_share(spec, &x, &mut b);
        let y = decode_share(spec, x.len(), &b).unwrap();
        assert_eq!(x.len(), y.len());
        for (a, c) in x.iter().zip(&y) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn topk_decoder_rejects_bad_indices() {
        let spec = Compression::TopK { den: 2 };
        let mut x = vec![0.0f32; 8];
        x[1] = 1.0;
        x[5] = 2.0;
        let mut b = Vec::new();
        encode_share(spec, &x, &mut b);
        // Claim a different dim: the index width disagrees.
        assert!(decode_share(spec, 1024, &b).is_err());
        // Truncate the value block.
        assert!(decode_share(spec, 8, &b[..b.len() - 1]).is_err());
        // Corrupt the count upward.
        let mut bad = b.clone();
        bad[0] = 200;
        assert!(decode_share(spec, 8, &bad).is_err());
    }

    #[test]
    fn qsgd_decode_encode_is_idempotent() {
        use crate::rng::Pcg;
        let spec = Compression::Qsgd { bits: 4 };
        let mut rng = Pcg::new(11);
        for _ in 0..50 {
            let x = rng.gaussian_vec(33);
            let mut b1 = Vec::new();
            encode_share(spec, &x, &mut b1);
            let d1 = decode_share(spec, x.len(), &b1).unwrap();
            let mut b2 = Vec::new();
            encode_share(spec, &d1, &mut b2);
            let d2 = decode_share(spec, x.len(), &b2).unwrap();
            for (a, c) in d1.iter().zip(&d2) {
                assert_eq!(a.to_bits(), c.to_bits(), "grid points must be fixed");
            }
        }
    }

    #[test]
    fn qsgd_share_bytes_match_the_simulator_accounting_scale() {
        // `bits` bits per coordinate plus the fixed header: the real
        // byte stream stays within a header's worth of the simulator's
        // `encoded_bytes` charge (which models an 8-byte header).
        let dim = 1024usize;
        let x = vec![0.5f32; dim];
        for bits in [2u8, 4, 8] {
            let spec = Compression::Qsgd { bits };
            let mut b = Vec::new();
            encode_share(spec, &x, &mut b);
            let packed = (dim * bits as usize).div_ceil(8);
            assert_eq!(b.len(), 8 + packed);
        }
    }

    #[test]
    fn qsgd_decoder_rejects_malformed_symbols() {
        let spec = Compression::Qsgd { bits: 3 };
        let x = vec![1.0f32, -0.5, 0.25, 0.0];
        let mut b = Vec::new();
        encode_share(spec, &x, &mut b);
        assert!(decode_share(spec, 8, &b).is_err(), "count mismatch");
        assert!(decode_share(spec, 4, &b[..b.len() - 1]).is_err(), "truncated");
        let mut bad = b.clone();
        bad[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode_share(spec, 4, &bad).is_err(), "non-finite scale");
    }

    #[test]
    fn negative_zero_survives_qsgd_roundtrip() {
        let spec = Compression::Qsgd { bits: 4 };
        let x = vec![-0.0f32, 1.0];
        let mut b = Vec::new();
        encode_share(spec, &x, &mut b);
        let y = decode_share(spec, 2, &b).unwrap();
        assert!(y[0] == 0.0 && y[0].is_sign_negative(), "sign bit shipped");
        assert_eq!(y[1], 1.0, "max coordinate is exact");
    }

    #[test]
    fn bit_packing_roundtrips_all_widths() {
        for bits in 1..=32u32 {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let vals: Vec<u32> =
                (0..17u32).map(|i| i.wrapping_mul(0x9E37_79B9) & mask).collect();
            let mut out = Vec::new();
            pack_bits(&mut out, vals.iter().copied(), bits);
            assert_eq!(out.len(), (vals.len() as u64 * bits as u64).div_ceil(8) as usize);
            let back = unpack_bits(&out, bits, vals.len()).unwrap();
            assert_eq!(back, vals);
            assert!(unpack_bits(&out[..out.len() - 1], bits, vals.len()).is_none());
        }
    }
}

//! Real multi-process deployment: a TCP coordinator plus gossip workers
//! speaking the compressed push-sum wire protocol.
//!
//! Everything else in this crate *simulates* the cluster ([`super::TimingSim`]
//! stays the default path — it is deterministic and fast). This subsystem is
//! the one place where the same algorithm runs over actual sockets:
//!
//! * [`wire`] — the length-framed, CRC-checked wire format. Payloads are the
//!   bit-packed encodings of [`crate::gossip::Compression`] shares, so the
//!   bytes saved by top-k / QSGD in the simulator are the bytes saved on the
//!   wire.
//! * [`coord`] — `repro coord`: registration, rank assignment, heartbeat
//!   tracking, membership broadcasts, and the end-of-run consensus + ledger
//!   audit.
//! * [`worker`] — `repro worker`: the per-process push-sum gossip loop with
//!   error-feedback banks, rescue-mode mass re-absorption on failed sends,
//!   and survivor schedule re-indexing on membership events.
//! * [`heartbeat`] — the two-threshold (slow vs dead) liveness monitor.
//!
//! Determinism caveat: unlike the simulator, real sockets deliver messages
//! with arbitrary timing, so runs are *not* bit-reproducible — correctness
//! is asserted through invariants (mass conservation, consensus spread)
//! rather than byte-identical trajectories. See ARCHITECTURE.md
//! ("Deployment layer") for the process diagram and header layout.

pub mod coord;
pub mod heartbeat;
pub mod wire;
pub mod worker;

pub use coord::{run_coordinator, CoordConfig, CoordSummary};
pub use heartbeat::{Health, HeartbeatMonitor, HeartbeatPolicy, Transition};
pub use worker::{run_worker, WorkerConfig, WorkerReport};

//! The deployment coordinator behind `repro coord`: worker registration,
//! rank/world-size assignment, heartbeat-driven membership tracking, and
//! the end-of-run consensus/ledger audit.
//!
//! Control plane only — gossip shares flow worker-to-worker; the
//! coordinator never touches a payload. Its job:
//!
//! 1. **Registration.** Accept TCP connections until `world` workers
//!    have sent `Join{listen_port}`; ranks are assigned in join order and
//!    every worker receives an [`Assignment`] carrying the full peer
//!    address table plus the run configuration (seed, rounds, dimension,
//!    compression scheme) — one source of truth, so every process draws
//!    identical quadratic centers and schedules.
//! 2. **Liveness.** Feed worker heartbeats into the two-threshold
//!    [`HeartbeatMonitor`]: silence past the slow threshold degrades a
//!    worker (broadcast — peers wait longer for it), silence past the
//!    dead threshold (or a closed connection, which is stronger
//!    evidence) evicts it with a `Leave` membership broadcast — the
//!    deployment analogue of [`crate::faults::MembershipEvent::Leave`] —
//!    after which survivors re-index their gossip schedules.
//! 3. **Audit.** Collect each survivor's [`DoneReport`] and check the
//!    mass-conservation ledger `w = 1 + w_recv − w_sent` per worker,
//!    compute the de-biased consensus mean and relative spread, and
//!    write a machine-readable summary JSON plus a JSONL membership
//!    event log (the loopback integration test and the CI `deploy-smoke`
//!    job assert on both).
//!
//! Every socket operation and the run as a whole are deadline-bounded:
//! a wedged worker can degrade the numbers, never hang the coordinator.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::faults::MembershipEvent;
use crate::gossip::Compression;
use crate::obs::trace::{TraceWriter, GLOBAL_RANK, SUMMARY_SCHEMA_VERSION};

use super::heartbeat::{Health, HeartbeatMonitor, HeartbeatPolicy, Transition};
use super::wire::{self, Assignment, DoneReport, Envelope, Frame, FrameReader, WireEvent};

/// Everything `repro coord` needs for one deployment run.
#[derive(Clone, Debug)]
pub struct CoordConfig {
    /// Listen address (`127.0.0.1:0` = pick a free port).
    pub bind: String,
    /// Number of workers to wait for.
    pub world: usize,
    /// Total gossip rounds per worker (including the cool-down tail).
    pub rounds: u64,
    /// Trailing dense no-gradient rounds (consensus tail).
    pub cooldown: u64,
    /// Share dimension.
    pub dim: usize,
    /// Shared seed (centers + schedule).
    pub seed: u64,
    /// Quadratic step size (0 = pure averaging).
    pub lr: f32,
    /// Gossip compression for the gradient phase.
    pub scheme: Compression,
    /// Worker pacing: minimum milliseconds per round.
    pub round_ms: u32,
    /// Worker patience: milliseconds to wait for one round's expected
    /// in-neighbour messages.
    pub round_timeout_ms: u32,
    /// Heartbeat thresholds (slow vs dead).
    pub hb: HeartbeatPolicy,
    /// Hard wall-clock bound on the whole run, seconds.
    pub deadline_s: u64,
    /// If set, the bound port is written here (atomically) once the
    /// listener is up — how spawning harnesses discover the port.
    pub port_file: Option<PathBuf>,
    /// Membership event log (JSONL, streamed — survives a kill). The
    /// format is the versioned [`crate::obs::trace`] schema, readable by
    /// `repro trace`.
    pub log_path: PathBuf,
    /// End-of-run summary JSON.
    pub summary_path: PathBuf,
    /// When set, the coordinator persists a durable run manifest
    /// (`run_manifest.json`) here at assignment time and rewrites it on
    /// every membership change: world size, seed, scheme, rounds, the
    /// peer table and the current survivor set — everything a restarted
    /// fleet needs to resume compatibly with the workers' own snapshot
    /// files (see [`super::worker::WorkerConfig::checkpoint_dir`]). Each
    /// write is logged as a `snapshot` trace event. Checkpoint I/O is
    /// best-effort: a write failure degrades to a stderr note, it never
    /// kills the run.
    pub checkpoint_dir: Option<PathBuf>,
    /// Mirror structured events as human-readable stderr lines.
    pub verbose: bool,
}

impl Default for CoordConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:0".to_string(),
            world: 4,
            rounds: 400,
            cooldown: 100,
            dim: 32,
            seed: 1,
            lr: 0.05,
            scheme: Compression::Identity,
            round_ms: 2,
            round_timeout_ms: 250,
            hb: HeartbeatPolicy::default(),
            deadline_s: 120,
            port_file: None,
            log_path: PathBuf::from("results/deploy/membership.jsonl"),
            summary_path: PathBuf::from("results/deploy/summary.json"),
            checkpoint_dir: None,
            verbose: false,
        }
    }
}

/// One membership-log record (also embedded in the summary JSON).
#[derive(Clone, Debug)]
pub struct EventRecord {
    /// Milliseconds since the coordinator started.
    pub t_ms: u64,
    /// Event kind (`join`, `assign`, `degraded`, `recovered`, `leave`,
    /// `done`, `deadline`, `dim_mismatch`, `audit`).
    pub kind: String,
    /// Rank the event is about (`u32::MAX` for group-wide events).
    pub rank: u32,
    /// Gossip round the event refers to (the rank's last reported round
    /// for liveness events, 0 during registration).
    pub round: u64,
}

/// Per-survivor audit row.
#[derive(Clone, Debug)]
pub struct WorkerAudit {
    /// Worker rank.
    pub rank: u32,
    /// Its final report.
    pub report: DoneReport,
    /// `w − (1 + recv_w − sent_w)` — zero up to f64 round-off when the
    /// push-sum mass ledger balances.
    pub ledger_residual: f64,
}

/// End-of-run audit: consensus + ledger over the survivors.
#[derive(Clone, Debug)]
pub struct CoordSummary {
    /// Port the coordinator listened on.
    pub port: u16,
    /// Configured world size.
    pub world: usize,
    /// Ranks that finished alive (sent a `Done` report).
    pub survivors: Vec<u32>,
    /// De-biased consensus mean over the survivors.
    pub mean: Vec<f64>,
    /// Max relative consensus spread `‖z_i − z̄‖ / max(‖z̄‖, ε)`.
    pub spread: f64,
    /// Push-sum weight missing from the group: `world − Σ w_i` over
    /// survivors — ≈ 0 for a clean run, ≈ the dead workers' held mass
    /// after a kill.
    pub missing_w: f64,
    /// Largest per-survivor ledger residual (absolute).
    pub max_ledger_residual: f64,
    /// Per-survivor audit rows.
    pub workers: Vec<WorkerAudit>,
    /// Membership events in order.
    pub events: Vec<EventRecord>,
}

/// Open the streamed JSONL event log as an [`crate::obs::trace`] writer
/// (best-effort: I/O errors degrade to a stderr note and a disabled
/// writer, they never kill the run).
fn open_event_log(path: &Path, world: usize, rounds: u64) -> TraceWriter {
    match TraceWriter::create(path, "coord", world, rounds) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("[coord] cannot open event log {}: {e}", path.display());
            TraceWriter::disabled()
        }
    }
}

enum Inbox {
    Frame(Envelope),
    Eof,
}

/// Read frames from one worker's control stream into the channel until
/// EOF or a decode error (both reported as `Eof` — for liveness they
/// mean the same thing: this stream is done).
fn reader_loop(mut stream: TcpStream, rank: usize, tx: mpsc::Sender<(usize, Inbox)>) {
    let mut fr = FrameReader::new();
    let mut buf = [0u8; 16 * 1024];
    'outer: loop {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                fr.extend(&buf[..n]);
                loop {
                    match fr.next_frame() {
                        Ok(None) => break,
                        Err(_) => break 'outer,
                        Ok(Some(env)) => {
                            if tx.send((rank, Inbox::Frame(env))).is_err() {
                                return;
                            }
                        }
                    }
                }
            }
        }
    }
    let _ = tx.send((rank, Inbox::Eof));
}

/// What one freshly-accepted registration-phase connection turned out
/// to be.
enum RegConn {
    /// A worker `Join` carrying its gossip listen port.
    Join(u16),
    /// An HTTP scrape (`GET …`) — the caller serves a metrics snapshot.
    Scrape,
    /// Closed, timed out, or sent garbage before completing a Join.
    Stray,
}

/// Classify one accepted registration-phase connection. The listener
/// doubles as the `/metrics` endpoint, so what connects here may be a
/// worker, a Prometheus scraper, or a stray socket — the first four
/// bytes decide (a framed `Join` starts with a small little-endian
/// length prefix, never the ASCII `GET `). The wait is bounded by the
/// **per-connection** `deadline` and every non-Join outcome is reported
/// to the caller, never propagated as an error: a scraper or a wedged
/// socket must not abort registration or eat the global window.
fn classify_reg_conn(stream: &mut TcpStream, deadline: Instant) -> RegConn {
    let mut fr = FrameReader::new();
    let mut buf = [0u8; 4096];
    let mut head = [0u8; 4];
    let mut head_len = 0usize;
    let mut sniffed = false;
    loop {
        if sniffed {
            match fr.next_frame() {
                Ok(Some(env)) => {
                    if let Frame::Join { listen_port } = env.msg {
                        return RegConn::Join(listen_port);
                    }
                    continue; // ignore anything else pre-join
                }
                Ok(None) => {}
                Err(_) => return RegConn::Stray,
            }
        }
        if Instant::now() >= deadline {
            return RegConn::Stray;
        }
        match stream.read(&mut buf) {
            Ok(0) => return RegConn::Stray,
            Ok(n) => {
                if sniffed {
                    fr.extend(&buf[..n]);
                } else {
                    let take = (4 - head_len).min(n);
                    head[head_len..head_len + take].copy_from_slice(&buf[..take]);
                    head_len += take;
                    if head_len == 4 {
                        if head == *b"GET " {
                            return RegConn::Scrape;
                        }
                        sniffed = true;
                        fr.extend(&head);
                        fr.extend(&buf[take..n]);
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return RegConn::Stray,
        }
    }
}

/// Write (atomically: tmp + rename) the durable run manifest a restarted
/// fleet resumes from: the full assignment-time configuration plus the
/// current survivor set. Best-effort by contract — any I/O failure is
/// reported to stderr and swallowed, because losing a bookkeeping
/// checkpoint must never take down a live run.
fn write_run_manifest(
    dir: &Path,
    cfg: &CoordConfig,
    port: u16,
    peers: &[String],
    dead: &[bool],
) {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema_version\": {SUMMARY_SCHEMA_VERSION},\n"));
    out.push_str(&format!("  \"port\": {port},\n"));
    out.push_str(&format!("  \"world\": {},\n", cfg.world));
    out.push_str(&format!("  \"rounds\": {},\n", cfg.rounds));
    out.push_str(&format!("  \"cooldown\": {},\n", cfg.cooldown.min(cfg.rounds)));
    out.push_str(&format!("  \"dim\": {},\n", cfg.dim));
    out.push_str(&format!("  \"seed\": {},\n", cfg.seed));
    out.push_str(&format!("  \"lr\": {:e},\n", cfg.lr));
    out.push_str(&format!("  \"scheme\": \"{}\",\n", cfg.scheme.label()));
    out.push_str(&format!("  \"round_ms\": {},\n", cfg.round_ms));
    out.push_str(&format!("  \"round_timeout_ms\": {},\n", cfg.round_timeout_ms));
    let peer_list: Vec<String> = peers.iter().map(|p| format!("\"{p}\"")).collect();
    out.push_str(&format!("  \"peers\": [{}],\n", peer_list.join(",")));
    let alive: Vec<String> = (0..cfg.world)
        .filter(|&r| !dead.get(r).copied().unwrap_or(false))
        .map(|r| r.to_string())
        .collect();
    out.push_str(&format!("  \"alive\": [{}]\n", alive.join(",")));
    out.push_str("}\n");
    let write = || -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("run_manifest.json");
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, &out)?;
        std::fs::rename(&tmp, &path)
    };
    if let Err(e) = write() {
        eprintln!("[coord] run-manifest checkpoint failed: {e} ({})", dir.display());
    }
}

fn write_port_file(path: &Path, port: u16) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, format!("{port}\n"))
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

fn l2(v: &[f64]) -> f64 {
    v.iter().map(|a| a * a).sum::<f64>().sqrt()
}

/// Run the coordinator to completion: register `world` workers, track
/// liveness, broadcast membership changes, audit the final reports.
/// Deadline-bounded end to end.
pub fn run_coordinator(cfg: &CoordConfig) -> Result<CoordSummary> {
    let io_timeout = Duration::from_millis(5000);
    let start = Instant::now();
    let now_ms = move || start.elapsed().as_millis() as u64;
    let mut log = open_event_log(&cfg.log_path, cfg.world, cfg.rounds);
    let mut events: Vec<EventRecord> = Vec::new();
    let record = |log: &mut TraceWriter,
                      events: &mut Vec<EventRecord>,
                      t_ms: u64,
                      kind: &str,
                      rank: u32,
                      round: u64,
                      extras: &[(&str, f64)]| {
        log.event(t_ms, kind, rank, round, extras);
        events.push(EventRecord { t_ms, kind: kind.to_string(), rank, round });
    };

    let listener =
        TcpListener::bind(&cfg.bind).with_context(|| format!("binding {}", cfg.bind))?;
    let port = listener.local_addr()?.port();
    if let Some(pf) = &cfg.port_file {
        write_port_file(pf, port)?;
    }
    if cfg.verbose {
        eprintln!("[coord] listening on port {port}, waiting for {} workers", cfg.world);
    }

    // --- Registration: accept until `world` Joins, rank = join order. --
    // The listener is also the `/metrics` endpoint, so a scraper may
    // connect before the workers do: each accepted connection is
    // classified (Join / scrape / stray) under its own short deadline —
    // only a completed Join consumes a rank, and nothing a non-worker
    // does can abort registration or exhaust the global window.
    listener.set_nonblocking(true)?;
    let reg_deadline = Instant::now() + Duration::from_secs(60);
    let mut joined: Vec<(TcpStream, String)> = Vec::new();
    while joined.len() < cfg.world {
        match listener.accept() {
            Ok((mut s, peer)) => {
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(Duration::from_millis(200)))?;
                s.set_write_timeout(Some(io_timeout))?;
                let conn_deadline =
                    (Instant::now() + Duration::from_secs(5)).min(reg_deadline);
                match classify_reg_conn(&mut s, conn_deadline) {
                    RegConn::Join(lp) => {
                        let rank = joined.len() as u32;
                        let addr = format!("{}:{}", peer.ip(), lp);
                        if cfg.verbose {
                            eprintln!("[coord] rank {rank} joined from {addr}");
                        }
                        record(&mut log, &mut events, now_ms(), "join", rank, 0, &[]);
                        joined.push((s, addr));
                    }
                    RegConn::Scrape => {
                        let body = reg_metrics_body(cfg.world, joined.len(), now_ms());
                        std::thread::spawn(move || write_http_ok(s, &body));
                    }
                    RegConn::Stray => {
                        if cfg.verbose {
                            eprintln!(
                                "[coord] dropping stray connection from {peer} \
                                 during registration"
                            );
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if Instant::now() >= reg_deadline {
                    bail!(
                        "registration timed out with {}/{} workers joined",
                        joined.len(),
                        cfg.world
                    );
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accepting worker connections"),
        }
    }

    // --- Assignment + reader threads. ---------------------------------
    let peers: Vec<String> = joined.iter().map(|(_, a)| a.clone()).collect();
    let (tx, rx) = mpsc::channel::<(usize, Inbox)>();
    let mut streams: Vec<TcpStream> = Vec::with_capacity(cfg.world);
    let mut frame_buf = Vec::new();
    for (rank, (stream, _)) in joined.into_iter().enumerate() {
        let assign = Assignment {
            rank: rank as u32,
            world: cfg.world as u32,
            seed: cfg.seed,
            rounds: cfg.rounds,
            cooldown: cfg.cooldown.min(cfg.rounds),
            dim: cfg.dim as u32,
            lr: cfg.lr,
            round_ms: cfg.round_ms,
            round_timeout_ms: cfg.round_timeout_ms,
            scheme: cfg.scheme,
            peers: peers.clone(),
        };
        frame_buf.clear();
        wire::encode_frame(
            &Envelope {
                sender: wire::UNASSIGNED,
                round: 0,
                scheme: cfg.scheme,
                msg: Frame::Assign(assign),
            },
            &mut frame_buf,
        );
        let mut stream = stream;
        stream
            .write_all(&frame_buf)
            .with_context(|| format!("sending Assign to rank {rank}"))?;
        let rd = stream.try_clone()?;
        rd.set_read_timeout(None)?;
        let tx = tx.clone();
        std::thread::spawn(move || reader_loop(rd, rank, tx));
        streams.push(stream);
    }
    drop(tx);
    record(&mut log, &mut events, now_ms(), "assign", GLOBAL_RANK, 0, &[]);
    if let Some(dir) = &cfg.checkpoint_dir {
        write_run_manifest(dir, cfg, port, &peers, &vec![false; cfg.world]);
        record(
            &mut log,
            &mut events,
            now_ms(),
            "snapshot",
            GLOBAL_RANK,
            0,
            &[("members", cfg.world as f64)],
        );
    }
    if cfg.verbose {
        eprintln!("[coord] all {} workers assigned; run started", cfg.world);
    }

    // --- Liveness loop: heartbeats in, membership broadcasts out. -----
    let mut monitor = HeartbeatMonitor::new(cfg.world, cfg.hb, now_ms());
    let mut last_round = vec![0u64; cfg.world];
    let mut done: Vec<Option<DoneReport>> = vec![None; cfg.world];
    let mut dead = vec![false; cfg.world];
    let run_deadline = start + Duration::from_secs(cfg.deadline_s.max(1));
    let mut deadline_hit = false;

    let broadcast = |streams: &mut [TcpStream], dead: &[bool], ev: WireEvent| {
        let mut buf = Vec::new();
        wire::encode_frame(
            &Envelope::control(wire::UNASSIGNED, 0, Frame::Membership(ev)),
            &mut buf,
        );
        for (r, s) in streams.iter_mut().enumerate() {
            if !dead[r] && r as u32 != ev.rank() {
                let _ = s.write_all(&buf);
            }
        }
    };

    loop {
        if (0..cfg.world).all(|r| dead[r] || done[r].is_some()) {
            break;
        }
        if Instant::now() >= run_deadline {
            deadline_hit = true;
            record(&mut log, &mut events, now_ms(), "deadline", GLOBAL_RANK, 0, &[]);
            break;
        }

        // The registration listener doubles as a plaintext Prometheus
        // endpoint for the rest of the run: any connection accepted here
        // that opens with `GET ` receives a `/metrics` snapshot. The
        // snapshot is rendered here (cheap string build) but all socket
        // I/O happens on a throwaway thread — a slow or reconnect-looping
        // scraper must never delay heartbeat processing, or it could
        // push healthy workers over the slow/dead thresholds itself.
        if let Ok((stream, _)) = listener.accept() {
            let body =
                metrics_body(cfg.world, now_ms(), events.len(), &monitor, &dead, &done, &last_round);
            std::thread::spawn(move || serve_metrics(stream, &body));
        }

        let mut transitions: Vec<Transition> = Vec::new();
        match rx.recv_timeout(Duration::from_millis(25)) {
            Ok((rank, Inbox::Frame(env))) => {
                if let Some(t) = monitor.observe(rank, now_ms()) {
                    transitions.push(t);
                }
                match env.msg {
                    Frame::Heartbeat => last_round[rank] = env.round,
                    Frame::Done(d) => {
                        last_round[rank] = env.round;
                        if cfg.verbose {
                            eprintln!(
                                "[coord] rank {rank} done at round {}: w={:.6}",
                                env.round, d.w
                            );
                        }
                        // The full ledger rides in the trace so `repro
                        // trace` can re-derive the audit offline.
                        record(
                            &mut log,
                            &mut events,
                            now_ms(),
                            "done",
                            rank as u32,
                            env.round,
                            &[
                                ("w", d.w),
                                ("recv_w", d.recv_w),
                                ("sent_w", d.sent_w),
                                ("rescued_w", d.rescued_w),
                                ("rescues", d.rescues as f64),
                                ("timeouts", d.timeouts as f64),
                                ("ledger_residual", d.w - (1.0 + d.recv_w - d.sent_w)),
                            ],
                        );
                        done[rank] = Some(d);
                    }
                    _ => {}
                }
            }
            Ok((rank, Inbox::Eof)) => {
                // A closed control stream is stronger evidence than
                // silence — unless the worker already reported Done
                // (normal teardown).
                if done[rank].is_none() {
                    if let Some(t) = monitor.mark_dead(rank) {
                        transitions.push(t);
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        transitions.extend(monitor.sweep(now_ms()));

        for t in transitions {
            match t {
                Transition::Degraded(r) if done[r].is_none() && !dead[r] => {
                    if cfg.verbose {
                        eprintln!("[coord] rank {r} is slow (degraded)");
                    }
                    record(&mut log, &mut events, now_ms(), "degraded", r as u32, last_round[r], &[]);
                    broadcast(
                        &mut streams,
                        &dead,
                        WireEvent::Degraded { rank: r as u32, at: last_round[r] },
                    );
                }
                Transition::Recovered(r) if done[r].is_none() && !dead[r] => {
                    if cfg.verbose {
                        eprintln!("[coord] rank {r} recovered");
                    }
                    record(&mut log, &mut events, now_ms(), "recovered", r as u32, last_round[r], &[]);
                    broadcast(
                        &mut streams,
                        &dead,
                        WireEvent::Recovered { rank: r as u32, at: last_round[r] },
                    );
                }
                Transition::Dead(r) if done[r].is_none() && !dead[r] => {
                    dead[r] = true;
                    // The canonical membership event the simulator's
                    // fault layer would have scheduled — here it is
                    // observed instead of injected.
                    let ev = MembershipEvent::Leave { node: r, at: last_round[r] };
                    if cfg.verbose {
                        eprintln!(
                            "[coord] rank {} declared dead at round {} — broadcasting {}",
                            ev.node(),
                            ev.at(),
                            ev.label()
                        );
                    }
                    record(&mut log, &mut events, now_ms(), ev.label(), r as u32, ev.at(), &[]);
                    broadcast(
                        &mut streams,
                        &dead,
                        WireEvent::Leave { rank: r as u32, at: last_round[r] },
                    );
                    // Membership changed → refresh the durable run
                    // manifest so a fleet restarted from the checkpoint
                    // resumes over the survivor set.
                    if let Some(dir) = &cfg.checkpoint_dir {
                        write_run_manifest(dir, cfg, port, &peers, &dead);
                        let members =
                            dead.iter().filter(|&&d| !d).count() as f64;
                        record(
                            &mut log,
                            &mut events,
                            now_ms(),
                            "snapshot",
                            GLOBAL_RANK,
                            last_round[r],
                            &[("members", members)],
                        );
                    }
                }
                _ => {}
            }
        }
    }

    // --- Teardown + audit. --------------------------------------------
    {
        let mut buf = Vec::new();
        wire::encode_frame(
            &Envelope::control(wire::UNASSIGNED, 0, Frame::Shutdown),
            &mut buf,
        );
        for (r, s) in streams.iter_mut().enumerate() {
            if !dead[r] {
                let _ = s.write_all(&buf);
            }
        }
    }

    if deadline_hit {
        let missing: Vec<usize> =
            (0..cfg.world).filter(|&r| !dead[r] && done[r].is_none()).collect();
        bail!(
            "run deadline ({}s) exceeded with workers {missing:?} unfinished \
             (membership log: {})",
            cfg.deadline_s,
            cfg.log_path.display()
        );
    }

    let mut workers: Vec<WorkerAudit> = Vec::new();
    for (r, d) in done.iter().enumerate() {
        let (Some(rep), false) = (d, dead[r]) else { continue };
        if rep.x.len() != cfg.dim {
            if cfg.verbose {
                eprintln!(
                    "[coord] rank {r} reported dim {} != configured {}; excluding",
                    rep.x.len(),
                    cfg.dim
                );
            }
            record(
                &mut log,
                &mut events,
                now_ms(),
                "dim_mismatch",
                r as u32,
                last_round[r],
                &[("got", rep.x.len() as f64), ("want", cfg.dim as f64)],
            );
            continue;
        }
        let ledger_residual = rep.w - (1.0 + rep.recv_w - rep.sent_w);
        workers.push(WorkerAudit { rank: r as u32, report: rep.clone(), ledger_residual });
    }
    if workers.is_empty() {
        bail!("no surviving worker reported a final state");
    }

    let m = workers.len() as f64;
    let mut mean = vec![0.0f64; cfg.dim];
    for a in &workers {
        for (acc, v) in mean.iter_mut().zip(&a.report.x) {
            *acc += *v as f64 / a.report.w / m;
        }
    }
    let mean_norm = l2(&mean).max(1e-12);
    let spread = workers
        .iter()
        .map(|a| {
            let d: Vec<f64> = a
                .report
                .x
                .iter()
                .zip(&mean)
                .map(|(v, mu)| *v as f64 / a.report.w - mu)
                .collect();
            l2(&d) / mean_norm
        })
        .fold(0.0f64, f64::max);
    let missing_w = cfg.world as f64 - workers.iter().map(|a| a.report.w).sum::<f64>();
    let max_ledger_residual =
        workers.iter().map(|a| a.ledger_residual.abs()).fold(0.0f64, f64::max);

    record(
        &mut log,
        &mut events,
        now_ms(),
        "audit",
        GLOBAL_RANK,
        cfg.rounds,
        &[
            ("world", cfg.world as f64),
            ("survivors", workers.len() as f64),
            ("missing_w", missing_w),
            ("max_ledger_residual", max_ledger_residual),
            ("spread", spread),
        ],
    );

    let summary = CoordSummary {
        port,
        world: cfg.world,
        survivors: workers.iter().map(|a| a.rank).collect(),
        mean,
        spread,
        missing_w,
        max_ledger_residual,
        workers,
        events,
    };
    write_summary(&cfg.summary_path, &summary)?;
    if cfg.verbose {
        eprintln!(
            "[coord] audit: survivors={:?} spread={:.3e} missing_w={:.6} \
             max_ledger_residual={:.3e}",
            summary.survivors, summary.spread, summary.missing_w, summary.max_ledger_residual
        );
    }
    Ok(summary)
}

/// Render the current run state as a plaintext Prometheus exposition.
/// Health encoding: 0 = healthy, 1 = degraded, 2 = dead, and a separate
/// `sgp_worker_done` flag once a rank's final report is in.
fn metrics_body(
    world: usize,
    uptime_ms: u64,
    events_total: usize,
    monitor: &HeartbeatMonitor,
    dead: &[bool],
    done: &[Option<DoneReport>],
    last_round: &[u64],
) -> String {
    let mut b = String::new();
    b.push_str("# TYPE sgp_coord_world gauge\n");
    let _ = writeln!(b, "sgp_coord_world {world}");
    b.push_str("# TYPE sgp_coord_uptime_ms counter\n");
    let _ = writeln!(b, "sgp_coord_uptime_ms {uptime_ms}");
    b.push_str("# TYPE sgp_coord_events_total counter\n");
    let _ = writeln!(b, "sgp_coord_events_total {events_total}");
    b.push_str("# TYPE sgp_worker_health gauge\n");
    for r in 0..world {
        let h = if dead[r] {
            2
        } else {
            match monitor.health(r) {
                Health::Healthy => 0,
                Health::Degraded => 1,
                Health::Dead => 2,
            }
        };
        let _ = writeln!(b, "sgp_worker_health{{rank=\"{r}\"}} {h}");
    }
    b.push_str("# TYPE sgp_worker_last_round gauge\n");
    for (r, k) in last_round.iter().enumerate() {
        let _ = writeln!(b, "sgp_worker_last_round{{rank=\"{r}\"}} {k}");
    }
    b.push_str("# TYPE sgp_worker_done gauge\n");
    for (r, d) in done.iter().enumerate() {
        let _ = writeln!(b, "sgp_worker_done{{rank=\"{r}\"}} {}", u8::from(d.is_some()));
    }
    b
}

/// The reduced metrics snapshot served while registration is still in
/// progress, before any per-worker state exists: world size, uptime,
/// and join progress.
fn reg_metrics_body(world: usize, joined: usize, uptime_ms: u64) -> String {
    let mut b = String::new();
    b.push_str("# TYPE sgp_coord_world gauge\n");
    let _ = writeln!(b, "sgp_coord_world {world}");
    b.push_str("# TYPE sgp_coord_uptime_ms counter\n");
    let _ = writeln!(b, "sgp_coord_uptime_ms {uptime_ms}");
    b.push_str("# TYPE sgp_coord_joined gauge\n");
    let _ = writeln!(b, "sgp_coord_joined {joined}");
    b
}

/// Answer one connection on the coordinator's listener: anything opening
/// with `GET ` receives the metrics snapshot as an HTTP/1.1 response;
/// everything else is dropped. Runs on a throwaway thread (never on the
/// liveness loop), with both directions timeout-bounded so a wedged
/// scraper leaks at most one short-lived thread.
fn serve_metrics(mut stream: TcpStream, body: &str) {
    // The listener is nonblocking (registration + scrape polling share
    // it); the accepted stream must block, bounded by the timeouts below.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut buf = [0u8; 512];
    let n = stream.read(&mut buf).unwrap_or(0);
    if buf[..n].starts_with(b"GET ") {
        write_http_ok(stream, body);
    }
}

/// Write `body` as a complete `HTTP/1.1 200` plaintext response
/// (write-timeout-bounded, errors swallowed — the scraper retries).
fn write_http_ok(mut stream: TcpStream, body: &str) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(resp.as_bytes());
}

/// Render the summary as JSON (exponent-form floats, machine-parseable
/// by the repo's own `model::json` reader).
fn write_summary(path: &Path, s: &CoordSummary) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema_version\": {SUMMARY_SCHEMA_VERSION},\n"));
    out.push_str(&format!("  \"port\": {},\n", s.port));
    out.push_str(&format!("  \"world\": {},\n", s.world));
    let surv: Vec<String> = s.survivors.iter().map(|r| r.to_string()).collect();
    out.push_str(&format!("  \"survivors\": [{}],\n", surv.join(",")));
    out.push_str(&format!("  \"spread\": {:e},\n", s.spread));
    out.push_str(&format!("  \"missing_w\": {:e},\n", s.missing_w));
    out.push_str(&format!(
        "  \"max_ledger_residual\": {:e},\n",
        s.max_ledger_residual
    ));
    let mean: Vec<String> = s.mean.iter().map(|v| format!("{v:e}")).collect();
    out.push_str(&format!("  \"mean\": [{}],\n", mean.join(",")));
    out.push_str("  \"workers\": [\n");
    for (i, a) in s.workers.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"rank\":{},\"w\":{:e},\"recv_w\":{:e},\"sent_w\":{:e},\
             \"rescued_w\":{:e},\"rescues\":{},\"timeouts\":{},\
             \"ledger_residual\":{:e}}}{}\n",
            a.rank,
            a.report.w,
            a.report.recv_w,
            a.report.sent_w,
            a.report.rescued_w,
            a.report.rescues,
            a.report.timeouts,
            a.ledger_residual,
            if i + 1 < s.workers.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"events\": [\n");
    for (i, e) in s.events.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"t_ms\":{},\"kind\":\"{}\",\"rank\":{},\"round\":{}}}{}\n",
            e.t_ms,
            e.kind,
            e.rank,
            e.round,
            if i + 1 < s.events.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_json_roundtrips_through_the_repo_parser() {
        let dir = std::env::temp_dir().join(format!("sgp_coord_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("summary.json");
        let s = CoordSummary {
            port: 41234,
            world: 4,
            survivors: vec![0, 1, 3],
            mean: vec![1.25, -0.5],
            spread: 3.2e-5,
            missing_w: 0.75,
            max_ledger_residual: 1e-12,
            workers: vec![WorkerAudit {
                rank: 0,
                report: DoneReport {
                    w: 1.5,
                    recv_w: 2.0,
                    sent_w: 1.5,
                    rescued_w: 0.0,
                    rescues: 0,
                    timeouts: 1,
                    x: vec![1.0, 2.0],
                },
                ledger_residual: 0.0,
            }],
            events: vec![EventRecord { t_ms: 12, kind: "leave".into(), rank: 2, round: 57 }],
        };
        write_summary(&path, &s).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::model::json::Json::parse(&text).unwrap();
        assert_eq!(
            j.get("schema_version").and_then(|v| v.as_usize()),
            Some(SUMMARY_SCHEMA_VERSION as usize),
            "downstream parsers key on the summary schema version"
        );
        assert_eq!(j.get("world").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(j.get("survivors").and_then(|v| v.as_arr()).unwrap().len(), 3);
        let spread = j.get("spread").and_then(|v| v.as_f64()).unwrap();
        assert!((spread - 3.2e-5).abs() < 1e-12, "{spread}");
        let ws = j.get("workers").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(ws[0].get("rank").and_then(|v| v.as_usize()), Some(0));
        let evs = j.get("events").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(evs[0].get("kind").and_then(|v| v.as_str()), Some("leave"));
        assert_eq!(evs[0].get("round").and_then(|v| v.as_usize()), Some(57));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn registration_classifies_scrapes_strays_and_joins() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let accept_configured = |l: &TcpListener| {
            let (s, _) = l.accept().unwrap();
            s.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            s
        };

        // A Prometheus scrape must be recognized, not parsed as a frame
        // (its `GET ` opener would otherwise read as a ~542 MB length
        // prefix and the decode error used to abort the coordinator).
        let mut scraper = TcpStream::connect(addr).unwrap();
        scraper.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut s = accept_configured(&l);
        let deadline = Instant::now() + Duration::from_secs(1);
        assert!(matches!(classify_reg_conn(&mut s, deadline), RegConn::Scrape));

        // Non-frame garbage is a stray, reported rather than propagated.
        let mut garbage = TcpStream::connect(addr).unwrap();
        garbage.write_all(&[0xff, 0xff, 0xff, 0xff, 1, 2, 3]).unwrap();
        let mut s = accept_configured(&l);
        let deadline = Instant::now() + Duration::from_secs(1);
        assert!(matches!(classify_reg_conn(&mut s, deadline), RegConn::Stray));

        // A silent connection burns only its own deadline, not the
        // caller's whole registration window.
        let _silent = TcpStream::connect(addr).unwrap();
        let mut s = accept_configured(&l);
        let t0 = Instant::now();
        let deadline = Instant::now() + Duration::from_millis(200);
        assert!(matches!(classify_reg_conn(&mut s, deadline), RegConn::Stray));
        assert!(t0.elapsed() < Duration::from_secs(2), "bounded by the per-conn deadline");

        // A framed Join still registers, listen port intact.
        let mut worker = TcpStream::connect(addr).unwrap();
        let mut buf = Vec::new();
        wire::encode_frame(
            &Envelope::control(wire::UNASSIGNED, 0, Frame::Join { listen_port: 4242 }),
            &mut buf,
        );
        worker.write_all(&buf).unwrap();
        let mut s = accept_configured(&l);
        let deadline = Instant::now() + Duration::from_secs(1);
        match classify_reg_conn(&mut s, deadline) {
            RegConn::Join(port) => assert_eq!(port, 4242),
            _ => panic!("a framed Join must classify as a worker"),
        }
    }

    #[test]
    fn run_manifest_checkpoint_roundtrips_and_tracks_survivors() {
        let dir = std::env::temp_dir().join(format!("sgp_manifest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CoordConfig { world: 3, ..Default::default() };
        let peers: Vec<String> =
            (1..=3).map(|p| format!("127.0.0.1:{p}")).collect();
        write_run_manifest(&dir, &cfg, 40000, &peers, &[false, true, false]);
        let text = std::fs::read_to_string(dir.join("run_manifest.json")).unwrap();
        let j = crate::model::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("world").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("seed").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("peers").and_then(|v| v.as_arr()).unwrap().len(), 3);
        // Rank 1 is dead: the survivor set the restarted fleet resumes over.
        assert_eq!(j.get("alive").and_then(|v| v.as_arr()).unwrap().len(), 2);
        assert!(!dir.join("run_manifest.json.tmp").exists(), "tmp renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn port_file_is_written_atomically_with_a_trailing_newline() {
        let dir = std::env::temp_dir().join(format!("sgp_portfile_{}", std::process::id()));
        let path = dir.join("port");
        write_port_file(&path, 40999).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "40999\n");
        assert!(!path.with_extension("tmp").exists(), "tmp renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }
}
